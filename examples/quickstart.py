"""Quickstart: block verification in 60 seconds.

Reproduces the paper's Section-2 motivating example exactly, then runs a
Monte-Carlo block-efficiency comparison of all three verification
algorithms on a random oracle model pair.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import oracle, simulate

print("=== Section 2 motivating example (exact enumeration) ===")
target, drafter = oracle.section2_models()
for kind, paper in [("token", "10/9"), ("block", "11/9"), ("ideal", "12/9")]:
    val = oracle.exact_expected_accepted(target, drafter, gamma=2, kind=kind)
    print(f"  E[accepted tokens] {kind:6s} = {val:.6f}   (paper: {paper})")

print("\n=== Block efficiency on a random LM pair (gamma=8) ===")
key = jax.random.key(0)
kt, kd = jax.random.split(key)
target = oracle.random_lm(kt, vocab=16, order=2)
drafter = oracle.perturbed_drafter(kd, target, alpha=0.35)
for name in ["token", "greedy_block", "block"]:
    be = float(simulate.block_efficiency(
        key, target, drafter, gamma=8, verifier_name=name,
        batch=1024, n_iters=48,
    ))
    print(f"  {name:13s} block efficiency = {be:.3f} tokens / target call")

print("\nBlock verification is lossless AND strictly faster -- Theorem 2.")
