"""End-to-end serving driver (the paper's use case):

1. trains a byte-level char-LM target + drafter on the synthetic corpus,
2. serves a batch of prompts through the continuous-batching engine with
   speculative decoding,
3. compares wall-clock and block efficiency: autoregressive baseline vs
   token verification vs block verification.

    PYTHONPATH=src python examples/serve_speculative.py [--steps 300]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.data.tokenizer import ByteTokenizer
from repro.data.synthetic import generate_prompts
from repro.serving.baseline import autoregressive_decode
from repro.serving.engine import EngineConfig, SpecEngine

from benchmarks.wallclock import _get_models


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--prompts", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument(
        "--async-prefill", action="store_true",
        help="serve through the disaggregated two-lane loop (background "
             "prefill in staging pages, decode slots hold ready work only)",
    )
    args = ap.parse_args()

    print("training / loading char-LM pair ...")
    tgt, drf, tp, dp = _get_models(args.steps)
    tok = ByteTokenizer()
    prompts = [tok.encode(p)[:24] for p in generate_prompts(7, args.prompts)]

    print("\n-- autoregressive baseline --")
    outs, wall = autoregressive_decode(
        tgt, tp, prompts, args.max_new, temperature=0.8, max_len=256
    )
    base_tps = args.prompts * args.max_new / wall
    print(f"   {base_tps:8.1f} tokens/s")
    print("   sample:", repr(tok.decode(outs[0])[:60]))

    for verifier in ["token", "block"]:
        print(f"\n-- speculative decoding, {verifier} verification --")
        eng = SpecEngine(tgt, drf, tp, dp, EngineConfig(
            gamma=args.gamma, verifier=verifier, max_slots=args.prompts,
            max_len=256, temperature=0.8, max_new_tokens=args.max_new,
            async_prefill=args.async_prefill,
        ))
        eng.submit(prompts[0], max_new_tokens=2)
        eng.run()      # warm the compile caches
        eng.reset()
        rids = [eng.submit(p) for p in prompts]
        out = eng.run()
        wall = eng.last_stats["wall_s"]
        total = sum(len(r.output) for r in out.values())
        iters = sum(r.iterations for r in out.values())
        be = sum(r.accepted_total + r.iterations for r in out.values()) / iters
        print(f"   {total/wall:8.1f} tokens/s  "
              f"(speedup {total/wall/base_tps:.2f}x, block efficiency {be:.2f})")
        metrics = eng.request_metrics()
        mean_ttft = sum(m["ttft_s"] for m in metrics) / len(metrics)
        mean_acc = sum(m["acceptance_rate"] for m in metrics) / len(metrics)
        print(f"   mean TTFT {mean_ttft*1e3:.1f} ms, "
              f"mean acceptance rate {mean_acc:.2f}, "
              f"{eng.last_stats['prefill_steps']} prefill chunks / "
              f"{eng.last_stats['iterations']} iterations")
        print("   sample:", repr(tok.decode(out[rids[0]].output)[:60]))


if __name__ == "__main__":
    main()
