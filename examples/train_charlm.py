"""Training driver: train the byte-level char-LM target (or drafter) on
the synthetic corpus with the full substrate (pipeline, AdamW + cosine,
checkpointing).

    PYTHONPATH=src python examples/train_charlm.py --model target --steps 300
"""

import argparse

from repro.configs import registry
from repro.data import pipeline
from repro.models import Model
from repro.training import checkpoint
from repro.training import train as training
from repro.training.optim import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="target", choices=["target", "drafter"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = registry.get_config(f"charlm-{args.model}")
    model = Model(cfg)
    print(f"{cfg.name}: {model.param_count():,} params")
    data = pipeline.batches(
        seed=0, batch_size=args.batch, seq_len=args.seq, n_steps=args.steps
    )
    params, hist = training.train(
        model, data, n_steps=args.steps,
        opt_cfg=OptConfig(lr=args.lr, warmup=20, total_steps=args.steps),
        log_every=25,
    )
    for h in hist:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}  ({h['elapsed_s']:.0f}s)")
    out = args.out or f"results/charlm/{args.model}"
    checkpoint.save(out, params, {"loss": hist[-1]["loss"], "cfg": cfg.name})
    print("saved to", out)


if __name__ == "__main__":
    main()
