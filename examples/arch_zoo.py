"""Architecture zoo: run speculative decoding through ANY assigned
architecture (reduced smoke variant on CPU) with ``--arch <id>``.

    PYTHONPATH=src python examples/arch_zoo.py --arch mamba2-370m
    PYTHONPATH=src python examples/arch_zoo.py --arch mixtral-8x22b --verifier token
"""

import argparse

import jax

from repro.configs import registry
from repro.models import Model
from repro.serving.engine import EngineConfig, SpecEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--arch", default="smollm-135m", choices=sorted(registry.ASSIGNED)
    )
    ap.add_argument("--verifier", default="block",
                    choices=["token", "block", "greedy_block"])
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = registry.smoke_config(args.arch)
    print(f"arch={cfg.name} family={cfg.family} "
          f"(full config: {registry.get_config(args.arch).source})")
    target = Model(cfg)
    drafter = Model(cfg.with_(d_model=128, d_ff=256 if cfg.d_ff else 0,
                              name=cfg.name + "-drafter"))
    kt, kd = jax.random.split(jax.random.key(0))
    tp, dp = target.init(kt), drafter.init(kd)
    print(f"target params: {target.param_count():,}  "
          f"drafter params: {drafter.param_count():,}")

    eng = SpecEngine(target, drafter, tp, dp, EngineConfig(
        gamma=args.gamma, verifier=args.verifier, max_slots=2,
        max_len=128, temperature=args.temperature,
        max_new_tokens=args.max_new,
    ))
    rids = [eng.submit([3, 1, 4, 1, 5]), eng.submit([2, 7, 1, 8])]
    out = eng.run()
    for rid in rids:
        r = out[rid]
        be = (r.accepted_total + r.iterations) / r.iterations
        print(f"req {rid}: {len(r.output)} tokens in {r.iterations} target "
              f"calls (block efficiency {be:.2f})")
        print("   tokens:", r.output[:16], "...")


if __name__ == "__main__":
    main()
