"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import OLMO_1B as CONFIG  # noqa: F401
