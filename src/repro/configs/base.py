"""Assigned-architecture configs (exact values from the public pool) and
the generic smoke-reduction used by per-arch CPU tests."""

from __future__ import annotations

from repro.models.common import ModelConfig

# --------------------------------------------------------------------------
# The 10 assigned architectures. Sources cited per entry.
# --------------------------------------------------------------------------

MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, window_pattern=(4096,),
    source="arXiv:2401.04088 (8 experts top-2, SWA)",
)

ZAMBA2_1P2B = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_ff=8192, vocab=32000,
    ssm_state=64, hybrid_attn_every=6, window_pattern=(4096,),
    source="arXiv:2411.15242 (Mamba2 backbone + shared attention blocks; "
    "window 4096 is our long-context adaptation, see DESIGN.md)",
)

OLMO_1B = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_ff=8192, vocab=50304,
    norm="np_layernorm",
    source="arXiv:2402.00838 (non-parametric LayerNorm)",
)

MISTRAL_LARGE_123B = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv=8, d_ff=28672, vocab=32768,
    head_dim=128,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

GEMMA2_9B = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv=8, d_ff=14336, vocab=256000,
    head_dim=256, window_pattern=(4096, -1), attn_softcap=50.0,
    final_softcap=30.0, post_norms=True, tie_embeddings=True,
    source="arXiv:2408.00118 (local/global alternating, logit softcap)",
)

SMOLLM_135M = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv=3, d_ff=1536, vocab=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M (llama-arch small)",
)

LLAMA4_SCOUT = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192, vocab=202048,
    head_dim=128, n_experts=16, top_k=1,
    window_pattern=(8192, 8192, 8192, -1),
    source="hf:meta-llama/Llama-4-Scout-17B-16E (MoE top-1; chunked local "
    "attention 3/4 layers, iRoPE-style global every 4th; text backbone "
    "only — early-fusion image tokens stubbed per DESIGN.md)",
)

WHISPER_TINY = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv=6, d_ff=1536, vocab=51865,
    n_encoder_layers=4, n_audio_frames=1500,
    norm="layernorm", mlp="gelu", use_rope=False,
    source="arXiv:2212.04356 (enc-dec; mel+conv frontend stubbed: "
    "input_specs feeds precomputed frame embeddings)",
)

LLAMA32_VISION_11B = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=128256,
    cross_attn_every=5, n_vision_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision (cross-attn image layers "
    "every 5th; ViT encoder stubbed: input_specs feeds patch embeddings)",
)

MAMBA2_370M = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv=0, d_ff=0, vocab=50280,
    head_dim=1, ssm_state=128,
    source="arXiv:2405.21060 (SSD state-space duality; attention-free)",
)

# The char-LM pair used for the paper-style Table-1 experiments
# (PALM-2 is proprietary; see DESIGN.md §6).
CHARLM_TARGET = ModelConfig(
    name="charlm-target", family="dense",
    n_layers=6, d_model=256, n_heads=8, n_kv=4, d_ff=1024, vocab=512,
    max_seq=1024,
    source="in-repo byte-level target model (paper M_b stand-in)",
)

CHARLM_DRAFTER = ModelConfig(
    name="charlm-drafter", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=512, vocab=512,
    max_seq=1024,
    source="in-repo byte-level drafter (paper M_s stand-in)",
)


def smoke_of(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests: <=2-ish layers,
    d_model <= 512, <= 4 experts, tiny windows (exercises ring caches)."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        d_model=256,
        d_ff=512 if cfg.d_ff else 0,
        vocab=512,
        head_dim=0,
        max_seq=256,
        ssm_state=32 if cfg.ssm_state else 0,
        ssm_chunk=16,
        n_vision_tokens=16 if cfg.n_vision_tokens else 0,
        n_audio_frames=32 if cfg.n_audio_frames else 0,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        window_pattern=tuple(
            32 if w > 0 else -1 for w in cfg.window_pattern
        ),
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv=max(1, 4 * cfg.n_kv // cfg.n_heads))
        if kw["n_heads"] % kw["n_kv"]:
            kw["n_kv"] = 2
    if cfg.n_experts:
        kw["n_experts"] = min(4, cfg.n_experts)
    if cfg.family == "hybrid":
        kw.update(n_layers=5, hybrid_attn_every=2)   # 2 groups + remainder
    elif cfg.family == "vlm":
        kw.update(n_layers=4, cross_attn_every=2)    # 2 (dense, cross) groups
    else:
        kw["n_layers"] = 2 * len(cfg.window_pattern)
    return cfg.with_(**kw)
