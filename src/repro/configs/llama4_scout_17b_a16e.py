"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import LLAMA4_SCOUT as CONFIG  # noqa: F401
