"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import MISTRAL_LARGE_123B as CONFIG  # noqa: F401
