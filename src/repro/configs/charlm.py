"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import CHARLM_TARGET as CONFIG  # noqa: F401
from repro.configs.base import CHARLM_DRAFTER as DRAFTER_CONFIG  # noqa: F401
