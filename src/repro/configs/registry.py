"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""

from __future__ import annotations

from repro.configs import base
from repro.models.common import ModelConfig

ARCHS: dict[str, ModelConfig] = {
    "mixtral-8x22b": base.MIXTRAL_8X22B,
    "zamba2-1.2b": base.ZAMBA2_1P2B,
    "olmo-1b": base.OLMO_1B,
    "mistral-large-123b": base.MISTRAL_LARGE_123B,
    "gemma2-9b": base.GEMMA2_9B,
    "smollm-135m": base.SMOLLM_135M,
    "llama4-scout-17b-a16e": base.LLAMA4_SCOUT,
    "whisper-tiny": base.WHISPER_TINY,
    "llama-3.2-vision-11b": base.LLAMA32_VISION_11B,
    "mamba2-370m": base.MAMBA2_370M,
    # the paper-experiment char-LM pair
    "charlm-target": base.CHARLM_TARGET,
    "charlm-drafter": base.CHARLM_DRAFTER,
}

ASSIGNED = tuple(k for k in ARCHS if not k.startswith("charlm"))


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    return base.smoke_of(get_config(name))
