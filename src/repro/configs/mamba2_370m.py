"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import MAMBA2_370M as CONFIG  # noqa: F401
