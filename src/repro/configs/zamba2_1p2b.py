"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import ZAMBA2_1P2B as CONFIG  # noqa: F401
