"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import GEMMA2_9B as CONFIG  # noqa: F401
