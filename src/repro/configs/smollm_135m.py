"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import SMOLLM_135M as CONFIG  # noqa: F401
