"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import LLAMA32_VISION_11B as CONFIG  # noqa: F401
