"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import MIXTRAL_8X22B as CONFIG  # noqa: F401
