"""Assigned architecture config — see base.py for the values and source."""

from repro.configs.base import WHISPER_TINY as CONFIG  # noqa: F401
