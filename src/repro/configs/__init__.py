from repro.configs.registry import ARCHS, ASSIGNED, get_config, smoke_config  # noqa: F401
