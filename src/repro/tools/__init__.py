"""Developer tooling that ships with the repro (no runtime deps)."""
