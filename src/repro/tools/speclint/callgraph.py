"""Module-level call graph + jit-root detection over an Index.

Edges come in three flavours:

* resolved calls — bare names through the lexical scope chain, dotted
  names through the import-alias map;
* method fallback — ``x.foo(...)`` with an unresolvable receiver links
  to *every* indexed function named ``foo`` (minus a denylist of
  generic container/array method names), which is what lets
  reachability cross ``self.``/duck-typed indirection;
* references — a function object loaded as a value
  (``partial(prefill_body, ...)``, ``jax.tree.map(cb, ...)``).

Jit roots are ``@jax.jit``-style decorators, ``jax.jit(f)`` /
``jax.jit(partial(f, ...))`` call sites, ``jax.lax.scan``-family
callee arguments, and ``pl.pallas_call`` kernels; the jitted set is
the closure of the roots over all edge kinds.
"""

from __future__ import annotations

from collections import deque

import ast

from . import config
from .index import FunctionInfo, Index, dotted_name

_JIT_MAKERS = ("jax.jit",)
_CONTROL_FLOW_SUFFIXES = (
    "lax.scan",
    "lax.while_loop",
    "lax.fori_loop",
    "lax.cond",
    "lax.switch",
    "lax.map",
    "pallas_call",
)
_PARTIAL_NAMES = ("functools.partial", "partial")


def _is_jit_maker(dotted: str | None) -> bool:
    return dotted is not None and (
        dotted in _JIT_MAKERS or dotted.endswith(".jax.jit")
    )


def _is_control_flow(dotted: str | None) -> bool:
    return dotted is not None and any(
        dotted == s or dotted.endswith("." + s)
        for s in _CONTROL_FLOW_SUFFIXES
    )


def _is_partial(dotted: str | None) -> bool:
    return dotted in _PARTIAL_NAMES


class CallGraph:
    def __init__(self, index: Index):
        self.index = index
        n = len(index.funcs)
        self.edges: dict[int, set[int]] = {f.fid: set() for f in index.funcs}
        # per-function external call records: (dotted-or-None, attr, node)
        self.external_calls: dict[int, list] = {
            f.fid: [] for f in index.funcs
        }
        self._jit_root_fids: set[int] = set()
        for func in index.funcs:
            self._analyze(func)
        self.jitted: set[int] = self._closure(self._jit_root_fids)

    # -- construction -------------------------------------------------------

    def _resolve_bare(self, func: FunctionInfo, name: str):
        for scope in func.ancestors():
            child = scope.children.get(name)
            if child is not None and child.fid >= 0:
                return child
        mod_fn = self.index.by_module_qual.get((func.file.module, name))
        if mod_fn is not None:
            return mod_fn
        dotted = func.file.aliases.get(name)
        if dotted is not None:
            return self.index.resolve_dotted(dotted)
        return None

    def _callee_refs(self, func: FunctionInfo, expr: ast.expr):
        """Function(s) an expression names: ``f``, ``mod.f``,
        ``partial(f, ...)``."""
        out = []
        if isinstance(expr, ast.Call):
            dotted = dotted_name(expr.func, func.file.aliases)
            if _is_partial(dotted) and expr.args:
                return self._callee_refs(func, expr.args[0])
            return out
        if isinstance(expr, ast.Name):
            hit = self._resolve_bare(func, expr.id)
            if hit is not None:
                out.append(hit)
        elif isinstance(expr, ast.Attribute):
            dotted = dotted_name(expr, func.file.aliases)
            if dotted is not None:
                hit = self.index.resolve_dotted(dotted)
                if hit is not None:
                    out.append(hit)
        return out

    def _analyze(self, func: FunctionInfo) -> None:
        aliases = func.file.aliases
        for call in func.calls:
            tgt = call.func
            dotted = None
            if isinstance(tgt, ast.Name):
                hit = self._resolve_bare(func, tgt.id)
                if hit is not None:
                    self.edges[func.fid].add(hit.fid)
                else:
                    dotted = aliases.get(tgt.id, tgt.id)
                    self.external_calls[func.fid].append(
                        (dotted, tgt.id, call)
                    )
            elif isinstance(tgt, ast.Attribute):
                dotted = dotted_name(tgt, aliases)
                hit = (
                    self.index.resolve_dotted(dotted) if dotted else None
                )
                if hit is not None:
                    self.edges[func.fid].add(hit.fid)
                else:
                    attr = tgt.attr
                    self.external_calls[func.fid].append(
                        (dotted, attr, call)
                    )
                    if attr not in config.METHOD_FALLBACK_DENYLIST:
                        for cand in self.index.by_bare.get(attr, ()):
                            self.edges[func.fid].add(cand.fid)
            # jit/scan/pallas call sites turn their callee args into roots
            site = dotted or dotted_name(tgt, aliases)
            if _is_jit_maker(site) and call.args:
                for hit in self._callee_refs(func, call.args[0]):
                    self._jit_root_fids.add(hit.fid)
            elif _is_control_flow(site):
                for arg in call.args:
                    for hit in self._callee_refs(func, arg):
                        self._jit_root_fids.add(hit.fid)

        # reference edges: function objects loaded as values
        call_funcs = {id(c.func) for c in func.calls}
        for nl in func.name_loads:
            if id(nl) in call_funcs:
                continue
            hit = self._resolve_bare(func, nl.id)
            if hit is not None:
                self.edges[func.fid].add(hit.fid)
        for al in func.attr_loads:
            if id(al) in call_funcs:
                continue
            dotted = dotted_name(al, aliases)
            if dotted is not None:
                hit = self.index.resolve_dotted(dotted)
                if hit is not None:
                    self.edges[func.fid].add(hit.fid)

        # decorator jit roots
        node = func.node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = dec
                if isinstance(d, ast.Call):
                    inner = dotted_name(d.func, aliases)
                    if _is_partial(inner) and d.args:
                        first = dotted_name(d.args[0], aliases)
                        if _is_jit_maker(first):
                            self._jit_root_fids.add(func.fid)
                        continue
                    d = d.func
                if _is_jit_maker(dotted_name(d, aliases)):
                    self._jit_root_fids.add(func.fid)

    # -- queries ------------------------------------------------------------

    def _closure(self, roots: set[int]) -> set[int]:
        seen = set(roots)
        frontier = deque(roots)
        while frontier:
            fid = frontier.popleft()
            for nxt in self.edges.get(fid, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    frontier.append(nxt)
        return seen

    def reachable_with_paths(
        self, roots: list[FunctionInfo]
    ) -> dict[int, list[str]]:
        """fid -> call chain of qualnames from the nearest root."""
        paths: dict[int, list[str]] = {}
        frontier: deque[int] = deque()
        for r in roots:
            if r.fid not in paths:
                paths[r.fid] = [r.qualname]
                frontier.append(r.fid)
        while frontier:
            fid = frontier.popleft()
            for nxt in self.edges.get(fid, ()):
                if nxt not in paths:
                    paths[nxt] = paths[fid] + [
                        self.index.funcs[nxt].qualname
                    ]
                    frontier.append(nxt)
        return paths

    def is_jitted(self, func: FunctionInfo) -> bool:
        return func.fid in self.jitted
