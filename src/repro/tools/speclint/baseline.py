"""Baseline (= committed findings artifact) load/apply/write.

``results/LINT.json`` doubles as the machine-readable report and the
baseline: the CLI subtracts its fingerprints so pre-existing debt is
tracked — visible in the artifact, not silenced — while any *new*
finding fails the run. Fingerprints are line-number independent (see
findings.Finding), so unrelated edits don't churn the baseline.
"""

from __future__ import annotations

import json
from pathlib import Path

from .findings import Finding

VERSION = 1


def load_fingerprints(path: str | Path) -> set[str]:
    data = json.loads(Path(path).read_text())
    return {f["fingerprint"] for f in data.get("findings", [])}


def split_by_baseline(
    findings: list[Finding], baseline: set[str]
) -> tuple[list[Finding], list[Finding], set[str]]:
    """-> (new, baselined, stale-baseline-fingerprints)."""
    new, old = [], []
    current = set()
    for f in findings:
        fp = f.fingerprint
        current.add(fp)
        (old if fp in baseline else new).append(f)
    return new, old, baseline - current


def report_dict(findings: list[Finding]) -> dict:
    by_pass: dict[str, int] = {}
    for f in findings:
        by_pass[f.pass_name] = by_pass.get(f.pass_name, 0) + 1
    return {
        "version": VERSION,
        "tool": "speclint",
        "total": len(findings),
        "by_pass": dict(sorted(by_pass.items())),
        "findings": [f.to_json() for f in findings],
    }


def write_report(findings: list[Finding], path: str | Path) -> None:
    Path(path).write_text(
        json.dumps(report_dict(findings), indent=2, sort_keys=False) + "\n"
    )
