"""Orchestration: index -> call graph -> passes -> filters."""

from __future__ import annotations

from pathlib import Path

from . import config
from .callgraph import CallGraph
from .context import LintContext
from .findings import Finding, assign_occurrences
from .index import build_index
from .passes import PASSES


def run_speclint(
    paths: list[str | Path],
    root: str | Path | None = None,
    passes: list[str] | None = None,
) -> list[Finding]:
    """Lint ``paths`` (files or directories of ``*.py``); returns all
    unsuppressed findings, baseline not applied. ``root`` anchors the
    repo-relative paths in findings (defaults to cwd)."""
    root = Path(root) if root is not None else Path.cwd()
    root = root.resolve()
    index = build_index([Path(p) for p in paths], root)
    graph = CallGraph(index)
    ctx = LintContext(index=index, graph=graph)

    selected = list(passes) if passes is not None else list(config.ALL_PASSES)
    unknown = [p for p in selected if p not in PASSES]
    if unknown:
        raise ValueError(f"unknown pass(es): {unknown}")

    findings: list[Finding] = []
    for name in selected:
        findings.extend(PASSES[name](ctx))

    files = {sf.relpath: sf for sf in index.files}
    kept = []
    for f in findings:
        if f.pass_name in config.PROD_ONLY_PASSES and not config.is_prod_path(
            f.path
        ):
            continue
        sf = files.get(f.path)
        if sf is not None and sf.suppressed(f.line, f.pass_name):
            continue
        kept.append(f)
    return assign_occurrences(kept)
