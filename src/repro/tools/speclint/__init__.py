"""speclint — AST/call-graph invariant checker for this repo.

Every guarantee the serving stack ships rests on structural
conventions a type checker cannot see:

* **losslessness** — prefill/staging bodies must consume no PRNG
  (``prng-discipline``);
* **throughput** — the double-buffered serve loop syncs host<->device
  at exactly its sanctioned points, and jitted bodies never sync or
  call host APIs (``host-sync``, ``jit-purity``);
* **allocator safety** — page-state transitions go through
  ``serving/paging.py``'s helpers and every host-side claim/evict is
  paired with its budget bookkeeping (``allocator-discipline``);
* **feature gating** — paged-only programs are only wired up behind an
  ``_assert_all_paged`` check (``feature-gating``).

speclint enforces them with stdlib ``ast`` plus a module-level call
graph — no third-party deps. Run it as::

    python -m repro.tools.speclint [--json out] [--baseline file] paths...

Annotations (in linted source):

* ``# speclint: sync-point(reason)`` — sanctions a host sync on the
  annotated statement (same line, line above, or trailing within the
  statement). The reason is mandatory.
* ``# speclint: disable=<pass>[,<pass>...]`` or ``disable=*`` —
  suppresses findings of the named pass(es) on that line / line below.
"""

from .findings import Finding
from .driver import run_speclint

__all__ = ["Finding", "run_speclint"]
