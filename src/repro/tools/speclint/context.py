"""Shared state handed to every pass."""

from __future__ import annotations

import ast
import dataclasses

from .callgraph import CallGraph
from .findings import Finding
from .index import FunctionInfo, Index


@dataclasses.dataclass
class LintContext:
    index: Index
    graph: CallGraph

    def finding(
        self,
        pass_name: str,
        rule: str,
        func: FunctionInfo,
        node: ast.AST,
        message: str,
    ) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(
            pass_name=pass_name,
            rule=rule,
            path=func.file.relpath,
            line=line,
            func=func.qualname,
            code=func.file.line(line),
            message=message,
        )


def enclosing_stmt(func: FunctionInfo, node: ast.AST) -> ast.stmt | None:
    """Smallest statement of ``func`` whose span covers ``node``."""
    line = getattr(node, "lineno", None)
    if line is None:
        return None
    best = None
    for stmt in func.scope_stmts:
        end = getattr(stmt, "end_lineno", stmt.lineno)
        if stmt.lineno <= line <= end:
            if best is None or (
                end - stmt.lineno
                < getattr(best, "end_lineno", best.lineno) - best.lineno
            ):
                best = stmt
    return best
