"""jit-purity: jitted/scan/donated bodies must not call host APIs
(``time.*``/``datetime.*``/host ``random``/``print`` — ``jax.debug.*``
is the sanctioned escape hatch) nor mutate captured Python state.

Host calls inside a traced body run once at trace time and never
again — timing reads measure compilation, prints vanish, host RNG
freezes into the compiled program. All are silent wrong-answer bugs.
"""

from __future__ import annotations

from .. import config
from ..context import LintContext
from ..index import dotted_name

PASS = "jit-purity"


def _host_call(dotted: str | None) -> str | None:
    if dotted is None:
        return None
    if dotted in config.ALLOWED_IN_JIT or dotted.startswith("jax.debug."):
        return None
    if dotted in config.HOST_CALL_NAMES:
        return dotted
    for prefix in config.HOST_CALL_PREFIXES:
        if dotted.startswith(prefix):
            return dotted
    return None


def run(ctx: LintContext):
    findings = []
    for fid in sorted(ctx.graph.jitted):
        func = ctx.index.funcs[fid]
        aliases = func.file.aliases
        for call in func.calls:
            bad = _host_call(dotted_name(call.func, aliases))
            if bad is not None:
                findings.append(
                    ctx.finding(
                        PASS,
                        "host-call-in-jit",
                        func,
                        call,
                        f"{bad}(...) inside jitted body "
                        f"{func.qualname!r} executes once at trace time "
                        "only (use jax.debug.print for tracing output)",
                    )
                )
        for stmt in func.globals_nonlocals:
            findings.append(
                ctx.finding(
                    PASS,
                    "state-mutation-in-jit",
                    func,
                    stmt,
                    f"{type(stmt).__name__.lower()} statement inside "
                    f"jitted body {func.qualname!r}: mutating captured "
                    "Python state under trace happens once, not per call",
                )
            )
    return findings
