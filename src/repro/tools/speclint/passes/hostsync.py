"""host-sync: the double-buffered serve loop syncs host<->device at
exactly its sanctioned, annotated points; jitted bodies never sync.

Scope: the serve-loop roots (``_run_serial``/``_run_async``/
``_process``/``serve``/``_service_wait``) plus every function they
reach *in the same file*, and every jitted function.

A sanctioned sync carries ``# speclint: sync-point(reason)`` on the
statement (line above or trailing); the reason is mandatory — an
empty one is its own finding, so every sync stays a reviewed,
documented decision.
"""

from __future__ import annotations

import ast

from .. import config
from ..context import LintContext, enclosing_stmt
from ..index import FunctionInfo, dotted_name

PASS = "host-sync"


def _sync_call_kind(call: ast.Call, aliases) -> str | None:
    d = dotted_name(call.func, aliases)
    if d in config.SYNC_CALLS:
        return d
    if isinstance(call.func, ast.Attribute):
        attr = call.func.attr
        if attr in config.SYNC_ATTRS:
            return f".{attr}()"
    return None


def _contains_sync_call(node: ast.AST, aliases) -> bool:
    return any(
        isinstance(n, ast.Call) and _sync_call_kind(n, aliases)
        for n in ast.walk(node)
    )


def _device_evidence(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if (
            isinstance(n, ast.Attribute)
            and n.attr in config.DEVICE_STATE_ATTRS
        ):
            return True
        if isinstance(n, ast.Name) and n.id in config.DEVICE_STATE_NAMES:
            return True
    return False


def _scalar_cast_sync(call: ast.Call, aliases) -> bool:
    """int()/float()/bool() over device state — but not over an explicit
    sync call, which gets its own finding."""
    if not (
        isinstance(call.func, ast.Name)
        and call.func.id in ("int", "float", "bool")
        and call.args
    ):
        return False
    arg = call.args[0]
    return _device_evidence(arg) and not _contains_sync_call(arg, aliases)


def _is_static_test(test: ast.expr) -> bool:
    """Tests that never concretize an array: None checks, isinstance,
    boolean combinations thereof."""
    if isinstance(test, ast.Compare):
        return all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops)
    if isinstance(test, ast.Call) and isinstance(test.func, ast.Name):
        return test.func.id in ("isinstance", "hasattr", "callable", "len")
    if isinstance(test, ast.BoolOp):
        return all(_is_static_test(v) for v in test.values)
    if isinstance(test, ast.UnaryOp):
        return _is_static_test(test.operand)
    if isinstance(test, (ast.Constant, ast.Name)):
        # a bare name if-test is a truthiness read; names are handled by
        # the caller's referenced-params check, constants are static
        return isinstance(test, ast.Constant)
    return False


def _nonstatic_params(func: FunctionInfo) -> set[str]:
    node = func.node
    if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return set()
    args = node.args
    all_args = list(args.posonlyargs) + list(args.args)
    static = set(config.STATIC_PARAM_NAMES)
    # literal-defaulted params are trace-time static knobs
    defaulted = all_args[len(all_args) - len(args.defaults):]
    for a, d in zip(defaulted, args.defaults):
        if isinstance(d, ast.Constant):
            static.add(a.arg)
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None and isinstance(d, ast.Constant):
            static.add(a.arg)
    for a in all_args + list(args.kwonlyargs):
        ann = a.annotation
        if isinstance(ann, ast.Name) and ann.id in ("int", "bool", "str"):
            static.add(a.arg)
    names = {a.arg for a in all_args + list(args.kwonlyargs)}
    return names - static


def _serve_scope(ctx: LintContext) -> dict[int, FunctionInfo]:
    roots = [f for f in ctx.index.funcs if f.name in config.SYNC_ROOTS]
    scope: dict[int, FunctionInfo] = {}
    by_file: dict = {}
    for r in roots:
        by_file.setdefault(id(r.file), []).append(r)
    for group in by_file.values():
        reach = ctx.graph.reachable_with_paths(group)
        gfile = group[0].file
        for fid in reach:
            func = ctx.index.funcs[fid]
            if func.file is gfile:
                scope[fid] = func
    return scope


def run(ctx: LintContext):
    findings = []
    serve = _serve_scope(ctx)

    for fid, func in sorted(serve.items()):
        aliases = func.file.aliases
        for call in func.calls:
            kind = _sync_call_kind(call, aliases)
            if kind is None and _scalar_cast_sync(call, aliases):
                kind = f"{call.func.id}() on device state"
            if kind is None:
                continue
            stmt = enclosing_stmt(func, call) or call
            reason = func.file.sync_annotation(
                stmt.lineno, getattr(stmt, "end_lineno", stmt.lineno)
            )
            if reason is None:
                findings.append(
                    ctx.finding(
                        PASS,
                        "unannotated-sync",
                        func,
                        call,
                        f"host sync {kind} in the serve loop without a "
                        "'# speclint: sync-point(reason)' annotation — "
                        "every sync must be an explicit reviewed decision",
                    )
                )
            elif not reason:
                findings.append(
                    ctx.finding(
                        PASS,
                        "empty-sync-reason",
                        func,
                        call,
                        "sync-point annotation needs a reason: "
                        "'# speclint: sync-point(why this must sync here)'",
                    )
                )

        for if_node in func.ifs:
            test = if_node.test
            if _is_static_test(test):
                continue
            if _device_evidence(test) and not _contains_sync_call(
                test, aliases
            ):
                findings.append(
                    ctx.finding(
                        PASS,
                        "array-if",
                        func,
                        test,
                        "``if`` on device-resident state in the serve "
                        "loop is an implicit blocking sync — materialize "
                        "via the sanctioned sync point first",
                    )
                )

    for fid in sorted(ctx.graph.jitted):
        func = ctx.index.funcs[fid]
        aliases = func.file.aliases
        for call in func.calls:
            kind = _sync_call_kind(call, aliases)
            if kind is not None:
                findings.append(
                    ctx.finding(
                        PASS,
                        "sync-in-jit",
                        func,
                        call,
                        f"{kind} inside jitted body {func.qualname!r}: "
                        "host materialization cannot happen under trace "
                        "and forces a device round-trip per call",
                    )
                )
        nonstatic = _nonstatic_params(func)
        if not nonstatic:
            continue
        for if_node in func.ifs:
            test = if_node.test
            if _is_static_test(test):
                continue
            # names read only through .shape/.dtype/... are static
            meta_only = {
                n.value.id
                for n in ast.walk(test)
                if isinstance(n, ast.Attribute)
                and n.attr in ("shape", "dtype", "ndim", "size")
                and isinstance(n.value, ast.Name)
            }
            used = (
                {
                    n.id
                    for n in ast.walk(test)
                    if isinstance(n, ast.Name)
                }
                - meta_only
            ) & nonstatic
            if used:
                findings.append(
                    ctx.finding(
                        PASS,
                        "array-if",
                        func,
                        test,
                        "``if`` on traced value(s) "
                        f"{sorted(used)} inside jitted body "
                        f"{func.qualname!r}: concretizes under trace — "
                        "use jnp.where / lax.cond",
                    )
                )
    return findings
