"""Pass registry: name -> run(ctx) -> list[Finding]."""

from __future__ import annotations

from . import allocator, faultsite, gating, hostsync, jitpurity, prng

PASSES = {
    "prng-discipline": prng.run,
    "host-sync": hostsync.run,
    "jit-purity": jitpurity.run,
    "allocator-discipline": allocator.run,
    "feature-gating": gating.run,
    "fault-site": faultsite.run,
}

__all__ = ["PASSES"]
