"""fault-site: every ``FaultInjector.fires(...)`` call site must name a
registered injection site and sit behind the ``faults`` config gate.

The fault plane's whole contract is that it is *structurally* a no-op
when ``EngineConfig.faults is None`` — the injector is never
constructed and no fault branch is reachable. That breaks two ways:

* a ``fires(...)`` call whose site is a free-hand string (typo'd sites
  raise at runtime, but only on the faulted path a normal run never
  takes), so the site argument must resolve to one of the registered
  ``SITE_*`` constants or their literal values;
* a ``fires(...)`` call not guarded by an ``is None`` / ``is not
  None`` test of the injector (or the ``faults`` config field) in the
  function or an enclosing function — an unguarded call turns the
  disabled plane into an AttributeError on ``None``.
"""

from __future__ import annotations

import ast

from .. import config
from ..context import LintContext
from ..index import FunctionInfo

PASS = "fault-site"


def _fires_calls(func: FunctionInfo):
    for call in func.calls:
        tgt = call.func
        if (
            isinstance(tgt, ast.Attribute)
            and tgt.attr == config.FAULT_FIRES_ATTR
        ):
            yield call


def _site_arg(call: ast.Call) -> ast.expr | None:
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "site":
            return kw.value
    return None


def _is_registered_site(arg: ast.expr | None) -> bool:
    if arg is None:
        return False
    if isinstance(arg, ast.Constant):
        return arg.value in config.FAULT_SITES
    name = (
        arg.attr
        if isinstance(arg, ast.Attribute)
        else arg.id if isinstance(arg, ast.Name) else None
    )
    return name in config.FAULT_SITE_CONSTS


def _none_guarded(func: FunctionInfo) -> bool:
    """True when the function (or an enclosing def) tests the injector
    or the ``faults`` config field against None."""
    for scope in func.ancestors():
        node = scope.node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for n in ast.walk(node):
            if not isinstance(n, ast.Compare) or len(n.ops) != 1:
                continue
            if not isinstance(n.ops[0], (ast.Is, ast.IsNot)):
                continue
            sides = [n.left] + list(n.comparators)
            if not any(
                isinstance(s, ast.Constant) and s.value is None
                for s in sides
            ):
                continue
            for s in sides:
                name = (
                    s.attr
                    if isinstance(s, ast.Attribute)
                    else s.id if isinstance(s, ast.Name) else None
                )
                if name in config.FAULT_GATE_NAMES:
                    return True
    return False


def run(ctx: LintContext):
    findings = []
    for func in ctx.index.funcs:
        if func.fid < 0:
            continue
        # the registry module itself defines fires(); its internals are
        # not call sites of the plane
        if func.file.relpath.endswith(config.FAULTS_MODULE_SUFFIX):
            continue
        calls = list(_fires_calls(func))
        if not calls:
            continue
        gated = _none_guarded(func)
        for call in calls:
            arg = _site_arg(call)
            if not _is_registered_site(arg):
                findings.append(
                    ctx.finding(
                        PASS,
                        "unregistered-fault-site",
                        func,
                        call,
                        f"fires(...) in {func.qualname!r} does not name "
                        "a registered SITE_* constant — a typo'd site "
                        "only raises on the faulted path a normal run "
                        "never takes",
                    )
                )
            if not gated:
                findings.append(
                    ctx.finding(
                        PASS,
                        "ungated-fault-site",
                        func,
                        call,
                        f"fires(...) in {func.qualname!r} is not behind "
                        "an injector/faults None-check — with faults "
                        "disabled the injector is None and this call "
                        "raises instead of no-opping",
                    )
                )
    return findings
