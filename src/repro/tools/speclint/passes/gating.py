"""feature-gating: paged-only programs (staging bodies, multi-path
decode, page pack/unpack) may only be wired up by code that checked
``_assert_all_paged`` on its config path.

These bodies read ``page_table``/pool storage for *every* layer; on a
mixed-attention model (sliding-window rings, SSM states, cross
caches) the non-paged entries silently lose history instead of
failing. The gate turns that into an actionable config error — so
every reference site must sit in a function (or enclosing function)
that calls the gate.
"""

from __future__ import annotations

import ast

from .. import config
from ..context import LintContext
from ..index import FunctionInfo, dotted_name

PASS = "feature-gating"


def _has_gate(func: FunctionInfo) -> bool:
    for scope in func.ancestors():
        for call in scope.calls:
            tgt = call.func
            name = (
                tgt.id
                if isinstance(tgt, ast.Name)
                else tgt.attr if isinstance(tgt, ast.Attribute) else None
            )
            if name == config.GATE_NAME:
                return True
    return False


def _paged_only_refs(func: FunctionInfo):
    """(name, node) for every reference to a paged-only program."""
    aliases = func.file.aliases
    for nl in func.name_loads:
        if nl.id in config.PAGED_ONLY_FUNCS:
            yield nl.id, nl
    for al in func.attr_loads:
        if al.attr in config.PAGED_ONLY_FUNCS:
            dotted = dotted_name(al, aliases)
            # only module-qualified references count — a stray method
            # attr with a colliding name is not a program reference
            if dotted is not None and not dotted.startswith("self."):
                yield al.attr, al


def run(ctx: LintContext):
    findings = []
    for func in ctx.index.funcs:
        if func.fid < 0 or func.name in config.PAGED_ONLY_FUNCS:
            continue
        refs = list(_paged_only_refs(func))
        if not refs:
            continue
        if _has_gate(func):
            continue
        for name, node in refs:
            findings.append(
                ctx.finding(
                    PASS,
                    "ungated-paged-only",
                    func,
                    node,
                    f"{name} assumes fully-paged caches but "
                    f"{func.qualname!r} never checks "
                    f"{config.GATE_NAME} on its config path — a "
                    "mixed-attention model would silently lose "
                    "non-paged layer history",
                )
            )
    return findings
