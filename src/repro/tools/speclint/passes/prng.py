"""prng-discipline: no ``jax.random.*`` reachable from prefill/staging
roots.

Losslessness of block verification (PAPER.md Eq. 4) and of the greedy
multi-path rule requires the decode-side key schedule to be a pure
function of the committed token stream. Prefill — serial, async-staged
or disaggregated — must therefore consume ZERO randomness: a single
``jax.random.split`` inside a staging body would make adopted slots
sample from a different key stream than serially-prefilled ones, and
no tier-1 test would catch it (the distributions only drift).
"""

from __future__ import annotations

from .. import config
from ..context import LintContext

PASS = "prng-discipline"


def run(ctx: LintContext):
    findings = []
    roots = [
        f for f in ctx.index.funcs if f.name in config.PRNG_ROOTS
    ]
    if not roots:
        return findings
    paths = ctx.graph.reachable_with_paths(roots)
    for fid, chain in sorted(paths.items()):
        func = ctx.index.funcs[fid]
        for dotted, _attr, call in ctx.graph.external_calls[fid]:
            if dotted is None or not (
                dotted.startswith("jax.random.") or dotted == "jax.random"
            ):
                continue
            via = " -> ".join(chain)
            findings.append(
                ctx.finding(
                    PASS,
                    "prng-in-prefill-path",
                    func,
                    call,
                    f"{dotted} is reachable from prefill/staging root "
                    f"{chain[0]!r} (via {via}); prefill must consume no "
                    "PRNG or losslessness breaks silently",
                )
            )
    return findings
