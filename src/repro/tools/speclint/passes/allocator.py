"""allocator-discipline: page-state transitions stay inside
``serving/paging.py``'s sanctioned helpers, device ops stay jitted,
host ops stay un-jitted, and every host-side claim/evict pairs with
its budget bookkeeping in the same function.

The page lifecycle (free -> staged -> referenced -> cached -> evicted)
is only provably never-fail because the pool's counters move through
the paging helpers in lockstep with the scheduler's ``PageBudget``;
an out-of-band field write or an unpaired claim breaks the accounting
invariant the admission proof rests on.
"""

from __future__ import annotations

import ast

from .. import config
from ..context import LintContext
from ..index import FunctionInfo, dotted_name

PASS = "allocator-discipline"


def _in_paging(func: FunctionInfo) -> bool:
    return func.file.relpath.endswith(config.PAGING_MODULE_SUFFIX)


def _paging_op(ctx: LintContext, func: FunctionInfo, call: ast.Call, ops):
    """Name of the paging op this call resolves to — an internal edge
    to a paging.py function, or an (unresolvable) ``paging.X`` dotted
    chain. Bare names / foreign methods that merely collide with an op
    name do not count."""
    tgt = call.func
    if isinstance(tgt, ast.Name):
        if tgt.id not in ops:
            return None
        hit = ctx.graph._resolve_bare(func, tgt.id)
        if hit is not None:
            return tgt.id if _in_paging(hit) else None
        dotted = func.file.aliases.get(tgt.id, "")
        parts = dotted.split(".")
        if len(parts) >= 2 and parts[-2] == "paging":
            return tgt.id
        return None
    if isinstance(tgt, ast.Attribute):
        if tgt.attr not in ops:
            return None
        dotted = dotted_name(tgt, func.file.aliases)
        hit = ctx.index.resolve_dotted(dotted) if dotted else None
        if hit is not None:
            return tgt.attr if _in_paging(hit) else None
        if dotted:
            parts = dotted.split(".")
            if len(parts) >= 2 and parts[-2] == "paging":
                return tgt.attr
        return None
    return None


def _method_attrs(func: FunctionInfo) -> set[str]:
    return {
        c.func.attr
        for c in func.calls
        if isinstance(c.func, ast.Attribute)
    } | {
        c.func.id for c in func.calls if isinstance(c.func, ast.Name)
    }


def run(ctx: LintContext):
    findings = []
    for func in ctx.index.funcs:
        if func.fid < 0:
            continue
        in_paging = _in_paging(func)
        jitted = ctx.graph.is_jitted(func)

        for call in func.calls:
            dev = _paging_op(ctx, func, call, config.PAGING_DEVICE_OPS)
            if dev and not in_paging and not jitted:
                findings.append(
                    ctx.finding(
                        PASS,
                        "device-op-outside-jit",
                        func,
                        call,
                        f"paging.{dev} is a device-side pool transition; "
                        "calling it from un-jitted host code round-trips "
                        "the pool per call — move it into a jitted body "
                        "or use the host_* helpers",
                    )
                )
            host = _paging_op(ctx, func, call, config.PAGING_HOST_OPS)
            if host and jitted:
                findings.append(
                    ctx.finding(
                        PASS,
                        "host-op-in-jit",
                        func,
                        call,
                        f"paging.{host} mutates host-visible pool state "
                        "and must never run under trace",
                    )
                )
            # pool reconstruction outside paging.py: _replace on pool
            # fields bypasses the sanctioned transitions
            if (
                not in_paging
                and isinstance(call.func, ast.Attribute)
                and call.func.attr == "_replace"
                and any(
                    kw.arg in config.POOL_FIELDS for kw in call.keywords
                )
            ):
                findings.append(
                    ctx.finding(
                        PASS,
                        "pool-write-outside-paging",
                        func,
                        call,
                        "PagePool field _replace outside serving/paging.py "
                        "— page-state transitions must go through the "
                        "paging helpers",
                    )
                )

        if not in_paging:
            for tgt in func.assign_targets:
                node = tgt
                if isinstance(node, ast.Subscript):
                    node = node.value
                if (
                    isinstance(node, ast.Attribute)
                    and node.attr
                    in (config.POOL_FIELDS | config.BUDGET_FIELDS)
                ):
                    findings.append(
                        ctx.finding(
                            PASS,
                            "pool-write-outside-paging",
                            func,
                            tgt,
                            f"write to allocator field .{node.attr} "
                            "outside serving/paging.py — only the "
                            "sanctioned helpers may move pool/budget "
                            "state",
                        )
                    )

        # claim <-> budget pairing (host code outside paging.py)
        if in_paging or jitted:
            continue
        called = _method_attrs(func)
        for op, notes in config.CLAIM_PAIRING.items():
            if op in called and not (called & notes):
                findings.append(
                    ctx.finding(
                        PASS,
                        "unpaired-claim"
                        if op.startswith("host_claim")
                        else "unpaired-evict",
                        func,
                        func.node,
                        f"{func.qualname!r} calls {op} without the "
                        f"matching budget bookkeeping "
                        f"({' / '.join(sorted(notes))}) in the same "
                        "function — pool and PageBudget drift apart",
                    )
                )
    return findings
