"""Pass configuration tuned to this codebase.

Root sets are matched by *bare function name* so the passes fire on
fixture copies in tests (e.g. a ``stage_prefill_body`` clone in a tmp
dir) exactly like on the live tree.
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# prng-discipline: functions that must never reach jax.random.*
# ---------------------------------------------------------------------------
# The jitted prefill/staging/transfer bodies plus the engine's host-side
# staging/adoption methods: losslessness (PAPER.md Eq. 4; PR 5/7) rests
# on prefill consuming ZERO randomness — the drafter/verifier key
# schedule must be byte-identical whether a prompt was prefilled
# serially, async-staged, or adopted across pods.
PRNG_ROOTS = frozenset(
    {
        "prefill_body",
        "stage_prefill_body",
        "_pack_stage_pages",
        "_unpack_stage_pages",
        "_release_stage_row",
        "_release_slot",
        "host_adopt_stage",
        "host_claim_prefix",
        "host_claim_live",
        "host_evict",
        # engine host-side admission/staging/adoption paths
        "_admit",
        "_stage",
        "_adopt",
        "_adopt_disagg",
        "_dispatch_transfers",
        "_advance_rides",
    }
)

# ---------------------------------------------------------------------------
# host-sync: the serve loop
# ---------------------------------------------------------------------------
# Serve-loop scope = these roots plus every function *defined in the
# same file* as a root that a root reaches (keeps scheduler/benchmarks
# host code out of the one-sync rule).
SYNC_ROOTS = frozenset(
    {"_run_serial", "_run_async", "_process", "serve", "_service_wait"}
)

# Calls that materialize device values on host. Matched against the
# import-alias-resolved dotted name.
SYNC_CALLS = frozenset(
    {
        "numpy.asarray",
        "numpy.array",
        "jax.device_get",
        "jax.block_until_ready",
    }
)
# Method attrs that sync regardless of receiver resolution.
SYNC_ATTRS = frozenset({"item", "block_until_ready"})

# int()/float()/bool() only count as syncs when their argument subtree
# visibly touches device state: a SYNC_CALLS call, or an attribute of
# these device-resident engine fields / names.
DEVICE_STATE_ATTRS = frozenset(
    {
        "batch",
        "stage",
        "stage_pool",
        "t_cache",
        "d_cache",
        "t_stage_cache",
        "d_stage_cache",
        "key",
    }
)
DEVICE_STATE_NAMES = frozenset({"outs", "pool"})

# Parameter names treated as trace-time static inside jitted bodies
# (config/spec/model objects), for the array-valued-``if`` check.
STATIC_PARAM_NAMES = frozenset(
    {
        "self",
        "cls",
        "cfg",
        "spec",
        "page_spec",
        "stage_spec",
        "model",
        "target",
        "drafter",
        "verify",
        "verify_mp",
        "plan",
        # per-layer plan entry / kernel geometry scalars: closed over or
        # passed as static_argnames, never traced
        "ldef",
        "window",
        "softcap",
        "interpret",
    }
)

# ---------------------------------------------------------------------------
# jit-purity: host APIs banned inside jitted/scan/donated bodies
# ---------------------------------------------------------------------------
HOST_CALL_PREFIXES = ("time.", "datetime.", "random.")
HOST_CALL_NAMES = frozenset({"print", "time", "datetime", "random"})
ALLOWED_IN_JIT = frozenset({"jax.debug.print", "jax.debug.callback"})

# ---------------------------------------------------------------------------
# allocator-discipline
# ---------------------------------------------------------------------------
PAGING_MODULE_SUFFIX = "serving/paging.py"
# Device-side page ops: jittable pool transitions. Outside paging.py
# they may only be called from jit-reachable code.
PAGING_DEVICE_OPS = frozenset({"ensure", "cow_ensure", "fork", "release"})
# Host-side transitions: never callable from jitted code.
PAGING_HOST_OPS = frozenset(
    {"host_claim_prefix", "host_claim_live", "host_evict", "host_adopt_stage"}
)
# PagePool / PageBudget state that only paging.py may write.
POOL_FIELDS = frozenset(
    {"free_stack", "free_count", "ref", "cached", "staged"}
)
BUDGET_FIELDS = frozenset({"slot_len", "stage_len"})
# claim/evict call -> the budget bookkeeping that must appear in the
# same function body.
CLAIM_PAIRING = {
    "host_claim_prefix": frozenset({"note_prefix_claim", "note_stage_claim"}),
    "host_claim_live": frozenset({"note_prefix_claim", "note_stage_claim"}),
    "host_evict": frozenset({"evict_deficit"}),
}

# ---------------------------------------------------------------------------
# feature-gating
# ---------------------------------------------------------------------------
# Programs that assume fully-paged caches; every reference must sit in
# a function that also calls _assert_all_paged on its config path.
PAGED_ONLY_FUNCS = frozenset(
    {
        "stage_prefill_body",
        "decode_body_multipath",
        "_pack_stage_pages",
        "_unpack_stage_pages",
    }
)
GATE_NAME = "_assert_all_paged"

# ---------------------------------------------------------------------------
# fault-site
# ---------------------------------------------------------------------------
# Mirror of serving/faults.py SITES / SITE_* constants (speclint is
# stdlib-only so it cannot import the live module);
# tests/test_faults.py pins the two registries in sync.
FAULT_SITES = frozenset(
    {
        "transfer_loss",
        "transfer_delay",
        "pod_dispatch",
        "alloc_deny",
        "nonfinite_logits",
    }
)
FAULT_SITE_CONSTS = frozenset(
    {
        "SITE_TRANSFER_LOSS",
        "SITE_TRANSFER_DELAY",
        "SITE_POD_DISPATCH",
        "SITE_ALLOC_DENY",
        "SITE_NONFINITE_LOGITS",
    }
)
FAULT_FIRES_ATTR = "fires"
# Gate evidence: an ``is None`` / ``is not None`` test against one of
# these names in the function or an enclosing function.
FAULT_GATE_NAMES = frozenset({"_injector", "faults"})
FAULTS_MODULE_SUFFIX = "serving/faults.py"

# ---------------------------------------------------------------------------
# call-graph method fallback
# ---------------------------------------------------------------------------
# Attr names too generic to fall back on every same-named function in
# the index (dict/array/list methods would wire the graph into a ball).
METHOD_FALLBACK_DENYLIST = frozenset(
    {
        "get",
        "pop",
        "items",
        "keys",
        "values",
        "append",
        "extend",
        "add",
        "update",
        "setdefault",
        "copy",
        "sort",
        "sorted",
        "split",
        "join",
        "format",
        "reshape",
        "astype",
        "at",
        "set",
        "sum",
        "mean",
        "min",
        "max",
        "any",
        "all",
        "item",
        "tolist",
        "flatten",
        "ravel",
        "read",
        "write",
        "close",
        "put",
        "clear",
        "remove",
        "index",
        "count",
    }
)

# Passes whose rules only make sense on production sources (tests and
# benchmarks drive allocator/paged internals directly, on purpose).
PROD_ONLY_PASSES = frozenset(
    {"allocator-discipline", "feature-gating", "fault-site"}
)

ALL_PASSES = (
    "prng-discipline",
    "host-sync",
    "jit-purity",
    "allocator-discipline",
    "feature-gating",
    "fault-site",
)


def is_prod_path(relpath: str) -> bool:
    """True for production sources (not tests/, benchmarks/, test_*.py,
    conftest.py)."""
    parts = relpath.replace("\\", "/").split("/")
    if "tests" in parts or "benchmarks" in parts:
        return False
    base = parts[-1]
    return not (base.startswith("test_") or base == "conftest.py")
