"""Source indexing: parse files, extract comments (suppressions +
sync-point annotations), import aliases, and per-function AST node
ownership."""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path

_SPECLINT_DISABLE = re.compile(
    r"#\s*speclint:\s*disable=([\w*,\- ]+)"
)
_SPECLINT_SYNC = re.compile(
    r"#\s*speclint:\s*sync-point(?:\((.*?)\))?"
)


@dataclasses.dataclass
class FunctionInfo:
    """One def (or the module top level) plus the AST nodes it owns —
    nodes inside nested defs belong to the nested FunctionInfo."""

    fid: int
    name: str                    # bare name ('<module>' for top level)
    qualname: str
    file: "SourceFile"
    node: ast.AST                # FunctionDef / AsyncFunctionDef / Module
    parent: "FunctionInfo | None"
    children: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)
    name_loads: list = dataclasses.field(default_factory=list)
    attr_loads: list = dataclasses.field(default_factory=list)
    assign_targets: list = dataclasses.field(default_factory=list)
    scope_stmts: list = dataclasses.field(default_factory=list)
    ifs: list = dataclasses.field(default_factory=list)
    globals_nonlocals: list = dataclasses.field(default_factory=list)

    def ancestors(self):
        f = self
        while f is not None:
            yield f
            f = f.parent


class SourceFile:
    def __init__(self, path: Path, relpath: str, module: str, text: str):
        self.path = path
        self.relpath = relpath
        self.module = module
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text)
        self.aliases: dict[str, str] = {}
        self.suppressions: dict[int, set[str]] = {}
        self.sync_points: dict[int, str] = {}
        self.functions: list[FunctionInfo] = []
        self._scan_comments()

    def line(self, n: int) -> str:
        if 1 <= n <= len(self.lines):
            return self.lines[n - 1].strip()
        return ""

    def _scan_comments(self) -> None:
        try:
            toks = tokenize.generate_tokens(io.StringIO(self.text).readline)
            for tok in toks:
                if tok.type != tokenize.COMMENT:
                    continue
                row = tok.start[0]
                m = _SPECLINT_DISABLE.search(tok.string)
                if m:
                    names = {
                        p.strip() for p in m.group(1).split(",") if p.strip()
                    }
                    self.suppressions.setdefault(row, set()).update(names)
                m = _SPECLINT_SYNC.search(tok.string)
                if m:
                    self.sync_points[row] = (m.group(1) or "").strip()
        except tokenize.TokenError:
            pass

    def suppressed(self, line: int, pass_name: str) -> bool:
        for row in (line, line - 1):
            names = self.suppressions.get(row)
            if names and ("*" in names or pass_name in names):
                return True
        return False

    def sync_annotation(self, start: int, end: int) -> str | None:
        """Return the sync-point reason annotating the statement spanning
        ``start..end`` (comment on the line above, or any line inside
        the span, e.g. trailing). None when unannotated."""
        for row in range(start - 1, end + 1):
            if row in self.sync_points:
                return self.sync_points[row]
        return None


def module_name(relpath: str) -> str:
    parts = relpath.replace("\\", "/").split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _collect_aliases(sf: SourceFile) -> None:
    pkg_parts = sf.module.split(".")[:-1] if sf.module else []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    sf.aliases[a.asname] = a.name
                else:
                    # ``import a.b.c`` binds ``a``
                    root = a.name.split(".")[0]
                    sf.aliases[root] = root
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg_parts[: len(pkg_parts) - (node.level - 1)]
                prefix = ".".join(base + ([node.module] if node.module else []))
            else:
                prefix = node.module or ""
            for a in node.names:
                if a.name == "*":
                    continue
                bound = a.asname or a.name
                sf.aliases[bound] = (
                    f"{prefix}.{a.name}" if prefix else a.name
                )


class _OwnerWalker(ast.NodeVisitor):
    """Assign every interesting node to its innermost enclosing def."""

    def __init__(self, sf: SourceFile, index: "Index"):
        self.sf = sf
        self.index = index
        self.current: FunctionInfo | None = None

    def _new_func(self, name: str, node: ast.AST) -> FunctionInfo:
        parent = self.current
        if parent is None or parent.name == "<module>":
            qual = name
        else:
            qual = f"{parent.qualname}.{name}"
        info = FunctionInfo(
            fid=len(self.index.funcs), name=name, qualname=qual,
            file=self.sf, node=node, parent=parent,
        )
        self.index.funcs.append(info)
        self.sf.functions.append(info)
        self.index.by_bare.setdefault(name, []).append(info)
        self.index.by_module_qual[(self.sf.module, qual)] = info
        if parent is not None:
            parent.children[name] = info
        return info

    def visit_Module(self, node: ast.Module):
        self.current = self._new_func("<module>", node)
        self.generic_visit(node)

    def _visit_def(self, node):
        prev = self.current
        info = self._new_func(node.name, node)
        # decorators/defaults belong to the enclosing scope
        self.current = prev
        for dec in node.decorator_list:
            self.visit(dec)
        for default in list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None
        ]:
            self.visit(default)
        self.current = info
        for stmt in node.body:
            self.visit(stmt)
        self.current = prev

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def

    def visit_ClassDef(self, node: ast.ClassDef):
        # methods nest under the class name via a pseudo scope so
        # qualnames read Class.method; node ownership stays with defs.
        prev = self.current
        pseudo = FunctionInfo(
            fid=-1, name=node.name,
            qualname=(
                node.name
                if prev is None or prev.name == "<module>"
                else f"{prev.qualname}.{node.name}"
            ),
            file=self.sf, node=node, parent=prev,
        )
        for dec in node.decorator_list:
            self.visit(dec)
        self.current = pseudo
        for stmt in node.body:
            self.visit(stmt)
        self.current = prev
        # statements owned by the class body (rare) re-home to parent
        if prev is not None:
            for lst_name in (
                "calls", "name_loads", "attr_loads", "assign_targets",
                "scope_stmts", "ifs", "globals_nonlocals",
            ):
                getattr(prev, lst_name).extend(getattr(pseudo, lst_name))
            for name, child in pseudo.children.items():
                prev.children.setdefault(name, child)

    # -- node collection ----------------------------------------------------

    def visit(self, node):
        cur = self.current
        if cur is not None and isinstance(node, ast.stmt):
            cur.scope_stmts.append(node)
        return super().visit(node)

    def visit_Call(self, node: ast.Call):
        if self.current is not None:
            self.current.calls.append(node)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        if self.current is not None and isinstance(node.ctx, ast.Load):
            self.current.name_loads.append(node)

    def visit_Attribute(self, node: ast.Attribute):
        if self.current is not None and isinstance(node.ctx, ast.Load):
            self.current.attr_loads.append(node)
        self.generic_visit(node)

    def visit_If(self, node: ast.If):
        if self.current is not None:
            self.current.ifs.append(node)
        self.generic_visit(node)

    def _visit_assign(self, node):
        if self.current is not None:
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            self.current.assign_targets.extend(targets)
        self.generic_visit(node)

    visit_Assign = _visit_assign
    visit_AugAssign = _visit_assign
    visit_AnnAssign = _visit_assign

    def visit_Global(self, node):
        if self.current is not None:
            self.current.globals_nonlocals.append(node)

    visit_Nonlocal = visit_Global


class Index:
    """All parsed files + every function across them."""

    def __init__(self) -> None:
        self.files: list[SourceFile] = []
        self.by_module: dict[str, SourceFile] = {}
        self.funcs: list[FunctionInfo] = []
        self.by_bare: dict[str, list[FunctionInfo]] = {}
        self.by_module_qual: dict[tuple[str, str], FunctionInfo] = {}

    def add_file(self, path: Path, root: Path) -> SourceFile | None:
        try:
            relpath = path.resolve().relative_to(root).as_posix()
        except ValueError:
            relpath = path.as_posix()
        try:
            text = path.read_text()
            sf = SourceFile(path, relpath, module_name(relpath), text)
        except (SyntaxError, UnicodeDecodeError):
            return None
        _collect_aliases(sf)
        _OwnerWalker(sf, self).visit(sf.tree)
        self.files.append(sf)
        self.by_module[sf.module] = sf
        return sf

    def resolve_dotted(self, dotted: str) -> FunctionInfo | None:
        """``pkg.mod.Class.fn`` -> FunctionInfo, trying the longest
        known-module prefix."""
        parts = dotted.split(".")
        for cut in range(len(parts) - 1, 0, -1):
            mod = ".".join(parts[:cut])
            sf = self.by_module.get(mod)
            if sf is not None:
                qual = ".".join(parts[cut:])
                hit = self.by_module_qual.get((sf.module, qual))
                if hit is not None:
                    return hit
        return None


def dotted_name(expr: ast.expr, aliases: dict[str, str]) -> str | None:
    """Resolve ``np.asarray`` / ``jax.random.split`` / ``paging.ensure``
    to an import-alias-expanded dotted string; None when the chain is
    not rooted at a plain name (e.g. ``self.runner.fn``... returns the
    chain with the raw root so callers can still pattern-match)."""
    parts: list[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if isinstance(expr, ast.Name):
        base = aliases.get(expr.id, expr.id)
        return ".".join([base] + list(reversed(parts)))
    return None


def build_index(paths: list[Path], root: Path) -> Index:
    index = Index()
    seen: set[Path] = set()
    for p in paths:
        files = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in files:
            rf = f.resolve()
            if rf in seen or f.suffix != ".py":
                continue
            seen.add(rf)
            index.add_file(f, root)
    return index
