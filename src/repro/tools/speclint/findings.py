"""Finding record + stable fingerprints for the baseline."""

from __future__ import annotations

import dataclasses
import hashlib


@dataclasses.dataclass
class Finding:
    """One lint violation.

    ``fingerprint`` is line-number independent — it hashes the pass,
    rule, file, enclosing function and the *normalized source line*
    (plus an occurrence index for identical lines), so a baseline
    survives unrelated edits above the finding.
    """

    pass_name: str
    rule: str
    path: str          # repo-relative, '/'-separated
    line: int
    func: str          # enclosing function qualname ('<module>' at top level)
    code: str          # stripped source line
    message: str
    occurrence: int = 0

    @property
    def fingerprint(self) -> str:
        key = "|".join(
            [
                self.pass_name,
                self.rule,
                self.path,
                self.func,
                " ".join(self.code.split()),
                str(self.occurrence),
            ]
        )
        return hashlib.sha256(key.encode("utf-8")).hexdigest()[:16]

    def to_json(self) -> dict:
        return {
            "pass": self.pass_name,
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "func": self.func,
            "code": self.code,
            "message": self.message,
            "fingerprint": self.fingerprint,
        }

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}: [{self.pass_name}/{self.rule}] "
            f"{self.message}\n    {self.code}"
        )


def assign_occurrences(findings: list[Finding]) -> list[Finding]:
    """Disambiguate findings whose fingerprint key would collide
    (same rule + file + function + source text) by occurrence index,
    in line order."""
    findings = sorted(
        findings, key=lambda f: (f.path, f.line, f.pass_name, f.rule)
    )
    seen: dict[tuple, int] = {}
    for f in findings:
        key = (f.pass_name, f.rule, f.path, f.func, " ".join(f.code.split()))
        f.occurrence = seen.get(key, 0)
        seen[key] = f.occurrence + 1
    return findings
