"""CLI: ``python -m repro.tools.speclint [--json out] [--baseline file]
paths...``

Exit codes: 0 clean (or all findings baselined), 1 new findings,
2 usage error.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import baseline as baseline_mod
from . import config
from .driver import run_speclint


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="speclint",
        description=(
            "AST/call-graph invariant checker: prng-discipline, "
            "host-sync, jit-purity, allocator-discipline, "
            "feature-gating"
        ),
    )
    parser.add_argument(
        "paths", nargs="*", default=["src"],
        help="files or directories to lint (default: src)",
    )
    parser.add_argument(
        "--json", metavar="OUT",
        help="write the full machine-readable report (all findings, "
        "baselined included) to this path",
    )
    parser.add_argument(
        "--baseline", metavar="FILE",
        help="subtract this report's fingerprints; only NEW findings "
        "fail the run",
    )
    parser.add_argument(
        "--passes", metavar="P1,P2",
        help=f"comma-separated subset of: {', '.join(config.ALL_PASSES)}",
    )
    parser.add_argument(
        "--root", metavar="DIR", default=None,
        help="repo root for relative paths in findings (default: cwd)",
    )
    args = parser.parse_args(argv)

    passes = None
    if args.passes:
        passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    try:
        findings = run_speclint(
            args.paths or ["src"], root=args.root, passes=passes
        )
    except ValueError as exc:
        print(f"speclint: {exc}", file=sys.stderr)
        return 2

    if args.json:
        baseline_mod.write_report(findings, args.json)

    if args.baseline:
        if not Path(args.baseline).exists():
            print(
                f"speclint: baseline {args.baseline} not found",
                file=sys.stderr,
            )
            return 2
        known = baseline_mod.load_fingerprints(args.baseline)
        new, old, stale = baseline_mod.split_by_baseline(findings, known)
        for f in new:
            print(f.render())
        print(
            f"speclint: {len(new)} new finding(s), "
            f"{len(old)} baselined, {len(stale)} stale baseline entr"
            f"{'y' if len(stale) == 1 else 'ies'}"
        )
        return 1 if new else 0

    for f in findings:
        print(f.render())
    print(f"speclint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
