"""Non-speculative autoregressive decoding baseline (the '1x' reference
for wall-clock speedup measurements, as in the paper's Table 1).

Mirrors the serving runner's layering in miniature: the whole decode loop
is ONE jitted program (a ``lax.scan`` over steps), so the host syncs a
single (T, B) token matrix at the end instead of one device→host round
trip per generated token."""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sampling
from repro.models.model import Model


def _decode_loop(model: Model, temperature, n_steps,
                 params, cache, last_tok, lens, key):
    """scan of n_steps single-token decode steps -> tokens (T, B)."""

    def step(carry, key_i):
        cache, last, lens = carry
        logits, cache, _ = model.apply(
            params, last[:, None], cache=cache, lens=lens - 1, mode="decode"
        )
        probs = sampling.logits_to_probs(
            logits[:, 0, : model.cfg.vocab], temperature=temperature
        )
        nxt = sampling.categorical(key_i, probs)
        return (cache, nxt, lens + 1), nxt

    keys = jax.random.split(key, n_steps)
    _, toks = jax.lax.scan(step, (cache, last_tok, lens), keys)
    return toks


def autoregressive_decode(
    model: Model,
    params,
    prompts: list[list[int]],
    max_new_tokens: int,
    temperature: float = 1.0,
    seed: int = 0,
    max_len: int = 512,
) -> tuple[list[list[int]], float]:
    """Greedy/sampled AR decoding of a batch of prompts (padded into a
    fixed batch). Returns (outputs, wall seconds for the decode loop)."""
    b = len(prompts)
    cache = model.init_cache(b, max_len, chunk_slack=16)
    max_p = max(len(p) for p in prompts)
    bucket = -(-max_p // 16) * 16
    toks = np.zeros((b, bucket), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    lens = jnp.asarray([len(p) for p in prompts], jnp.int32)

    prefill = jax.jit(
        lambda pr, t, vl: model.apply(
            pr, t, cache=cache, extras=model.make_extras(b),
            mode="prefill", valid_len=vl,
        )[1]
    )
    cache = prefill(params, jnp.asarray(toks), lens - 1)
    last = jnp.asarray([p[-1] for p in prompts], jnp.int32)

    loop = jax.jit(
        partial(_decode_loop, model, temperature, max_new_tokens)
    )
    key = jax.random.key(seed)
    # warmup compile (full loop: one executable for all max_new steps)
    jax.block_until_ready(loop(params, cache, last, lens, key))

    t0 = time.perf_counter()
    out_toks = np.asarray(loop(params, cache, last, lens, key))  # (T, B)
    wall = time.perf_counter() - t0
    outs = [out_toks[:, i].tolist() for i in range(b)]
    return outs, wall
