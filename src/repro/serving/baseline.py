"""Non-speculative autoregressive decoding baseline (the '1x' reference
for wall-clock speedup measurements, as in the paper's Table 1)."""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sampling
from repro.models.model import Model


def _decode_step(model: Model, temperature, params, cache, last_tok, lens, key):
    logits, cache, _ = model.apply(
        params, last_tok[:, None], cache=cache, lens=lens - 1, mode="decode"
    )
    probs = sampling.logits_to_probs(
        logits[:, 0, : model.cfg.vocab], temperature=temperature
    )
    nxt = sampling.categorical(key, probs)
    return cache, nxt, lens + 1


def autoregressive_decode(
    model: Model,
    params,
    prompts: list[list[int]],
    max_new_tokens: int,
    temperature: float = 1.0,
    seed: int = 0,
    max_len: int = 512,
) -> tuple[list[list[int]], float]:
    """Greedy/sampled AR decoding of a batch of prompts (padded into a
    fixed batch). Returns (outputs, wall seconds for the decode loop)."""
    b = len(prompts)
    cache = model.init_cache(b, max_len, chunk_slack=16)
    max_p = max(len(p) for p in prompts)
    bucket = -(-max_p // 16) * 16
    toks = np.zeros((b, bucket), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    lens = jnp.asarray([len(p) for p in prompts], jnp.int32)

    prefill = jax.jit(
        lambda pr, t, vl: model.apply(
            pr, t, cache=cache, extras=model.make_extras(b),
            mode="prefill", valid_len=vl,
        )[1]
    )
    cache = prefill(params, jnp.asarray(toks), lens - 1)
    last = jnp.asarray([p[-1] for p in prompts], jnp.int32)

    step = jax.jit(partial(_decode_step, model, temperature))
    key = jax.random.key(seed)
    # warmup compile
    step(params, cache, last, lens, key)

    outs = [[] for _ in range(b)]
    t0 = time.perf_counter()
    for _ in range(max_new_tokens):
        key, sub = jax.random.split(key)
        cache, last, lens = step(params, cache, last, lens, sub)
        for i, t in enumerate(np.asarray(last)):
            outs[i].append(int(t))
    wall = time.perf_counter() - t0
    return outs, wall
