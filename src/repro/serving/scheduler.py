"""Host-side request lifecycle for the serving engine.

The :class:`Scheduler` owns everything that is *about requests* rather
than about tensors: the admission queue, the slot→request mapping,
retirement, preemption, and per-request metrics (TTFT, tokens/s,
acceptance rate). It holds a host mirror of the device-resident prefill
progress — chunk counts are deterministic, so the mirror needs no device
sync: after each dispatched prefill step every prefilling slot has
consumed exactly ``min(chunk, remaining)`` more prompt tokens.

Admission is FIFO by default, refined by three optional layers that
compose strictly top-down (see :meth:`Scheduler._select_index`):
**priority classes** (``submit(..., priority=n)``; lower n is a
strictly higher tier — premium admits before any best-effort request
and is preempted/killed last), **per-tenant weighted fairness within a
tier** (``submit(..., tenant=name)`` + :meth:`set_tenant_weight`;
stride scheduling over virtual time gives each tenant a
weight-proportional share of admissions under saturation), and
**cache-aware admission** (the engine installs ``match_fn`` when live
prefix sharing is on): within the chosen tenant both lanes admit the
queued request with the LONGEST page-aligned prefix match against the
live-inclusive prefix index instead of the head of the queue — a burst
sharing a prefix admits back-to-back while the span is hot, instead of
interleaving cold prompts between the hits. Starvation within a tier
is bounded by an aging counter: every time a request is overtaken its
``age`` ticks, and once it reaches ``aging_limit`` it is admitted
before any younger request in its tier regardless of tenant or match
(most-starved first, ties by submit order; ``age`` resets at every
queue exit — admission and preemption requeue alike). Selection is
deterministic — class, then age, then tenant virtual time, then match
pages, then submit order — so admission order (and therefore
allocation order) stays reproducible.

**Riding** (claim-behind-the-writer): a row admitted behind a live
writer of its own prompt prefix holds its prefill while the writer's
chunks commit (the engine extends its claim instead). A riding row is
excluded from the prefill mirror's dispatch accounting — the device
program skips held rows, so the mirror must too — via
:meth:`set_slot_riding` / :meth:`set_stage_riding`.

Paged engines hand the scheduler a :class:`repro.serving.paging.PageBudget`
— admission then goes by *free-page budget* instead of blind slot-fill:
a queued request is admitted only when the pool can cover every live
slot's conservative worst case plus the newcomer's. For multi-path
engines that worst case is **post-fork**: it includes the K forked path
tables' copy-on-write and speculative transient, so the in-program
fork/cow allocators can never run the pool dry. When decoding grows
live slots past the budget (over-subscribed pools), the engine preempts
the most recently admitted slot: its pages are freed and the request
requeues at the *front* with ``prompt + output`` as its resume prompt —
recompute-on-resume, the classic trade of a little prefill compute for
not reserving worst-case memory. With the cross-request prefix cache
enabled, the engine parks a victim's committed full pages in the
``cached`` state instead of freeing them, so resume usually re-*claims*
its own prefix rather than re-prefilling it (the engine reports the
claim via :meth:`Scheduler.note_prefix_claim`, which shrinks the
prefill mirror).

Async-prefill engines (``EngineConfig(async_prefill=True)``) run the
scheduler **two-lane**: the submit queue feeds *staging* slots (the
background prefill program's lanes, mirrored by ``_stage_left`` exactly
like the decode lanes' prefill mirror), and a *ready queue* — staging
slots whose final chunk has dispatched — feeds decode slots by
**adoption** (:meth:`adopt`): the request moves from ``stage_len`` to
``slot_len`` in the page budget (a pure key move, so adoption can never
fail allocation) and its decode slot admits already-``ready``. Under
pressure the engine kills *staging* lanes first (least progress,
:meth:`pick_stage_victim`), then preempts decode slots LIFO as before;
either way the victim requeues at the front.

It never touches device arrays; the engine translates admissions,
adoptions and retirements into :mod:`repro.serving.batch` updates.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

from repro.serving.paging import PageBudget


@dataclass
class RequestState:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    output: list[int] = field(default_factory=list)
    iterations: int = 0
    accepted_total: int = 0
    # lifecycle timestamps (engine clock; None until reached)
    submit_t: float = 0.0
    admit_t: float | None = None
    # Monotonic admission sequence number (bumped at every (re)admission).
    # Preemption picks its LIFO victim by this, NOT by admit_t: all
    # requests admitted in one admit() call share the same clock reading,
    # so a timestamp tie-break silently degrades to "highest slot index".
    admit_seq: int = -1
    # TTFT breakdown anchors (set only while first_token_t is None, so
    # they describe the attempt that actually produced the first token;
    # earlier preempted attempts are visible as ttft_s exceeding the
    # three components' sum):
    stage_t: float | None = None   # prefill started (staging/admission)
    ready_t: float | None = None   # final prefill chunk dispatched
    adopt_t: float | None = None   # adopted into a decode slot (async)
    first_token_t: float | None = None
    finish_t: float | None = None
    finish_reason: str | None = None
    finished: bool = False
    preemptions: int = 0
    # Time spent requeued between a preemption and the matching
    # readmission AFTER the first token was emitted — excluded from
    # decode throughput (pre-first-token waits are already outside the
    # first_token_t -> finish_t window).
    requeue_wait_s: float = 0.0
    # Requeue waits BEFORE the first token (a staged background prefill
    # killed under pressure, or a still-prefilling decode slot
    # preempted): accumulated separately because they land inside the
    # submit -> stage_t window — ttft_queue_s subtracts them so a
    # killed staging attempt's dead time isn't misattributed to queue
    # wait (and never pollutes the post-first-token decode window that
    # tokens_per_s corrects by requeue_wait_s).
    pre_first_requeue_wait_s: float = 0.0
    # Times this queued request was overtaken by cache-aware admission;
    # at Scheduler.aging_limit it regains absolute priority within its
    # class tier. Reset to 0 at every (re)queue boundary — admission AND
    # preemption requeue — so a victim never re-enters with stale age.
    age: int = 0
    # Strict priority class: 0 is the highest tier, larger numbers are
    # more best-effort. Classes gate admission absolutely (a queued
    # class-0 request always admits before any class-1 request); aging
    # and fairness only reorder WITHIN a tier.
    priority: int = 0
    # Fairness accounting key: requests sharing a tenant share that
    # tenant's deficit-weighted slice of admissions within their tier.
    tenant: str = "default"
    # Streaming cursor: output tokens already handed to the front end's
    # per-token emit callback. Monotone, survives preemption (output is
    # never truncated), and never passes len(output) — which is itself
    # the host mirror of the device committed frontier
    # (batch.committed_frontier), so a streamed token is always a
    # committed token.
    emitted: int = 0
    # Caller-facing SLO: once ``clock - submit_t`` exceeds this, the
    # request is shed — at admission (it never takes a slot) or at the
    # retire check (it stops decoding) — with finish_reason "deadline".
    # None = no deadline.
    deadline_s: float | None = None
    # Per-request quarantine: a service-loop exception attributable to
    # this request finishes it with reason "error" and the message here,
    # instead of tearing down the service thread.
    error: str | None = None
    # Degradation-ladder failover: set when this request's staged lane
    # exhausted its transfer retries (or the prefill pod is down) — the
    # staging lane skips it and it admits straight into a decode slot,
    # prefilling on the decode pod (serial semantics).
    no_stage: bool = False
    _preempt_t: float | None = None

    def past_deadline(self, now: float) -> bool:
        return (
            self.deadline_s is not None
            and now - self.submit_t > self.deadline_s
        )

    def serve_prompt(self) -> list[int]:
        """Tokens to prefill at (re)admission: the original prompt plus
        everything already generated (recompute-on-resume)."""
        return self.prompt + self.output

    def serve_max_new(self) -> int:
        """Remaining new-token budget at (re)admission."""
        return self.max_new_tokens - len(self.output)

    @property
    def ttft_s(self) -> float | None:
        """Time to first token, including queue wait."""
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    # -- TTFT breakdown (what async prefill moves between buckets) --------

    @property
    def ttft_queue_s(self) -> float | None:
        """Submit → prefill start (queue wait; staging admission in the
        async engine, decode-slot admission in the serial one), minus
        any pre-first-token requeue waits — time between a staged kill
        (or still-prefilling preemption) and the retry's readmission is
        preemption dead time, not queue wait, and lives in
        :attr:`pre_first_requeue_wait_s`."""
        if self.first_token_t is None or self.stage_t is None:
            return None
        return self.stage_t - self.submit_t - self.pre_first_requeue_wait_s

    @property
    def ttft_prefill_s(self) -> float | None:
        """Prefill start → final prompt chunk dispatched."""
        if (
            self.first_token_t is None
            or self.ready_t is None
            or self.stage_t is None
        ):
            return None
        return self.ready_t - self.stage_t

    @property
    def ttft_transfer_s(self) -> float | None:
        """Prefill complete → adopted into a decode slot: the adoption
        wait plus (disaggregated engines) the staged pages' pack →
        device_put → unpack transfer, which this anchor attributes
        explicitly instead of letting it silently inflate
        ``ttft_decode_s`` (the PR 6 staged-kill attribution rule).
        0.0 for rows that never adopt (the serial engine, or a resumed
        victim re-admitted straight into a decode slot)."""
        if self.first_token_t is None or self.ready_t is None:
            return None
        if self.adopt_t is None:
            return 0.0
        return self.adopt_t - self.ready_t

    @property
    def ttft_decode_s(self) -> float | None:
        """Decode-slot entry → first token materialized on the host
        (first decode iterations; adoption/transfer wait lives in
        :attr:`ttft_transfer_s`)."""
        if self.first_token_t is None or self.ready_t is None:
            return None
        anchor = self.adopt_t if self.adopt_t is not None else self.ready_t
        return self.first_token_t - anchor

    @property
    def tokens_per_s(self) -> float | None:
        """Decode throughput: output tokens over the time the request was
        actually generating — first token to finish, minus any
        post-first-token preemption requeue waits
        (:attr:`requeue_wait_s`). Queue wait and requeue time belong to
        :attr:`e2e_tokens_per_s`; folding them in here deflated
        per-request decode throughput under load."""
        if (
            self.finish_t is None
            or self.first_token_t is None
            or not self.output
        ):
            return None
        dur = self.finish_t - self.first_token_t - self.requeue_wait_s
        return len(self.output) / dur if dur > 0 else None

    @property
    def e2e_tokens_per_s(self) -> float | None:
        """End-to-end throughput including queue wait and requeue time."""
        if self.finish_t is None or not self.output:
            return None
        dur = self.finish_t - self.submit_t
        return len(self.output) / dur if dur > 0 else None

    def acceptance_rate(self, gamma: int) -> float:
        """Fraction of drafted tokens accepted (block efficiency - 1 is a
        related but distinct quantity: BE counts the bonus token)."""
        drafted = self.iterations * gamma
        return self.accepted_total / drafted if drafted else 0.0


class Scheduler:
    """Admission queue + slot bookkeeping + per-request metrics."""

    def __init__(
        self,
        num_slots: int,
        default_max_new: int,
        prefill_chunk: int,
        clock=time.perf_counter,
        budget: PageBudget | None = None,
        num_stage_slots: int = 0,
        aging_limit: int = 8,
        stage_budget: PageBudget | None = None,
    ):
        self.num_slots = num_slots
        self.default_max_new = default_max_new
        self.prefill_chunk = prefill_chunk
        self.clock = clock
        self.budget = budget
        # Disaggregated engines split the accounting: ``budget`` covers
        # the decode pod's pool, ``stage_budget`` the prefill pod's.
        # Staging then charges stage_budget only, and adoption becomes a
        # cross-pool move (decode ``note_admit`` + stage ``note_unstage``)
        # gated by the decode pool's ``can_admit`` — unlike the shared
        # pool, the decode side holds no up-front reservation for staged
        # rows, so adoption CAN stall (head-blocking, FIFO-preserving).
        self.stage_budget = stage_budget
        self.queue: deque[RequestState] = deque()
        self.slot_req: list[RequestState | None] = [None] * num_slots
        self._prefill_left = [0] * num_slots
        self._slot_riding = [False] * num_slots
        # Async staging lane (num_stage_slots > 0): the submit queue
        # feeds staging slots; completed stages queue for adoption.
        self.num_stage_slots = num_stage_slots
        self.stage_req: list[RequestState | None] = [None] * num_stage_slots
        self._stage_left = [0] * num_stage_slots
        self._stage_riding = [False] * num_stage_slots
        self.ready_q: deque[int] = deque()  # staged sids awaiting adoption
        self.done: dict[int, RequestState] = {}
        self._next_rid = 0
        self._admit_seq = 0
        # Cache-aware admission: the engine installs a prompt ->
        # matched-pages oracle (longest page-aligned prefix claimable
        # from the live-inclusive prefix index, including what a live
        # writer will still commit); None keeps admission FIFO.
        self.match_fn = None
        self.aging_limit = aging_limit
        # Per-tenant weighted fairness (stride scheduling over virtual
        # time): each admission charges its tenant
        # (prompt + max_new) / weight virtual seconds; selection picks
        # the tenant with the smallest clamped virtual time. The floor
        # tracks the last admission's start tag so a tenant idle for a
        # while re-enters at "now" instead of burning a huge banked
        # deficit (the classic start-time-fair-queuing clamp).
        self.tenant_weights: dict[str, float] = {}
        self._tenant_vtime: dict[str, float] = {}
        self._vtime_floor = 0.0

    # -- submission / admission --------------------------------------------

    def set_tenant_weight(self, tenant: str, weight: float) -> None:
        """Give ``tenant`` a ``weight``-proportional share of admissions
        within its priority tier (default weight 1.0)."""
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        self.tenant_weights[tenant] = float(weight)

    def _tenant_vtag(self, tenant: str) -> float:
        """Clamped virtual-time tag used for selection and charging."""
        return max(self._tenant_vtime.get(tenant, 0.0), self._vtime_floor)

    def submit(
        self,
        prompt_ids: list[int],
        max_new_tokens: int | None = None,
        priority: int = 0,
        tenant: str = "default",
        deadline_s: float | None = None,
    ) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(
            RequestState(
                rid=rid,
                prompt=list(prompt_ids),
                max_new_tokens=(
                    self.default_max_new
                    if max_new_tokens is None else max_new_tokens
                ),
                submit_t=self.clock(),
                priority=priority,
                tenant=tenant,
                deadline_s=deadline_s,
            )
        )
        return rid

    # -- lifecycle hardening: finalize / cancel / deadline shed -------------

    def finalize(self, req: RequestState, reason: str) -> RequestState:
        """Finish a request OUTSIDE a decode slot (cancelled while
        queued/staged, shed at a deadline, quarantined on error) — the
        off-slot twin of :meth:`retire`. The caller has already detached
        the request from whatever structure held it."""
        req.finished = True
        req.finish_t = self.clock()
        req.finish_reason = reason
        self.done[req.rid] = req
        return req

    def find(self, rid: int):
        """Locate a live request: ``("queued", index)``, ``("staged",
        sid)``, ``("slot", slot)``, ``("done", None)``, or ``None`` for
        an unknown rid."""
        if rid in self.done:
            return ("done", None)
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                return ("queued", i)
        for sid, req in enumerate(self.stage_req):
            if req is not None and req.rid == rid:
                return ("staged", sid)
        for slot, req in enumerate(self.slot_req):
            if req is not None and req.rid == rid:
                return ("slot", slot)
        return None

    def cancel_queued(self, idx: int, reason: str = "cancelled"):
        """Remove + finalize ``queue[idx]``. No aging side effects — a
        cancellation is not an overtake."""
        req = self.queue[idx]
        del self.queue[idx]
        return self.finalize(req, reason)

    def drop_stage(self, sid: int, reason: str = "cancelled"):
        """Clear a staging lane and FINALIZE its request (cancel /
        deadline / failover-exhausted), unlike :meth:`kill_stage` which
        requeues it. The engine has already released the lane's device
        state."""
        req = self.stage_req[sid]
        assert req is not None, sid
        self.stage_req[sid] = None
        self._stage_left[sid] = 0
        self._stage_riding[sid] = False
        if sid in self.ready_q:
            self.ready_q.remove(sid)
        sb = self.stage_budget if self.stage_budget is not None else self.budget
        if sb is not None:
            sb.note_unstage(sid)
        return self.finalize(req, reason)

    def shed_expired(self) -> list[RequestState]:
        """Deadline shedding at the admission boundary: finalize every
        queued request already past its deadline, so an expired request
        never takes a slot (or a budget reservation) it cannot use.
        Returns the shed requests; the engine emits their terminal
        deltas."""
        now = self.clock()
        shed = []
        for i in [
            i for i, r in enumerate(self.queue) if r.past_deadline(now)
        ][::-1]:
            req = self.queue[i]
            del self.queue[i]
            shed.append(self.finalize(req, "deadline"))
        return shed[::-1]

    def _pop_at(self, idx: int, now: float) -> RequestState:
        """Pop ``queue[idx]`` and stamp the admission bookkeeping BOTH
        lanes share: the admit clock, requeue-wait accounting for
        resumed preemption victims (routed by whether the first token
        has emitted — see :attr:`RequestState.pre_first_requeue_wait_s`),
        the monotonic ``admit_seq`` (LIFO victim order), and the TTFT
        prefill-start anchor. Requests overtaken by cache-aware
        selection (everything in front of ``idx``) age by one."""
        req = self.queue[idx]
        del self.queue[idx]
        for j in range(idx):
            self.queue[j].age += 1
        req.age = 0
        req.admit_t = now
        if req._preempt_t is not None:  # resuming after preemption/kill
            if req.first_token_t is None:
                req.pre_first_requeue_wait_s += now - req._preempt_t
            else:
                req.requeue_wait_s += now - req._preempt_t
            req._preempt_t = None
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        # Stride-scheduling charge: the admitted request advances its
        # tenant's virtual time by worst-case serve cost over weight, so
        # heavier-weighted tenants accrue virtual time slower and win
        # the min-vtag selection proportionally more often.
        weight = self.tenant_weights.get(req.tenant, 1.0)
        start = self._tenant_vtag(req.tenant)
        cost = len(req.serve_prompt()) + req.serve_max_new()
        self._tenant_vtime[req.tenant] = start + cost / weight
        self._vtime_floor = start
        if req.first_token_t is None:
            req.stage_t = now
            # The retry's adoption (if any) re-stamps this; a resumed
            # victim admitted straight into a decode slot stays None
            # (ttft_transfer_s = 0).
            req.adopt_t = None
        return req

    def _select_index(self, pred=None) -> int | None:
        """Queue index the next admission should take. Deterministic
        hierarchy, each level only reordering within the one above:

        1. **Class tier** (strict): only the lowest ``priority`` value
           present in the queue is eligible — premium traffic admits
           before any best-effort request, full stop.
        2. **Aging** (within the tier): any request overtaken to
           ``aging_limit`` goes first; among the aged, most-starved
           first (highest ``age``), ties by submission order (``rid`` —
           queue *position* is not a tie-break because preemption
           requeues victims at the front with a fresh age).
        3. **Tenant fairness** (within the tier): when the tier holds
           several tenants, only the tenant with the smallest clamped
           virtual time (see :meth:`set_tenant_weight`) is eligible;
           ties by tenant name.
        4. **Cache affinity / FIFO**: within the chosen tenant, the
           longest live-inclusive prefix match wins when the engine
           installed ``match_fn`` (ties by queue order), plain FIFO
           otherwise.

        With defaults (one class, one tenant, no ``match_fn``) this
        collapses to the head of the queue — exact FIFO. ``pred``
        restricts eligibility (the degradation ladder's lane routing:
        staging skips ``no_stage`` requests, the async decode lane only
        takes them); returns None when nothing is eligible."""
        idxs = [
            i for i, r in enumerate(self.queue)
            if pred is None or pred(r)
        ]
        if not idxs:
            return None
        if len(idxs) == 1:
            return idxs[0]
        top = min(self.queue[i].priority for i in idxs)
        cand = [i for i in idxs if self.queue[i].priority == top]
        aged = [i for i in cand if self.queue[i].age >= self.aging_limit]
        if aged:
            return min(
                aged, key=lambda i: (-self.queue[i].age, self.queue[i].rid)
            )
        tenants = {self.queue[i].tenant for i in cand}
        if len(tenants) > 1:
            pick = min(tenants, key=lambda t: (self._tenant_vtag(t), t))
            cand = [i for i in cand if self.queue[i].tenant == pick]
        if self.match_fn is None or len(cand) == 1:
            return cand[0]
        best, best_pages = cand[0], -1
        for i in cand:
            pages = self.match_fn(self.queue[i].serve_prompt())
            if pages > best_pages:
                best, best_pages = i, pages
        return best

    def admit(self, pred=None) -> list[tuple[int, RequestState]]:
        """Fill free slots from the queue — FIFO, or cache-aware when
        ``match_fn`` is installed (see :meth:`_select_index`). With a
        page budget, admission stops at the first *selected* request the
        pool cannot cover (the selected request keeps its claim on the
        next free slot — no further overtaking past a budget stall).
        ``pred`` restricts which queued requests this lane may take (the
        async engine's failover path admits only ``no_stage`` requests
        straight into decode slots). Returns the new (slot, request)
        pairs; the engine stages them on device."""
        admitted = []
        now = self.clock()
        for slot in range(self.num_slots):
            if self.slot_req[slot] is None and self.queue:
                idx = self._select_index(pred)
                if idx is None:
                    break
                plen = len(self.queue[idx].serve_prompt())
                if self.budget is not None and not self.budget.can_admit(plen):
                    break
                req = self._pop_at(idx, now)
                self.slot_req[slot] = req
                # Both models must consume plen - 1 prompt tokens.
                self._prefill_left[slot] = max(plen - 1, 0)
                if (
                    self._prefill_left[slot] == 0
                    and req.first_token_t is None
                ):
                    req.ready_t = now
                if self.budget is not None:
                    self.budget.note_admit(slot, plen)
                admitted.append((slot, req))
        return admitted

    def note_prefix_claim(self, slot: int, prefix_len: int) -> None:
        """Account a prefix-cache hit for a just-admitted slot: the first
        ``prefix_len`` prompt tokens were claimed from cached pages, so
        chunked prefill only has to consume the remainder."""
        self._prefill_left[slot] = max(
            self._prefill_left[slot] - prefix_len, 0
        )
        req = self.slot_req[slot]
        if (
            self._prefill_left[slot] == 0
            and req is not None
            and req.first_token_t is None
        ):
            # Overwrite unconditionally, like every other ready_t site:
            # a preempted-then-resumed request whose resume is a
            # full-prefix claim must not keep the FIRST attempt's
            # (earlier) ready_t, or ttft_prefill_s goes negative.
            req.ready_t = self.clock()

    # -- prefill mirror ----------------------------------------------------

    def set_slot_riding(self, slot: int, riding: bool) -> None:
        """Mark/unmark a decode slot as riding a live writer's prefill
        (the device program holds its prefill; the engine grows its
        claim instead). Riding slots are excluded from the prefill
        mirror — they consume no chunks until the ride ends."""
        self._slot_riding[slot] = riding

    def slot_riding(self, slot: int) -> bool:
        return self._slot_riding[slot]

    def prefill_pending(self) -> bool:
        return any(
            left > 0
            and self.slot_req[slot] is not None
            and not self._slot_riding[slot]
            for slot, left in enumerate(self._prefill_left)
        )

    def note_prefill_dispatch(self) -> int:
        """Account one dispatched chunked-prefill step: every prefilling
        slot advanced by ``min(chunk, remaining)`` tokens (riding slots
        are held by the device program, so the mirror skips them too).
        Returns the total prompt tokens consumed by the dispatch — the
        engine's prefill-volume telemetry (what prefix-cache hits
        shrink)."""
        consumed = 0
        now = self.clock()
        for slot in range(self.num_slots):
            req = self.slot_req[slot]
            if req is not None and not self._slot_riding[slot]:
                left = self._prefill_left[slot]
                consumed += min(left, self.prefill_chunk)
                self._prefill_left[slot] = max(left - self.prefill_chunk, 0)
                if (
                    left > 0
                    and self._prefill_left[slot] == 0
                    and req.first_token_t is None
                ):
                    req.ready_t = now
        return consumed

    def prefill_left(self, slot: int) -> int:
        """Prompt tokens slot ``slot`` has not yet consumed — 0 once
        decodable. The engine uses it at preemption time to bound the
        cacheable committed-KV prefix of a still-prefilling victim."""
        return self._prefill_left[slot]

    def ready_slots(self) -> dict[int, RequestState]:
        """Live slots whose prefill has fully dispatched (decodable)."""
        return {
            slot: req
            for slot, req in enumerate(self.slot_req)
            if req is not None and self._prefill_left[slot] == 0
        }

    # -- async staging lane ------------------------------------------------

    def stage_admit(self) -> list[tuple[int, RequestState]]:
        """Fill free *staging* slots from the queue (FIFO or cache-aware
        like :meth:`admit`, same budget stall rule — a staging slot
        reserves its eventual decode worst case up front, which is what
        makes adoption infallible). Returns the new (sid, request)
        pairs; the engine stages them on device."""
        staged = []
        now = self.clock()
        # Disaggregated: staging admission goes by the PREFILL pod's
        # budget (fully provisioned per lane, so it never stalls); the
        # decode pool is charged later, at adoption.
        sb = self.stage_budget if self.stage_budget is not None else self.budget
        for sid in range(self.num_stage_slots):
            if self.stage_req[sid] is None and self.queue:
                # Failed-over requests never restage: the ladder routes
                # them through the decode-lane admit (serial semantics).
                idx = self._select_index(lambda r: not r.no_stage)
                if idx is None:
                    break
                plen = len(self.queue[idx].serve_prompt())
                if sb is not None and not sb.can_admit(plen):
                    break
                req = self._pop_at(idx, now)
                self.stage_req[sid] = req
                self._stage_left[sid] = max(plen - 1, 0)
                if sb is not None:
                    sb.note_stage(sid, plen)
                self._stage_check_ready(sid)
                staged.append((sid, req))
        return staged

    def note_stage_claim(self, sid: int, prefix_len: int) -> None:
        """Prefix-cache hit for a just-staged slot (the async twin of
        :meth:`note_prefix_claim`)."""
        self._stage_left[sid] = max(self._stage_left[sid] - prefix_len, 0)
        self._stage_check_ready(sid)

    def _stage_check_ready(self, sid: int) -> None:
        if self._stage_left[sid] == 0 and sid not in self.ready_q:
            self.ready_q.append(sid)
            req = self.stage_req[sid]
            if req is not None and req.first_token_t is None:
                req.ready_t = self.clock()

    def set_stage_riding(self, sid: int, riding: bool) -> None:
        """Staging twin of :meth:`set_slot_riding`."""
        self._stage_riding[sid] = riding

    def stage_riding(self, sid: int) -> bool:
        return self._stage_riding[sid]

    def stage_pending(self) -> bool:
        """Any (non-riding) staging slot still owing prefill chunks?"""
        return any(
            left > 0
            and self.stage_req[sid] is not None
            and not self._stage_riding[sid]
            for sid, left in enumerate(self._stage_left)
        )

    def note_stage_prefill_dispatch(self) -> int:
        """Account one dispatched background-prefill chunk (the async
        twin of :meth:`note_prefill_dispatch`): every non-riding staging
        slot advanced by ``min(chunk, remaining)``; slots reaching zero
        join the ready queue in sid order. Returns the prompt tokens the
        dispatch consumed."""
        consumed = 0
        for sid in range(self.num_stage_slots):
            if self.stage_req[sid] is not None and not self._stage_riding[sid]:
                left = self._stage_left[sid]
                consumed += min(left, self.prefill_chunk)
                self._stage_left[sid] = max(left - self.prefill_chunk, 0)
                self._stage_check_ready(sid)
        return consumed

    def adopt(self, gate=None) -> list[tuple[int, int, RequestState]]:
        """Move completed background prefills into free decode slots
        (ready-queue order — stage-completion FIFO). Shared-pool async:
        the page budget's reservation transfers key-for-key
        (``note_adopt``), so adoption never fails and never changes
        ``used_worst()``. Disaggregated (``stage_budget`` installed):
        adoption is a cross-pool move — the decode pool is charged its
        worst case here (``note_admit``, gated by ``can_admit``: the
        decode side holds no reservation for staged rows) and the
        prefill pool released (``note_unstage``); both stalls
        head-block, preserving ready-queue FIFO. ``gate(sid) -> bool``
        (the engine's transfer-arrival check) also head-blocks: a lane
        whose staged pages are still in flight must not map into a
        decode slot. Returns (sid, slot, request) triples; the engine
        performs the device-side adoption (staged table install +
        ``staged``-mark clear — or, disaggregated, the packed-page
        unpack — plus ``admit_slot`` with the full prompt already
        consumed)."""
        adopted = []
        free = [s for s, r in enumerate(self.slot_req) if r is None]
        now = None
        while self.ready_q and free:
            sid = self.ready_q[0]
            if gate is not None and not gate(sid):
                break
            req = self.stage_req[sid]
            assert req is not None and self._stage_left[sid] == 0, sid
            if (
                self.stage_budget is not None
                and self.budget is not None
                and not self.budget.can_admit(len(req.serve_prompt()))
            ):
                break
            self.ready_q.popleft()
            slot = free.pop(0)
            self.stage_req[sid] = None
            self.slot_req[slot] = req
            self._prefill_left[slot] = 0
            # A ride that completed exactly at the prompt frontier can
            # leave the row ready while still flagged; the flag moves
            # with the request (the engine re-keys the ride itself).
            self._slot_riding[slot] = self._stage_riding[sid]
            self._stage_riding[sid] = False
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            if req.first_token_t is None:
                if now is None:
                    now = self.clock()
                req.adopt_t = now
            if self.stage_budget is not None:
                if self.budget is not None:
                    self.budget.note_admit(slot, len(req.serve_prompt()))
                self.stage_budget.note_unstage(sid)
            elif self.budget is not None:
                self.budget.note_adopt(sid, slot)
            adopted.append((sid, slot, req))
        return adopted

    def pick_stage_victim(self) -> int | None:
        """Staging slot to kill under page pressure: most recently
        staged first (LIFO by ``admit_seq``, like decode preemption) —
        background prefills carry the least progress, so they die
        before any decoding slot is preempted. Class-aware: the lowest
        class (highest ``priority`` value) dies first, LIFO within a
        class, so premium stages outlive best-effort ones."""
        live = [
            (req.priority, req.admit_seq, sid)
            for sid, req in enumerate(self.stage_req)
            if req is not None
        ]
        if not live:
            return None
        return max(live)[2]

    def kill_stage(self, sid: int) -> RequestState:
        """Kill a background prefill: requeue its request at the FRONT
        (its committed progress is just the prompt — and, with the
        prefix cache on, the engine parks its fully-written pages, so
        the retry usually re-claims them)."""
        req = self.stage_req[sid]
        assert req is not None, sid
        self.stage_req[sid] = None
        self._stage_left[sid] = 0
        self._stage_riding[sid] = False
        if sid in self.ready_q:
            self.ready_q.remove(sid)
        sb = self.stage_budget if self.stage_budget is not None else self.budget
        if sb is not None:
            sb.note_unstage(sid)
        self._requeue_victim(req)
        return req

    def _requeue_victim(self, req: RequestState) -> None:
        """Shared preemption bookkeeping for BOTH lanes: count the
        preemption, stamp the requeue-wait anchor unconditionally —
        :meth:`_pop_at` routes the coming wait to ``requeue_wait_s``
        for victims that have already emitted (kept out of their decode
        ``tokens_per_s`` — the PR 4 metrics rule) and to
        ``pre_first_requeue_wait_s`` for pre-first-token victims (a
        killed staging attempt, a still-prefilling preemption) so
        ``ttft_queue_s`` doesn't absorb kill→re-stage dead time — and
        requeue at the FRONT so progress-holding requests resume
        first. ``age`` resets: aging measures time spent *queued and
        overtaken*, and a victim re-enters the queue fresh — stale age
        from before its admission would let it claim the aged fast-path
        over genuinely starved requests (and, pre-fix, made victim
        resume order depend on how starved the victim once was rather
        than on its front-of-queue position)."""
        req.preemptions += 1
        req.age = 0
        req._preempt_t = self.clock()
        self.queue.appendleft(req)

    def stage_prefill_left(self, sid: int) -> int:
        """Prompt tokens staging slot ``sid`` has not yet consumed."""
        return self._stage_left[sid]

    # -- retirement --------------------------------------------------------

    def retire(self, slot: int, reason: str) -> RequestState:
        req = self.slot_req[slot]
        assert req is not None, slot
        req.finished = True
        req.finish_t = self.clock()
        req.finish_reason = reason
        self.done[req.rid] = req
        self.slot_req[slot] = None
        self._prefill_left[slot] = 0
        self._slot_riding[slot] = False
        if self.budget is not None:
            self.budget.note_release(slot)
        return req

    # -- preemption (paged engines) ----------------------------------------

    def needs_preemption(self) -> bool:
        return (
            self.budget is not None and self.budget.needs_preemption()
        ) or self.stage_budget_over()

    def stage_budget_over(self) -> bool:
        """Disaggregated prefill-pod pool over budget? (Never fires when
        the stage pool is fully provisioned — ``stage_slots *
        max_pages`` covers every lane's clamped worst case — but the
        engine's kill-stage-first preemption rule keys off it so an
        under-provisioned prefill pod still degrades gracefully.)"""
        return (
            self.stage_budget is not None
            and self.stage_budget.needs_preemption()
        )

    def pick_victim(self) -> int | None:
        """Slot to preempt when the pool runs dry: the most recently
        admitted live slot (LIFO — protects the oldest requests' progress
        and matches the resume queue's front-insertion order), decided by
        the monotonic ``admit_seq`` — NOT ``admit_t``, whose one-clock-
        reading-per-``admit()`` ties made "most recent" collapse to
        "highest slot index". Never offers the last live slot: a lone
        slot always fits the pool (``num_pages >= max_pages`` is
        asserted at spec construction). Class-aware: among live slots
        the lowest class (highest ``priority`` value) is preempted
        first, LIFO within a class — best-effort work yields memory
        back before any premium request loses progress."""
        live = [
            (req.priority, req.admit_seq, slot)
            for slot, req in enumerate(self.slot_req)
            if req is not None
        ]
        if len(live) <= 1:
            return None
        return max(live)[2]

    def preempt(self, slot: int) -> RequestState:
        """Evict a live request: free its slot and requeue it at the
        FRONT with its progress intact. Readmission re-prefills
        ``prompt + output`` (recompute-on-resume)."""
        req = self.slot_req[slot]
        assert req is not None, slot
        self.slot_req[slot] = None
        self._prefill_left[slot] = 0
        self._slot_riding[slot] = False
        if self.budget is not None:
            self.budget.note_release(slot)
        self._requeue_victim(req)
        return req

    def has_work(self) -> bool:
        return (
            bool(self.queue)
            or any(r is not None for r in self.slot_req)
            or any(r is not None for r in self.stage_req)
        )

    # -- metrics -----------------------------------------------------------

    def request_metrics(self, gamma: int) -> list[dict]:
        out = []
        for req in sorted(self.done.values(), key=lambda r: r.rid):
            out.append(
                {
                    "rid": req.rid,
                    "priority": req.priority,
                    "tenant": req.tenant,
                    "prompt_len": len(req.prompt),
                    "output_len": len(req.output),
                    "iterations": req.iterations,
                    "ttft_s": req.ttft_s,
                    "ttft_queue_s": req.ttft_queue_s,
                    "ttft_prefill_s": req.ttft_prefill_s,
                    "ttft_transfer_s": req.ttft_transfer_s,
                    "ttft_decode_s": req.ttft_decode_s,
                    "tokens_per_s": req.tokens_per_s,
                    "e2e_tokens_per_s": req.e2e_tokens_per_s,
                    "preemptions": req.preemptions,
                    "acceptance_rate": req.acceptance_rate(gamma),
                    "block_efficiency": (
                        (req.accepted_total + req.iterations) / req.iterations
                        if req.iterations else 0.0
                    ),
                    "finish_reason": req.finish_reason,
                }
            )
        return out
