"""Host-side request lifecycle for the serving engine.

The :class:`Scheduler` owns everything that is *about requests* rather
than about tensors: the FIFO admission queue, the slot→request mapping,
retirement, preemption, and per-request metrics (TTFT, tokens/s,
acceptance rate). It holds a host mirror of the device-resident prefill
progress — chunk counts are deterministic, so the mirror needs no device
sync: after each dispatched prefill step every prefilling slot has
consumed exactly ``min(chunk, remaining)`` more prompt tokens.

Paged engines hand the scheduler a :class:`repro.serving.paging.PageBudget`
— admission then goes by *free-page budget* instead of blind slot-fill:
a queued request is admitted only when the pool can cover every live
slot's conservative worst case plus the newcomer's. For multi-path
engines that worst case is **post-fork**: it includes the K forked path
tables' copy-on-write and speculative transient, so the in-program
fork/cow allocators can never run the pool dry. When decoding grows
live slots past the budget (over-subscribed pools), the engine preempts
the most recently admitted slot: its pages are freed and the request
requeues at the *front* with ``prompt + output`` as its resume prompt —
recompute-on-resume, the classic trade of a little prefill compute for
not reserving worst-case memory. With the cross-request prefix cache
enabled, the engine parks a victim's committed full pages in the
``cached`` state instead of freeing them, so resume usually re-*claims*
its own prefix rather than re-prefilling it (the engine reports the
claim via :meth:`Scheduler.note_prefix_claim`, which shrinks the
prefill mirror).

It never touches device arrays; the engine translates admissions and
retirements into :mod:`repro.serving.batch` updates.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

from repro.serving.paging import PageBudget


@dataclass
class RequestState:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    output: list[int] = field(default_factory=list)
    iterations: int = 0
    accepted_total: int = 0
    # lifecycle timestamps (engine clock; None until reached)
    submit_t: float = 0.0
    admit_t: float | None = None
    # Monotonic admission sequence number (bumped at every (re)admission).
    # Preemption picks its LIFO victim by this, NOT by admit_t: all
    # requests admitted in one admit() call share the same clock reading,
    # so a timestamp tie-break silently degrades to "highest slot index".
    admit_seq: int = -1
    first_token_t: float | None = None
    finish_t: float | None = None
    finish_reason: str | None = None
    finished: bool = False
    preemptions: int = 0
    # Time spent requeued between a preemption and the matching
    # readmission AFTER the first token was emitted — excluded from
    # decode throughput (pre-first-token waits are already outside the
    # first_token_t -> finish_t window).
    requeue_wait_s: float = 0.0
    _preempt_t: float | None = None

    def serve_prompt(self) -> list[int]:
        """Tokens to prefill at (re)admission: the original prompt plus
        everything already generated (recompute-on-resume)."""
        return self.prompt + self.output

    def serve_max_new(self) -> int:
        """Remaining new-token budget at (re)admission."""
        return self.max_new_tokens - len(self.output)

    @property
    def ttft_s(self) -> float | None:
        """Time to first token, including queue wait."""
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def tokens_per_s(self) -> float | None:
        """Decode throughput: output tokens over the time the request was
        actually generating — first token to finish, minus any
        post-first-token preemption requeue waits
        (:attr:`requeue_wait_s`). Queue wait and requeue time belong to
        :attr:`e2e_tokens_per_s`; folding them in here deflated
        per-request decode throughput under load."""
        if (
            self.finish_t is None
            or self.first_token_t is None
            or not self.output
        ):
            return None
        dur = self.finish_t - self.first_token_t - self.requeue_wait_s
        return len(self.output) / dur if dur > 0 else None

    @property
    def e2e_tokens_per_s(self) -> float | None:
        """End-to-end throughput including queue wait and requeue time."""
        if self.finish_t is None or not self.output:
            return None
        dur = self.finish_t - self.submit_t
        return len(self.output) / dur if dur > 0 else None

    def acceptance_rate(self, gamma: int) -> float:
        """Fraction of drafted tokens accepted (block efficiency - 1 is a
        related but distinct quantity: BE counts the bonus token)."""
        drafted = self.iterations * gamma
        return self.accepted_total / drafted if drafted else 0.0


class Scheduler:
    """FIFO queue + slot bookkeeping + per-request metrics."""

    def __init__(
        self,
        num_slots: int,
        default_max_new: int,
        prefill_chunk: int,
        clock=time.perf_counter,
        budget: PageBudget | None = None,
    ):
        self.num_slots = num_slots
        self.default_max_new = default_max_new
        self.prefill_chunk = prefill_chunk
        self.clock = clock
        self.budget = budget
        self.queue: deque[RequestState] = deque()
        self.slot_req: list[RequestState | None] = [None] * num_slots
        self._prefill_left = [0] * num_slots
        self.done: dict[int, RequestState] = {}
        self._next_rid = 0
        self._admit_seq = 0

    # -- submission / admission --------------------------------------------

    def submit(
        self, prompt_ids: list[int], max_new_tokens: int | None = None
    ) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(
            RequestState(
                rid=rid,
                prompt=list(prompt_ids),
                max_new_tokens=(
                    self.default_max_new
                    if max_new_tokens is None else max_new_tokens
                ),
                submit_t=self.clock(),
            )
        )
        return rid

    def admit(self) -> list[tuple[int, RequestState]]:
        """Fill free slots from the queue (FIFO). With a page budget,
        admission stops at the first request the pool cannot cover
        (head-of-line order is preserved — no unfair overtaking by short
        prompts). Returns the new (slot, request) pairs; the engine
        stages them on device."""
        admitted = []
        now = self.clock()
        for slot in range(self.num_slots):
            if self.slot_req[slot] is None and self.queue:
                plen = len(self.queue[0].serve_prompt())
                if self.budget is not None and not self.budget.can_admit(plen):
                    break
                req = self.queue.popleft()
                req.admit_t = now
                if req._preempt_t is not None:  # resuming after preemption
                    req.requeue_wait_s += now - req._preempt_t
                    req._preempt_t = None
                req.admit_seq = self._admit_seq
                self._admit_seq += 1
                self.slot_req[slot] = req
                # Both models must consume plen - 1 prompt tokens.
                self._prefill_left[slot] = max(plen - 1, 0)
                if self.budget is not None:
                    self.budget.note_admit(slot, plen)
                admitted.append((slot, req))
        return admitted

    def note_prefix_claim(self, slot: int, prefix_len: int) -> None:
        """Account a prefix-cache hit for a just-admitted slot: the first
        ``prefix_len`` prompt tokens were claimed from cached pages, so
        chunked prefill only has to consume the remainder."""
        self._prefill_left[slot] = max(
            self._prefill_left[slot] - prefix_len, 0
        )

    # -- prefill mirror ----------------------------------------------------

    def prefill_pending(self) -> bool:
        return any(
            left > 0 and self.slot_req[slot] is not None
            for slot, left in enumerate(self._prefill_left)
        )

    def note_prefill_dispatch(self) -> int:
        """Account one dispatched chunked-prefill step: every prefilling
        slot advanced by ``min(chunk, remaining)`` tokens. Returns the
        total prompt tokens consumed by the dispatch — the engine's
        prefill-volume telemetry (what prefix-cache hits shrink)."""
        consumed = 0
        for slot in range(self.num_slots):
            if self.slot_req[slot] is not None:
                left = self._prefill_left[slot]
                consumed += min(left, self.prefill_chunk)
                self._prefill_left[slot] = max(left - self.prefill_chunk, 0)
        return consumed

    def prefill_left(self, slot: int) -> int:
        """Prompt tokens slot ``slot`` has not yet consumed — 0 once
        decodable. The engine uses it at preemption time to bound the
        cacheable committed-KV prefix of a still-prefilling victim."""
        return self._prefill_left[slot]

    def ready_slots(self) -> dict[int, RequestState]:
        """Live slots whose prefill has fully dispatched (decodable)."""
        return {
            slot: req
            for slot, req in enumerate(self.slot_req)
            if req is not None and self._prefill_left[slot] == 0
        }

    # -- retirement --------------------------------------------------------

    def retire(self, slot: int, reason: str) -> RequestState:
        req = self.slot_req[slot]
        assert req is not None, slot
        req.finished = True
        req.finish_t = self.clock()
        req.finish_reason = reason
        self.done[req.rid] = req
        self.slot_req[slot] = None
        self._prefill_left[slot] = 0
        if self.budget is not None:
            self.budget.note_release(slot)
        return req

    # -- preemption (paged engines) ----------------------------------------

    def needs_preemption(self) -> bool:
        return self.budget is not None and self.budget.needs_preemption()

    def pick_victim(self) -> int | None:
        """Slot to preempt when the pool runs dry: the most recently
        admitted live slot (LIFO — protects the oldest requests' progress
        and matches the resume queue's front-insertion order), decided by
        the monotonic ``admit_seq`` — NOT ``admit_t``, whose one-clock-
        reading-per-``admit()`` ties made "most recent" collapse to
        "highest slot index". Never offers the last live slot: a lone
        slot always fits the pool (``num_pages >= max_pages`` is
        asserted at spec construction)."""
        live = [
            (req.admit_seq, slot)
            for slot, req in enumerate(self.slot_req)
            if req is not None
        ]
        if len(live) <= 1:
            return None
        return max(live)[1]

    def preempt(self, slot: int) -> RequestState:
        """Evict a live request: free its slot and requeue it at the
        FRONT with its progress intact. Readmission re-prefills
        ``prompt + output`` (recompute-on-resume)."""
        req = self.slot_req[slot]
        assert req is not None, slot
        req.preemptions += 1
        if req.first_token_t is not None:
            # Mid-decode victim: the coming requeue wait must not count
            # against its decode throughput.
            req._preempt_t = self.clock()
        self.slot_req[slot] = None
        self._prefill_left[slot] = 0
        if self.budget is not None:
            self.budget.note_release(slot)
        self.queue.appendleft(req)
        return req

    def has_work(self) -> bool:
        return bool(self.queue) or any(
            r is not None for r in self.slot_req
        )

    # -- metrics -----------------------------------------------------------

    def request_metrics(self, gamma: int) -> list[dict]:
        out = []
        for req in sorted(self.done.values(), key=lambda r: r.rid):
            out.append(
                {
                    "rid": req.rid,
                    "prompt_len": len(req.prompt),
                    "output_len": len(req.output),
                    "iterations": req.iterations,
                    "ttft_s": req.ttft_s,
                    "tokens_per_s": req.tokens_per_s,
                    "e2e_tokens_per_s": req.e2e_tokens_per_s,
                    "preemptions": req.preemptions,
                    "acceptance_rate": req.acceptance_rate(gamma),
                    "block_efficiency": (
                        (req.accepted_total + req.iterations) / req.iterations
                        if req.iterations else 0.0
                    ),
                    "finish_reason": req.finish_reason,
                }
            )
        return out
