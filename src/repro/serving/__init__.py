"""Serving: scheduler → batch-state → runner → verification → kernels.

Public surface: :class:`SpecEngine` (facade preserving ``submit()`` /
``run()``), its :class:`EngineConfig`, and the layer classes for callers
that compose them directly (the launch dry-run uses the runner bodies)."""

from repro.serving.batch import BatchState, init_batch  # noqa: F401
from repro.serving.engine import EngineConfig, SpecEngine  # noqa: F401
from repro.serving.runner import Runner, StepOutputs  # noqa: F401
from repro.serving.scheduler import RequestState, Scheduler  # noqa: F401
