"""Serving: frontend → scheduler → batch-state → runner → verification
→ kernels.

Public surface: :class:`SpecEngine` (facade preserving ``submit()`` /
``run()``, plus ``serve()`` with continuous-batching hooks),
:class:`ServingFrontend` (the open-stream start/submit/stream/drain
front end over one engine), :class:`EngineConfig`, and the layer
classes for callers that compose them directly (the launch dry-run uses
the runner bodies)."""

from repro.serving.batch import (  # noqa: F401
    BatchState, committed_frontier, init_batch,
)
from repro.serving.engine import EngineConfig, SpecEngine  # noqa: F401
from repro.serving.frontend import (  # noqa: F401
    RequestHandle, ServingFrontend, StreamDelta, replay_open_loop,
)
from repro.serving.runner import Runner, StepOutputs  # noqa: F401
from repro.serving.scheduler import RequestState, Scheduler  # noqa: F401
