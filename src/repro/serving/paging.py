"""Paged KV cache: page-pool allocator with a device-resident free list
and copy-on-write page sharing.

Instead of reserving a dense ``(max_len, n_kv, hd)`` ring per slot up
front, global-attention layers write K/V into a **global page pool**
shared by all slots; each slot owns a small **page table** mapping its
logical pages (position // page_size) to physical pool pages. Concurrency
is then bounded by *actual* token usage, not worst-case length — the
defining property of a production serving engine (vLLM-style
PagedAttention), and the substrate for copy-on-write prefix sharing
across multi-path draft candidates (see PAPERS.md).

Pieces:

* :class:`PageSpec` — static geometry (page size, pool size, per-slot
  table length). Derived from the engine config via :func:`spec_of`.
* :class:`PagePool` + :func:`ensure` / :func:`release` — the device-side
  allocator. ``free_stack[:free_count]`` holds the free physical page
  ids and ``ref`` the per-page reference counts; ``ensure`` pops pages
  (all-or-nothing per slot, slot-index order, so allocation is
  deterministic) to cover a target length, ``release`` drops a row's
  claims and pushes pages whose refcount reaches zero back onto the
  stack. Rows may alias each other's pages (forked path tables) —
  duplicate references decrement once each. All allocator ops are pure
  jittable functions over ``(page_table, pages_used, pool)`` and run
  *inside* the runner's fixed-shape programs — allocation never syncs
  the host.
* :func:`fork` / :func:`cow_ensure` — copy-on-write sharing: ``fork``
  aliases a slot's table into K path tables (converting its one claim
  per page into K claims), ``cow_ensure`` prepares a path table for
  writes — growing fresh pages for the unmapped tail and remapping any
  *shared* page in the write window to a private copy (the caller
  applies the returned ``src -> dst`` pool copies before writing). A
  path writing through its table therefore never perturbs a sibling's
  view of the shared prefix.
* :class:`PageBudget` — the host-side conservative mirror the scheduler
  admits/preempts by. The device allocates from exact lengths; the host
  only sees lengths one double-buffered step late, so it budgets with
  ``worst_pages(len + 2 * (gamma + 1))`` per slot — an upper bound on
  what the device can allocate before the next budget check — plus, for
  multi-path engines, the worst-case post-fork transient of
  ``num_paths`` path tables' CoW copies and speculative pages. As long
  as ``sum(worst) <= num_pages`` before every dispatch, the device-side
  allocators can never fail and slots never stall.
* :class:`PrefixCache` + :func:`host_claim_prefix` / :func:`host_evict`
  — **cross-request prefix caching**. Pages released with a cache mask
  enter a ``cached`` state (refcount 0 but *off* the free stack, content
  preserved) and are registered in a host-side radix index keyed by
  page-aligned committed token spans. When a new request is admitted,
  the longest matching page-aligned prefix of its prompt is *claimed*
  (refcount bump, table installed) instead of re-prefilled, and chunked
  prefill starts at the first uncached position. Cached pages are
  evicted LRU — removed from the index and pushed back onto the free
  stack — only when the budget says the next dispatch could otherwise
  run the free stack dry (:meth:`PageBudget.evict_deficit`).
* **live prefix sharing** (:meth:`PrefixCache.register_live` +
  :func:`host_claim_live`) — the same radix index additionally mirrors
  the committed spans of **live** rows (decode slots and staging
  lanes), registered at prefill-chunk granularity as the engine's
  mirrors advance (insert-as-you-commit). A live node carries its
  owner key instead of a parked page id; its physical id is resolved
  lazily — one read of the owner's page table at claim time — and a
  claimant *pins* the page where it sits (:func:`host_claim_live`:
  refcount bump on an in-use page, free count untouched). Pinned live
  pages are never in ``by_page``, hence structurally non-reclaimable;
  when the owner releases, its live nodes convert in place to cached
  nodes (``insert`` with the owner key), so claimants ride the
  transition without ever observing a freed page. Claims obey the
  same claimer-never-writes page-alignment cap as cached hits, and an
  owner only ever writes at positions at or past its committed
  frontier, so a shared live page is read-only for every party by
  construction — live hits stay bit-identical.
* the **staging lane** (``EngineConfig(async_prefill=True)``) — pages
  popped by the background prefill program carry a ``staged`` mark:
  they are referenced (ref 1, held by a *staging-lane* table, not a
  decode slot's), hold partially-written prompt K/V, and are invisible
  to decode — no decode slot's page table maps them until the prompt's
  final chunk lands and the engine *adopts* the staging table into a
  decode slot (:func:`host_adopt_stage`: table install + ``staged``
  clear — a mask flip, never a pool copy). Staged pages are counted in
  :class:`PageBudget` (``note_stage``) at the slot's eventual decode
  worst case, so adoption provably never needs pages the pool cannot
  supply.

Page lifecycle (each physical page):

    free ──ensure──▶ referenced ──release(cache)──▶ cached ──host_evict──▶ free
    (on stack,        (ref ≥ 1)      ▲    (ref 0, off stack,   (back on stack)
     ref 0)           ▲  │  ▲        └────claim── content kept)
      │               │  │  └─host_claim_live── pinned (ref ≥ 2: owner +
      │               │  ▼                      claimants; owner's release
      │               │ (referenced ⇄ pinned)   leaves it referenced or
      │               │                         cached, never free)
      │               │ host_adopt_stage (ready flip: staged → decode-
      │               │ visible, same physical page, zero copies)
      └─ensure(staged)─▶ staging ──release──▶ free | cached
         (ref 1, held by a prefilling request, invisible to decode;
          a killed background prefill parks its fully-written pages
          as ``cached`` — they are already indexable prompt K/V)

The allocator is exercised by both models' caches with a *single* page
table: target and drafter pools are indexed by the same physical page
ids (their per-page byte sizes differ; the id space is shared) — so a
claimed prefix restores BOTH models' committed K/V at once.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PagePool(NamedTuple):
    """Device free-list: ``free_stack[:free_count]`` are free page ids;
    ``ref[p]`` counts the table entries (across slots and forked path
    tables) referencing physical page ``p`` — 0 for free pages.
    ``cached[p]`` marks pages held by the cross-request prefix index:
    a cached page whose refcount reaches 0 stays OFF the free stack
    (its K/V content must survive for future claims) until the host
    evicts it (:func:`host_evict`). The stack and the cached set are
    always disjoint. ``staged[p]`` marks pages held by the async
    staging lane — referenced by a *prefilling* request's staging
    table, invisible to every decode slot until adoption clears the
    mark (:func:`host_adopt_stage`); staged pages are never free,
    never cached, and never mapped by a decode slot's table."""

    free_stack: jax.Array  # (num_pages,) int32
    free_count: jax.Array  # () int32
    ref: jax.Array         # (num_pages,) int32
    cached: jax.Array      # (num_pages,) bool
    staged: jax.Array      # (num_pages,) bool

    def audit(self, spec, **kw):
        """Reconcile this pool against the host's ground truth — see
        :func:`audit_pool` (the engine runs it at quiesce and after
        every kill/cancel, counting repairs into
        ``stats["audit_repairs"]``)."""
        return audit_pool(spec, self, **kw)


@dataclass(frozen=True)
class PageSpec:
    """Static pool geometry (baked into the compiled programs)."""

    page_size: int   # tokens per page
    num_pages: int   # physical pages in the pool
    max_pages: int   # page-table length == pages covering max_len + slack

    def pages_for(self, length: int) -> int:
        """Host-side: pages needed to cover ``length`` tokens."""
        return min(-(-length // self.page_size), self.max_pages)


def chunk_slack_of(cfg) -> int:
    """Longest in-flight chunk either runner body writes past a committed
    length (mirrors ``Runner.chunk_slack``)."""
    return max(cfg.gamma + 1, cfg.prefill_chunk)


def path_transient_pages(spec: PageSpec, gamma: int) -> int:
    """Upper bound on the fresh pages ONE forked path can hold mid-step:
    its write window [lens - 1, lens + gamma] spans at most
    ``pages_for(gamma + 2) + 1`` pages, each either a CoW copy of a
    shared page or a newly grown speculative page."""
    return spec.pages_for(gamma + 2) + 1


def spec_of(cfg) -> PageSpec | None:
    """Derive the *decode* pool geometry from an engine config.
    ``num_pages=None`` fully provisions the pool: ``max_slots *
    max_pages`` plus the forked paths' transient for multi-path engines,
    plus — for shared-pool async-prefill engines — one more worst-case
    slot term per *staging* lane (each staged request reserves its
    eventual decode worst case in the budget, and
    ``PageBudget.worst_pages`` never exceeds ``max_pages +
    fork_extra``). No over-subscription: admission never blocks,
    preemption never fires, and the staging lane is never starved while
    decode slots sit at their worst case.

    Under ``disaggregated=True`` the staging lanes write a SEPARATE pool
    on the prefill pod (:func:`stage_spec_of`), so the decode pool drops
    the staging term: an adoption transfers the staged pages' K/V into
    decode pages freshly allocated out of THIS pool, and the scheduler
    charges the decode budget (``note_admit``) before the transfer's
    unpack program dispatches — the ``max_slots`` worst-case terms alone
    keep that allocation provably never-fail."""
    if not getattr(cfg, "paged", False):
        return None
    ps = cfg.page_size
    max_pages = -(-(cfg.max_len + chunk_slack_of(cfg)) // ps)
    num_paths = getattr(cfg, "num_paths", 1)
    spec = PageSpec(page_size=ps, num_pages=0, max_pages=max_pages)
    fork_extra = (
        num_paths * path_transient_pages(spec, cfg.gamma)
        if num_paths > 1 else 0
    )
    stage_lanes = (
        getattr(cfg, "stage_slots", 0)
        if getattr(cfg, "async_prefill", False)
        and not getattr(cfg, "disaggregated", False) else 0
    )
    num_pages = cfg.num_pages
    if num_pages is None:
        num_pages = (cfg.max_slots + stage_lanes) * (max_pages + fork_extra)
    assert num_pages >= max_pages + fork_extra, (
        f"pool of {num_pages} pages cannot hold one full-length slot "
        f"({max_pages} pages + {fork_extra} fork transient); raise "
        f"num_pages or shrink max_len"
    )
    return PageSpec(page_size=ps, num_pages=num_pages, max_pages=max_pages)


def stage_spec_of(cfg) -> PageSpec | None:
    """Geometry of the *staging* pool the prefill pod owns under
    ``disaggregated=True``. Shared-pool engines (``async_prefill=True``
    alone) return :func:`spec_of` — staging lanes allocate out of the
    decode pool and adoption is a mask flip, so there is only one
    geometry. Disaggregated engines get a second, physically separate
    pool sized ``stage_slots * max_pages``: page size and ``max_pages``
    match the decode spec exactly (tables and the prefill program are
    geometry-compatible across pods; only the pool page-id spaces
    differ), and one worst-case term per lane makes staging-lane
    allocation never-fail by the same clamping argument —
    ``pages_for(length) <= max_pages`` for any length the admission
    gate accepts."""
    if not getattr(cfg, "paged", False):
        return None
    if not getattr(cfg, "async_prefill", False):
        return None
    if not getattr(cfg, "disaggregated", False):
        return spec_of(cfg)
    base = spec_of(cfg)
    stage_lanes = max(1, getattr(cfg, "stage_slots", 1))
    return PageSpec(
        page_size=base.page_size,
        num_pages=stage_lanes * base.max_pages,
        max_pages=base.max_pages,
    )


def init_pool(spec: PageSpec) -> PagePool:
    return PagePool(
        free_stack=jnp.arange(spec.num_pages, dtype=jnp.int32),
        free_count=jnp.asarray(spec.num_pages, jnp.int32),
        ref=jnp.zeros((spec.num_pages,), jnp.int32),
        cached=jnp.zeros((spec.num_pages,), bool),
        staged=jnp.zeros((spec.num_pages,), bool),
    )


def init_tables(spec: PageSpec, num_slots: int):
    """Empty per-slot page tables: (page_table, pages_used)."""
    return (
        jnp.full((num_slots, spec.max_pages), -1, jnp.int32),
        jnp.zeros((num_slots,), jnp.int32),
    )


def ensure(
    spec: PageSpec,
    page_table: jax.Array,  # (B, max_pages) int32, -1 = unmapped
    pages_used: jax.Array,  # (B,) int32
    pool: PagePool,
    need_len: jax.Array,    # (B,) int32 — cover positions [0, need_len)
    mask: jax.Array,        # (B,) bool — slots requesting coverage
    *,
    mark_staged: bool = False,
):
    """Grow each masked slot's page table to cover ``need_len`` tokens.

    Pops pages off the free stack in slot-index order, all-or-nothing per
    slot. Returns ``(page_table, pages_used, pool, ok)`` where ``ok[b]``
    is False iff slot ``b`` asked for pages the pool could not supply
    (the caller must then exclude the slot from the step — the host
    budget guarantees this never happens in the serving engine).
    ``mark_staged=True`` (the background prefill program) additionally
    stamps every granted page ``staged``: referenced by a staging-lane
    table, invisible to decode until adoption clears the mark."""
    ps = spec.page_size
    need = jnp.clip((need_len + ps - 1) // ps, 0, spec.max_pages)
    need = jnp.where(mask, need, pages_used)
    deficit = jnp.maximum(need - pages_used, 0)
    cum_excl = jnp.cumsum(deficit) - deficit
    ok = cum_excl + deficit <= pool.free_count
    granted = jnp.where(ok, deficit, 0)
    goff = jnp.cumsum(granted) - granted

    jj = jnp.arange(spec.max_pages)[None]           # (1, MAXP)
    take = jj < granted[:, None]                    # (B, MAXP)
    src = pool.free_count - 1 - (goff[:, None] + jj)
    ids = pool.free_stack[jnp.clip(src, 0, spec.num_pages - 1)]
    b_idx = jnp.broadcast_to(
        jnp.arange(take.shape[0])[:, None], take.shape
    )
    dst_col = jnp.where(take, pages_used[:, None] + jj, spec.max_pages)
    page_table = page_table.at[b_idx, dst_col].set(
        jnp.where(take, ids, -1), mode="drop"
    )
    pages_used = pages_used + granted
    granted_ids = jnp.where(take, ids, spec.num_pages)
    ref = pool.ref.at[granted_ids].set(1, mode="drop")
    staged = pool.staged
    if mark_staged:
        staged = staged.at[granted_ids].set(True, mode="drop")
    pool = PagePool(
        pool.free_stack, pool.free_count - jnp.sum(granted), ref,
        pool.cached, staged,
    )
    return page_table, pages_used, pool, ok


def release(
    spec: PageSpec,
    page_table: jax.Array,  # (N, max_pages) — slot tables OR path tables
    pages_used: jax.Array,  # (N,)
    pool: PagePool,
    mask: jax.Array,  # (N,) bool — rows to free
    cache_cols: jax.Array | None = None,  # (N, max_pages) bool — to cache
):
    """Drop every masked row's page claims and clear its table.

    Refcount-aware: each mapped entry decrements its physical page's
    refcount (rows may alias each other's pages — forked path tables;
    duplicates decrement once each) and only pages reaching refcount 0
    are pushed back onto the free stack (in page-id order). Pages that
    are ``cached`` (held by the prefix index) are NEVER pushed — at
    refcount 0 they park off-stack, content intact, until the host
    claims them again or evicts them. ``cache_cols`` marks released
    entries that should *enter* the cached state (the host registered
    them in the prefix index in the same breath). Every released entry
    leaves the ``staged`` state: a staging table dropping its claim
    either frees the page or (killed background prefill, fully-written
    page) parks it cached. Returns ``(page_table, pages_used, pool)``."""
    jj = jnp.arange(spec.max_pages)[None]
    give = mask[:, None] & (jj < pages_used[:, None]) & (page_table >= 0)
    entries = jnp.where(give, page_table, spec.num_pages)  # OOB -> drop
    cached = pool.cached
    if cache_cols is not None:
        cached = cached.at[
            jnp.where(give & cache_cols, page_table, spec.num_pages)
        ].set(True, mode="drop")
    staged = pool.staged.at[entries].set(False, mode="drop")
    ref = pool.ref.at[entries].add(
        -give.astype(jnp.int32), mode="drop"
    )
    touched = (
        jnp.zeros((spec.num_pages,), jnp.int32)
        .at[entries].add(give.astype(jnp.int32), mode="drop")
    ) > 0
    freed = touched & (ref <= 0) & ~cached
    ref = jnp.where(touched & (ref <= 0), 0, ref)
    idx = jnp.cumsum(freed) - freed
    dst = jnp.where(freed, pool.free_count + idx, spec.num_pages)
    stack = pool.free_stack.at[dst].set(
        jnp.arange(spec.num_pages), mode="drop"
    )
    page_table = jnp.where(mask[:, None], -1, page_table)
    pages_used = jnp.where(mask, 0, pages_used)
    pool = PagePool(
        stack, pool.free_count + jnp.sum(freed), ref, cached, staged
    )
    return page_table, pages_used, pool


def fork(
    spec: PageSpec,
    page_table: jax.Array,  # (B, max_pages)
    pages_used: jax.Array,  # (B,)
    pool: PagePool,
    num_paths: int,
    mask: jax.Array,        # (B,) bool — slots to fork
):
    """Fork each masked slot's table into ``num_paths`` aliased path
    tables.

    The slot's single claim on each mapped page is converted into
    ``num_paths`` path claims (``ref += num_paths - 1``); after
    verification the caller adopts the winning path's table as the
    slot's new main table (keeping that path's claim) and ``release``-s
    the other ``num_paths - 1`` rows — refcounts on the shared prefix
    return to exactly 1. Unmasked slots get empty path rows and no
    refcount change. Returns ``(path_tables (B, K, MP), path_used
    (B, K), pool)``."""
    b, mp = page_table.shape
    path_tables = jnp.broadcast_to(
        jnp.where(mask[:, None, None], page_table[:, None], -1),
        (b, num_paths, mp),
    )
    path_used = jnp.broadcast_to(
        jnp.where(mask[:, None], pages_used[:, None], 0), (b, num_paths)
    )
    jj = jnp.arange(mp)[None]
    mapped = mask[:, None] & (jj < pages_used[:, None]) & (page_table >= 0)
    entries = jnp.where(mapped, page_table, spec.num_pages)
    ref = pool.ref.at[entries].add(
        jnp.where(mapped, num_paths - 1, 0), mode="drop"
    )
    return path_tables, path_used, pool._replace(ref=ref)


def cow_ensure(
    spec: PageSpec,
    page_table: jax.Array,   # (N, max_pages) — path tables (N = B * K)
    pages_used: jax.Array,   # (N,)
    pool: PagePool,
    write_begin: jax.Array,  # (N,) int32 — first position to be written
    need_len: jax.Array,     # (N,) int32 — cover positions [0, need_len)
    mask: jax.Array,         # (N,) bool — rows about to write
    *,
    max_write_pages: int,    # static bound on write-window pages
):
    """Prepare each masked row's table for KV writes in
    ``[write_begin, need_len)``: grow fresh pages (refcount 1) for the
    unmapped tail like :func:`ensure`, and remap every *shared* mapped
    page in the write window (refcount > 1) to a fresh private copy —
    copy-on-write. All-or-nothing per row, row-index order.

    Returns ``(page_table, pages_used, pool, copy_src, copy_dst, ok)``;
    ``copy_src/copy_dst`` are ``(N, max_write_pages)`` physical-page copy
    pairs (sentinel -1 = no copy) the caller MUST apply to every
    pool-backed cache entry before the writes land. A source page whose
    claims all CoW away is freed in the same call."""
    ps = spec.page_size
    n, mp = page_table.shape
    w = max_write_pages
    p_sent = spec.num_pages

    need = jnp.clip((need_len + ps - 1) // ps, 0, spec.max_pages)
    need = jnp.where(mask, jnp.maximum(need, pages_used), pages_used)
    deficit = need - pages_used

    # Shared mapped pages inside the write window -> CoW.
    first_w = jnp.clip(write_begin // ps, 0, spec.max_pages)
    wj = first_w[:, None] + jnp.arange(w)[None]          # (N, W) logical
    in_win = mask[:, None] & (wj < pages_used[:, None]) & (wj < mp)
    phys_w = jnp.take_along_axis(
        page_table, jnp.clip(wj, 0, mp - 1), axis=1
    )
    in_win &= phys_w >= 0
    shared = in_win & (pool.ref[jnp.clip(phys_w, 0, p_sent - 1)] > 1)
    n_cow = jnp.sum(shared, axis=1)

    # All-or-nothing grant over (CoW copies + growth), row order.
    tot = n_cow + deficit
    cum_excl = jnp.cumsum(tot) - tot
    ok = cum_excl + tot <= pool.free_count
    granted_tot = jnp.where(ok, tot, 0)
    goff = jnp.cumsum(granted_tot) - granted_tot

    row = jnp.arange(n)[:, None]
    # CoW pages pop first (window order)...
    cow_take = shared & ok[:, None]
    cow_rank = jnp.cumsum(shared, axis=1) - shared
    csrc = pool.free_count - 1 - (goff[:, None] + cow_rank)
    cow_new = pool.free_stack[jnp.clip(csrc, 0, p_sent - 1)]
    dst_col = jnp.where(cow_take, wj, spec.max_pages)
    page_table = page_table.at[
        jnp.broadcast_to(row, dst_col.shape), dst_col
    ].set(jnp.where(cow_take, cow_new, -1), mode="drop")
    # ... then growth pages for the unmapped tail.
    gj = jnp.arange(spec.max_pages)[None]
    grow_take = (gj < deficit[:, None]) & ok[:, None]
    gsrc = pool.free_count - 1 - (goff[:, None] + n_cow[:, None] + gj)
    grow_new = pool.free_stack[jnp.clip(gsrc, 0, p_sent - 1)]
    dst_col = jnp.where(grow_take, pages_used[:, None] + gj, spec.max_pages)
    page_table = page_table.at[
        jnp.broadcast_to(row, dst_col.shape), dst_col
    ].set(jnp.where(grow_take, grow_new, -1), mode="drop")
    pages_used = pages_used + jnp.where(ok, deficit, 0)

    # Refcounts: fresh pages claim 1; CoW sources lose one claim each —
    # a source every fork CoW'd away is freed (its content lives on in
    # the copies).
    ref = pool.ref.at[jnp.where(cow_take, cow_new, p_sent)].set(
        1, mode="drop"
    )
    ref = ref.at[jnp.where(grow_take, grow_new, p_sent)].set(1, mode="drop")
    ref = ref.at[jnp.where(cow_take, phys_w, p_sent)].add(-1, mode="drop")
    touched = (
        jnp.zeros((spec.num_pages,), jnp.int32)
        .at[jnp.where(cow_take, phys_w, p_sent)]
        .add(1, mode="drop")
    ) > 0
    freed = touched & (ref <= 0) & ~pool.cached
    ref = jnp.where(touched & (ref <= 0), 0, ref)
    base = pool.free_count - jnp.sum(granted_tot)
    idx = jnp.cumsum(freed) - freed
    stack = pool.free_stack.at[
        jnp.where(freed, base + idx, p_sent)
    ].set(jnp.arange(spec.num_pages), mode="drop")
    pool = PagePool(
        stack, base + jnp.sum(freed), ref, pool.cached, pool.staged
    )

    copy_src = jnp.where(cow_take, phys_w, -1)
    copy_dst = jnp.where(cow_take, cow_new, -1)
    return page_table, pages_used, pool, copy_src, copy_dst, ok


# ---------------------------------------------------------------------------
# Cross-request prefix caching
# ---------------------------------------------------------------------------


def host_claim_prefix(
    spec: PageSpec,
    page_table: jax.Array,  # (B, max_pages)
    pages_used: jax.Array,  # (B,)
    pool: PagePool,
    slot: int,
    page_ids: list[int],
):
    """Claim (pin) a cached page run as slot ``slot``'s table prefix:
    install the physical ids, bump each page's refcount by one. Runs
    eagerly at admission (host-driven, like ``admit_slot``) — the pages
    are off the free stack (cached state), so the free count is
    untouched. The caller guarantees the ids come from the prefix index
    (distinct, cached, never mid-eviction)."""
    n = len(page_ids)
    ids = jnp.asarray(page_ids, jnp.int32)
    page_table = page_table.at[slot, :n].set(ids)
    pages_used = pages_used.at[slot].set(n)
    ref = pool.ref.at[ids].add(1)
    return page_table, pages_used, pool._replace(ref=ref)


def host_claim_live(
    spec: PageSpec,
    page_table: jax.Array,  # (N, max_pages) — decode OR staging tables
    pages_used: jax.Array,  # (N,)
    pool: PagePool,
    row: int,
    page_ids: list[int],
    start: int = 0,
):
    """Pin a page run into row ``row``'s table at columns ``[start,
    start + n)`` and bump each page's refcount by one — the **live**
    twin of :func:`host_claim_prefix`. The ids may back cached nodes
    (ref 0 → 1, the PR 4 path) or pages still mapped by a live owner's
    table (ref ≥ 1 → pinned): either way the pages are off the free
    stack, so the free count is untouched, and the refcount bump is
    what keeps the page alive after the owner releases — a pinned page
    can only reach the stack once every claimant has released too.
    ``start > 0`` extends an earlier claim in place (claim-behind-the-
    writer: a rider's claim grows as the writer commits chunks); the
    caller guarantees ``pages_used[row] == start`` and that the ids
    come from the prefix index (distinct, committed, never
    mid-eviction)."""
    n = len(page_ids)
    if n == 0:
        return page_table, pages_used, pool
    ids = jnp.asarray(page_ids, jnp.int32)
    page_table = page_table.at[row, start:start + n].set(ids)
    pages_used = pages_used.at[row].set(start + n)
    ref = pool.ref.at[ids].add(1)
    return page_table, pages_used, pool._replace(ref=ref)


def host_evict(spec: PageSpec, pool: PagePool, page_ids: list[int]) -> PagePool:
    """Evict cached pages: un-mark them and push them back onto the free
    stack. The caller (the engine, driven by
    :meth:`PageBudget.evict_deficit` over the prefix index's LRU order)
    guarantees every id is cached with refcount 0 — no live claimant."""
    if not page_ids:
        return pool
    n = len(page_ids)
    ids = jnp.asarray(page_ids, jnp.int32)
    cached = pool.cached.at[ids].set(False)
    stack = pool.free_stack.at[pool.free_count + jnp.arange(n)].set(ids)
    return pool._replace(
        free_stack=stack, free_count=pool.free_count + n, cached=cached
    )


def host_adopt_stage(
    spec: PageSpec,
    page_table: jax.Array,  # (B, max_pages) — DECODE slot tables
    pages_used: jax.Array,  # (B,)
    pool: PagePool,
    slot: int,
    page_ids: list[int],
):
    """Adopt a completed background prefill into decode slot ``slot``:
    install the staging table's physical ids as the slot's table prefix
    and clear their ``staged`` marks — the ready flip. The staging
    lane's claim (ref 1 per page, popped by the prefill program's
    ``ensure(mark_staged=True)``) transfers to the decode slot, so
    refcounts are untouched and not a byte of K/V moves: the pages the
    prefill program wrote are the pages decode will read. Runs eagerly
    at adoption time (host-driven, like :func:`host_claim_prefix`); the
    caller zeroes the staging row's table WITHOUT releasing it
    (``repro.serving.batch.clear_stage_slot``). ``page_ids`` may be
    empty (a one-token or fully-claimed prompt stages no pages)."""
    n = len(page_ids)
    if n == 0:
        return page_table, pages_used, pool
    ids = jnp.asarray(page_ids, jnp.int32)
    page_table = page_table.at[slot, :n].set(ids)
    pages_used = pages_used.at[slot].set(n)
    staged = pool.staged.at[ids].set(False)
    return page_table, pages_used, pool._replace(staged=staged)


def audit_pool(
    spec: PageSpec,
    pool: PagePool,
    page_table=None,      # (B, max_pages) decode tables mapping THIS pool
    pages_used=None,      # (B,)
    live_rows=(),         # decode rows that legitimately hold mappings
    stage_table=None,     # (S, max_pages) staging tables on THIS pool
    stage_used=None,      # (S,)
    stage_rows=(),        # staging lanes that legitimately hold mappings
    prefix_cache=None,    # PrefixCache mirroring THIS pool (or None)
    budget=None,          # PageBudget whose terms charge THIS pool
) -> tuple[PagePool, dict]:
    """Self-healing reconciliation of a pool against host ground truth.

    Ground truth is the set of live table mappings (decode rows +
    staging lanes on this pool) plus the prefix index's cached mirror;
    from it the audit recomputes what every pool field *must* be:
    ``ref[p]`` = number of live table entries mapping ``p``, ``staged``
    = mapped by a staging lane, ``cached`` = parked in ``by_page``, and
    the free stack = exactly the pages none of those account for.  Any
    divergence — a leaked refcount after a kill raced a cancel, an
    orphaned page neither free nor mapped, a stale free-stack entry, a
    budget term for a retired row — is **repaired in place**: the pool
    is rebuilt from ground truth (reclaiming verified-orphaned pages to
    the free stack) and stale budget keys are dropped.  A clean pool is
    returned *unchanged* (bitwise — audits on the healthy path can
    never perturb allocation order or determinism).

    This is a host op (one materialization of the pool + tables); the
    engine invokes it only at quiesce and after kill/cancel unwinding,
    never on the per-iteration hot path.  Runs in O(num_pages +
    mapped entries).
    """
    n_pages = spec.num_pages
    ref = np.asarray(pool.ref)
    cached = np.asarray(pool.cached)
    staged = np.asarray(pool.staged)
    stack = np.asarray(pool.free_stack)
    fc = int(pool.free_count)

    expected_ref = np.zeros(n_pages, np.int64)
    expected_staged = np.zeros(n_pages, bool)
    if page_table is not None and live_rows:
        pt = np.asarray(page_table)
        pu = np.asarray(pages_used)
        for row in live_rows:
            ids = pt[row, : int(pu[row])]
            np.add.at(expected_ref, ids, 1)
    if stage_table is not None and stage_rows:
        st = np.asarray(stage_table)
        su = np.asarray(stage_used)
        for row in stage_rows:
            ids = st[row, : int(su[row])]
            np.add.at(expected_ref, ids, 1)
            expected_staged[ids] = True
    expected_cached = np.zeros(n_pages, bool)
    if prefix_cache is not None:
        for pid in prefix_cache.by_page:
            expected_cached[pid] = True

    report = {
        "ghost_refs": int(np.count_nonzero(ref != expected_ref)),
        "bad_staged": int(np.count_nonzero(staged != expected_staged)),
        "mirror_mismatch": int(np.count_nonzero(cached != expected_cached)),
        "leaked_pages": 0,
        "bad_free": 0,
        "stale_budget_keys": 0,
    }

    # The free set is everything ground truth does not account for.
    free_ok = ~(expected_ref > 0) & ~expected_cached & ~expected_staged
    cur = [int(p) for p in stack[:fc]]
    seen: set[int] = set()
    kept: list[int] = []
    for p in cur:
        if free_ok[p] and p not in seen:
            kept.append(p)
            seen.add(p)
        else:
            report["bad_free"] += 1
    orphans = [int(p) for p in np.nonzero(free_ok)[0] if int(p) not in seen]
    report["leaked_pages"] = len(orphans)

    if budget is not None:
        live_set, stage_set = set(live_rows), set(stage_rows)
        for slot in [s for s in budget.slot_len if s not in live_set]:
            budget.note_release(slot)
            report["stale_budget_keys"] += 1
        for sid in [s for s in budget.stage_len if s not in stage_set]:
            budget.note_unstage(sid)
            report["stale_budget_keys"] += 1

    pool_dirty = (
        report["ghost_refs"] or report["bad_staged"]
        or report["mirror_mismatch"] or report["bad_free"]
        or report["leaked_pages"]
    )
    report["repairs"] = (
        report["ghost_refs"] + report["bad_staged"]
        + report["mirror_mismatch"] + report["bad_free"]
        + report["leaked_pages"] + report["stale_budget_keys"]
    )
    report["clean"] = report["repairs"] == 0
    if pool_dirty:
        # Rebuild from ground truth: surviving stack entries keep their
        # order, reclaimed orphans append in page-id order — repairs are
        # as deterministic as the faults that caused them.
        new_stack = kept + sorted(orphans)
        stack_arr = np.zeros(n_pages, np.int32)
        stack_arr[: len(new_stack)] = new_stack
        pool = PagePool(
            free_stack=jnp.asarray(stack_arr),
            free_count=jnp.asarray(len(new_stack), jnp.int32),
            ref=jnp.asarray(expected_ref, jnp.int32),
            cached=jnp.asarray(expected_cached),
            staged=jnp.asarray(expected_staged),
        )
    return pool, report


@dataclass
class _PrefixNode:
    """One indexed page in the radix tree: ``key`` is the page's
    ``page_size``-token span, the path from the root is the full
    page-aligned token prefix it represents. ``owner is None`` is a
    **cached** node — ``page`` parks in the pool's ``cached`` state.
    ``owner is not None`` is a **live** node: the span is committed
    K/V on a live row's table (decode slot or staging lane, keyed by
    the engine's owner tuple), ``page`` is ``-1`` until a claimant
    resolves it from the owner's table, and the node converts to
    cached in place when the owner releases — never evicted while
    live."""

    key: tuple[int, ...]
    page: int
    parent: "_PrefixNode | None"
    children: dict = field(default_factory=dict)
    claims: int = 0      # live slots currently claiming this node's path
    last_use: int = 0    # logical LRU tick
    owner: tuple | None = None  # live-row key while the span is in flight


class PrefixCache:
    """Host-side radix index over **page-aligned committed token spans**.

    Keying rule: a node at depth ``i`` is keyed by tokens
    ``[i*page_size, (i+1)*page_size)``; a physical page is indexed iff
    every position in it holds *committed* K/V (the engine only inserts
    pages fully inside ``[0, committed_len - 1)`` — position ``len-1``
    is rewritten by the next verify chunk, so its page is never shared).
    Claims are page-aligned and capped at ``(prompt_len - 1) //
    page_size`` pages, which guarantees a claiming slot only ever
    *writes* at positions ``>= prompt_len - 1`` — strictly past its
    claimed prefix — so claimed pages are read-only by construction and
    need no copy-on-write.

    The host mirror is exact: claims/releases/evictions are all
    host-initiated, and decode-side refcount transients (multi-path
    fork/adopt) are net-zero per step, so ``claims == device ref``
    contribution of live slots at every dispatch boundary. Claiming a
    node claims its whole path, so ``claims`` is monotone up the tree —
    a claim-free node never has a claimed descendant, which makes the
    claim-free set downward-closed and leaf-first LRU eviction always
    able to reclaim every claim-free page.

    **Live spans** (:meth:`register_live`): the index also mirrors the
    committed spans of live rows, inserted as the engine's prefill
    mirrors advance — chunk granularity, host-only, no device sync
    (physical ids resolve lazily at claim time from the owner's
    table). ``self.live[owner]`` is the host mirror of each owner's
    registered nodes, in depth order. Live nodes never enter
    ``by_page``, so eviction cannot touch a page a live table maps;
    they convert to cached nodes in place when the owner's release
    runs :meth:`insert` with its owner key."""

    def __init__(self, spec: PageSpec):
        self.spec = spec
        self.children: dict[tuple, _PrefixNode] = {}  # root level
        self.by_page: dict[int, _PrefixNode] = {}
        # host mirror of live/staging committed spans: owner key -> the
        # nodes that owner registered (depth order, contiguous from its
        # first unindexed page).
        self.live: dict[tuple, list[_PrefixNode]] = {}
        self._tick = 0
        # cumulative telemetry (engine snapshots into per-run stats)
        self.hits = 0
        self.misses = 0
        self.claimed_tokens = 0
        self.evicted_pages = 0
        self.live_hits = 0

    @staticmethod
    def _page_keys(tokens: list[int], n_pages: int, ps: int) -> list[tuple]:
        """Radix keys for the first ``n_pages`` page spans of ``tokens``,
        built in ONE pass over the prefix. Walking with per-step
        ``tuple(tokens[i*ps:(i+1)*ps])`` slices re-copied the list at
        every level; hoisting the key construction keeps each lookup
        O(prompt_pages) dict probes over keys materialized exactly
        once (tuple hashes are cached per object, so the probes don't
        re-hash the spans either)."""
        return [
            tuple(tokens[o:o + ps]) for o in range(0, n_pages * ps, ps)
        ]

    # -- lookup / claim ----------------------------------------------------

    def lookup(self, tokens: list[int]) -> list[_PrefixNode]:
        """Longest indexed page-aligned prefix of ``tokens`` — cached
        and live nodes alike — capped so a claiming slot still prefills
        (and first writes) at or past position ``len(tokens) - 1``."""
        ps = self.spec.page_size
        cap = max(len(tokens) - 1, 0) // ps
        path: list[_PrefixNode] = []
        children = self.children
        for key in self._page_keys(tokens, cap, ps):
            node = children.get(key)
            if node is None:
                break
            path.append(node)
            children = node.children
        return path

    def claim(self, path: list[_PrefixNode], extend: bool = False) -> None:
        """Pin a looked-up path for a newly admitted slot (the caller
        applies :func:`host_claim_prefix` / :func:`host_claim_live`
        for the device side). ``extend=True`` grows an earlier claim
        (claim-behind-the-writer): the caller passes only the NEW
        nodes — each carries one claim for the whole claimed run — and
        the extension counts toward the original hit, not a new one."""
        self._tick += 1
        for node in path:
            node.claims += 1
            node.last_use = self._tick
        if not extend:
            self.hits += 1
            if any(node.owner is not None for node in path):
                self.live_hits += 1
        self.claimed_tokens += len(path) * self.spec.page_size

    def release_claims(self, path: list[_PrefixNode]) -> None:
        for node in path:
            node.claims -= 1
            assert node.claims >= 0, "claim/release imbalance"

    # -- insertion (at retire / preempt) -----------------------------------

    def insert(
        self, tokens: list[int], page_ids: list[int],
        owner: tuple | None = None,
    ) -> list[bool]:
        """Register a releasing row's committed full pages. Returns one
        bool per page: True — the row's physical page backs (or already
        backed) the index node, so it must move to the ``cached`` state;
        False — a different physical page with identical content got
        there first, and the row's duplicate releases normally.

        ``owner`` is the releasing row's live-registration key: a live
        node it owns converts IN PLACE to a cached node (page pinned to
        the row's physical id — which any claimant already resolved it
        to — owner cleared, eviction-eligible once claim-free), so
        claimants ride the owner's retirement without re-claiming. A
        live node owned by a DIFFERENT row stays live; this row's page
        still parks cached iff it is the very page the node resolved to
        (the row claimed it from that owner)."""
        ps = self.spec.page_size
        adopted: list[bool] = []
        children, parent = self.children, None
        self._tick += 1
        keys = self._page_keys(tokens, len(page_ids), ps)
        for key, pid in zip(keys, page_ids):
            pid = int(pid)
            node = children.get(key)
            if node is None:
                node = _PrefixNode(
                    key=key, page=pid, parent=parent, last_use=self._tick
                )
                children[key] = node
                self.by_page[pid] = node
                adopted.append(True)
            elif node.owner is not None and node.owner == owner:
                # Our own live registration retiring: convert to cached.
                assert node.page in (-1, pid), (node.page, pid)
                node.page = pid
                node.owner = None
                node.last_use = self._tick
                self.by_page[pid] = node
                adopted.append(True)
            else:
                node.last_use = self._tick
                adopted.append(node.page == pid)
            children, parent = node.children, node
        return adopted

    # -- live spans (insert-as-you-commit) ---------------------------------

    def register_live(
        self, owner: tuple, tokens: list[int], n_pages: int
    ) -> None:
        """Mirror a live row's committed prompt span into the index:
        nodes for page depths ``[0, n_pages)`` of ``tokens`` that are
        not indexed yet are created as **live** nodes owned by
        ``owner`` (page unresolved until a claimant reads the owner's
        table). Idempotent and monotone — the engine calls it after
        every prefill dispatch with the owner's committed full-page
        count; spans already indexed (cached content, another owner's
        live span, or our own earlier chunks) are traversed untouched,
        so the first writer of a span wins and duplicates never shadow
        it. Registered spans are always fully inside ``[0,
        len(prompt) - 1)`` — exactly the pages the owner's release
        will offer to :meth:`insert`, which converts ours to cached;
        :meth:`release_live` then only drops the owner's mirror
        entry."""
        ps = self.spec.page_size
        mine = self.live.setdefault(owner, [])
        children, parent = self.children, None
        self._tick += 1
        for key in self._page_keys(tokens, n_pages, ps):
            node = children.get(key)
            if node is None:
                node = _PrefixNode(
                    key=key, page=-1, parent=parent,
                    last_use=self._tick, owner=owner,
                )
                children[key] = node
                mine.append(node)
            children, parent = node.children, node

    def release_live(self, owner: tuple) -> None:
        """Drop a releasing row's live-span mirror. The row's release
        path runs :meth:`insert` (same pages, same owner key) FIRST,
        which converts every node the row still owned to cached — so
        this is pure mirror cleanup. Defensive: a node somehow still
        owned (insert skipped — e.g. the engine released without a
        cacheable prefix) is unlinked from the tree if it is safe to
        (claim-free, childless), since its backing page is about to be
        freed; a claimed or interior leftover would be a bug upstream
        and is asserted against."""
        for node in self.live.pop(owner, []):
            if node.owner != owner:
                continue  # converted to cached (or re-owned) — keep
            assert node.claims == 0 and not node.children, (
                "live node released while claimed or interior", node.key
            )
            siblings = (
                node.parent.children if node.parent else self.children
            )
            if siblings.get(node.key) is node:
                del siblings[node.key]

    def move_owner(self, old: tuple, new: tuple) -> None:
        """Re-key a live owner — adoption moves a staging lane's spans
        (and their unresolved nodes) to the decode slot that inherited
        its table."""
        nodes = self.live.pop(old, [])
        for node in nodes:
            if node.owner == old:
                node.owner = new
        if nodes:
            self.live.setdefault(new, []).extend(nodes)

    def live_pages(self, owner: tuple) -> int:
        """Live nodes ``owner`` created (committed full pages of its
        prompt span that no earlier index entry covered)."""
        return len(self.live.get(owner, []))

    # -- eviction ----------------------------------------------------------

    def reclaimable_pages(self) -> int:
        """Cached pages with no live claimant — exactly the pages whose
        device refcount is 0 and that :meth:`evict_lru` may reclaim.
        Live nodes are structurally excluded (never in ``by_page``):
        their pages sit on a live table at refcount >= 1, so treating
        them as reclaimable would let the budget double-spend pages
        that cannot reach the free stack."""
        return sum(1 for n in self.by_page.values() if n.claims == 0)

    def evict_lru(self, n: int) -> list[int]:
        """Pick ``n`` pages to evict, least-recently-used childless nodes
        first (an interior page must outlive its descendants or they
        become unreachable and leak). The caller pushes the returned ids
        back onto the device free stack (:func:`host_evict`).

        Heap over the current claim-free leaves; evicting a leaf can
        only newly expose its own parent, so one push per eviction keeps
        the candidate set exact without rescanning the index."""
        heap = [
            (nd.last_use, nd.page)
            for nd in self.by_page.values()
            if nd.claims == 0 and not nd.children
        ]
        heapq.heapify(heap)
        out: list[int] = []
        while heap and len(out) < n:
            _, page = heapq.heappop(heap)
            nd = self.by_page[page]
            siblings = nd.parent.children if nd.parent else self.children
            del siblings[nd.key]
            del self.by_page[page]
            out.append(page)
            parent = nd.parent
            if (
                parent is not None
                and parent.claims == 0
                and not parent.children
            ):
                heapq.heappush(heap, (parent.last_use, parent.page))
        self.evicted_pages += len(out)
        return out

    # -- telemetry ---------------------------------------------------------

    @property
    def cached_pages(self) -> int:
        return len(self.by_page)

    @property
    def live_span_pages(self) -> int:
        """Live nodes currently registered across all owners."""
        return sum(len(nodes) for nodes in self.live.values())

    def live_pinned_pages(self) -> int:
        """Live nodes with at least one claimant — pages pinned where
        they sit on an owner's table (device ref >= 2)."""
        return sum(
            1
            for nodes in self.live.values()
            for n in nodes
            if n.owner is not None and n.claims > 0
        )

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "live_hits": self.live_hits,
            "claimed_tokens": self.claimed_tokens,
            "cached_pages": self.cached_pages,
            "reclaimable_pages": self.reclaimable_pages(),
            "evicted_pages": self.evicted_pages,
            "live_span_pages": self.live_span_pages,
            "live_pinned_pages": self.live_pinned_pages(),
        }


@dataclass
class PageBudget:
    """Host-side conservative page accounting (no device syncs).

    The device allocates from exact per-slot lengths; with the engine's
    double-buffered loop the host only learns lengths one step late, so
    each live slot is budgeted at ``worst_pages(len + 2 * (gamma + 1))``
    — covering the unmaterialized in-flight step plus the step about to
    be dispatched. Multi-path engines add the worst-case post-fork
    transient: the adopted winner table may cover one extra drafted
    block, and mid-step every one of the ``num_paths`` path tables can
    hold :func:`path_transient_pages` fresh pages (CoW copies plus
    speculative growth). Invariant enforced by the scheduler/engine: the
    sum of worst-case pages over live slots never exceeds ``num_pages``
    at dispatch time, so the device-side allocators cannot fail.

    The async staging lane is budgeted alongside (``stage_len``): a
    staging slot reserves its *eventual decode* worst case from the
    moment it is staged — the background prefill program itself writes
    at most ``pages_for(plen - 1)`` of that — so adoption is a pure
    key move (:meth:`note_adopt`) that cannot change ``used_worst()``
    and provably never needs pages the pool cannot supply.

    **Live prefix sharing double-counts pinned pages — safely.** A
    claimant of a live span budgets its FULL prompt length (its
    ``slot_len`` includes the claimed prefix) while the owner's term
    covers the same physical pages, so ``used_worst()`` counts a
    pinned page once per mapping row. That is the conservative
    direction everywhere the budget is load-bearing: ``can_admit`` /
    ``needs_preemption`` over-estimate, and the free-stack-sufficiency
    argument of :meth:`evict_deficit` only needs *referenced pages <=
    used_worst()*, which double-counting can never violate. It is also
    necessary: preempting the owner does NOT return pinned pages to
    the stack (claimants keep them at ref >= 1), so the claimant's own
    term must stand for them after the owner's term vanishes — which
    it does, because the claimant's length already covers its claimed
    prefix. Pinned pages are likewise never eviction fodder:
    :meth:`PrefixCache.reclaimable_pages` counts only claim-free
    CACHED nodes, so ``evict_deficit`` treats live-claimed pages as
    non-reclaimable by construction."""

    spec: PageSpec
    gamma: int
    num_paths: int = 1
    slot_len: dict[int, int] = field(default_factory=dict)
    stage_len: dict[int, int] = field(default_factory=dict)

    def worst_pages(self, length: int) -> int:
        worst = self.spec.pages_for(length + 2 * (self.gamma + 1))
        if self.num_paths > 1:
            worst = self.spec.pages_for(length + 3 * (self.gamma + 1))
            worst += self.num_paths * path_transient_pages(
                self.spec, self.gamma
            )
        return worst

    def used_worst(self) -> int:
        return (
            sum(self.worst_pages(n) for n in self.slot_len.values())
            + sum(self.worst_pages(n) for n in self.stage_len.values())
        )

    def occupancy_pages(self) -> int:
        """Exact committed-page count across live slots — the host-lagged
        pool occupancy the per-step allocation telemetry reports (the
        device may briefly hold up to ``used_worst()``). Staging lanes
        count at full-prompt coverage — an upper bound on what their
        background prefill has materialized so far. With live prefix
        sharing, pages pinned by multiple rows count once per mapping
        row (an upper bound on distinct physical pages, matching
        ``used_worst()``'s convention)."""
        return (
            sum(self.spec.pages_for(n) for n in self.slot_len.values())
            + sum(self.spec.pages_for(n) for n in self.stage_len.values())
        )

    def can_admit(self, prompt_len: int) -> bool:
        """Cached pages don't block admission: reclaimable ones are
        evicted on demand (:meth:`evict_deficit`) and claimed ones are
        already inside their claimants' worst-case terms."""
        return (
            self.used_worst() + self.worst_pages(prompt_len)
            <= self.spec.num_pages
        )

    def needs_preemption(self) -> bool:
        return self.used_worst() > self.spec.num_pages

    def evict_deficit(self, reclaimable_cached: int) -> int:
        """Cached pages the engine must evict before the next dispatch so
        the device allocators provably cannot run the free stack dry.

        Pages referenced by live slots never exceed ``used_worst()`` and
        the step's new allocations are covered by the same bound, so the
        free stack suffices iff claim-free cached pages fit the
        remainder: ``reclaimable <= num_pages - used_worst()``. (Claimed
        cached pages are referenced, hence inside ``used_worst()``.)
        Always satisfiable: the preemption/admission invariants keep
        ``used_worst() <= num_pages``."""
        return max(
            0, reclaimable_cached - (self.spec.num_pages - self.used_worst())
        )

    def note_admit(self, slot: int, prompt_len: int) -> None:
        self.slot_len[slot] = prompt_len

    def note_commit(self, slot: int, num_tokens: int) -> None:
        if slot in self.slot_len:
            self.slot_len[slot] += num_tokens

    def note_release(self, slot: int) -> None:
        self.slot_len.pop(slot, None)

    # -- async staging lane -------------------------------------------------

    def note_stage(self, sid: int, prompt_len: int) -> None:
        """Reserve a staging slot at its eventual decode worst case."""
        self.stage_len[sid] = prompt_len

    def note_unstage(self, sid: int) -> None:
        """Killed background prefill: drop the staging reservation."""
        self.stage_len.pop(sid, None)

    def note_adopt(self, sid: int, slot: int) -> None:
        """Completed prefill adopted into a decode slot: pure key move —
        ``used_worst()`` is unchanged, so adoption can never trip the
        preemption threshold nor fail allocation.

        This shared-pool form only applies when staging lanes and decode
        slots draw from ONE pool. Disaggregated engines track two
        budgets (one per pool) and adoption is a *cross-pool move*: the
        scheduler charges the decode budget via ``note_admit(slot,
        plen)`` BEFORE the transfer's unpack program (which allocates
        the destination pages) is dispatched, and releases the prefill
        budget via the stage budget's ``note_unstage(sid)`` once the
        staged source pages are freed — so "allocation never fails"
        stays provable on both pools independently: the decode pool by
        its admission gate (``can_admit`` checked at adoption), the
        prefill pool because a lane's worst case is clamped to
        ``max_pages`` and the stage pool holds ``stage_slots *
        max_pages`` pages."""
        self.slot_len[slot] = self.stage_len.pop(sid)
