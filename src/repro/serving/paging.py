"""Paged KV cache: page-pool allocator with a device-resident free list.

Instead of reserving a dense ``(max_len, n_kv, hd)`` ring per slot up
front, global-attention layers write K/V into a **global page pool**
shared by all slots; each slot owns a small **page table** mapping its
logical pages (position // page_size) to physical pool pages. Concurrency
is then bounded by *actual* token usage, not worst-case length — the
defining property of a production serving engine (vLLM-style
PagedAttention), and the prerequisite for copy-on-write prefix sharing
across multi-path draft candidates (see PAPERS.md).

Three pieces live here:

* :class:`PageSpec` — static geometry (page size, pool size, per-slot
  table length). Derived from the engine config via :func:`spec_of`.
* :class:`PagePool` + :func:`ensure` / :func:`release` — the device-side
  allocator. ``free_stack[:free_count]`` holds the free physical page
  ids; ``ensure`` pops pages (all-or-nothing per slot, slot-index order,
  so allocation is deterministic) to cover a target length, ``release``
  pushes a retired slot's pages back (LIFO). Both are pure jittable
  functions over ``(page_table, pages_used, pool)`` and run *inside* the
  runner's fixed-shape programs — allocation never syncs the host.
* :class:`PageBudget` — the host-side conservative mirror the scheduler
  admits/preempts by. The device allocates from exact lengths; the host
  only sees lengths one double-buffered step late, so it budgets with
  ``worst_pages(len + 2 * (gamma + 1))`` per slot — an upper bound on
  what the device can allocate before the next budget check. As long as
  ``sum(worst) <= num_pages`` before every dispatch, the device-side
  ``ensure`` can never fail and slots never stall.

The allocator is exercised by both models' caches with a *single* page
table: target and drafter pools are indexed by the same physical page
ids (their per-page byte sizes differ; the id space is shared).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp


class PagePool(NamedTuple):
    """Device free-list: ``free_stack[:free_count]`` are free page ids."""

    free_stack: jax.Array  # (num_pages,) int32
    free_count: jax.Array  # () int32


@dataclass(frozen=True)
class PageSpec:
    """Static pool geometry (baked into the compiled programs)."""

    page_size: int   # tokens per page
    num_pages: int   # physical pages in the pool
    max_pages: int   # page-table length == pages covering max_len + slack

    def pages_for(self, length: int) -> int:
        """Host-side: pages needed to cover ``length`` tokens."""
        return min(-(-length // self.page_size), self.max_pages)


def chunk_slack_of(cfg) -> int:
    """Longest in-flight chunk either runner body writes past a committed
    length (mirrors ``Runner.chunk_slack``)."""
    return max(cfg.gamma + 1, cfg.prefill_chunk)


def spec_of(cfg) -> PageSpec | None:
    """Derive the pool geometry from an engine config. ``num_pages=None``
    fully provisions the pool (``max_slots * max_pages``: no
    over-subscription, admission never blocks, preemption never fires)."""
    if not getattr(cfg, "paged", False):
        return None
    ps = cfg.page_size
    max_pages = -(-(cfg.max_len + chunk_slack_of(cfg)) // ps)
    num_pages = cfg.num_pages
    if num_pages is None:
        num_pages = cfg.max_slots * max_pages
    assert num_pages >= max_pages, (
        f"pool of {num_pages} pages cannot hold one full-length slot "
        f"({max_pages} pages); raise num_pages or shrink max_len"
    )
    return PageSpec(page_size=ps, num_pages=num_pages, max_pages=max_pages)


def init_pool(spec: PageSpec) -> PagePool:
    return PagePool(
        free_stack=jnp.arange(spec.num_pages, dtype=jnp.int32),
        free_count=jnp.asarray(spec.num_pages, jnp.int32),
    )


def init_tables(spec: PageSpec, num_slots: int):
    """Empty per-slot page tables: (page_table, pages_used)."""
    return (
        jnp.full((num_slots, spec.max_pages), -1, jnp.int32),
        jnp.zeros((num_slots,), jnp.int32),
    )


def ensure(
    spec: PageSpec,
    page_table: jax.Array,  # (B, max_pages) int32, -1 = unmapped
    pages_used: jax.Array,  # (B,) int32
    pool: PagePool,
    need_len: jax.Array,    # (B,) int32 — cover positions [0, need_len)
    mask: jax.Array,        # (B,) bool — slots requesting coverage
):
    """Grow each masked slot's page table to cover ``need_len`` tokens.

    Pops pages off the free stack in slot-index order, all-or-nothing per
    slot. Returns ``(page_table, pages_used, pool, ok)`` where ``ok[b]``
    is False iff slot ``b`` asked for pages the pool could not supply
    (the caller must then exclude the slot from the step — the host
    budget guarantees this never happens in the serving engine)."""
    ps = spec.page_size
    need = jnp.clip((need_len + ps - 1) // ps, 0, spec.max_pages)
    need = jnp.where(mask, need, pages_used)
    deficit = jnp.maximum(need - pages_used, 0)
    cum_excl = jnp.cumsum(deficit) - deficit
    ok = cum_excl + deficit <= pool.free_count
    granted = jnp.where(ok, deficit, 0)
    goff = jnp.cumsum(granted) - granted

    jj = jnp.arange(spec.max_pages)[None]           # (1, MAXP)
    take = jj < granted[:, None]                    # (B, MAXP)
    src = pool.free_count - 1 - (goff[:, None] + jj)
    ids = pool.free_stack[jnp.clip(src, 0, spec.num_pages - 1)]
    b_idx = jnp.broadcast_to(
        jnp.arange(take.shape[0])[:, None], take.shape
    )
    dst_col = jnp.where(take, pages_used[:, None] + jj, spec.max_pages)
    page_table = page_table.at[b_idx, dst_col].set(
        jnp.where(take, ids, -1), mode="drop"
    )
    pages_used = pages_used + granted
    pool = PagePool(pool.free_stack, pool.free_count - jnp.sum(granted))
    return page_table, pages_used, pool, ok


def release(
    spec: PageSpec,
    page_table: jax.Array,
    pages_used: jax.Array,
    pool: PagePool,
    mask: jax.Array,  # (B,) bool — slots to free
):
    """Push every masked slot's pages back onto the free stack and clear
    its table. Returns ``(page_table, pages_used, pool)``."""
    give_n = jnp.where(mask, pages_used, 0)
    off = jnp.cumsum(give_n) - give_n
    jj = jnp.arange(spec.max_pages)[None]
    give = mask[:, None] & (jj < pages_used[:, None])
    dst = jnp.where(give, pool.free_count + off[:, None] + jj, spec.num_pages)
    stack = pool.free_stack.at[dst].set(
        jnp.where(give, page_table, 0), mode="drop"
    )
    page_table = jnp.where(mask[:, None], -1, page_table)
    pages_used = jnp.where(mask, 0, pages_used)
    return page_table, pages_used, PagePool(stack, pool.free_count + jnp.sum(give_n))


@dataclass
class PageBudget:
    """Host-side conservative page accounting (no device syncs).

    The device allocates from exact per-slot lengths; with the engine's
    double-buffered loop the host only learns lengths one step late, so
    each live slot is budgeted at ``worst_pages(len + 2 * (gamma + 1))``
    — covering the unmaterialized in-flight step plus the step about to
    be dispatched. Invariant enforced by the scheduler/engine: the sum
    of worst-case pages over live slots never exceeds ``num_pages`` at
    dispatch time, so the device-side ``ensure`` cannot fail."""

    spec: PageSpec
    gamma: int
    slot_len: dict[int, int] = field(default_factory=dict)

    def worst_pages(self, length: int) -> int:
        return self.spec.pages_for(length + 2 * (self.gamma + 1))

    def used_worst(self) -> int:
        return sum(self.worst_pages(n) for n in self.slot_len.values())

    def can_admit(self, prompt_len: int) -> bool:
        return (
            self.used_worst() + self.worst_pages(prompt_len)
            <= self.spec.num_pages
        )

    def needs_preemption(self) -> bool:
        return self.used_worst() > self.spec.num_pages

    def note_admit(self, slot: int, prompt_len: int) -> None:
        self.slot_len[slot] = prompt_len

    def note_commit(self, slot: int, num_tokens: int) -> None:
        if slot in self.slot_len:
            self.slot_len[slot] += num_tokens

    def note_release(self, slot: int) -> None:
        self.slot_len.pop(slot, None)
