"""Continuous-batching serving front end.

Turns the batch-submit :class:`~repro.serving.engine.SpecEngine` into an
open-stream service (the SGLang-JAX shape: tokenizer → scheduler →
detokenizer, with only the scheduler on the critical path):

    fe = ServingFrontend(engine, tokenizer=ByteTokenizer(),
                         tenant_weights={"gold": 2.0})
    fe.start()                                   # service loop spins up
    h = fe.submit("prompt", priority=0, tenant="gold")
    for delta in fe.stream(h):                   # per-token streaming
        print(delta.text, end="")
    results = fe.drain()                         # quiesce + join

Threading model — exactly two kinds of thread touch the front end:

* **Caller threads** run :meth:`submit` (tokenization happens HERE, off
  the scheduler's critical path), :meth:`stream`/:meth:`result`
  (incremental detokenization happens here too), and :meth:`drain`.
  They never touch JAX state; they only append to the ingress list
  under a lock and park on per-request queues/events.
* **The service thread** (spawned by :meth:`start`) runs
  ``engine.serve(pump, emit, idle)``. All JAX dispatch, all scheduler
  mutation, and all engine state stay on this one thread: ``pump``
  drains the ingress into ``engine.submit`` at the top of every loop
  iteration, ``emit`` fans each request's newly *committed* tokens out
  to its handle's event queue (the committed-token frontier — a
  streamed token is never speculative and never rolls back), and
  ``idle`` parks on a wake event when there is neither work nor
  ingress, so an idle service loop costs ~nothing.

Losslessness is untouched: the front end only changes WHEN
``engine.submit`` is called, never what the verifiers commit. At
temperature 0 a streamed open-loop arrival schedule is bit-identical to
batch submission; at sampled temperatures, sequential submission is
bit-identical to sequential batch runs (the PRNG advances once per
decode dispatch with live work — idle pump/wait iterations dispatch
nothing and consume no key splits). ``tests/test_frontend.py`` pins
both.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass, field

from repro.serving.scheduler import RequestState


@dataclass
class StreamDelta:
    """One streaming event: the tokens committed since the previous
    event for this request (possibly several — speculative decoding
    commits blocks, so deltas arrive in E[tau]-sized bursts), plus the
    incrementally detokenized text when the front end has a tokenizer
    (the longest newly decodable UTF-8 suffix; multi-byte glyphs split
    across deltas surface once complete).

    ``error`` is set on the terminal delta when the request was
    quarantined by the engine (per-request failure; the service keeps
    running) or when the service loop itself died (every orphaned
    handle gets one such delta before :meth:`ServingFrontend.drain`
    re-raises). Error deltas always carry ``finished=True`` so
    :meth:`ServingFrontend.stream` flushes its incremental detokenizer
    — partial multi-byte glyphs never survive past a request's last
    delta."""

    rid: int
    tokens: list[int]
    finished: bool
    text: str | None = None
    error: str | None = None


@dataclass
class RequestHandle:
    """A submitted request's streaming endpoint. Created by
    :meth:`ServingFrontend.submit`; consumed via
    :meth:`ServingFrontend.stream` or :meth:`ServingFrontend.result`."""

    prompt_ids: list[int]
    max_new_tokens: int | None
    priority: int
    tenant: str
    deadline_s: float | None = None
    rid: int | None = None          # assigned by the service thread
    state: RequestState | None = None  # set when the request finishes
    events: queue.Queue = field(default_factory=queue.Queue)
    done: threading.Event = field(default_factory=threading.Event)


class ServingFrontend:
    """start()/submit()/stream()/drain() lifecycle around one engine.

    ::

        caller threads                   service thread
        --------------                   --------------
        submit(text)
          tokenize ──► ingress ──wake──► pump() ─► engine.submit()
                                         ┌──────────────────────┐
        stream(h) ◄── h.events ◄─ emit() ┤ double-buffered       │
          detokenize                     │ admit/prefill/decode  │
                                         └──────────────────────┘
        drain() ──close──► wake ───────► quiesce ─► results

    ``tenant_weights`` maps tenant name → fair-share weight, applied to
    the engine's scheduler at :meth:`start` (and live via
    :meth:`set_tenant_weight`).
    """

    def __init__(
        self,
        engine,
        tokenizer=None,
        tenant_weights: dict[str, float] | None = None,
        idle_wait_s: float = 0.002,
    ):
        self.engine = engine
        self.tokenizer = tokenizer
        self.tenant_weights = dict(tenant_weights or {})
        self.idle_wait_s = idle_wait_s
        self._lock = threading.Lock()
        self._ingress: deque[RequestHandle] = deque()
        self._cancels: deque[RequestHandle] = deque()
        self._by_rid: dict[int, RequestHandle] = {}
        self._wake = threading.Event()
        self._closed = True  # not accepting until start()
        self._thread: threading.Thread | None = None
        self._results: dict[int, RequestState] | None = None
        self._error: BaseException | None = None

    # -- lifecycle ---------------------------------------------------------

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "ServingFrontend":
        if self.running:
            raise RuntimeError("front end is already running")
        for tenant, weight in self.tenant_weights.items():
            self.engine.scheduler.set_tenant_weight(tenant, weight)
        self._results = None
        self._error = None
        self._wake.clear()
        with self._lock:
            self._closed = False
        self._thread = threading.Thread(
            target=self._serve, name="spec-frontend", daemon=True
        )
        self._thread.start()
        return self

    def __enter__(self) -> "ServingFrontend":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.drain()
        else:  # don't mask the caller's exception with a drain timeout
            with self._lock:
                self._closed = True
            self._wake.set()

    def drain(self, timeout_s: float | None = None) -> dict[int, RequestState]:
        """Stop accepting new requests, serve everything already
        submitted to completion, join the service thread, and return
        ``rid -> RequestState`` for every finished request."""
        with self._lock:
            self._closed = True
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout_s)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"service loop did not quiesce within {timeout_s}s"
                )
            self._thread = None
        if self._error is not None:
            raise RuntimeError("service loop failed") from self._error
        return dict(self._results or {})

    def _serve(self) -> None:
        try:
            self._results = self.engine.serve(
                pump=self._pump, emit=self._emit, idle=self._idle
            )
        except BaseException as exc:  # noqa: BLE001 — surface to callers
            self._error = exc
            with self._lock:
                self._closed = True
                orphans, seen = [], set()
                for h in (
                    list(self._ingress)
                    + list(self._cancels)          # may alias _by_rid entries
                    + list(self._by_rid.values())
                ):
                    if id(h) not in seen:
                        seen.add(id(h))
                        orphans.append(h)
                self._ingress.clear()
                self._cancels.clear()
                self._by_rid.clear()
            msg = f"service loop failed: {type(exc).__name__}: {exc}"
            for h in orphans:  # fail waiters instead of hanging them
                h.done.set()
                h.events.put(StreamDelta(
                    rid=-1 if h.rid is None else h.rid, tokens=[],
                    finished=True, error=msg,
                ))

    # -- ingress (caller threads) ------------------------------------------

    def submit(
        self,
        prompt,
        max_new_tokens: int | None = None,
        priority: int = 0,
        tenant: str = "default",
        deadline_s: float | None = None,
    ) -> RequestHandle:
        """Enqueue a request while the loop runs. ``prompt`` may be text
        (tokenized here, in the caller's thread) or token ids. Returns
        immediately with a :class:`RequestHandle`. ``deadline_s`` is a
        wall-clock budget from submission: the scheduler sheds the
        request (terminal ``finish_reason="deadline"``) if it has not
        finished within that many seconds."""
        if isinstance(prompt, str):
            if self.tokenizer is None:
                raise ValueError("text prompt needs a tokenizer")
            prompt_ids = self.tokenizer.encode(prompt)
        else:
            prompt_ids = [int(t) for t in prompt]
        # Validate in the caller's thread so a bad request fails its
        # submitter, not the shared service loop.
        if not 1 <= len(prompt_ids) < self.engine.cfg.max_len:
            raise ValueError(
                f"prompt length {len(prompt_ids)} must be in "
                f"[1, max_len={self.engine.cfg.max_len})"
            )
        if max_new_tokens is not None and max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}"
            )
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        handle = RequestHandle(
            prompt_ids=prompt_ids,
            max_new_tokens=max_new_tokens,
            priority=priority,
            tenant=tenant,
            deadline_s=deadline_s,
        )
        with self._lock:
            if self._closed:
                raise RuntimeError(
                    "front end is not accepting requests "
                    "(start() it, or it is already draining)"
                )
            self._ingress.append(handle)
        self._wake.set()
        return handle

    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a request from any caller thread, at any lifecycle
        stage. A handle still parked in the ingress is retracted right
        here (it never reached the scheduler); anything later — queued,
        staged, riding, or mid-decode — is marshalled through the pump
        so engine/scheduler/JAX state is only ever touched on the
        service thread. Either way the handle receives a terminal
        ``finished`` delta (``finish_reason="cancelled"``), so
        :meth:`stream` terminates and flushes its detokenizer. Returns
        False only when the request is already finished."""
        with self._lock:
            if handle.done.is_set():
                return False
            try:
                self._ingress.remove(handle)
                retracted = True
            except ValueError:
                retracted = False
                self._cancels.append(handle)
        if retracted:
            handle.state = RequestState(
                rid=-1, prompt=list(handle.prompt_ids),
                max_new_tokens=handle.max_new_tokens or 0,
                priority=handle.priority, tenant=handle.tenant,
                finished=True, finish_reason="cancelled",
            )
            handle.events.put(
                StreamDelta(rid=-1, tokens=[], finished=True)
            )
            handle.done.set()
        else:
            self._wake.set()
        return True

    def set_tenant_weight(self, tenant: str, weight: float) -> None:
        """Adjust a tenant's fair-share weight; effective from the next
        admission (the scheduler reads weights at pop time)."""
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        self.tenant_weights[tenant] = float(weight)
        self.engine.scheduler.set_tenant_weight(tenant, weight)

    # -- service-thread hooks ----------------------------------------------

    def _pump(self) -> bool:
        """Engine hook (service thread): drain the ingress into the
        scheduler, then apply marshalled cancellations. Returns whether
        the front end still accepts new requests — False lets the
        engine quiesce once drained."""
        with self._lock:
            batch = list(self._ingress)
            self._ingress.clear()
            cancels = list(self._cancels)
            self._cancels.clear()
            accepting = not self._closed
        for h in batch:
            h.rid = self.engine.submit(
                h.prompt_ids, h.max_new_tokens,
                priority=h.priority, tenant=h.tenant,
                deadline_s=h.deadline_s,
            )
            self._by_rid[h.rid] = h
        for h in cancels:
            if h.rid is None:
                # Defensive: a cancel filed while this handle sat
                # between ingress snapshot and engine.submit is only
                # snapshotted by the NEXT pump (after its rid lands),
                # so this should be unreachable — requeue, don't drop.
                with self._lock:
                    self._cancels.append(h)
            else:
                self.engine.cancel(h.rid)  # no-op if already finished
        return accepting

    def _emit(self, req: RequestState, tokens: list[int], finished: bool) -> None:
        """Engine hook (service thread): fan newly committed tokens out
        to the request's handle."""
        h = self._by_rid.get(req.rid)
        if h is None:
            return
        if finished:
            h.state = req
            del self._by_rid[req.rid]
        h.events.put(StreamDelta(
            rid=req.rid, tokens=tokens, finished=finished,
            error=req.error if finished else None,
        ))
        if finished:
            h.done.set()

    def _idle(self) -> None:
        """Engine hook (service thread): nothing to do — park until a
        submit/drain wakes us (bounded, so a wake racing the clear is
        only ever one timeout late)."""
        self._wake.wait(self.idle_wait_s)
        self._wake.clear()

    # -- egress (caller threads) -------------------------------------------

    def stream(self, handle: RequestHandle, timeout_s: float = 120.0):
        """Yield :class:`StreamDelta` events for one request as its
        tokens commit, detokenizing incrementally when the front end has
        a tokenizer. Terminates after the ``finished`` delta. ``timeout_s``
        bounds the wait BETWEEN deltas, not the whole stream."""
        from repro.data.tokenizer import IncrementalDetokenizer

        detok = IncrementalDetokenizer() if self.tokenizer is not None else None
        while True:
            try:
                delta = handle.events.get(timeout=timeout_s)
            except queue.Empty:
                raise TimeoutError(
                    f"no stream delta within {timeout_s}s "
                    f"(rid={handle.rid})"
                ) from None
            if detok is not None:
                # Feed-then-flush on EVERY terminal delta — cancelled
                # and errored requests included — so partial multi-byte
                # glyphs never outlive the stream.
                delta.text = detok.feed(delta.tokens)
                if delta.finished:
                    delta.text += detok.flush()
            if (
                delta.finished
                and delta.error is not None
                and handle.state is None
            ):
                # Service loop died: deliver the terminal delta, then
                # surface the failure the same way drain() does.
                yield delta
                raise RuntimeError("service loop failed") from self._error
            yield delta
            if delta.finished:
                return

    def result(
        self, handle: RequestHandle, timeout_s: float | None = None
    ) -> RequestState:
        """Block until one request finishes; return its final state.
        (Streaming events remain queued on the handle — result() and
        stream() compose.)"""
        if not handle.done.wait(timeout_s):
            raise TimeoutError(f"request rid={handle.rid} not finished")
        if handle.state is None:
            raise RuntimeError("service loop failed") from self._error
        return handle.state

    def text(self, handle: RequestHandle, timeout_s: float | None = None) -> str:
        """Convenience: block for completion, return the decoded output."""
        state = self.result(handle, timeout_s)
        if self.tokenizer is None:
            raise ValueError("text output needs a tokenizer")
        return self.tokenizer.decode(state.output)


def _poisson_arrivals(rng, n: int, mean_interarrival_s: float) -> list[float]:
    """Seeded open-loop Poisson arrival offsets (seconds from t0) for
    the benchmarks — here so load generators share one definition."""
    t, out = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(mean_interarrival_s))
        out.append(t)
    return out


def replay_open_loop(
    frontend: ServingFrontend,
    requests: list[dict],
    arrivals_s: list[float],
    clock=time.perf_counter,
    sleep=time.sleep,
) -> list[RequestHandle]:
    """Replay an open-loop schedule against a RUNNING front end: submit
    ``requests[i]`` (kwargs for :meth:`ServingFrontend.submit`) at
    ``arrivals_s[i]`` seconds after the call, sleeping between arrivals
    — open-loop, so submission never waits for service (the queue grows
    when the engine can't keep up; that's the point of the bench)."""
    assert len(requests) == len(arrivals_s)
    t0 = clock()
    handles = []
    for req, at in zip(requests, arrivals_s):
        lag = at - (clock() - t0)
        if lag > 0:
            sleep(lag)
        handles.append(frontend.submit(**req))
    return handles
