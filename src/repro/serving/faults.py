"""Deterministic fault-injection plane for the serving stack.

The serving engine has three topologies (serial, async-prefill,
device-disaggregated) and a handful of host/device boundaries where real
deployments fail: a page transfer that never lands, a prefill pod that
drops a dispatch, an allocator that transiently refuses, a drafter that
emits non-finite logits.  ``FaultPlan``/``FaultInjector`` make those
failures *schedulable*: every injection decision is a pure function of
``(seed, site, iteration, rid)``, so a chaos run is exactly reproducible
and the unaffected requests can be pinned bit-identical to a fault-free
run.

Structure matters more than mechanism here:

* Sites are **registered** in ``SITES`` — speclint's ``fault-site`` pass
  rejects a ``fires(...)`` call whose site literal is not in the
  registry, and rejects call sites not gated on the ``faults`` config
  field.
* When ``EngineConfig.faults is None`` the injector is never
  constructed and no fault branch is reachable — the fault plane is
  structurally a no-op, not a dynamic one.
* Rate-driven sites are **bounded** (``max_per_site``): chaos must
  terminate, because the acceptance gate is "every non-cancelled
  request completes", not "the ladder retries forever".
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# Registered injection sites.  speclint's fault-site pass cross-checks
# call-site literals against this tuple (mirrored in
# tools/speclint/config.py::FAULT_SITES).
SITE_TRANSFER_LOSS = "transfer_loss"      # disagg page transfer dropped in flight
SITE_TRANSFER_DELAY = "transfer_delay"    # disagg page transfer held N iterations
SITE_POD_DISPATCH = "pod_dispatch"        # prefill-pod stage dispatch fails
SITE_ALLOC_DENY = "alloc_deny"            # transient allocator admission denial
SITE_NONFINITE_LOGITS = "nonfinite_logits"  # drafter emits a non-finite row

SITES: Tuple[str, ...] = (
    SITE_TRANSFER_LOSS,
    SITE_TRANSFER_DELAY,
    SITE_POD_DISPATCH,
    SITE_ALLOC_DENY,
    SITE_NONFINITE_LOGITS,
)


@dataclass(frozen=True)
class FaultPlan:
    """Immutable, hashable description of a chaos schedule.

    ``rates`` drives probabilistic injection (per-site Bernoulli on a
    counter-mode hash of ``seed × site × iteration × rid``);
    ``schedule`` pins explicit ``(site, iteration, rid)`` triples that
    fire unconditionally (use ``rid=-1`` to match any request at that
    iteration).  Both coexist; rate-driven firings stop after
    ``max_per_site`` so every chaos run quiesces.
    """

    seed: int = 0
    rates: Tuple[Tuple[str, float], ...] = ()
    schedule: Tuple[Tuple[str, int, int], ...] = ()
    max_per_site: int = 4
    # Degradation-ladder knobs (consumed by the engine, carried here so
    # one object describes the whole failure model of a run).
    transfer_delay_iters: int = 2      # how long a delayed transfer is held
    transfer_timeout_iters: int = 4    # inflight iterations before a retry
    transfer_max_retries: int = 2      # re-dispatches before lane failover
    pod_failure_limit: int = 3         # pod-side failures before disagg→async

    def __post_init__(self) -> None:
        for site, _ in self.rates:
            if site not in SITES:
                raise ValueError(f"unknown fault site in rates: {site!r}")
        for site, _, _ in self.schedule:
            if site not in SITES:
                raise ValueError(f"unknown fault site in schedule: {site!r}")

    @staticmethod
    def make(seed: int = 0, rates: Dict[str, float] | None = None,
             schedule=(), **kw) -> "FaultPlan":
        """Dict-friendly constructor (``FaultPlan`` itself stores tuples
        so it stays hashable inside the frozen ``EngineConfig``)."""
        r = tuple(sorted((rates or {}).items()))
        s = tuple((site, int(it), int(rid)) for site, it, rid in schedule)
        return FaultPlan(seed=seed, rates=r, schedule=s, **kw)


def _unit_hash(seed: int, site: str, iteration: int, rid: int) -> float:
    """Deterministic uniform in [0, 1) from the injection coordinates."""
    key = f"{seed}:{site}:{iteration}:{rid}".encode()
    digest = hashlib.blake2b(key, digest_size=8).digest()
    return int.from_bytes(digest, "big") / float(1 << 64)


class FaultInjector:
    """Evaluates a ``FaultPlan`` at each (site, iteration, rid) coordinate.

    The injector is pure host-side bookkeeping: it decides *whether* a
    fault fires and logs it; the call site owns *what* the fault means
    (dropping a transfer entry, vetoing an admission, building a device
    corruption mask).  ``log`` is the ground truth a chaos test uses to
    partition requests into affected vs unaffected.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rates: Dict[str, float] = dict(plan.rates)
        self._sched = set(plan.schedule)
        self._fired: Dict[str, int] = {site: 0 for site in SITES}
        self.log: List[Tuple[str, int, int]] = []

    def fires(self, site: str, *, iteration: int, rid: int) -> bool:
        if site not in SITES:
            raise ValueError(f"unregistered fault site: {site!r}")
        hit = (site, iteration, rid) in self._sched or \
              (site, iteration, -1) in self._sched
        if not hit:
            rate = self._rates.get(site, 0.0)
            if rate > 0.0 and self._fired[site] < self.plan.max_per_site:
                hit = _unit_hash(self.plan.seed, site, iteration, rid) < rate
        if hit:
            self._fired[site] += 1
            self.log.append((site, iteration, rid))
        return hit

    def affected_rids(self, site: str | None = None) -> set:
        """rids that took at least one injection (optionally one site)."""
        return {rid for s, _, rid in self.log
                if rid >= 0 and (site is None or s == site)}

    def stats(self) -> Dict[str, int]:
        return {site: n for site, n in self._fired.items() if n}
