"""Jitted model-execution bodies for the serving engine.

Two fixed-shape programs cover the whole request lifecycle:

* :func:`prefill_body` — **chunked prefill**: every admitted slot advances
  through up to ``cfg.prefill_chunk`` prompt tokens per call (both target
  and drafter, ``mode="verify"`` with per-row valid lengths), so prompt
  ingestion is ONE compiled program regardless of prompt length and runs
  concurrently with decode for the already-ready slots — no per-bucket
  jit cache, no blocking the decode loop on admission.

* :func:`decode_body` — one speculative iteration over all ready slots
  (the old engine ``_iteration``): drafter catch-up chunk, gamma-1 draft
  steps, target verify chunk, draft verification (token / block / greedy
  — the paper's algorithms), commit. EOS / max-new-tokens / max-len stop
  detection runs *inside* the program, so the host loop syncs only the
  small :class:`StepOutputs` tuple per step and the bookkeeping arrays
  stay device-resident (see ``repro.serving.batch``).

* :func:`stage_prefill_body` — the **async staging lane**
  (``cfg.async_prefill``): a detached chunked-prefill program over the
  engine's :class:`~repro.serving.batch.StageState` — its own slot
  bookkeeping, disjoint from :class:`BatchState` — that writes both
  models' prompt K/V directly into *staged* pool pages
  (``paging.ensure(mark_staged=True)``) and flips each slot's ``ready``
  flag in-program when the final chunk lands. Because no decode slot's
  page table maps a staged page, :func:`decode_body` is structurally
  blind to in-flight prefill: the engine dispatches both programs in
  the same host iteration (decode first) and a completed prefill joins
  the decode batch by *adoption* — table install + ``staged``-mark
  clear, zero K/V movement. Requires fully-paged caches: pooled
  storage is what lets prompt state written at batch index ``i`` of a
  staging program be read at batch index ``j`` of the decode program.

* :func:`decode_body_multipath` — the ``num_paths > 1`` variant: after
  the shared drafter catch-up, the slot's page table is **forked** into
  K aliased path tables (``paging.fork``), each path copy-on-writes the
  shared boundary page and grows private speculative pages
  (``paging.cow_ensure``), K draft paths run as ``B * K`` flattened
  lanes through one drafter scan and ONE fused target verify pass
  (every lane attends through its own aliased table into the shared
  pools), greedy multi-path verification picks the winning path, whose
  table the slot adopts; the losing paths' claims are released inside
  the same program. ``num_paths == 1`` keeps :func:`decode_body`
  bitwise intact.

Bookkeeping invariants (per slot): ``seq_buf[: len]`` holds all committed
tokens; the *target* has consumed ``seq_buf[: len-1]`` — the last
committed token is consumed at the start of the next verify chunk; the
*drafter* has consumed ``seq_buf[: d_len]`` and catches up to ``len`` at
the start of each iteration (a small re-process chunk; cheap because the
drafter is small, and it makes SSM-state rollback trivial: the drafter
never commits state past ``len``). KV ring writes past ``len`` are safe:
they are either overwritten by the true tokens at those positions or
masked by causality — provided the cache ``chunk_slack`` covers the
longest in-flight chunk (``max(gamma + 1, prefill_chunk)``).

Note on verifiers: ``token`` and ``block`` are lossless end-to-end (the
greedy-equality tests check token-identical outputs at temperature 0).
``greedy_block`` is served WITHOUT the Algorithm-5 distribution
modification (the paper presents it as a theoretical device and
recommends block verification); its faithful lossless form — including
nested modification — lives in ``repro.core.simulate``.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sampling, verification
from repro.models.attention import PagedKV
from repro.models.model import Model
from repro.models.transformer import build_plan
from repro.models.ssm import SSMEntry
from repro.serving import paging
from repro.serving.batch import BatchState, StageState


class StepOutputs(NamedTuple):
    """The only per-iteration device→host traffic (all shapes O(B·gamma)):
    everything else — seq_buf, lens, masks, caches — stays on device."""

    tokens: jax.Array      # (B, G+1) int32 — this iteration's decoded tokens
    n_keep: jax.Array      # (B,) int32 — tokens to emit (0 past EOS/budget)
    num_tokens: jax.Array  # (B,) int32 — tau + 1 (acceptance accounting)
    done: jax.Array        # (B,) bool — slot finished, retire it


def _restore_ssm(drafted_cache, committed_cache):
    """Keep post-draft KV entries (stale-safe) but restore SSM entries to
    the committed catch-up state (SSM state cannot be rolled back)."""

    def pick(a, b):
        if isinstance(a, SSMEntry):
            return b
        return a

    return jax.tree.map(
        pick, drafted_cache, committed_cache,
        is_leaf=lambda x: isinstance(x, SSMEntry),
    )


def _mask_batch(new, old, mask, axis):
    shape = [1] * new.ndim
    shape[axis] = mask.shape[0]
    return jnp.where(mask.reshape(shape), new, old)


def _mask_cache(new_cache, old_cache, mask):
    """Per-slot cache select: stacked *per-slot* cache entries carry batch
    at axis 1. :class:`PagedKV` pools pass through as-is — their per-slot
    write suppression already happened at scatter time (``kv_write_mask``
    in the model forward), because pooled storage has no batch axis to
    select over after the fact."""
    return jax.tree.map(
        lambda new, old: (
            new if isinstance(new, PagedKV)
            else _mask_batch(new, old, mask, axis=1)
        ),
        new_cache, old_cache,
        is_leaf=lambda x: isinstance(x, PagedKV),
    )


def _probs_of(cfg, vocab: int, logits: jax.Array) -> jax.Array:
    return sampling.logits_to_probs(
        logits[..., :vocab], temperature=cfg.temperature
    )


def _catch_up_drafter(
    drafter: Model, cfg, d_params, d_cache,
    seq_buf, lens, d_lens, page_table, write_mask,
):
    """Shared head of both decode bodies — drafter catch-up: one chunk of
    up to ``gamma + 1`` tokens advances the drafter from ``d_lens`` to
    the committed length ``lens``. Returns the committed drafter cache
    and ``q(.| committed prefix)`` as probabilities."""
    g = cfg.gamma
    k_catch = g + 1
    idx = d_lens[:, None] + jnp.arange(k_catch)[None]
    catch_toks = jnp.take_along_axis(
        seq_buf, jnp.minimum(idx, seq_buf.shape[1] - 1), axis=1
    )
    n_valid = jnp.clip(lens - d_lens, 1, k_catch)  # in [1, g+1]
    d_logits, d_vcache, _ = drafter.apply(
        d_params, catch_toks, cache=d_cache, lens=d_lens,
        mode="verify", valid_len=n_valid,
        page_table=page_table, kv_write_mask=write_mask,
    )
    committed = drafter.commit_cache(d_vcache, n_valid - 1)
    # q(. | committed prefix): logits at index n_valid-1.
    last_q_logits = jnp.take_along_axis(
        d_logits, (n_valid - 1)[:, None, None], axis=1
    )[:, 0]
    return committed, _probs_of(cfg, drafter.cfg.vocab, last_q_logits)


def _draft_gamma(
    drafter: Model, cfg, d_params, cache,
    q0, lens, page_table, write_mask, key,
):
    """Shared by both decode bodies — sample ``X_1 .. X_gamma``
    autoregressively from the drafter, one lane per draft path. Returns
    ``(drafted cache, draft_toks (N, G), q_rows (N, G, V))`` with
    ``q_rows = [q0, q(.|X^1), ..., q(.|X^{G-1})]`` as verification
    needs them."""
    g = cfg.gamma
    vocab = drafter.cfg.vocab
    key, sub = jax.random.split(key)
    x1 = sampling.categorical(sub, q0)

    def draft_step(carry, i):
        cache, tok, key_i = carry
        key_i, sub = jax.random.split(key_i)
        # the drafter has consumed lens + i tokens so far
        logits, cache, _ = drafter.apply(
            d_params, tok[:, None], cache=cache, lens=lens + i,
            mode="decode", page_table=page_table, kv_write_mask=write_mask,
        )
        q = _probs_of(cfg, vocab, logits[:, 0])
        nxt = sampling.categorical(sub, q)
        return (cache, nxt, key_i), (tok, q)

    (drafted, _, _), (draft_toks, q_scan) = jax.lax.scan(
        draft_step, (cache, x1, key), jnp.arange(g)
    )
    draft_toks = draft_toks.T                          # (N, G): X_1..X_G
    # q_scan[i] = q(. | prefix, X_1..X_{i+1}); verification needs
    # [q0, q(.|X_1), ..., q(.|X^{G-1})].
    q_rows = jnp.concatenate(
        [q0[:, None], jnp.swapaxes(q_scan, 0, 1)[:, : g - 1]], axis=1
    )                                                  # (N, G, V)
    return drafted, draft_toks, q_rows


def _commit_and_stop(cfg, batch: BatchState, run, tokens, num_tokens):
    """Shared tail of both decode bodies: write the iteration's committed
    tokens into ``seq_buf``, advance ``lens``/``d_lens``, and detect
    EOS / max-new-tokens / max-len stops on device. Returns
    ``(seq_buf, new_lens, new_d_lens, n_keep, done)``."""
    seq_buf, lens, d_lens = batch.seq_buf, batch.lens, batch.d_lens
    b = seq_buf.shape[0]
    g = cfg.gamma
    pos = jnp.arange(g + 1)[None]
    write_idx = lens[:, None] + pos
    valid = (pos < num_tokens[:, None]) & run[:, None]
    write_idx = jnp.where(valid, write_idx, seq_buf.shape[1] - 1)
    b_idx = jnp.broadcast_to(jnp.arange(b)[:, None], write_idx.shape)
    seq_buf = seq_buf.at[b_idx, write_idx].set(
        jnp.where(valid, tokens, seq_buf[b_idx, write_idx])
    )
    new_lens = jnp.where(run, lens + num_tokens, lens)
    new_d_lens = jnp.where(run, lens, d_lens)

    emitted_before = lens - batch.out_start  # output tokens so far
    cum_out = emitted_before[:, None] + pos + 1
    in_block = pos < num_tokens[:, None]
    hit = in_block & (cum_out >= batch.max_new[:, None])
    if cfg.eos_id >= 0:
        hit = hit | (in_block & (tokens == cfg.eos_id))
    first_stop = jnp.min(jnp.where(hit, pos, g + 1), axis=1)
    n_keep = jnp.where(run, jnp.minimum(num_tokens, first_stop + 1), 0)
    done = run & (
        (first_stop <= g) | (new_lens + g + 2 >= cfg.max_len)
    )
    return seq_buf, new_lens, new_d_lens, n_keep, done


def _ensure_pages(cfg, batch: BatchState, need_len, mask):
    """Grow masked slots' page tables to cover ``need_len`` tokens (no-op
    for dense engines). Returns (batch, ok): ``ok=False`` slots got no
    pages and must sit the step out — the scheduler's host-side budget
    makes that unreachable in the engine, but the mask keeps an
    over-subscribed pool from ever corrupting live slots."""
    spec = paging.spec_of(cfg)
    if spec is None:
        return batch, jnp.ones_like(mask)
    table, used, pool, ok = paging.ensure(
        spec, batch.page_table, batch.pages_used, batch.pool, need_len, mask
    )
    return batch._replace(page_table=table, pages_used=used, pool=pool), ok


def prefill_body(
    target: Model, drafter: Model, cfg,
    t_params, d_params, t_cache, d_cache, batch: BatchState,
):
    """Advance every prefilling slot by one fixed-size prompt chunk.

    Both models consume up to ``cfg.prefill_chunk`` tokens per slot from
    ``seq_buf[t_pref:]`` (stopping at ``lens - 1``: the engine invariant
    is that the last committed token is consumed by the next chunk —
    verify chunk for the target, catch-up chunk for the drafter). Slots
    that are ready, inactive, or mid-decode are restored untouched.
    """
    c = cfg.prefill_chunk
    rem = batch.lens - 1 - batch.t_pref
    # Held rows (riders on a live writer's prefill) consume nothing:
    # their committed prefix is being written by another row, and the
    # engine advances t_pref by claiming the writer's pages instead.
    pending = batch.active & ~batch.ready & ~batch.hold
    n = jnp.where(pending, jnp.clip(rem, 0, c), 0)   # tokens this chunk
    # Pages are allocated incrementally as the prompt streams in — a
    # long-prompt slot only holds pages for what it has consumed so far.
    batch, ok = _ensure_pages(cfg, batch, batch.t_pref + n, n > 0)
    n = jnp.where(ok, n, 0)
    nn = jnp.maximum(n, 1)                           # safe valid_len
    touched = n > 0

    idx = batch.t_pref[:, None] + jnp.arange(c)[None]
    toks = jnp.take_along_axis(
        batch.seq_buf, jnp.minimum(idx, batch.max_len - 1), axis=1
    )

    def advance(model, params, cache):
        _, vcache, _ = model.apply(
            params, toks, cache=cache, lens=batch.t_pref,
            mode="verify", valid_len=nn, last_logits_only=True,
            page_table=batch.page_table, kv_write_mask=touched,
        )
        # commit_cache(c, k) commits k+1 consumed tokens.
        return _mask_cache(model.commit_cache(vcache, nn - 1), cache, touched)

    t_cache = advance(target, t_params, t_cache)
    d_cache = advance(drafter, d_params, d_cache)

    t_pref = batch.t_pref + n
    ready = batch.ready | (batch.active & (t_pref >= batch.lens - 1))
    return t_cache, d_cache, batch._replace(t_pref=t_pref, ready=ready)


def stage_prefill_body(
    target: Model, drafter: Model, cfg, spec: paging.PageSpec,
    t_params, d_params, t_cache, d_cache,
    stage: StageState, pool: paging.PagePool,
):
    """Advance every staging slot by one fixed-size prompt chunk — the
    background half of the disaggregated serve loop.

    Mirrors :func:`prefill_body` over :class:`StageState` instead of
    :class:`BatchState`: pages are popped with ``mark_staged=True`` (so
    the staging lane's writes are provably invisible to decode until
    adoption), both models consume up to ``cfg.prefill_chunk`` tokens
    from ``seq_buf[pos:]`` stopping at ``plen - 1``, and a slot whose
    final chunk lands flips ``ready`` in-program. The caches are the
    engine's shared pytrees — fully paged by the ``async_prefill``
    gate, so every write is a pool scatter through the *staging* table
    and the per-slot write suppression happens at scatter time
    (``kv_write_mask``); no commit/mask select is needed afterwards
    (``commit_cache`` is the identity for pooled entries).

    ``spec`` is the pool geometry this program allocates out of —
    ``paging.spec_of`` for the shared-pool engine,
    ``paging.stage_spec_of`` under ``disaggregated=True`` where the
    caches/pool live on the prefill pod and this executable never
    touches decode-pod state."""
    c = cfg.prefill_chunk
    rem = stage.plen - 1 - stage.pos
    # Riders hold like in prefill_body — the engine rides the writer.
    pending = stage.active & ~stage.ready & ~stage.hold
    n = jnp.where(pending, jnp.clip(rem, 0, c), 0)  # tokens this chunk
    table, used, pool, ok = paging.ensure(
        spec, stage.page_table, stage.pages_used, pool,
        stage.pos + n, n > 0, mark_staged=True,
    )
    n = jnp.where(ok, n, 0)
    nn = jnp.maximum(n, 1)                          # safe valid_len
    touched = n > 0

    idx = stage.pos[:, None] + jnp.arange(c)[None]
    toks = jnp.take_along_axis(
        stage.seq_buf, jnp.minimum(idx, stage.max_len - 1), axis=1
    )

    def advance(model, params, cache):
        _, vcache, _ = model.apply(
            params, toks, cache=cache, lens=stage.pos,
            mode="verify", valid_len=nn, last_logits_only=True,
            page_table=table, kv_write_mask=touched,
        )
        return vcache

    t_cache = advance(target, t_params, t_cache)
    d_cache = advance(drafter, d_params, d_cache)

    pos = stage.pos + n
    ready = stage.ready | (stage.active & (pos >= stage.plen - 1))
    stage = stage._replace(
        pos=pos, ready=ready, page_table=table, pages_used=used
    )
    return t_cache, d_cache, stage, pool


def _release_stage_row(
    spec, stage: StageState, pool: paging.PagePool, sid, cache_cols
):
    """Kill one staging row (background prefill preempted): drop its
    page claims — entries flagged in ``cache_cols`` park ``cached``
    (the engine registered the fully-written pages in the prefix index
    in the same breath), the rest return to the free stack — and clear
    the row. Shared-pool adoption does NOT come through here: an
    adopted row's pages transfer to the decode slot and only the
    bookkeeping resets (``batch.clear_stage_slot``). DISAGGREGATED
    adoption does: after the pack program copies the staged K/V out,
    the prefill-pool source pages are dead and this releases them."""
    mask = jnp.arange(stage.num_slots) == sid
    table, used, pool = paging.release(
        spec, stage.page_table, stage.pages_used, pool, mask,
        cache_cols=mask[:, None] & cache_cols[None, :],
    )
    z = jnp.zeros_like(stage.pos)
    return stage._replace(
        active=stage.active & ~mask, ready=stage.ready & ~mask,
        hold=stage.hold & ~mask,
        pos=jnp.where(mask, z, stage.pos),
        plen=jnp.where(mask, z, stage.plen),
        page_table=table, pages_used=used,
    ), pool


def _pack_stage_pages(cache, page_ids: jax.Array):
    """Prefill-pod half of a disaggregated adoption transfer: gather the
    ``n`` staged pages named by ``page_ids`` out of every pool leaf into
    compact ``(G, n, page, n_kv, hd)`` buffers. The result is what the
    engine ``jax.device_put``s to the decode pod — only the adopted
    prompt's K/V crosses the interconnect, never the pool. Shapes are
    keyed on ``n`` (the staged page count), so the jit cache holds one
    tiny gather program per distinct prompt page count."""

    def one(leaf: PagedKV) -> PagedKV:
        return PagedKV(k=leaf.k[:, page_ids], v=leaf.v[:, page_ids])

    return jax.tree.map(
        one, cache, is_leaf=lambda x: isinstance(x, PagedKV)
    )


def _unpack_stage_pages(
    spec: paging.PageSpec, n: int,
    t_cache, d_cache, batch: BatchState, slot, t_packed, d_packed,
):
    """Decode-pod half of a disaggregated adoption transfer: allocate
    ``n`` fresh pages for ``slot`` out of the DECODE pool and scatter
    the transferred buffers into them. Consuming the ``device_put``
    results as inputs is what makes "decode never maps an un-arrived
    page" a dataflow fact: this program cannot execute before the
    transfer lands, and no decode dispatch can map the new pages before
    this program (same device, program order) has installed them. The
    scheduler charges the decode budget before dispatching, so the
    ensure provably succeeds; a failed ensure (unreachable) drops the
    scatter instead of corrupting live pages, mirroring
    :func:`_ensure_pages`."""
    mask = jnp.arange(batch.num_slots) == slot
    need = jnp.full((batch.num_slots,), n * spec.page_size, jnp.int32)
    table, used, pool, ok = paging.ensure(
        spec, batch.page_table, batch.pages_used, batch.pool, need, mask
    )
    ids = table[slot, :n]
    dst = jnp.where(ids >= 0, ids, jnp.iinfo(jnp.int32).max)  # drop

    def scatter(cache, packed):
        def one(leaf: PagedKV, buf: PagedKV) -> PagedKV:
            return PagedKV(
                k=leaf.k.at[:, dst].set(buf.k, mode="drop"),
                v=leaf.v.at[:, dst].set(buf.v, mode="drop"),
            )

        return jax.tree.map(
            one, cache, packed,
            is_leaf=lambda x: isinstance(x, PagedKV),
        )

    t_cache = scatter(t_cache, t_packed)
    d_cache = scatter(d_cache, d_packed)
    batch = batch._replace(page_table=table, pages_used=used, pool=pool)
    return t_cache, d_cache, batch


def decode_body(
    target: Model, drafter: Model, cfg, verify,
    t_params, d_params, t_cache, d_cache, batch: BatchState, key,
    corrupt=None,
):
    """One speculative iteration over all ready slots. Returns the updated
    caches and batch plus :class:`StepOutputs`; ``num_tokens``/``n_keep``
    are 0 and ``done`` False for slots that did not run.

    ``corrupt`` (fault plane, ``cfg.faults``): optional per-slot bool
    mask — flagged slots' drafted probability rows are overwritten with
    NaN before verification, modelling a drafter that emitted non-finite
    logits. The non-finite guard in ``verification.make_context`` zeroes
    those rows, so every draft token rejects and the bonus falls back to
    a pure target-distribution sample — still lossless. ``None`` (the
    only value ever passed without a fault plan) traces the exact
    fault-free program."""
    seq_buf, lens, d_lens = batch.seq_buf, batch.lens, batch.d_lens
    g = cfg.gamma
    vocab = target.cfg.vocab
    run = batch.active & batch.ready
    # One iteration writes K/V through position lens + gamma (verify
    # chunk [lens-1, lens+g-1] plus the drafter's catch-up reaching
    # lens + g); grow the page tables to cover it before any scatter.
    batch, ok = _ensure_pages(cfg, batch, lens + g + 1, run)
    run = run & ok
    key_d, key_v = jax.random.split(key)

    # ---- 1. drafter catch-up: chunk of up to g+1 tokens from d_lens. ----
    d_cache_committed, q0 = _catch_up_drafter(
        drafter, cfg, d_params, d_cache, seq_buf, lens, d_lens,
        batch.page_table, run,
    )

    # ---- 2. draft gamma tokens. ----
    d_cache_drafted, draft_toks, q_rows = _draft_gamma(
        drafter, cfg, d_params, d_cache_committed, q0, lens,
        batch.page_table, run, key_d,
    )
    d_cache_next = _restore_ssm(d_cache_drafted, d_cache_committed)

    if corrupt is not None:
        # Fault plane: flagged slots' drafter rows become non-finite
        # before verification (static Python branch — fault-free runs
        # trace the identical program).
        q_rows = jnp.where(corrupt[:, None, None], jnp.nan, q_rows)

    # ---- 3. target verify chunk [last_token, X_1..X_gamma]. ----
    last_tok = jnp.take_along_axis(seq_buf, (lens - 1)[:, None], axis=1)
    chunk = jnp.concatenate([last_tok, draft_toks], axis=1)  # (B, G+1)
    t_logits, t_vcache, _ = target.apply(
        t_params, chunk, cache=t_cache, lens=lens - 1, mode="verify",
        page_table=batch.page_table, kv_write_mask=run,
    )
    p_rows = _probs_of(cfg, vocab, t_logits)            # (B, G+1, V)

    # ---- 4. verification (the paper's algorithms). ----
    res = verify(key_v, verification.make_context(draft_toks, q_rows, p_rows))
    tau = res.num_accepted
    num_tokens = jnp.where(run, res.num_tokens, 0)

    # ---- 5. commit + stop detection (device-side). ----
    t_cache_next = _mask_cache(target.commit_cache(t_vcache, tau), t_cache, run)
    d_cache_next = _mask_cache(d_cache_next, d_cache, run)
    seq_buf, new_lens, new_d_lens, n_keep, done = _commit_and_stop(
        cfg, batch, run, res.tokens, num_tokens
    )

    # Deactivate finished slots on device immediately: with the engine's
    # double-buffered loop the next iteration is dispatched before the host
    # sees `done`, and this mask keeps that in-flight step from wasting
    # work on (or corrupting state of) a finished slot.
    new_batch = batch._replace(
        seq_buf=seq_buf, lens=new_lens, d_lens=new_d_lens,
        active=batch.active & ~done, ready=batch.ready & ~done,
    )
    outs = StepOutputs(
        tokens=res.tokens, n_keep=n_keep, num_tokens=num_tokens, done=done
    )
    return t_cache_next, d_cache_next, new_batch, outs


def _apply_pool_copies(cache, copy_src: jax.Array, copy_dst: jax.Array):
    """Apply CoW page copies (physical src -> dst pairs, -1 = none) to
    every :class:`PagedKV` pool in a cache pytree. Pool leaves are
    stacked over layer groups — pages live on axis 1."""
    src = copy_src.reshape(-1)
    dst = copy_dst.reshape(-1)
    dst = jnp.where(dst >= 0, dst, jnp.iinfo(jnp.int32).max)  # drop

    def copy(leaf: PagedKV) -> PagedKV:
        def one(pool):
            rows = pool[:, jnp.clip(src, 0, pool.shape[1] - 1)]
            return pool.at[:, dst].set(rows, mode="drop")

        return PagedKV(k=one(leaf.k), v=one(leaf.v))

    return jax.tree.map(
        lambda e: copy(e) if isinstance(e, PagedKV) else e,
        cache,
        is_leaf=lambda x: isinstance(x, PagedKV),
    )


def _tile_paths(x: jax.Array, num_paths: int) -> jax.Array:
    """(B, ...) -> (B * K, ...) with lane index b * K + j."""
    return jnp.repeat(x, num_paths, axis=0)


def decode_body_multipath(
    target: Model, drafter: Model, cfg, verify_mp,
    t_params, d_params, t_cache, d_cache, batch: BatchState, key,
    corrupt=None,
):
    """One multi-path speculative iteration (``cfg.num_paths`` > 1).

    Requires fully-paged caches (both models all-global attention): the
    K forked paths share every pool and differ only through their page
    tables, so the drafter scan and the target verify chunk run as one
    fused fixed-shape program over ``B * num_paths`` lanes."""
    spec = paging.spec_of(cfg)
    seq_buf, lens, d_lens = batch.seq_buf, batch.lens, batch.d_lens
    b = seq_buf.shape[0]
    g = cfg.gamma
    k = cfg.num_paths
    bk = b * k
    vocab = target.cfg.vocab
    run = batch.active & batch.ready
    key_d, key_v = jax.random.split(key)

    # ---- 0. cover the committed prefix; speculative pages are per-path.
    table, used, pool, ok = paging.ensure(
        spec, batch.page_table, batch.pages_used, batch.pool, lens, run
    )
    run = run & ok

    # ---- 1. drafter catch-up on the committed tokens (once per slot:
    # pre-fork, through the slot's main table — every path forks this
    # state). ----
    d_cache, q0 = _catch_up_drafter(
        drafter, cfg, d_params, d_cache, seq_buf, lens, d_lens, table, run
    )

    # ---- 2. fork the page table into K aliased path tables and prepare
    # each path's write window (CoW the shared boundary page, grow
    # private speculative pages). ----
    path_tables, path_used, pool = paging.fork(spec, table, used, pool, k, run)
    pt = path_tables.reshape(bk, spec.max_pages)
    pu = path_used.reshape(bk)
    run_k = _tile_paths(run, k)
    lens_k = _tile_paths(lens, k)
    w_pages = spec.pages_for(g + 1) + 1  # write window [lens-1, lens+g)
    pt, pu, pool, copy_src, copy_dst, ok_k = paging.cow_ensure(
        spec, pt, pu, pool, lens_k - 1, lens_k + g, run_k,
        max_write_pages=w_pages,
    )
    # All-or-nothing per slot: a slot whose paths could not all get pages
    # sits the step out (the host budget makes this unreachable).
    run = run & jnp.all(ok_k.reshape(b, k), axis=1)
    run_k = _tile_paths(run, k)
    t_cache = _apply_pool_copies(t_cache, copy_src, copy_dst)
    d_cache = _apply_pool_copies(d_cache, copy_src, copy_dst)

    # ---- 3. draft K i.i.d. paths (B * K flattened lanes). ----
    d_cache_drafted, draft_toks, q_rows = _draft_gamma(
        drafter, cfg, d_params, d_cache, _tile_paths(q0, k), lens_k,
        pt, run_k, key_d,
    )                                                  # (BK, G), (BK, G, V)
    d_cache = _restore_ssm(d_cache_drafted, d_cache)

    if corrupt is not None:
        # Fault plane: a flagged slot corrupts every one of its K paths
        # (static branch; see :func:`decode_body`).
        q_rows = jnp.where(
            _tile_paths(corrupt, k)[:, None, None], jnp.nan, q_rows
        )

    # ---- 4. ONE fused target pass verifies all K paths: each lane
    # attends through its own aliased page table into the shared pools.
    last_tok = jnp.take_along_axis(seq_buf, (lens - 1)[:, None], axis=1)
    chunk = jnp.concatenate(
        [_tile_paths(last_tok, k), draft_toks], axis=1
    )                                                  # (BK, G+1)
    t_logits, t_vcache, _ = target.apply(
        t_params, chunk, cache=t_cache, lens=lens_k - 1, mode="verify",
        page_table=pt, kv_write_mask=run_k,
    )
    p_rows = _probs_of(cfg, vocab, t_logits)           # (BK, G+1, V)

    # ---- 5. greedy multi-path verification. ----
    mctx = verification.make_multi_context(
        draft_toks.reshape(b, k, g),
        q_rows.reshape(b, k, g, vocab),
        p_rows.reshape(b, k, g + 1, vocab),
    )
    res = verify_mp(key_v, mctx)
    tau = res.num_accepted
    num_tokens = jnp.where(run, res.num_tokens, 0)

    # ---- 6. adopt the winner's table, release the losing paths. Every
    # forked slot adopts exactly one path row's claim (a slot that sat
    # the step out adopts path 0, whose table is a superset alias of its
    # old one) so the committed pages' refcounts return to exactly 1.
    forked = batch.active & batch.ready & ok
    winner = jnp.where(run, res.winner, 0)
    t_cache = _mask_cache(target.commit_cache(t_vcache, tau), t_cache, run)
    path_tables = pt.reshape(b, k, spec.max_pages)
    path_used = pu.reshape(b, k)
    win_table = jnp.take_along_axis(
        path_tables, winner[:, None, None], axis=1
    )[:, 0]
    win_used = jnp.take_along_axis(path_used, winner[:, None], axis=1)[:, 0]
    new_table = jnp.where(forked[:, None], win_table, table)
    new_used = jnp.where(forked, win_used, used)
    keep = jnp.tile(jnp.arange(k), (b,)) == _tile_paths(winner, k)
    pt, pu, pool = paging.release(
        spec, pt, pu, pool, _tile_paths(forked, k) & ~keep
    )

    # ---- 7. commit + stop detection (shared with the single-path body).
    seq_buf, new_lens, new_d_lens, n_keep, done = _commit_and_stop(
        cfg, batch, run, res.tokens, num_tokens
    )

    new_batch = batch._replace(
        seq_buf=seq_buf, lens=new_lens, d_lens=new_d_lens,
        active=batch.active & ~done, ready=batch.ready & ~done,
        page_table=new_table, pages_used=new_used, pool=pool,
    )
    outs = StepOutputs(
        tokens=res.tokens, n_keep=n_keep, num_tokens=num_tokens, done=done
    )
    return t_cache, d_cache, new_batch, outs


def _assert_all_paged(
    model: Model, cfg, chunk_slack: int, role: str,
    feature: str = "num_paths",
):
    """Multi-path serving runs K paths as flattened lanes over shared
    page pools, prefix-cache claims restore pooled K/V only, and the
    async staging lane prefills at one batch index what decode reads at
    another — in every case each cache entry must be a
    :class:`PagedKV` (no dense rings, SSM states or cross-attention
    caches, whose per-slot batch axes cannot follow a fork, survive a
    claim, or cross from the staging program to the decode program)."""
    cache = jax.eval_shape(
        lambda: model.init_cache(
            1, cfg.max_len, chunk_slack=chunk_slack, page_pool=(1, 1)
        )
    )
    plan = build_plan(model.cfg)
    bad = []  # (global layer indices, LayerDef, offending entry types)
    base = 0
    for seg_def, seg in zip(plan, cache["segments"]):
        width = len(seg_def.layers)
        for j, (ldef, entry) in enumerate(zip(seg_def.layers, seg)):
            parts = entry.values() if isinstance(entry, dict) else [entry]
            types = sorted(
                {
                    type(e).__name__
                    for e in parts
                    if not isinstance(e, PagedKV)
                }
            )
            if types:
                idxs = [
                    base + g * width + j for g in range(seg_def.n_groups)
                ]
                bad.append((idxs, ldef, types))
        base += width * seg_def.n_groups
    if bad:
        want = {
            "num_paths": f"num_paths={cfg.num_paths}",
            "prefix_cache": "prefix_cache=True",
            "async_prefill": "async_prefill=True",
        }.get(feature, f"{feature}=True")

        def fmt_idxs(idxs):
            head = ", ".join(map(str, idxs[:8]))
            return head + (", ..." if len(idxs) > 8 else "")

        detail = "; ".join(
            f"layer{'s' if len(idxs) > 1 else ''} [{fmt_idxs(idxs)}]: "
            + ldef.kind
            + (f"(window={ldef.window})" if ldef.window > 0 else "")
            + f" -> {'/'.join(types)}"
            for idxs, ldef, types in bad
        )
        raise ValueError(
            f"{want} needs fully-paged caches, but the "
            f"{role} model {model.cfg.name!r} has non-paged entries at "
            f"{detail}; serve it without {feature}"
        )


class Runner:
    """Owns the compiled programs for one (target, drafter) pair. Exactly
    two executables cover the whole lifecycle — chunked prefill and the
    speculative iteration — both fixed-shape, so no shape-keyed jit
    caches and no recompiles at serve time."""

    def __init__(self, target: Model, drafter: Model, cfg):
        assert target.cfg.vocab == drafter.cfg.vocab
        self.target, self.drafter, self.cfg = target, drafter, cfg
        self.page_spec = paging.spec_of(cfg)
        self.stage_spec = paging.stage_spec_of(cfg)
        if getattr(cfg, "disaggregated", False):
            # Disaggregation is a placement refinement of async prefill:
            # the SAME staging executable, on its own device group over
            # its own pool, with adoption swapped from a mask flip to a
            # pack -> device_put -> unpack transfer.
            if not getattr(cfg, "async_prefill", False):
                raise ValueError(
                    "disaggregated=True requires async_prefill=True"
                )
        self.verify = verification.get_ctx_verifier(
            cfg.verifier, residual_backend=cfg.residual_backend
        )
        self._prefill_fn = jax.jit(partial(prefill_body, target, drafter, cfg))
        if getattr(cfg, "prefix_cache", False):
            # Prefix claims restore only pooled K/V; dense rings and SSM
            # states are zeroed per slot at admission, so a claimed
            # prefix would silently lose those layers' history.
            if self.page_spec is None:
                raise ValueError("prefix_cache=True requires paged=True")
            for model, role in ((target, "target"), (drafter, "drafter")):
                _assert_all_paged(
                    model, cfg, self.chunk_slack, role,
                    feature="prefix_cache",
                )
        if getattr(cfg, "live_share", False):
            # Live sharing leans on the prefix cache everywhere: live
            # spans live in the SAME radix index, rides abort by parking
            # the writer's committed pages cached, and live→cached
            # conversion at release is what lets a claimant outlive its
            # writer. Without prefix_cache none of those paths exist.
            if not getattr(cfg, "prefix_cache", False):
                raise ValueError("live_share=True requires prefix_cache=True")
        if getattr(cfg, "async_prefill", False):
            # The staging program's batch is the stage-slot count, not
            # max_slots: only pooled (batch-free) K/V written there can
            # be read back by the decode program after adoption.
            if self.page_spec is None:
                raise ValueError("async_prefill=True requires paged=True")
            if getattr(cfg, "stage_slots", 0) < 1:
                raise ValueError(
                    "async_prefill=True needs at least one staging lane "
                    f"(stage_slots={cfg.stage_slots})"
                )
            for model, role in ((target, "target"), (drafter, "drafter")):
                _assert_all_paged(
                    model, cfg, self.chunk_slack, role,
                    feature="async_prefill",
                )
            # Staging allocates out of stage_spec's pool: the decode
            # pool itself for the shared-pool engine, the prefill pod's
            # own pool when disaggregated.
            self._stage_prefill_fn = jax.jit(
                partial(stage_prefill_body, target, drafter, cfg,
                        self.stage_spec)
            )
            self._release_stage_fn = jax.jit(
                partial(_release_stage_row, self.stage_spec)
            )
            self._pack_stage_fn = jax.jit(_pack_stage_pages)
            self._unpack_stage_fn = jax.jit(
                partial(_unpack_stage_pages, self.page_spec),
                static_argnums=0,
            )
        if getattr(cfg, "num_paths", 1) > 1:
            if self.page_spec is None:
                raise ValueError("num_paths > 1 requires paged=True")
            _assert_all_paged(target, cfg, self.chunk_slack, "target")
            _assert_all_paged(drafter, cfg, self.chunk_slack, "drafter")
            verify_mp = verification.get_multipath_verifier(
                cfg.residual_backend
            )
            self._decode_fn = jax.jit(
                partial(
                    decode_body_multipath, target, drafter, cfg, verify_mp
                )
            )
        else:
            self._decode_fn = jax.jit(
                partial(decode_body, target, drafter, cfg, self.verify)
            )
        self._release_fn = jax.jit(partial(_release_slot, self.page_spec))

    @property
    def chunk_slack(self) -> int:
        """Longest in-flight chunk either program writes past a committed
        length — the ring-capacity slack the caches must reserve."""
        return max(self.cfg.gamma + 1, self.cfg.prefill_chunk)

    def init_caches(self, dtype=jnp.float32):
        cfg = self.cfg
        pool = None
        if self.page_spec is not None:
            pool = (self.page_spec.num_pages, self.page_spec.page_size)
        t_cache = self.target.init_cache(
            cfg.max_slots, cfg.max_len, dtype, chunk_slack=self.chunk_slack,
            page_pool=pool,
        )
        d_cache = self.drafter.init_cache(
            cfg.max_slots, cfg.max_len, dtype, chunk_slack=self.chunk_slack,
            page_pool=pool,
        )
        return t_cache, d_cache

    def init_stage_caches(self, dtype=jnp.float32):
        """Disaggregated engines only: the prefill pod's own cache pair,
        pooled over the staging spec's (smaller) page space. The batch
        dim is ``stage_slots`` — the staging executable's lane count —
        and only pooled K/V matters (fully-paged is asserted above), so
        the per-slot dense entries the models also allocate are inert."""
        cfg = self.cfg
        spec = self.stage_spec
        pool = (spec.num_pages, spec.page_size)
        t_cache = self.target.init_cache(
            cfg.stage_slots, cfg.max_len, dtype,
            chunk_slack=self.chunk_slack, page_pool=pool,
        )
        d_cache = self.drafter.init_cache(
            cfg.stage_slots, cfg.max_len, dtype,
            chunk_slack=self.chunk_slack, page_pool=pool,
        )
        return t_cache, d_cache

    def pack_stage(self, cache, page_ids):
        """Gather staged pages into a compact transfer buffer (runs on
        whichever device holds ``cache`` — the prefill pod)."""
        return self._pack_stage_fn(cache, jnp.asarray(page_ids, jnp.int32))

    def unpack_stage(
        self, n: int, t_cache, d_cache, batch, slot, t_packed, d_packed
    ):
        """Allocate ``n`` decode-pool pages for ``slot`` and scatter the
        transferred buffers into them (runs on the decode pod). Returns
        ``(t_cache, d_cache, batch)``."""
        return self._unpack_stage_fn(
            n, t_cache, d_cache, batch, jnp.asarray(slot, jnp.int32),
            t_packed, d_packed,
        )

    def prefill_step(self, t_params, d_params, t_cache, d_cache, batch):
        return self._prefill_fn(t_params, d_params, t_cache, d_cache, batch)

    def stage_prefill_step(
        self, t_params, d_params, t_cache, d_cache, stage, pool
    ):
        """One background-prefill chunk over the staging lane. Returns
        ``(t_cache, d_cache, stage, pool)``."""
        return self._stage_prefill_fn(
            t_params, d_params, t_cache, d_cache, stage, pool
        )

    def release_stage(
        self, stage: StageState, pool: paging.PagePool, sid: int,
        cache_cols=None,
    ):
        """Kill a staging row: release its staged pages (entries flagged
        in ``cache_cols`` park in the prefix cache) and clear the row.
        Disaggregated adoptions also come through here (no cache_cols):
        once the pack program has read the staged pages, the source
        copies return to the PREFILL pool's free stack — the decode-pod
        copies installed by the unpack are the surviving ones."""
        spec = self.stage_spec
        if cache_cols is None:
            cache_cols = jnp.zeros((spec.max_pages,), bool)
        else:
            cache_cols = jnp.asarray(cache_cols, bool)
        return self._release_stage_fn(
            stage, pool, jnp.asarray(sid, jnp.int32), cache_cols
        )

    def decode_step(
        self, t_params, d_params, t_cache, d_cache, batch, key, corrupt=None
    ):
        # ``corrupt is None`` (every call without an active fault plan)
        # omits the trailing arg entirely, so the jitted fault-free
        # program — and its compile cache key — are byte-identical to a
        # build without the fault plane.
        if corrupt is None:
            return self._decode_fn(
                t_params, d_params, t_cache, d_cache, batch, key
            )
        return self._decode_fn(
            t_params, d_params, t_cache, d_cache, batch, key,
            jnp.asarray(corrupt),
        )

    def release_slot(
        self, batch: BatchState, slot: int, cache_cols=None
    ) -> BatchState:
        """Deactivate a retired/preempted slot and (paged engines) push
        its pages back onto the free stack — except entries flagged in
        ``cache_cols`` ((max_pages,) bool), which the engine just
        registered in the prefix index: those park in the ``cached``
        state, content intact, for future claims."""
        spec = self.page_spec
        if cache_cols is None:
            cache_cols = (
                jnp.zeros((spec.max_pages,), bool)
                if spec is not None else jnp.zeros((0,), bool)
            )
        else:
            cache_cols = jnp.asarray(cache_cols, bool)
        return self._release_fn(
            batch, jnp.asarray(slot, jnp.int32), cache_cols
        )


def _release_slot(spec, batch: BatchState, slot, cache_cols):
    mask = jnp.arange(batch.num_slots) == slot
    batch = batch._replace(
        active=batch.active & ~mask, ready=batch.ready & ~mask,
        hold=batch.hold & ~mask,
    )
    if spec is None:
        return batch
    table, used, pool = paging.release(
        spec, batch.page_table, batch.pages_used, batch.pool, mask,
        cache_cols=mask[:, None] & cache_cols[None, :],
    )
    return batch._replace(page_table=table, pages_used=used, pool=pool)
