"""Device-resident batch bookkeeping for the serving engine.

:class:`BatchState` is a pytree (NamedTuple of arrays) holding every
per-slot quantity the jitted runner bodies need — including the ``active``
mask and the stop-condition inputs (``out_start``, ``max_new``) — so one
speculative iteration syncs only its small output tuple back to the host,
never the bookkeeping itself.

Slot-lifecycle invariants (see ``repro.serving.runner`` for how the jitted
bodies consume them):

* ``seq_buf[s, : lens[s]]`` holds all committed tokens of slot ``s``;
* the *target* model has consumed ``t_pref[s]`` prompt tokens while the
  slot is prefilling; once ``ready[s]`` the target has consumed
  ``lens[s] - 1`` tokens (the last committed token is consumed at the
  start of the next verify chunk);
* the *drafter* has consumed ``d_lens[s]`` tokens and catches up to
  ``lens[s]`` inside each decode iteration;
* ``out_start[s]`` is the prompt length — everything past it is output;
* ``max_new[s]`` is the per-request budget used by the in-step stop check.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BatchState(NamedTuple):
    seq_buf: jax.Array    # (B, max_len) int32 — committed tokens per slot
    lens: jax.Array       # (B,) int32 — committed token counts
    d_lens: jax.Array     # (B,) int32 — drafter-consumed token counts
    t_pref: jax.Array     # (B,) int32 — prompt tokens consumed by prefill
    active: jax.Array     # (B,) bool — slot holds a live request
    ready: jax.Array      # (B,) bool — prefill complete, slot decodable
    out_start: jax.Array  # (B,) int32 — prompt length (output begins here)
    max_new: jax.Array    # (B,) int32 — per-request new-token budget

    @property
    def num_slots(self) -> int:
        return self.seq_buf.shape[0]

    @property
    def max_len(self) -> int:
        return self.seq_buf.shape[1]


def init_batch(num_slots: int, max_len: int) -> BatchState:
    z = jnp.zeros((num_slots,), jnp.int32)
    f = jnp.zeros((num_slots,), bool)
    return BatchState(
        seq_buf=jnp.zeros((num_slots, max_len), jnp.int32),
        lens=z, d_lens=z, t_pref=z, active=f, ready=f,
        out_start=z, max_new=z,
    )


def admit_slot(
    state: BatchState, slot: int, prompt_ids: list[int], max_new: int
) -> BatchState:
    """Stage a request into a free slot. The models have consumed nothing
    yet (``t_pref = 0``); the runner's chunked prefill advances both
    through ``plen - 1`` tokens, after which the slot turns ``ready``."""
    plen = len(prompt_ids)
    assert 1 <= plen < state.max_len, (plen, state.max_len)
    row = jnp.zeros((state.max_len,), jnp.int32)
    row = row.at[:plen].set(jnp.asarray(prompt_ids, jnp.int32))
    return state._replace(
        seq_buf=state.seq_buf.at[slot].set(row),
        lens=state.lens.at[slot].set(plen),
        d_lens=state.d_lens.at[slot].set(plen - 1),
        t_pref=state.t_pref.at[slot].set(0),
        active=state.active.at[slot].set(True),
        ready=state.ready.at[slot].set(plen <= 1),
        out_start=state.out_start.at[slot].set(plen),
        max_new=state.max_new.at[slot].set(max_new),
    )


def release_slot(state: BatchState, slot: int) -> BatchState:
    """Deactivate a retired slot (its buffers are reset at readmission)."""
    return state._replace(
        active=state.active.at[slot].set(False),
        ready=state.ready.at[slot].set(False),
    )


def clear_slot_cache(cache, slot: int):
    """Zero one slot's rows across a model cache pytree (all stacked cache
    entries carry batch at axis 1). Required at admission: chunked prefill
    resumes SSM recurrences from the cached state, so a reused slot must
    start from the zero state; KV rows are zeroed for hygiene (they would
    be masked/overwritten anyway)."""
    return jax.tree.map(lambda x: x.at[:, slot].set(0), cache)
