"""Device-resident batch bookkeeping for the serving engine.

:class:`BatchState` is a pytree (NamedTuple of arrays) holding every
per-slot quantity the jitted runner bodies need — including the ``active``
mask and the stop-condition inputs (``out_start``, ``max_new``) — so one
speculative iteration syncs only its small output tuple back to the host,
never the bookkeeping itself.

Slot-lifecycle invariants (see ``repro.serving.runner`` for how the jitted
bodies consume them):

* ``seq_buf[s, : lens[s]]`` holds all committed tokens of slot ``s``;
* the *target* model has consumed ``t_pref[s]`` prompt tokens while the
  slot is prefilling; once ``ready[s]`` the target has consumed
  ``lens[s] - 1`` tokens (the last committed token is consumed at the
  start of the next verify chunk);
* the *drafter* has consumed ``d_lens[s]`` tokens and catches up to
  ``lens[s]`` inside each decode iteration;
* ``out_start[s]`` is the prompt length — everything past it is output;
* ``max_new[s]`` is the per-request budget used by the in-step stop check.

Async-prefill engines additionally carry a :class:`StageState` — the
background prefill lane's own per-slot bookkeeping, disjoint from
:class:`BatchState` by construction so the decode program never
depends on (or observes) an in-flight prefill chunk.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.attention import PagedKV
from repro.serving import paging


class BatchState(NamedTuple):
    seq_buf: jax.Array    # (B, max_len) int32 — committed tokens per slot
    lens: jax.Array       # (B,) int32 — committed token counts
    d_lens: jax.Array     # (B,) int32 — drafter-consumed token counts
    t_pref: jax.Array     # (B,) int32 — prompt tokens consumed by prefill
    active: jax.Array     # (B,) bool — slot holds a live request
    ready: jax.Array      # (B,) bool — prefill complete, slot decodable
    # hold: the slot is RIDING a live writer's prefill (live prefix
    # sharing) — its committed prefix is being written by another row,
    # and the engine grows its claim as the writer's chunks land. The
    # prefill body must not touch a held row (it would redundantly
    # re-write pages the writer owns); the engine clears the flag when
    # the ride ends and any tail remainder prefills normally.
    hold: jax.Array       # (B,) bool — prefill held while riding a writer
    out_start: jax.Array  # (B,) int32 — prompt length (output begins here)
    max_new: jax.Array    # (B,) int32 — per-request new-token budget
    # Paged-KV bookkeeping (None when the engine serves dense caches):
    # the page table maps each slot's logical pages to physical pool
    # pages; the pool is the shared device free-list plus per-page
    # refcounts. One table serves both models — target and drafter pools
    # share the page-id space. Multi-path engines fork this table into
    # K copy-on-write aliases *inside* the decode body
    # (runner.decode_body_multipath); only the adopted winner's table
    # lands back here, so the batch pytree stays (B, max_pages) no
    # matter how many paths an iteration scored.
    page_table: jax.Array | None = None   # (B, max_pages) int32, -1 empty
    pages_used: jax.Array | None = None   # (B,) int32 — allocated pages
    pool: paging.PagePool | None = None   # shared free-list + refcounts

    @property
    def num_slots(self) -> int:
        return self.seq_buf.shape[0]

    @property
    def max_len(self) -> int:
        return self.seq_buf.shape[1]


def init_batch(
    num_slots: int, max_len: int, page_spec: paging.PageSpec | None = None
) -> BatchState:
    z = jnp.zeros((num_slots,), jnp.int32)
    f = jnp.zeros((num_slots,), bool)
    table, used, pool = None, None, None
    if page_spec is not None:
        table, used = paging.init_tables(page_spec, num_slots)
        pool = paging.init_pool(page_spec)
    return BatchState(
        seq_buf=jnp.zeros((num_slots, max_len), jnp.int32),
        lens=z, d_lens=z, t_pref=z, active=f, ready=f, hold=f,
        out_start=z, max_new=z,
        page_table=table, pages_used=used, pool=pool,
    )


def committed_frontier(state: BatchState) -> jax.Array:
    """Per-slot count of OUTPUT tokens committed since the slot's
    current admission, ``max(lens - out_start, 0)`` — the device-side
    streaming frontier. Every counted token was committed by a verifier
    (nothing speculative: draft tokens live only inside the decode
    body's transient buffers, never in ``seq_buf``/``lens``). For a
    first-admission slot ``out_start`` is the original prompt length and
    this equals the host mirror ``len(req.output)`` once the step
    materializes; a preemption-resumed slot re-admits with ``prompt +
    output`` as its prompt, so its frontier counts post-resume output
    only (total committed output is then ``lens - len(req.prompt)``).
    Either way a streaming front end's ``emitted`` cursor never passes
    the committed count — streamed tokens are committed tokens."""
    return jnp.maximum(state.lens - state.out_start, 0)


def admit_slot(
    state: BatchState, slot: int, prompt_ids: list[int], max_new: int,
    prefix_len: int = 0, hold: bool = False,
) -> BatchState:
    """Stage a request into a free slot. With ``prefix_len = 0`` the
    models have consumed nothing yet (``t_pref = 0``) and the runner's
    chunked prefill advances both through ``plen - 1`` tokens, after
    which the slot turns ``ready``. A prefix-cache hit passes the
    claimed token count as ``prefix_len`` (page-aligned, both models'
    K/V for ``[0, prefix_len)`` already live in the claimed pool pages):
    prefill then starts at the first uncached position — a full-prefix
    hit (``prefix_len == plen - 1``) is ready immediately. ``hold=True``
    admits the slot as a *rider*: ``prefix_len`` is the writer's
    committed frontier, and the engine advances it (:func:`ride_slot`)
    as the writer's chunks land instead of letting prefill run."""
    plen = len(prompt_ids)
    assert 1 <= plen < state.max_len, (plen, state.max_len)
    assert 0 <= prefix_len <= plen - 1, (prefix_len, plen)
    row = jnp.zeros((state.max_len,), jnp.int32)
    row = row.at[:plen].set(jnp.asarray(prompt_ids, jnp.int32))
    return state._replace(
        seq_buf=state.seq_buf.at[slot].set(row),
        lens=state.lens.at[slot].set(plen),
        d_lens=state.d_lens.at[slot].set(plen - 1),
        t_pref=state.t_pref.at[slot].set(prefix_len),
        active=state.active.at[slot].set(True),
        ready=state.ready.at[slot].set(prefix_len >= plen - 1),
        hold=state.hold.at[slot].set(hold and prefix_len < plen - 1),
        out_start=state.out_start.at[slot].set(plen),
        max_new=state.max_new.at[slot].set(max_new),
    )


def ride_slot(
    state: BatchState, slot: int, t_pref: int, done: bool
) -> BatchState:
    """Advance a riding decode slot's claim frontier (live prefix
    sharing): the engine just claimed the writer's newly committed
    pages into this row's table, so the target-consumed counter jumps
    to ``t_pref`` without a prefill dispatch. ``done=True`` ends the
    ride — the hold clears and any tail remainder past ``t_pref``
    prefills normally (the ready flag flips in-program, or here when
    the ride covered the full ``plen - 1`` span)."""
    ready = state.ready.at[slot].set(t_pref >= state.lens[slot] - 1)
    return state._replace(
        t_pref=state.t_pref.at[slot].set(t_pref),
        ready=ready if done else state.ready,
        hold=state.hold.at[slot].set(not done),
    )


def release_slot(state: BatchState, slot: int) -> BatchState:
    """Deactivate a retired slot (its buffers are reset at readmission)."""
    return state._replace(
        active=state.active.at[slot].set(False),
        ready=state.ready.at[slot].set(False),
        hold=state.hold.at[slot].set(False),
    )


class StageState(NamedTuple):
    """Device-resident bookkeeping for the **async staging lane**
    (``EngineConfig(async_prefill=True)``): the detached background
    prefill program's own slot state, deliberately disjoint from
    :class:`BatchState` so cold-prompt prefill never rides the decode
    critical path. A staging slot holds one prefilling request; the
    prefill program writes its K/V into *staged* pool pages through
    ``page_table`` and flips ``ready`` in-program when the final chunk
    lands. Decode cannot observe any of this: no decode slot's table
    maps a staged page until the engine adopts the completed row
    (table install + ``staged``-mark clear — masks flip, K/V stays
    put). The shared :class:`~repro.serving.paging.PagePool` is NOT a
    field — it lives in :class:`BatchState` and is threaded through
    both programs explicitly."""

    seq_buf: jax.Array     # (S, max_len) int32 — the prompt being staged
    plen: jax.Array        # (S,) int32 — prompt length
    pos: jax.Array         # (S,) int32 — prompt tokens consumed so far
    active: jax.Array      # (S,) bool — staging slot holds a request
    ready: jax.Array       # (S,) bool — final chunk landed (pos>=plen-1)
    hold: jax.Array        # (S,) bool — prefill held while riding a writer
    page_table: jax.Array  # (S, max_pages) int32 — staged pages, -1 empty
    pages_used: jax.Array  # (S,) int32

    @property
    def num_slots(self) -> int:
        return self.seq_buf.shape[0]

    @property
    def max_len(self) -> int:
        return self.seq_buf.shape[1]


def init_stage(
    num_slots: int, max_len: int, page_spec: paging.PageSpec
) -> StageState:
    table, used = paging.init_tables(page_spec, num_slots)
    z = jnp.zeros((num_slots,), jnp.int32)
    f = jnp.zeros((num_slots,), bool)
    return StageState(
        seq_buf=jnp.zeros((num_slots, max_len), jnp.int32),
        plen=z, pos=z, active=f, ready=f, hold=f,
        page_table=table, pages_used=used,
    )


def stage_slot(
    state: StageState, sid: int, prompt_ids: list[int], prefix_len: int = 0,
    hold: bool = False,
) -> StageState:
    """Stage a request into a free staging slot: the background prefill
    program will consume ``plen - 1`` prompt tokens (the last committed
    token is consumed by the adopting decode slot's first verify
    chunk). A prefix-cache hit passes the claimed token count as
    ``prefix_len`` (the claimed pages were installed into this row's
    table by ``paging.host_claim_prefix``); a full-prefix hit or a
    one-token prompt is ready without a single prefill dispatch.
    ``hold=True`` stages a *rider* behind a live writer — see
    :func:`admit_slot`."""
    plen = len(prompt_ids)
    assert 1 <= plen < state.max_len, (plen, state.max_len)
    assert 0 <= prefix_len <= plen - 1, (prefix_len, plen)
    row = jnp.zeros((state.max_len,), jnp.int32)
    row = row.at[:plen].set(jnp.asarray(prompt_ids, jnp.int32))
    return state._replace(
        seq_buf=state.seq_buf.at[sid].set(row),
        plen=state.plen.at[sid].set(plen),
        pos=state.pos.at[sid].set(prefix_len),
        active=state.active.at[sid].set(True),
        ready=state.ready.at[sid].set(prefix_len >= plen - 1),
        hold=state.hold.at[sid].set(hold and prefix_len < plen - 1),
    )


def ride_stage(
    state: StageState, sid: int, pos: int, done: bool
) -> StageState:
    """Staging twin of :func:`ride_slot`: jump the consumed counter to
    the freshly claimed frontier; ``done=True`` clears the hold (ready
    flips here if the ride covered the whole ``plen - 1`` span, else
    in-program when the tail remainder finishes)."""
    ready = state.ready.at[sid].set(pos >= state.plen[sid] - 1)
    return state._replace(
        pos=state.pos.at[sid].set(pos),
        ready=ready if done else state.ready,
        hold=state.hold.at[sid].set(not done),
    )


def clear_stage_slot(state: StageState, sid: int) -> StageState:
    """Reset a staging row after adoption: its pages now belong to the
    adopting decode slot's table, so the row's table is zeroed WITHOUT
    releasing anything (contrast a killed prefill, which releases via
    ``paging.release`` first)."""
    mp = state.page_table.shape[1]
    return state._replace(
        active=state.active.at[sid].set(False),
        ready=state.ready.at[sid].set(False),
        hold=state.hold.at[sid].set(False),
        pos=state.pos.at[sid].set(0),
        plen=state.plen.at[sid].set(0),
        page_table=state.page_table.at[sid].set(
            jnp.full((mp,), -1, jnp.int32)
        ),
        pages_used=state.pages_used.at[sid].set(0),
    )


def clear_slot_cache(cache, slot: int):
    """Zero one slot's rows across a model cache pytree (all stacked
    *per-slot* cache entries carry batch at axis 1). Required at
    admission: chunked prefill resumes SSM recurrences from the cached
    state, so a reused slot must start from the zero state; KV rows are
    zeroed for hygiene (they would be masked/overwritten anyway).

    :class:`PagedKV` pools pass through untouched — pooled storage has no
    per-slot rows, and a freshly admitted slot's pages can only contain
    stale data at positions its reads mask out (>= its token count) or
    that its own chunks rewrite before reading."""
    return jax.tree.map(
        lambda x: x if isinstance(x, PagedKV) else x.at[:, slot].set(0),
        cache,
        is_leaf=lambda x: isinstance(x, PagedKV),
    )
