"""Speculative-decoding serving engine: the thin facade.

Layering (one concern per module):

* ``scheduler.py`` — host-side request lifecycle: queue, page-budget
  admission, preemption, retirement, per-request metrics (TTFT,
  tokens/s, acceptance rate).
* ``batch.py``     — :class:`BatchState`, the device-resident per-slot
  bookkeeping pytree (seq_buf / lens / d_lens / active / ready / budgets
  / page tables + the shared page-pool free list).
* ``paging.py``    — the page-pool allocator (device free-list ops used
  inside the runner bodies; host-side conservative budget mirror).
* ``runner.py``    — the two jitted fixed-shape programs: chunked prefill
  and the speculative iteration (allocate pages → draft → verify →
  commit → stop check).
* this module      — :class:`SpecEngine`, which wires them into a
  **double-buffered async serve loop**: iteration N+1 is dispatched
  before iteration N's outputs are materialized, so host bookkeeping
  (token extraction, retirement, metrics) overlaps device compute. Each
  step syncs only the small ``StepOutputs`` tuple; EOS/length stops are
  detected on device.

With ``EngineConfig(async_prefill=True)`` the loop goes **two-lane**
(disaggregated prefill): cold-prompt prefill moves off the decode
critical path into a detached staging program over ``stage_slots``
lanes, writing K/V into ``staged`` pool pages decode cannot map; each
host iteration dispatches decode FIRST and the prefill chunk second,
and a completed prefill is *adopted* into a free decode slot by mask
flips (staging table install + ``staged`` clear) — never by copying
cache. Decode slots hold only ready work, so a burst of long cold
prompts no longer steals decode iterations from in-flight requests.
``async_prefill=False`` keeps the single-lane loop below, bit-for-bit.

Adding ``disaggregated=True`` splits the two lanes across DEVICE pods:
the staging lanes get their own page pool + cache pair committed to a
prefill device group, the decode batch lives on a decode group, and
adoption becomes an explicit asynchronous page transfer (jitted pack on
the prefill pod → ``jax.device_put`` → jitted unpack on the decode
pod), overlapped with decode and gated so a decode slot never maps an
un-arrived page. Bit-identical to ``async_prefill=True``.

A slot retired while an iteration was already in flight simply wastes
that slot's lane for one step (its outputs are dropped); the slot's
buffers and cache rows are reset at readmission. Verification routes the
block residual sums through the backend registry — with the default
``residual_backend="auto"`` the fused Pallas kernel entry point
(``repro.kernels.ops``) is used.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.models.model import Model
from repro.serving import batch as batch_mod
from repro.serving import faults as faults_mod
from repro.serving import paging
from repro.serving.runner import Runner, StepOutputs
from repro.serving.scheduler import RequestState, Scheduler  # noqa: F401

PREFILL_CHUNK = 16
# Per-step allocation telemetry is decimated once it reaches this many
# entries (stride doubles, every other retained entry is dropped), so a
# long-lived engine keeps a bounded, coarsening trace instead of one
# dict per decode iteration forever.
ALLOC_TRACE_CAP = 4096


def _decimate_trace(trace: list) -> list:
    """Halve the allocation trace for the stride-doubling coarsening,
    anchoring both ends: the first sample (the run's starting occupancy)
    and the last (the freshest) always survive. The old ``del
    trace[::2]`` dropped the even indices — including sample 0 — so long
    runs lost the trace's start and the coarsened series no longer began
    where the run did."""
    kept = trace[0::2]
    if len(trace) > 1 and (len(trace) - 1) % 2:
        kept.append(trace[-1])
    return kept


@dataclass(frozen=True)
class EngineConfig:
    gamma: int = 8
    verifier: str = "block"         # token | block | greedy_block
    max_slots: int = 4
    max_len: int = 512
    temperature: float = 1.0
    eos_id: int = -1                # -1: never stop on EOS
    max_new_tokens: int = 128
    prefill_chunk: int = PREFILL_CHUNK
    residual_backend: str | None = "auto"  # auto | pallas* | jnp | None
    # Paged KV cache (repro.serving.paging). ``paged=True`` pools the
    # global-attention KV of both models into a shared page pool with
    # per-slot page tables; ``num_pages=None`` fully provisions the pool
    # (lossless, admission never blocks). A smaller ``num_pages``
    # over-subscribes memory: admission goes by free-page budget and the
    # engine preempts (recompute-on-resume) when decode outgrows the
    # pool. ``paged=False`` keeps the dense per-slot reservation.
    paged: bool = True
    page_size: int = 16             # tokens per page
    num_pages: int | None = None    # physical pages; None = max_slots quota
    # Greedy multi-path block verification (repro.core.verification):
    # each decode iteration forks every slot's page table into
    # ``num_paths`` copy-on-write aliases, drafts K i.i.d. paths, scores
    # them in one fused target pass and greedily commits the longest
    # accepted path. Requires paged=True and fully-paged caches (all
    # global-attention layers). ``num_paths=1`` is the single-path
    # engine, bit-for-bit.
    num_paths: int = 1
    # Disaggregated async prefill (the staging lane): cold-prompt
    # prefill runs in a DETACHED jitted program over its own
    # ``stage_slots`` staging lanes, writing both models' prompt K/V
    # into pool pages marked ``staged`` — invisible to decode, which
    # only ever maps a staged page after the prompt's final chunk lands
    # and the engine adopts the staging table into a decode slot (mask
    # flips, zero K/V copies). The serve loop dispatches decode FIRST
    # and the prefill chunk second each host iteration, so the decode
    # program never consumes a same-iteration prefill's outputs; decode
    # slots are fed only *ready* work (a cold prompt never squats a
    # decode lane while it prefills), which is where the measured wins
    # come from: fuller decode batches (fewer iterations for the same
    # tokens) and staging lanes batching cold chunks (fewer prefill
    # dispatches). On a single device the two programs still chain
    # through the shared pool — true executable overlap needs the
    # device-disaggregated split (ROADMAP). Requires paged=True and
    # fully-paged caches. ``async_prefill=False`` keeps the serial
    # single-lane loop, bit-for-bit.
    async_prefill: bool = False
    stage_slots: int = 2            # background prefill lanes
    # Cross-request prefix caching (repro.serving.paging.PrefixCache):
    # a retiring/preempted request's committed full pages park in the
    # pool's ``cached`` state, indexed by their token spans; a newly
    # admitted request claims the longest matching page-aligned prefix
    # of its prompt (refcount bump, no recompute) and chunked prefill
    # starts at the first uncached position. Cached pages are evicted
    # LRU only under allocation pressure. Requires paged=True and
    # fully-paged caches; hits cannot affect sampled distributions —
    # claimed pages hold bitwise the K/V the prefill would recompute.
    prefix_cache: bool = False
    # Live prefix sharing + cache-aware admission: the radix index also
    # mirrors the committed prompt spans of LIVE rows (decode slots and
    # staging lanes), registered chunk-by-chunk as the prefill mirrors
    # advance, so a burst of requests sharing a prefix pays for ~one
    # prefill of the shared span instead of N — later requests pin the
    # writer's in-use pages (refcount bump, ``paging.host_claim_live``)
    # and, when admitted while the writer is still mid-prefill, RIDE it:
    # the row admits held (``hold``) at the writer's committed frontier
    # and the engine grows its claim as each chunk lands, prefilling
    # only its own divergent tail. Admission turns cache-aware: the
    # scheduler admits the queued request with the longest
    # live-inclusive prefix match (aging-bounded, deterministic). Hits
    # stay bit-identical: claimed pages are read-only under the
    # claimer-never-writes cap and prefill consumes no PRNG, so a
    # claimed page holds bitwise the K/V the rider would recompute.
    # Requires prefix_cache=True.
    live_share: bool = False
    # Device-disaggregated prefill (requires async_prefill=True): the
    # staging lanes get their OWN page pool and cache pair
    # (``paging.stage_spec_of``), committed to a prefill device group,
    # while the decode batch/caches live on a decode group — the two
    # executables stop chaining through a shared pool, so background
    # prefill truly overlaps decode. Adoption becomes an explicit page
    # TRANSFER instead of PR 5's mask flip: a jitted pack gathers the
    # staged pages into a compact ``(n_pages, page, n_kv, hd)`` buffer
    # on the prefill pod, ``jax.device_put`` ships it (dispatched
    # asynchronously, overlapped with decode), and a jitted unpack
    # allocates decode-pool pages and scatters the buffer in. A
    # transfer-inflight gate keeps a ready lane out of the decode batch
    # until its transfer has been dispatched; because the unpack
    # CONSUMES the device_put results before installing the table,
    # decode can never map an un-arrived page (per-device program order
    # + data dependencies — a dataflow fact, not a host-timing one).
    # Bit-identical to ``async_prefill=True`` on a single process:
    # prefill consumes no PRNG and transfers move K/V bitwise.
    disaggregated: bool = False
    # Pod placement: None (defaults — prefill pod = jax.devices()[-1],
    # decode pod = jax.devices()[0]), a single jax.Device, a device
    # list, or a Mesh (see launch.mesh.make_disaggregated_meshes /
    # distributed.sharding.carve_pods); only the group's first device
    # anchors the single-process engine's placement.
    prefill_mesh: object | None = None
    decode_mesh: object | None = None
    # Deterministic fault injection + degradation ladder
    # (repro.serving.faults.FaultPlan). None — the default — is the
    # *structural* no-op: no FaultInjector is constructed, no injection
    # branch is reachable, and the ladder state stays inert (speclint's
    # fault-site pass checks every injection call site is gated on this
    # field). With a plan installed, faults fire as a pure function of
    # (seed, site, iteration, rid) and the engine degrades instead of
    # failing: lost transfers time out and re-dispatch with backoff,
    # lanes that exhaust their retries fail over to decode-pod prefill,
    # repeated pod failure downgrades disagg → async for new
    # admissions, and non-finite drafter rows fall back to a pure
    # target-distribution resample (still lossless — and bit-identical
    # at temp 0, because every fallback prefill is PRNG-free and the
    # guard's bonus sample IS the greedy token).
    faults: faults_mod.FaultPlan | None = None


class SpecEngine:
    """Batched speculative-decoding engine for one (target, drafter) pair."""

    def __init__(
        self,
        target: Model,
        drafter: Model,
        t_params,
        d_params,
        cfg: EngineConfig,
    ):
        assert target.cfg.vocab == drafter.cfg.vocab
        self.target, self.drafter = target, drafter
        self.t_params, self.d_params = t_params, d_params
        self.cfg = cfg
        self.runner = Runner(target, drafter, cfg)
        self.reset()

    # ------------------------------------------------------------------
    # state
    # ------------------------------------------------------------------

    def reset(self, seed: int = 0):
        cfg = self.cfg
        self.t_cache, self.d_cache = self.runner.init_caches()
        spec = self.runner.page_spec
        self.batch = batch_mod.init_batch(cfg.max_slots, cfg.max_len, spec)
        budget = (
            paging.PageBudget(spec, cfg.gamma, num_paths=cfg.num_paths)
            if spec is not None else None
        )
        self._disagg = bool(cfg.disaggregated) and spec is not None
        stage_budget = (
            paging.PageBudget(self.runner.stage_spec, cfg.gamma)
            if self._disagg else None
        )
        self.scheduler = Scheduler(
            cfg.max_slots, cfg.max_new_tokens, cfg.prefill_chunk,
            budget=budget,
            num_stage_slots=cfg.stage_slots if cfg.async_prefill else 0,
            stage_budget=stage_budget,
        )
        self.stage = (
            batch_mod.init_stage(
                cfg.stage_slots, cfg.max_len, self.runner.stage_spec
            )
            if cfg.async_prefill else None
        )
        # Disaggregated: the prefill pod owns its own pool + cache pair
        # and a params replica; every stage-side pytree is COMMITTED to
        # the prefill device and every decode-side one to the decode
        # device, so jit placement (computation follows committed
        # inputs) pins the two executables to their pods.
        if self._disagg:
            self._prefill_dev, self._decode_dev = self._pod_devices()
            self.stage_pool = jax.device_put(
                paging.init_pool(self.runner.stage_spec), self._prefill_dev
            )
            t_sc, d_sc = self.runner.init_stage_caches()
            self.t_stage_cache = jax.device_put(t_sc, self._prefill_dev)
            self.d_stage_cache = jax.device_put(d_sc, self._prefill_dev)
            self.t_params_stage = jax.device_put(
                self.t_params, self._prefill_dev
            )
            self.d_params_stage = jax.device_put(
                self.d_params, self._prefill_dev
            )
            self.stage = jax.device_put(self.stage, self._prefill_dev)
            self.t_params = jax.device_put(self.t_params, self._decode_dev)
            self.d_params = jax.device_put(self.d_params, self._decode_dev)
            self.t_cache = jax.device_put(self.t_cache, self._decode_dev)
            self.d_cache = jax.device_put(self.d_cache, self._decode_dev)
            self.batch = jax.device_put(self.batch, self._decode_dev)
        # In-flight page transfers: sid -> {"n", "t_packed", "d_packed"}
        # (the adoption gate — a ready lane adopts only once its entry
        # exists, i.e. its pack + device_put chain has been dispatched);
        # ``_transfer_log`` records ("dispatch"|"adopt", sid, loop_iter)
        # tuples for the ordering invariants the tests assert.
        self._transfers: dict[int, dict] = {}
        self._transfer_log: list[tuple] = []
        self._loop_iter = 0
        # Fault plane (cfg.faults; None keeps every site unreachable)
        # and the degradation-ladder state it drives: per-sid transfer
        # retry counts and backoff horizons, plus the pod-failure tally
        # behind the disagg → async downgrade for new admissions.
        self._injector = (
            faults_mod.FaultInjector(cfg.faults)
            if cfg.faults is not None else None
        )
        self._transfer_retries: dict[int, int] = {}
        self._transfer_backoff: dict[int, int] = {}
        self._pod_failures = 0
        self._pod_down = False
        # Live stats dict while a serve loop runs (audit repairs and
        # cancel/shed counters land here from outside the loop body).
        self._stats: dict | None = None
        self.prefix_cache = (
            paging.PrefixCache(spec)
            if cfg.prefix_cache and spec is not None else None
        )
        self._claims: dict[int, list] = {}  # slot -> claimed trie nodes
        self._stage_claims: dict[int, list] = {}  # sid -> claimed nodes
        # Live prefix sharing (cfg.live_share): owner keys are
        # ("slot", i) / ("stage", i). ``_live_prompt`` maps each live
        # row to the prompt it is serving (what register_live mirrors
        # and _find_writer scans); ``_rides`` maps a RIDER row to its
        # in-flight claim-behind-the-writer state.
        self._live_on = cfg.live_share and self.prefix_cache is not None
        self._live_prompt: dict[tuple, list[int]] = {}
        self._rides: dict[tuple, dict] = {}
        if self._live_on and not self._disagg:
            # Disaggregated staging lanes cannot claim (disjoint id
            # spaces — see _stage), so cache-aware admission would
            # reorder the queue for zero benefit; staging stays FIFO.
            self.scheduler.match_fn = self._match_pages
        self.key = jax.random.key(seed)
        self.last_stats: dict = {}
        # Continuous-batching hooks, installed per-serve by serve();
        # None ⇒ classic batch-mode run-to-completion.
        self._pump_cb = None
        self._emit_cb = None
        self._idle_cb = None

    def _pod_devices(self):
        """Resolve ``(prefill device, decode device)`` from the config's
        mesh args — each may be None, a single :class:`jax.Device`, a
        device sequence, or a Mesh; only the first device anchors
        placement in the single-process engine. Defaults pick opposite
        ends of ``jax.devices()`` so a fake multi-device CPU split
        (``--xla_force_host_platform_device_count``) disaggregates for
        real, while one device degenerates to same-device transfers
        (still bit-identical, exercising the full pack/ship/unpack
        path)."""

        def first(arg, default):
            if arg is None:
                return default
            devs = getattr(arg, "devices", None)  # Mesh
            if devs is not None:
                return np.asarray(devs).flat[0]
            if isinstance(arg, (list, tuple)):
                return arg[0]
            return arg
        devs = jax.devices()
        return (
            first(self.cfg.prefill_mesh, devs[-1]),
            first(self.cfg.decode_mesh, devs[0]),
        )

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------

    def submit(
        self,
        prompt_ids: list[int],
        max_new_tokens: int | None = None,
        priority: int = 0,
        tenant: str = "default",
        deadline_s: float | None = None,
    ) -> int:
        if not 1 <= len(prompt_ids) < self.cfg.max_len:
            raise ValueError(
                f"prompt length {len(prompt_ids)} must be in "
                f"[1, max_len={self.cfg.max_len})"
            )
        if max_new_tokens is not None and max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        return self.scheduler.submit(
            prompt_ids, max_new_tokens, priority=priority, tenant=tenant,
            deadline_s=deadline_s,
        )

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it lives in the pipeline. Queued:
        removed and finalized, nothing else to unwind. Staged: the
        lane's device state (claims, staged pages, any in-flight
        transfer) is released exactly like a pressure kill, but the
        request finalizes instead of requeueing. Live decode slot
        (riding, prefilling or decoding): retired with its pages
        released/parked through the same path preemption uses —
        claimants' pins are honored (cached conversion via ``insert``)
        and a ride ends with the row. A slot cancelled while an
        iteration is in flight wastes that lane for one step; its
        outputs drop at the ``req.finished`` check in :meth:`_process`.
        Returns True when the request was live (False: unknown rid or
        already finished). The pool is audited after every device-side
        unwind."""
        sched = self.scheduler
        loc = sched.find(rid)
        if loc is None or loc[0] == "done":
            return False
        kind, where = loc
        if kind == "queued":
            req = sched.cancel_queued(where)
        elif kind == "staged":
            req = sched.stage_req[where]
            left = sched.stage_prefill_left(where)
            sched.drop_stage(where)
            self._kill_stage_and_cache(where, req, left)
            self._audit()
        else:  # live decode slot
            req = sched.slot_req[where]
            left = sched.prefill_left(where)
            sched.retire(where, "cancelled")
            self.batch = self._release_and_cache(where, req, left)
            self._audit()
        if self._stats is not None:
            self._stats["cancelled"] += 1
        self._emit_terminal(req)
        return True

    def _emit_terminal(self, req: RequestState) -> None:
        """Terminal delta for a request finished OUTSIDE
        :meth:`_process` (cancelled, deadline-shed, quarantined): the
        front end must always observe a ``finished=True`` delta or its
        caller parks on the stream forever. Unstreamed committed tokens
        are dropped by contract — the cursor jumps to the frontier."""
        if self._emit_cb is not None:
            req.emitted = len(req.output)
            self._emit_cb(req, [], True)

    def _audit(self) -> dict | None:
        """Reconcile the page pool(s) against host ground truth
        (:func:`repro.serving.paging.audit_pool`): refcounts, page
        tables, the free stack, the cached radix mirror and the
        ``PageBudget`` terms. Called at quiesce and after every
        kill/cancel/quarantine unwind. A clean pool comes back bitwise
        unchanged — the healthy path never perturbs allocation
        determinism — and repairs (verified-orphaned pages reclaimed,
        stale budget keys dropped) are counted into
        ``stats["audit_repairs"]``."""
        spec = self.runner.page_spec
        if spec is None:
            return None
        sched = self.scheduler
        live = [s for s, r in enumerate(sched.slot_req) if r is not None]
        srows = [s for s, r in enumerate(sched.stage_req) if r is not None]
        shared_stage = self.stage is not None and not self._disagg
        pool, report = paging.audit_pool(
            spec, self.batch.pool,
            page_table=self.batch.page_table,
            pages_used=self.batch.pages_used,
            live_rows=live,
            stage_table=self.stage.page_table if shared_stage else None,
            stage_used=self.stage.pages_used if shared_stage else None,
            stage_rows=srows if shared_stage else (),
            prefix_cache=self.prefix_cache,
            budget=sched.budget,
        )
        self.batch = self.batch._replace(pool=pool)
        if self._disagg:
            spool, srep = paging.audit_pool(
                self.runner.stage_spec, self.stage_pool,
                stage_table=self.stage.page_table,
                stage_used=self.stage.pages_used,
                stage_rows=srows,
                budget=sched.stage_budget,
            )
            self.stage_pool = spool
            for k, v in srep.items():
                if k == "clean":
                    report["clean"] = report["clean"] and v
                else:
                    report[k] += v
        if self._stats is not None:
            self._stats["audit_repairs"] += report["repairs"]
        return report

    def _quarantine_slot(
        self, slot: int, req: RequestState, exc: Exception
    ) -> None:
        """Per-request error quarantine (decode slot): an exception
        attributable to one request finishes IT with reason "error"
        instead of tearing down the service thread. Release is
        best-effort — whatever a half-mutated unwind leaves behind, the
        audit reclaims as verified orphans."""
        req.error = f"{type(exc).__name__}: {exc}"
        sched = self.scheduler
        if sched.slot_req[slot] is req:
            sched.retire(slot, "error")
            try:
                self.batch = self._release_and_cache(slot, req, 0)
            except Exception:
                pass
        elif not req.finished:
            sched.finalize(req, "error")
        else:
            req.finish_reason = "error"
        self._audit()
        self._emit_terminal(req)

    def _quarantine_stage(
        self, sid: int, req: RequestState, exc: Exception
    ) -> None:
        """Staging-lane twin of :meth:`_quarantine_slot`."""
        req.error = f"{type(exc).__name__}: {exc}"
        sched = self.scheduler
        if sched.stage_req[sid] is req:
            left = sched.stage_prefill_left(sid)
            sched.drop_stage(sid, "error")
            try:
                self._kill_stage_and_cache(sid, req, left)
            except Exception:
                pass
        elif not req.finished:
            sched.finalize(req, "error")
        else:
            req.finish_reason = "error"
        self._audit()
        self._emit_terminal(req)

    def _admit(self, slot: int, req: RequestState):
        """Stage an admitted request: zero the slot's cache rows (chunked
        prefill resumes SSM recurrences from cached state) and write the
        prompt + budgets into the batch pytree. A preempted request
        resumes with ``prompt + output`` and its remaining budget.

        With the prefix cache on, the longest cached page-aligned prefix
        of the (resume) prompt is claimed instead of re-prefilled: the
        claimed pages' refcounts bump, the slot's table starts with
        them, and prefill begins at the first uncached position. With
        live sharing on, the claimable prefix may be a live writer's
        in-flight pages, and when the writer will commit MORE shareable
        pages than are claimable right now the slot admits as a rider
        (held prefill, claim grows via :meth:`_advance_rides`)."""
        self.t_cache = batch_mod.clear_slot_cache(self.t_cache, slot)
        self.d_cache = batch_mod.clear_slot_cache(self.d_cache, slot)
        prompt = req.serve_prompt()
        nodes, prefix_len = self._lookup_claim(prompt, self._claims, slot)
        okey = ("slot", slot)
        hold = self._maybe_ride(okey, prompt, len(nodes))
        if hold:
            self.scheduler.set_slot_riding(slot, True)
        self.batch = batch_mod.admit_slot(
            self.batch, slot, prompt, req.serve_max_new(),
            prefix_len=prefix_len, hold=hold,
        )
        if nodes:
            table, used, pool = paging.host_claim_live(
                self.runner.page_spec, self.batch.page_table,
                self.batch.pages_used, self.batch.pool, slot,
                self._resolve_node_ids(nodes),
            )
            self.batch = self.batch._replace(
                page_table=table, pages_used=used, pool=pool
            )
            self.scheduler.note_prefix_claim(slot, prefix_len)
        if self._live_on:
            self._live_prompt[okey] = prompt

    def _lookup_claim(self, prompt: list[int], claims: dict, key: int):
        """Shared prefix-cache lookup + claim for a row being admitted
        (decode slot or staging lane): pin the longest cached
        page-aligned prefix, record the claimed trie nodes under
        ``claims[key]``, and return ``(nodes, prefix_len)``. The caller
        installs the physical pages into its own table
        (``host_claim_prefix``) and notifies its lane's mirror."""
        if self.prefix_cache is None:
            return [], 0
        nodes = self.prefix_cache.lookup(prompt)
        if nodes:
            self.prefix_cache.claim(nodes)
            claims[key] = nodes
        else:
            self.prefix_cache.misses += 1
        return nodes, len(nodes) * self.cfg.page_size

    def _stage(self, sid: int, req: RequestState):
        """Stage an admitted request into the background prefill lane:
        write the prompt into the staging row and (prefix cache on)
        claim the longest cached — or, with live sharing, live —
        page-aligned prefix into the *staging* table, so the background
        prefill starts at the first uncached position; a rider stages
        held (see :meth:`_admit`). No decode-side state is touched.

        Disaggregated: claims, rides and live registration are all
        skipped — the prefix index holds DECODE-pool page ids, and a
        staging table on the prefill pod must never map them (two
        disjoint physical id spaces). Shareable rows become visible to
        the index only after adoption lands their pages in the decode
        pool (:meth:`_adopt_disagg`), so every claim resolves to
        post-transfer decode-pool ids by construction."""
        prompt = req.serve_prompt()
        if self._disagg:
            self.stage = batch_mod.stage_slot(self.stage, sid, prompt)
            return
        nodes, prefix_len = self._lookup_claim(
            prompt, self._stage_claims, sid
        )
        okey = ("stage", sid)
        hold = self._maybe_ride(okey, prompt, len(nodes))
        if hold:
            self.scheduler.set_stage_riding(sid, True)
        self.stage = batch_mod.stage_slot(
            self.stage, sid, prompt, prefix_len=prefix_len, hold=hold
        )
        if nodes:
            table, used, pool = paging.host_claim_live(
                self.runner.page_spec, self.stage.page_table,
                self.stage.pages_used, self.batch.pool, sid,
                self._resolve_node_ids(nodes),
            )
            self.stage = self.stage._replace(
                page_table=table, pages_used=used
            )
            self.batch = self.batch._replace(pool=pool)
            self.scheduler.note_stage_claim(sid, prefix_len)
        if self._live_on:
            self._live_prompt[okey] = prompt

    # -- live prefix sharing (cfg.live_share) --------------------------

    def _find_writer(self, prompt: list[int]) -> tuple[tuple, int] | None:
        """Best live writer to ride for ``prompt``: the non-riding live
        row whose prompt shares the longest token LCP, as ``(owner,
        limit_pages)`` where ``limit`` caps the ride at the smallest of
        the LCP, the rider's own claimer-never-writes cap and the
        writer's committed-by-prefill span (both ``plen - 1``). None
        when no writer would yield a single full page."""
        ps = self.cfg.page_size
        best = None
        for okey, wprompt in self._live_prompt.items():
            if okey in self._rides:
                continue  # a rider's pages are someone else's
            lcp = 0
            for a, b in zip(prompt, wprompt):
                if a != b:
                    break
                lcp += 1
            limit = min(lcp, len(prompt) - 1, len(wprompt) - 1) // ps
            if limit > 0 and (best is None or limit > best[1]):
                best = (okey, limit)
        return best

    def _maybe_ride(self, okey: tuple, prompt: list[int], have: int) -> bool:
        """Decide claim-behind-the-writer for a row being admitted with
        ``have`` pages already claimable from the index: if a live
        writer will commit MORE shareable pages than that, record the
        ride and admit the row held. The initial claim (the writer's
        committed frontier) is installed by the caller; the ride grows
        it as chunks land."""
        if not self._live_on:
            return False
        w = self._find_writer(prompt)
        if w is None or w[1] <= have:
            return False
        self._rides[okey] = {
            "writer": w[0], "limit": w[1], "prompt": prompt,
        }
        return True

    def _match_pages(self, prompt: list[int]) -> int:
        """Cache-aware admission oracle (installed as the scheduler's
        ``match_fn``): pages of ``prompt`` shareable right now (cached +
        live-committed) or promised by a live writer's remaining
        chunks."""
        pages = len(self.prefix_cache.lookup(prompt))
        w = self._find_writer(prompt)
        if w is not None:
            pages = max(pages, w[1])
        return pages

    def _resolve_node_ids(self, path: list, start: int = 0) -> list[int]:
        """Physical ids backing ``path[start:]``, resolving still-live
        nodes (``page == -1``) from their owner's device table — the
        one host↔device sync live sharing ever does, paid only when a
        claim actually lands (registration itself is sync-free). A
        node's depth in the path IS its column in the owner's table
        (the owner registered it there), and resolution memoizes into
        ``node.page`` so later claimants reuse it."""
        rows: dict[tuple, np.ndarray] = {}
        out = []
        for depth in range(start, len(path)):
            node = path[depth]
            if node.page < 0:
                okey = node.owner
                if okey not in rows:
                    table = (
                        self.batch.page_table if okey[0] == "slot"
                        else self.stage.page_table
                    )
                    # speclint: sync-point(memoized owner-row read - the only sync live sharing does)
                    rows[okey] = np.asarray(table[okey[1]])
                node.page = int(rows[okey][depth])
                assert node.page >= 0, (okey, depth)
            out.append(node.page)
        return out

    def _update_live_index(self) -> None:
        """Mirror every non-riding live row's committed full prompt
        pages into the radix index (insert-as-you-commit). Driven by
        the scheduler's prefill mirrors — chunk counts are
        deterministic, so no device sync; ``register_live`` is
        idempotent and monotone, so re-registering after every dispatch
        is O(pages) dict probes."""
        ps = self.cfg.page_size
        sched = self.scheduler
        for slot, req in enumerate(sched.slot_req):
            okey = ("slot", slot)
            prompt = self._live_prompt.get(okey)
            if req is None or prompt is None or sched.slot_riding(slot):
                continue
            consumed = max(len(prompt) - 1 - sched.prefill_left(slot), 0)
            self.prefix_cache.register_live(okey, prompt, consumed // ps)
        for sid, req in enumerate(sched.stage_req):
            okey = ("stage", sid)
            prompt = self._live_prompt.get(okey)
            if req is None or prompt is None or sched.stage_riding(sid):
                continue
            consumed = max(
                len(prompt) - 1 - sched.stage_prefill_left(sid), 0
            )
            self.prefix_cache.register_live(okey, prompt, consumed // ps)

    def _advance_rides(self) -> None:
        """Grow every rider's claim to its writer's committed frontier
        and finish rides that are done. A ride ends when the claim
        reaches its limit, or the writer's row is gone (retired /
        preempted / killed — its committed pages parked ``cached``, so
        everything claimable was claimed; the rider's hold clears and
        its tail prefills normally). Device side: the rider's
        ``t_pref``/``pos`` jumps to the claimed frontier
        (``batch.ride_slot`` / ``ride_stage``); mirror side:
        ``note_prefix_claim`` / ``note_stage_claim`` shrink the lane's
        prefill debt."""
        ps = self.cfg.page_size
        spec = self.runner.page_spec
        for okey in list(self._rides):
            ride = self._rides[okey]
            kind, row = okey
            claims = self._claims if kind == "slot" else self._stage_claims
            mine = claims.get(row, [])
            have = len(mine)
            path = self.prefix_cache.lookup(ride["prompt"])
            avail = min(len(path), ride["limit"])
            if avail > have:
                new_nodes = path[have:avail]
                ids = self._resolve_node_ids(path[:avail], start=have)
                self.prefix_cache.claim(new_nodes, extend=have > 0)
                if have == 0:
                    claims[row] = mine = []
                mine.extend(new_nodes)
                if kind == "slot":
                    table, used, pool = paging.host_claim_live(
                        spec, self.batch.page_table,
                        self.batch.pages_used, self.batch.pool, row,
                        ids, start=have,
                    )
                    self.batch = self.batch._replace(
                        page_table=table, pages_used=used, pool=pool
                    )
                    self.scheduler.note_prefix_claim(row, len(ids) * ps)
                else:
                    table, used, pool = paging.host_claim_live(
                        spec, self.stage.page_table,
                        self.stage.pages_used, self.batch.pool, row,
                        ids, start=have,
                    )
                    self.stage = self.stage._replace(
                        page_table=table, pages_used=used
                    )
                    self.batch = self.batch._replace(pool=pool)
                    self.scheduler.note_stage_claim(row, len(ids) * ps)
                have = avail
            done = (
                have >= ride["limit"]
                or ride["writer"] not in self._live_prompt
            )
            if kind == "slot":
                self.batch = batch_mod.ride_slot(
                    self.batch, row, have * ps, done
                )
                if done:
                    self.scheduler.set_slot_riding(row, False)
            else:
                self.stage = batch_mod.ride_stage(
                    self.stage, row, have * ps, done
                )
                if done:
                    self.scheduler.set_stage_riding(row, False)
            if done:
                del self._rides[okey]

    def _drop_live_row(self, okey: tuple) -> None:
        """Live-sharing cleanup for a releasing row: drop its live-span
        mirror (its owned nodes were just converted to cached by the
        release path's ``insert``), its writer registration, and — if it
        was mid-ride as a rider — the ride itself (its claims were
        released with the row)."""
        if not self._live_on:
            return
        self.prefix_cache.release_live(okey)
        self._live_prompt.pop(okey, None)
        self._rides.pop(okey, None)

    def _adopt(
        self, sid: int, slot: int, req: RequestState, stats: dict | None = None
    ):
        """Fold a completed background prefill into the decode batch —
        the ready flip. The staging row's physical pages (claimed
        prefix + staged growth, in logical order) become the decode
        slot's page table; their ``staged`` marks clear; ``admit_slot``
        stages the prompt with ``prefix_len = plen - 1`` (every prompt
        token both models needed is already consumed), so the slot is
        decodable immediately. One small device→host sync reads the
        staging row's page ids — the only host visibility the staging
        lane ever needs.

        Disaggregated engines take :meth:`_adopt_disagg` instead — the
        pools are disjoint, so adoption installs the TRANSFERRED pages,
        not the staging table."""
        if self._disagg:
            return self._adopt_disagg(sid, slot, req, stats)
        prompt = req.serve_prompt()
        # speclint: sync-point(adoption's one sync: staging row page ids, one device_get round-trip)
        used_arr, ids_arr = jax.device_get(
            (self.stage.pages_used[sid], self.stage.page_table[sid])
        )
        used = int(used_arr)
        ids = ids_arr[:used].tolist() if used else []
        assert all(p >= 0 for p in ids), (sid, ids)
        self._claims[slot] = self._stage_claims.pop(sid, [])
        if self._live_on:
            # The staging row's identity moves to the decode slot:
            # re-key its live-span registrations, its writer entry, and
            # any ride that was following it as a writer. (It cannot
            # itself still be a rider — a ride either completes before
            # the row turns ready or clears its hold first.)
            old, new = ("stage", sid), ("slot", slot)
            self.prefix_cache.move_owner(old, new)
            if old in self._live_prompt:
                self._live_prompt[new] = self._live_prompt.pop(old)
            for ride in self._rides.values():
                if ride["writer"] == old:
                    ride["writer"] = new
        self.batch = batch_mod.admit_slot(
            self.batch, slot, prompt, req.serve_max_new(),
            prefix_len=len(prompt) - 1,
        )
        table, pages_used, pool = paging.host_adopt_stage(
            self.runner.page_spec, self.batch.page_table,
            self.batch.pages_used, self.batch.pool, slot, ids,
        )
        self.batch = self.batch._replace(
            page_table=table, pages_used=pages_used, pool=pool
        )
        self.stage = batch_mod.clear_stage_slot(self.stage, sid)

    def _adopt_disagg(
        self, sid: int, slot: int, req: RequestState, stats: dict | None = None
    ):
        """Disaggregated adoption: complete the page transfer dispatched
        by :meth:`_dispatch_transfers`. The scheduler's gate guarantees
        the transfer entry exists; the unpack program allocates the
        slot's decode-pool pages and scatters the shipped buffers in —
        because it CONSUMES the ``device_put`` results, the installed
        table provably never maps an un-arrived page (data dependency,
        not host timing). The staging row's source pages then return to
        the PREFILL pool's free stack; no host sync anywhere (the page
        count is deterministic: claims are disabled under disagg, so
        ``n = pages_for(plen - 1)``).

        Transfer telemetry (``stats["transfers"]`` / ``transfer_bytes``)
        is counted HERE, not at dispatch: a staging lane killed while
        its transfer is in flight drops the ``_transfers`` entry without
        adopting, and counting at dispatch over-reported those dead
        shipments (and double-counted the retry's re-shipment)."""
        prompt = req.serve_prompt()
        tr = self._transfers.pop(sid)
        if stats is not None and tr["n"]:
            stats["transfers"] += 1
            stats["transfer_bytes"] += tr["bytes"]
        self.batch = batch_mod.admit_slot(
            self.batch, slot, prompt, req.serve_max_new(),
            prefix_len=len(prompt) - 1,
        )
        if tr["n"]:
            self.t_cache, self.d_cache, self.batch = (
                self.runner.unpack_stage(
                    tr["n"], self.t_cache, self.d_cache, self.batch,
                    slot, tr["t_packed"], tr["d_packed"],
                )
            )
        self.stage, self.stage_pool = self.runner.release_stage(
            self.stage, self.stage_pool, sid
        )
        self._transfer_retries.pop(sid, None)
        self._transfer_backoff.pop(sid, None)
        if self._live_on:
            # First index visibility AFTER the transfer: the row's live
            # spans now resolve to decode-pool ids via batch.page_table.
            self._live_prompt[("slot", slot)] = prompt
        self._transfer_log.append(("adopt", sid, self._loop_iter))

    def _transfer_ready(self, sid: int) -> bool:
        """Adoption gate under disagg: the lane's transfer must have
        been dispatched, not be marked lost in flight, and (fault plane)
        be past any injected delay. Without faults every dispatched
        entry is immediately ready, so this is exactly the old ``sid in
        self._transfers`` check."""
        entry = self._transfers.get(sid)
        if entry is None or entry.get("lost"):
            return False
        return self._loop_iter >= entry.get("ready_iter", 0)

    def _fail_over_stage(self, sid: int, stats: dict | None) -> None:
        """Transfer retries exhausted: fail the staged lane over to the
        in-decode-pod prefill path. The request is marked ``no_stage``
        (the staging lane never takes it again), its lane unwinds
        exactly like a pressure kill (requeued at the front), and the
        next decode-lane admission prefills it on the decode pod —
        serial semantics, PRNG-free, so the failover is invisible at
        temp 0."""
        sched = self.scheduler
        req = sched.stage_req[sid]
        left = sched.stage_prefill_left(sid)
        req.no_stage = True
        sched.kill_stage(sid)
        self._kill_stage_and_cache(sid, req, left)
        self._transfer_log.append(("failover", sid, self._loop_iter))
        if stats is not None:
            stats["failovers"] += 1
        self._audit()

    def _note_pod_failure(self, stats: dict | None) -> None:
        """Count a prefill-pod dispatch failure; at the plan's
        ``pod_failure_limit`` the engine downgrades disagg → async for
        NEW admissions: staging stops taking requests and the
        decode-lane admit (decode-pod prefill, serial semantics) takes
        over. In-flight staged lanes finish normally."""
        self._pod_failures += 1
        if stats is not None:
            stats["pod_failures"] += 1
        if (
            not self._pod_down
            and self._pod_failures >= self.cfg.faults.pod_failure_limit
        ):
            self._pod_down = True
            if stats is not None:
                stats["downgraded"] = True
            self._transfer_log.append(("downgrade", -1, self._loop_iter))

    def _dispatch_transfers(self, stats: dict | None = None) -> None:
        """Ship every ready-but-not-yet-dispatched staging lane's pages
        to the decode pod: a jitted pack gathers the lane's ``n`` staged
        pages into compact ``(G, n, page, n_kv, hd)`` buffers on the
        prefill pod, ``jax.device_put`` ships them, and the entry lands
        in ``_transfers`` — the adoption gate. Everything here is an
        async dispatch (the page-id slice is a lazy device view, ``n``
        is host-deterministic), so the transfer overlaps the decode
        iterations that run until a decode slot frees up.

        Fault plane: a dispatch may be injected as *lost* (the entry
        never turns ready; once inflight past
        ``transfer_timeout_iters`` it is reaped here and re-dispatched
        after a linear backoff, up to ``transfer_max_retries`` before
        the lane fails over) or *delayed* (ready only after
        ``transfer_delay_iters``). Without a plan neither branch is
        reachable and a dispatched transfer always lands."""
        sched = self.scheduler
        spec = self.runner.stage_spec
        plan = self.cfg.faults
        for sid in list(sched.ready_q):
            entry = self._transfers.get(sid)
            if entry is not None:
                # Ladder: reap a lost transfer once it times out —
                # re-dispatch with backoff or fail the lane over.
                if plan is not None and entry.get("lost") and (
                    self._loop_iter - entry["iter"]
                    >= plan.transfer_timeout_iters
                ):
                    self._transfers.pop(sid)
                    self._transfer_log.append(
                        ("timeout", sid, self._loop_iter)
                    )
                    retries = self._transfer_retries.get(sid, 0) + 1
                    self._transfer_retries[sid] = retries
                    if stats is not None:
                        stats["transfer_retries"] += 1
                    if retries > plan.transfer_max_retries:
                        self._fail_over_stage(sid, stats)
                    else:
                        # k-th retry waits k iterations before the
                        # re-dispatch (linear backoff).
                        self._transfer_backoff[sid] = (
                            self._loop_iter + retries
                        )
                continue
            if self._loop_iter < self._transfer_backoff.get(sid, 0):
                continue
            req = sched.stage_req[sid]
            plen = len(req.serve_prompt())
            n = spec.pages_for(plen - 1) if plen > 1 else 0
            entry = {"n": n, "iter": self._loop_iter}
            if n:
                page_ids = self.stage.page_table[sid, :n]
                t_packed = self.runner.pack_stage(
                    self.t_stage_cache, page_ids
                )
                d_packed = self.runner.pack_stage(
                    self.d_stage_cache, page_ids
                )
                entry["t_packed"] = jax.device_put(
                    t_packed, self._decode_dev
                )
                entry["d_packed"] = jax.device_put(
                    d_packed, self._decode_dev
                )
                # Sized here (the packed buffers are in hand) but
                # counted into stats only at adoption — see
                # _adopt_disagg; a killed lane's shipment never counts.
                entry["bytes"] = int(sum(
                    leaf.nbytes
                    for pk in (t_packed, d_packed)
                    for leaf in jax.tree.leaves(pk)
                ))
            if self._injector is not None:
                if self._injector.fires(
                    faults_mod.SITE_TRANSFER_LOSS,
                    iteration=self._loop_iter, rid=req.rid,
                ):
                    entry["lost"] = True
                elif self._injector.fires(
                    faults_mod.SITE_TRANSFER_DELAY,
                    iteration=self._loop_iter, rid=req.rid,
                ):
                    entry["ready_iter"] = (
                        self._loop_iter + plan.transfer_delay_iters
                    )
            self._transfers[sid] = entry
            self._transfer_log.append(("dispatch", sid, self._loop_iter))

    def _nonfinite_mask(self, snapshot) -> np.ndarray | None:
        """Per-slot drafter-corruption mask for one decode dispatch.
        None when ``cfg.faults`` is off — the jitted decode program (and
        its signature) stays byte-identical to the fault-free build. A
        flagged slot's drafted rows are overwritten with NaN inside the
        decode body; verification's non-finite guard then zeroes the row
        — every draft rejects and the bonus falls back to a pure
        target-distribution sample. Still lossless, and at temp 0
        bit-identical: the bonus argmax IS the greedy token."""
        if self._injector is None:
            return None
        flags = np.zeros((self.cfg.max_slots,), dtype=bool)
        for slot, req in snapshot.items():
            if self._injector.fires(
                faults_mod.SITE_NONFINITE_LOGITS,
                iteration=self._loop_iter, rid=req.rid,
            ):
                flags[slot] = True
        return flags

    def _cacheable_cols(
        self, req, prefill_left: int, claims, table_row, owner=None,
    ):
        """Shared prefix-cache parking logic for a releasing row (decode
        slot or staging lane): drop the row's own claims, register its
        committed **full** pages — those entirely inside ``[0,
        consumed)``, where ``consumed`` counts tokens whose K/V both
        models have materialized (the last committed token is only
        consumed by the *next* chunk, and a prefilling victim stops at
        its mirror's frontier) — in the radix index, and return the
        ``(max_pages,)`` bool column mask of entries that must park
        ``cached`` (None when nothing parks). Pages an
        identical-content index entry already covers release normally
        (no double-indexing). One small device->host sync reads the
        physical ids backing the row's committed prefix. Callers gate on
        ``prefix_cache is not None`` (dense engines have no page table
        to read ids from)."""
        self.prefix_cache.release_claims(claims)
        committed = req.serve_prompt()
        consumed = len(committed) - 1 - prefill_left
        n_cache = max(consumed, 0) // self.cfg.page_size
        if n_cache == 0:
            return None
        # speclint: sync-point(one row read at release: physical ids backing the committed prefix)
        ids = np.asarray(table_row[:n_cache]).tolist()
        assert all(p >= 0 for p in ids), ids
        # ``owner`` (live sharing): the row's own live registrations
        # convert in place to cached nodes, so claimants riding this
        # row outlive its release without re-claiming.
        adopted = self.prefix_cache.insert(committed, ids, owner=owner)
        cache_cols = np.zeros((self.runner.page_spec.max_pages,), bool)
        cache_cols[:n_cache] = adopted
        return cache_cols

    def _kill_stage_and_cache(
        self, sid: int, req: RequestState, prefill_left: int
    ):
        """Release a killed background prefill's staged pages. With the
        prefix cache on this composes exactly like a decode-slot
        preemption (:meth:`_release_and_cache`): the fully-written
        pages park ``cached`` instead of freeing, so the request's
        retry (requeued at the front) usually re-claims its own prefix
        instead of re-prefilling it.

        Disaggregated: never park — the pages are PREFILL-pool ids and
        the prefix index is a decode-pool structure; injecting them
        would hand later claimants pages from the wrong device's pool.
        Any in-flight transfer entry is dropped too (its buffers were
        shipped but will simply never be unpacked)."""
        if self._disagg:
            self._transfers.pop(sid, None)
            self._transfer_retries.pop(sid, None)
            self._transfer_backoff.pop(sid, None)
            self.stage, self.stage_pool = self.runner.release_stage(
                self.stage, self.stage_pool, sid
            )
            return
        okey = ("stage", sid)
        cache_cols = None
        if self.prefix_cache is not None:
            cache_cols = self._cacheable_cols(
                req, prefill_left, self._stage_claims.pop(sid, []),
                self.stage.page_table[sid],
                owner=okey if self._live_on else None,
            )
        self._drop_live_row(okey)
        self.stage, pool = self.runner.release_stage(
            self.stage, self.batch.pool, sid, cache_cols
        )
        self.batch = self.batch._replace(pool=pool)

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------

    def run(self) -> dict[int, RequestState]:
        """Serve until queue + slots drain. Returns rid -> RequestState."""
        return self.serve()

    def serve(self, pump=None, emit=None, idle=None) -> dict[int, RequestState]:
        """Run the service loop with optional continuous-batching hooks
        (all None ⇒ classic batch-submit run-to-completion, bit-identical
        to :meth:`run` before the hooks existed — an idle iteration
        dispatches nothing and so consumes no PRNG state).

        ``pump()`` is called at the top of every loop iteration (and
        while idling) on the SERVICE thread — the front end drains its
        ingress there via :meth:`submit`, so JAX state is only ever
        touched from one thread. It returns False once the front end has
        been closed to new requests (drain), which lets the loop
        quiesce. ``emit(req, tokens, finished)`` fires from
        :meth:`_process` with each request's newly committed tokens —
        the committed-token frontier, never a speculative/uncommitted
        token. ``idle()`` blocks briefly when the engine has no work and
        pump produced none (the front end parks on a wake event instead
        of hot-spinning)."""
        self._pump_cb, self._emit_cb, self._idle_cb = pump, emit, idle
        try:
            if self.cfg.async_prefill:
                return self._run_async()
            return self._run_serial()
        finally:
            self._pump_cb = self._emit_cb = self._idle_cb = None

    def _service_wait(self) -> bool:
        """Idle/quiesce path, reached when the loop has fully drained:
        keep pumping (and idling between pumps) until new work arrives
        (True — keep serving) or the front end closes with nothing left
        (False — quiesce and return). Batch mode (no pump) quiesces
        immediately."""
        if self._pump_cb is None:
            return False
        while True:
            accepting = self._pump_cb()
            if self.scheduler.has_work():
                return True
            if not accepting:
                return False
            if self._idle_cb is not None:
                self._idle_cb()

    def _stats_init(self):
        stats = {
            "iterations": 0, "prefill_steps": 0, "prefill_tokens": 0,
            "tokens": 0, "preemptions": 0, "wall_s": 0.0,
            # Lane-interaction counters: ``prefill_stall_steps`` counts
            # serial-loop iterations whose decode dispatch consumed a
            # same-iteration prefill chunk's outputs (the cost async
            # prefill removes); ``overlap_steps`` counts async-loop
            # iterations that co-dispatched BOTH a decode step and a
            # background prefill chunk — both lanes made progress that
            # iteration (on one device the executables still chain
            # through the shared pool);
            # ``adoptions`` counts completed background prefills folded
            # into the decode batch (mask flips — or, disaggregated,
            # completed page transfers); ``transfers``/``transfer_bytes``
            # count the disaggregated pack→ship→unpack dispatches and
            # the bytes they moved (0 in every other mode).
            "prefill_stall_steps": 0, "overlap_steps": 0, "adoptions": 0,
            "transfers": 0, "transfer_bytes": 0,
            # Fault plane / degradation ladder / lifecycle counters —
            # all zero on a fault-free run with no cancels or deadlines.
            # ``audit_repairs`` counts pool-audit reclamations (quiesce
            # + every kill/cancel/quarantine unwind); ``downgraded``
            # records the disagg → async downgrade tripping.
            "audit_repairs": 0, "cancelled": 0, "deadline_shed": 0,
            "transfer_retries": 0, "failovers": 0, "pod_failures": 0,
            "downgraded": False,
            # Per-step allocation telemetry (paged engines): host-mirror
            # pool occupancy and cumulative preemptions at each decode
            # dispatch, consumed by benchmarks/wallclock.py into
            # results/BENCH_serving.json. ``alloc_trace_stride`` is the
            # effective sampling stride after decimation (see
            # ``_decimate_trace``).
            "alloc_trace": [],
            "alloc_trace_stride": 1,
        }
        pc0 = (
            self.prefix_cache.stats()
            if self.prefix_cache is not None else None
        )
        self._stats = stats
        return stats, pc0, time.perf_counter()

    def _stats_finish(self, stats, pc0, t0) -> None:
        stats["wall_s"] = time.perf_counter() - t0
        if self._injector is not None:
            stats["fault_injections"] = self._injector.stats()
            stats["fault_log"] = list(self._injector.log)
        self._stats = None
        if pc0 is not None:
            pc = self.prefix_cache.stats()
            # Counters are per-run deltas (the index persists across
            # run() calls); *_pages occupancy values are absolute
            # end-of-run gauges.
            counters = (
                "hits", "misses", "live_hits", "claimed_tokens",
                "evicted_pages",
            )
            stats["prefix_cache"] = {
                k: pc[k] - pc0[k] if k in counters else pc[k] for k in pc
            }
        self.last_stats = stats

    def _trace_alloc(self, stats: dict, active_slots: int) -> None:
        budget = self.scheduler.budget
        if budget is None or stats["iterations"] % stats["alloc_trace_stride"]:
            return
        if len(stats["alloc_trace"]) >= ALLOC_TRACE_CAP:
            stats["alloc_trace"] = _decimate_trace(stats["alloc_trace"])
            stats["alloc_trace_stride"] *= 2
        stats["alloc_trace"].append({
            "step": stats["iterations"],
            "occupancy_pages": budget.occupancy_pages(),
            "worst_case_pages": budget.used_worst(),
            "num_pages": budget.spec.num_pages,
            "active_slots": active_slots,
            "preemptions": stats["preemptions"],
            "cached_pages": (
                self.prefix_cache.cached_pages
                if self.prefix_cache is not None else 0
            ),
        })

    def _evict_cached_pressure(self) -> None:
        """Cached-page pressure: evict LRU reclaimable pages until the
        free stack provably covers the next dispatch's worst case
        (claims/admissions may have shifted both sides)."""
        if self.prefix_cache is None:
            return
        deficit = self.scheduler.budget.evict_deficit(
            self.prefix_cache.reclaimable_pages()
        )
        if deficit > 0:
            self.batch = self.batch._replace(
                pool=paging.host_evict(
                    self.runner.page_spec, self.batch.pool,
                    self.prefix_cache.evict_lru(deficit),
                )
            )

    def _run_serial(self) -> dict[int, RequestState]:
        sched = self.scheduler
        stats, pc0, t0 = self._stats_init()
        # (snapshot of live-at-dispatch slots, in-flight StepOutputs)
        pending: tuple[dict[int, RequestState], StepOutputs] | None = None
        while True:
            # Continuous batching: drain the front end's ingress before
            # admission, so requests that arrived while the previous
            # iteration's programs ran are eligible this iteration.
            if self._pump_cb is not None:
                self._pump_cb()
            # Deadline shedding at the admission boundary (clock reads
            # only happen when some queued request carries a deadline,
            # so deadline-free runs keep their exact clock sequence).
            if any(r.deadline_s is not None for r in sched.queue):
                for req in sched.shed_expired():
                    stats["deadline_shed"] += 1
                    self._emit_terminal(req)
            # Page pressure (over-subscribed pools only): when the live
            # slots' conservative worst case outgrows the pool, sync the
            # in-flight step so lengths are exact, then preempt newest
            # slots until the next dispatch provably cannot exhaust the
            # device free list.
            if sched.needs_preemption():
                if pending is not None:
                    self._process(*pending, stats)
                    pending = None
                while sched.needs_preemption():
                    victim = sched.pick_victim()
                    if victim is None:
                        break
                    req = sched.slot_req[victim]
                    left = sched.prefill_left(victim)
                    sched.preempt(victim)
                    # Cache-aware release: the victim's committed full
                    # pages park in the prefix index, so its resume
                    # usually re-claims them instead of re-prefilling.
                    self.batch = self._release_and_cache(victim, req, left)
                    stats["preemptions"] += 1
            # Transient allocator denial (fault plane): veto this
            # iteration's admissions; the queue retries next loop —
            # behaviorally a one-iteration budget stall.
            denied = (
                self._injector is not None
                and bool(sched.queue)
                and self._injector.fires(
                    faults_mod.SITE_ALLOC_DENY,
                    iteration=self._loop_iter, rid=sched.queue[0].rid,
                )
            )
            if not denied:
                for slot, req in sched.admit():
                    try:
                        self._admit(slot, req)
                    except Exception as exc:  # per-request quarantine
                        self._quarantine_slot(slot, req, exc)
            if self._live_on:
                self._update_live_index()
                self._advance_rides()
            self._evict_cached_pressure()
            prefilled = False
            if sched.prefill_pending():
                self.t_cache, self.d_cache, self.batch = (
                    self.runner.prefill_step(
                        self.t_params, self.d_params,
                        self.t_cache, self.d_cache, self.batch,
                    )
                )
                stats["prefill_tokens"] += sched.note_prefill_dispatch()
                stats["prefill_steps"] += 1
                prefilled = True
                if self._live_on:
                    self._update_live_index()
            outs = None
            snapshot = sched.ready_slots()
            if snapshot:
                self.key, sub = jax.random.split(self.key)
                self.t_cache, self.d_cache, self.batch, outs = (
                    self.runner.decode_step(
                        self.t_params, self.d_params,
                        self.t_cache, self.d_cache, self.batch, sub,
                        corrupt=self._nonfinite_mask(snapshot),
                    )
                )
                stats["iterations"] += 1
                if prefilled:
                    # This decode dispatch consumes the caches a prefill
                    # chunk just produced: the chunk sits on the decode
                    # critical path (what async_prefill removes).
                    stats["prefill_stall_steps"] += 1
                self._trace_alloc(stats, len(snapshot))
            # Materialize the PREVIOUS step's outputs while the device runs
            # the one just dispatched (double buffering).
            if pending is not None:
                self._process(*pending, stats)
            pending = (snapshot, outs) if outs is not None else None
            self._loop_iter += 1
            if (
                pending is None
                and not sched.prefill_pending()
                and not sched.has_work()
                and not self._service_wait()
            ):
                break
        self._audit()
        self._stats_finish(stats, pc0, t0)
        return dict(sched.done)

    def _run_async(self) -> dict[int, RequestState]:
        """The disaggregated two-lane loop: decode is dispatched FIRST
        each host iteration (its dependency chain holds only the
        previous iteration's programs — never a same-iteration prefill
        chunk), then the background prefill program advances the
        staging lanes into ``staged`` pool pages decode cannot map.
        Completed prefills are *adopted* into free decode slots at the
        top of the next iteration: the staging table's physical pages
        become the decode slot's table prefix and their ``staged``
        marks clear — masks flip, no K/V moves. Decode slots therefore
        only ever hold ready work: a burst of cold prompts prefills in
        the staging lane while every decode lane keeps emitting.

        Disaggregated (``cfg.disaggregated``): the same loop shape, but
        the staging dispatch runs on the prefill pod's own
        params/caches/pool, completed lanes' pages ship asynchronously
        at the bottom of each iteration (:meth:`_dispatch_transfers`),
        and adoption — gated on the transfer having been dispatched —
        unpacks them into the decode pool instead of flipping masks."""
        sched = self.scheduler
        stats, pc0, t0 = self._stats_init()
        pending: tuple[dict[int, RequestState], StepOutputs] | None = None
        while True:
            if self._pump_cb is not None:
                self._pump_cb()
            if any(r.deadline_s is not None for r in sched.queue):
                for req in sched.shed_expired():
                    stats["deadline_shed"] += 1
                    self._emit_terminal(req)
            # Page pressure: sync the in-flight step so lengths are
            # exact, then shed load — background prefills first (least
            # progress; their fully-written pages park as cacheable),
            # decode slots LIFO only if staging alone cannot cover it.
            if sched.needs_preemption():
                if pending is not None:
                    self._process(*pending, stats)
                    pending = None
                while sched.needs_preemption():
                    # Disaggregated: killing a staging lane frees
                    # PREFILL-pool pages, which cannot relieve decode
                    # pressure — go straight for decode victims unless
                    # the stage pool itself is over (never, when fully
                    # provisioned).
                    sid = (
                        sched.pick_stage_victim()
                        if not self._disagg or sched.stage_budget_over()
                        else None
                    )
                    if sid is not None:
                        req = sched.stage_req[sid]
                        left = sched.stage_prefill_left(sid)
                        sched.kill_stage(sid)
                        self._kill_stage_and_cache(sid, req, left)
                        stats["preemptions"] += 1
                        continue
                    victim = sched.pick_victim()
                    if victim is None:
                        break
                    req = sched.slot_req[victim]
                    sched.preempt(victim)
                    self.batch = self._release_and_cache(victim, req, 0)
                    stats["preemptions"] += 1
            for sid, slot, req in sched.adopt(
                gate=self._transfer_ready if self._disagg else None
            ):
                try:
                    self._adopt(sid, slot, req, stats)
                except Exception as exc:  # per-request quarantine
                    self._quarantine_slot(slot, req, exc)
                    continue
                stats["adoptions"] += 1
            denied = (
                self._injector is not None
                and bool(sched.queue)
                and self._injector.fires(
                    faults_mod.SITE_ALLOC_DENY,
                    iteration=self._loop_iter, rid=sched.queue[0].rid,
                )
            )
            if not denied:
                if not self._pod_down:
                    for sid, req in sched.stage_admit():
                        try:
                            self._stage(sid, req)
                        except Exception as exc:
                            self._quarantine_stage(sid, req, exc)
                # Ladder floor: failed-over (``no_stage``) requests —
                # and, once the pod is down, every new admission — take
                # decode slots directly and prefill on the decode pod.
                # Structurally inert without the fault plane (no_stage
                # is only ever set by the ladder), so the fault-free
                # clock/PRNG sequence is untouched.
                if self._pod_down or any(r.no_stage for r in sched.queue):
                    for slot, req in sched.admit(
                        pred=(
                            None if self._pod_down
                            else (lambda r: r.no_stage)
                        )
                    ):
                        try:
                            self._admit(slot, req)
                        except Exception as exc:
                            self._quarantine_slot(slot, req, exc)
            if self._live_on:
                self._update_live_index()
                self._advance_rides()
            self._evict_cached_pressure()
            outs = None
            snapshot = sched.ready_slots()
            if snapshot:
                self.key, sub = jax.random.split(self.key)
                self.t_cache, self.d_cache, self.batch, outs = (
                    self.runner.decode_step(
                        self.t_params, self.d_params,
                        self.t_cache, self.d_cache, self.batch, sub,
                        corrupt=self._nonfinite_mask(snapshot),
                    )
                )
                stats["iterations"] += 1
                self._trace_alloc(stats, len(snapshot))
            if sched.stage_pending():
                # Prefill-pod dispatch failure (fault plane, disagg
                # only): the pod drops this iteration's stage dispatch —
                # the mirror does not advance, the lanes retry next
                # iteration, and repeated failures downgrade the engine.
                pod_fail = False
                if self._injector is not None and self._disagg:
                    rid = next(
                        (
                            r.rid
                            for s, r in enumerate(sched.stage_req)
                            if r is not None
                            and not sched.stage_riding(s)
                            and sched.stage_prefill_left(s) > 0
                        ),
                        None,
                    )
                    pod_fail = rid is not None and self._injector.fires(
                        faults_mod.SITE_POD_DISPATCH,
                        iteration=self._loop_iter, rid=rid,
                    )
                    if pod_fail:
                        self._note_pod_failure(stats)
                if pod_fail:
                    pass
                elif self._disagg:
                    # The prefill pod's OWN params/caches/pool: the
                    # staging executable runs device-disjoint from the
                    # decode dispatch above — true overlap, not two
                    # programs chained through one pool.
                    (
                        self.t_stage_cache, self.d_stage_cache,
                        self.stage, self.stage_pool,
                    ) = self.runner.stage_prefill_step(
                        self.t_params_stage, self.d_params_stage,
                        self.t_stage_cache, self.d_stage_cache,
                        self.stage, self.stage_pool,
                    )
                else:
                    self.t_cache, self.d_cache, self.stage, pool = (
                        self.runner.stage_prefill_step(
                            self.t_params, self.d_params,
                            self.t_cache, self.d_cache,
                            self.stage, self.batch.pool,
                        )
                    )
                    self.batch = self.batch._replace(pool=pool)
                if not pod_fail:
                    stats["prefill_tokens"] += (
                        sched.note_stage_prefill_dispatch()
                    )
                    stats["prefill_steps"] += 1
                    if outs is not None:
                        stats["overlap_steps"] += 1
                    if self._live_on:
                        self._update_live_index()
            if sched.prefill_pending():
                # Ladder floor: failed-over / post-downgrade admissions
                # prefill in their DECODE slot on the decode pod (serial
                # semantics; the slot turns ready once its chunks are
                # consumed). Unreachable without the fault plane — the
                # async loop never admits unprefillled work into decode
                # slots otherwise.
                self.t_cache, self.d_cache, self.batch = (
                    self.runner.prefill_step(
                        self.t_params, self.d_params,
                        self.t_cache, self.d_cache, self.batch,
                    )
                )
                stats["prefill_tokens"] += sched.note_prefill_dispatch()
                stats["prefill_steps"] += 1
            if self._disagg:
                # Ship newly-ready lanes' pages now (decode for this
                # iteration is already in flight — transfers overlap
                # it); the lanes adopt at the top of the next iteration,
                # exactly when the mask-flip path would have adopted.
                self._dispatch_transfers(stats)
            if pending is not None:
                self._process(*pending, stats)
            pending = (snapshot, outs) if outs is not None else None
            self._loop_iter += 1
            if (
                pending is None
                and not sched.stage_pending()
                and not sched.prefill_pending()
                and not sched.has_work()
                and not self._service_wait()
            ):
                break
        self._audit()
        self._stats_finish(stats, pc0, t0)
        return dict(sched.done)

    def _process(
        self,
        snapshot: dict[int, RequestState],
        outs: StepOutputs,
        stats: dict,
    ):
        """Host bookkeeping for one materialized iteration: append emitted
        tokens, update acceptance accounting, retire finished slots."""
        # speclint: sync-point(THE per-iteration sync: materialize iteration N-1's StepOutputs while N runs)
        ot, nk, nt, dn = (
            np.asarray(outs.tokens), np.asarray(outs.n_keep),
            np.asarray(outs.num_tokens), np.asarray(outs.done),
        )
        now = time.perf_counter()
        budget = self.scheduler.budget
        for slot, req in snapshot.items():
            if req.finished:
                # Retired after this step was dispatched: the lane ran one
                # wasted iteration whose outputs are dropped.
                continue
            try:
                req.iterations += 1
                req.accepted_total += max(int(nt[slot]) - 1, 0)
                if budget is not None:
                    budget.note_commit(slot, int(nt[slot]))
                k = int(nk[slot])
                if k > 0:
                    if not req.output:
                        req.first_token_t = now
                    req.output.extend(int(t) for t in ot[slot, :k])
                done_now = bool(dn[slot])
                reason = None
                if (
                    not done_now
                    and req.deadline_s is not None
                    and req.past_deadline(self.scheduler.clock())
                ):
                    # Deadline shedding at the retire check: the request
                    # stops decoding the first time its blown SLO is
                    # observed; tokens committed so far are kept.
                    done_now = True
                    reason = "deadline"
                    stats["deadline_shed"] += 1
                if done_now:
                    self.scheduler.retire(
                        slot, reason or self._finish_reason(req)
                    )
                    # Count EVERY retired request's output — including
                    # requests cut off by the max_len guard, which earlier
                    # versions silently dropped from throughput accounting.
                    stats["tokens"] += len(req.output)
                    self.batch = self._release_and_cache(slot, req, 0)
                # Streaming: hand the front end everything newly committed
                # since the last emit. ``output`` only ever extends (the
                # committed frontier is monotone — preemption recomputes but
                # never truncates), so the cursor slice is exactly the fresh
                # committed tokens; emitting after retirement means a final
                # delta observes finish_t/finish_reason already stamped.
                if self._emit_cb is not None:
                    fresh = req.output[req.emitted:]
                    if fresh or req.finished:
                        req.emitted = len(req.output)
                        self._emit_cb(req, fresh, req.finished)
            except Exception as exc:  # per-request quarantine
                self._quarantine_slot(slot, req, exc)

    def _release_and_cache(
        self, slot: int, req: RequestState, prefill_left: int
    ):
        """Release a retired/preempted slot's pages, parking its
        committed full pages in the prefix cache
        (:meth:`_cacheable_cols`) instead of freeing them."""
        okey = ("slot", slot)
        cache_cols = None
        if self.prefix_cache is not None:
            cache_cols = self._cacheable_cols(
                req, prefill_left, self._claims.pop(slot, []),
                self.batch.page_table[slot],
                owner=okey if self._live_on else None,
            )
        self._drop_live_row(okey)
        return self.runner.release_slot(self.batch, slot, cache_cols)

    def _finish_reason(self, req: RequestState) -> str:
        if (
            self.cfg.eos_id >= 0
            and req.output
            and req.output[-1] == self.cfg.eos_id
        ):
            return "eos"
        if len(req.output) >= req.max_new_tokens:
            return "length"
        return "max_len_guard"

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------

    def request_metrics(self) -> list[dict]:
        """Per-request serving metrics (TTFT, tokens/s, acceptance rate)."""
        return self.scheduler.request_metrics(self.cfg.gamma)
