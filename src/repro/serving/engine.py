"""Speculative-decoding serving engine with continuous batching.

Design (mirrors production spec-dec servers, adapted to JAX/TPU):

* **Slots**: a fixed-size batch of sequence slots; requests are admitted
  into free slots (prefill one, decode many) and retired on EOS/limit.
* **Bookkeeping invariants** (per slot):
  - ``seq_buf[: len]`` holds all committed tokens;
  - the *target* has consumed ``seq_buf[: len-1]`` — the last committed
    token is consumed at the start of the next verify chunk;
  - the *drafter* has consumed ``seq_buf[: d_len]`` and catches up to
    ``len`` at the start of each iteration (a small re-process chunk;
    cheap because the drafter is small, and it makes SSM-state rollback
    trivial: the drafter never commits state past ``len``).
* **One iteration** (fully jitted, fixed shapes):
  1. drafter catch-up chunk (verify mode, committed at the valid length),
  2. gamma-1 drafter decode steps (SSM entries are scratch — restored to
     the committed catch-up state afterwards; KV ring writes past ``len``
     are safe: they are either overwritten by the true tokens at those
     positions or masked by causality),
  3. target verify chunk ``[last_token, X_1..X_gamma]``,
  4. draft verification (token / block / greedy — the paper's algorithms),
  5. commit: roll SSM states back to the accepted position, extend
     ``seq_buf``/lengths.

The verification step is where this paper lives; everything else is the
substrate it needs.

Note on verifiers: ``token`` and ``block`` are lossless end-to-end (the
greedy-equality tests check token-identical outputs at temperature 0).
``greedy_block`` is served WITHOUT the Algorithm-5 distribution
modification (the paper presents it as a theoretical device and
recommends block verification); its faithful lossless form — including
nested modification — lives in ``repro.core.simulate`` where Table 3 is
reproduced.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sampling, verification
from repro.models.model import Model
from repro.models.ssm import SSMEntry

PREFILL_BUCKET = 16


@dataclass(frozen=True)
class EngineConfig:
    gamma: int = 8
    verifier: str = "block"         # token | block | greedy_block
    max_slots: int = 4
    max_len: int = 512
    temperature: float = 1.0
    eos_id: int = -1                # -1: never stop on EOS
    max_new_tokens: int = 128


@dataclass
class RequestState:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    output: list[int] = field(default_factory=list)
    iterations: int = 0
    accepted_total: int = 0


def _restore_ssm(drafted_cache, committed_cache):
    """Keep post-draft KV entries (stale-safe) but restore SSM entries to
    the committed catch-up state (SSM state cannot be rolled back)."""

    def pick(a, b):
        if isinstance(a, SSMEntry):
            return b
        return a

    return jax.tree.map(
        pick, drafted_cache, committed_cache,
        is_leaf=lambda x: isinstance(x, SSMEntry),
    )


class SpecEngine:
    """Batched speculative-decoding engine for one (target, drafter) pair."""

    def __init__(
        self,
        target: Model,
        drafter: Model,
        t_params,
        d_params,
        cfg: EngineConfig,
    ):
        assert target.cfg.vocab == drafter.cfg.vocab
        self.target, self.drafter = target, drafter
        self.t_params, self.d_params = t_params, d_params
        self.cfg = cfg
        self._iter_fn = jax.jit(
            partial(_iteration, target, drafter, cfg),
        )
        self._prefill_fns: dict[int, Any] = {}
        self.reset()

    # ------------------------------------------------------------------
    # state
    # ------------------------------------------------------------------

    def reset(self):
        cfg = self.cfg
        b = cfg.max_slots
        slack = max(cfg.gamma + 1, PREFILL_BUCKET)
        self.t_cache = self.target.init_cache(b, cfg.max_len, chunk_slack=slack)
        self.d_cache = self.drafter.init_cache(b, cfg.max_len, chunk_slack=slack)
        self.seq_buf = jnp.zeros((b, cfg.max_len), jnp.int32)
        self.lens = jnp.zeros((b,), jnp.int32)     # committed tokens
        self.d_lens = jnp.zeros((b,), jnp.int32)   # drafter-consumed tokens
        self.active = np.zeros((b,), bool)
        self.slot_req: list[RequestState | None] = [None] * b
        self.key = jax.random.key(0)
        self._queue: list[RequestState] = []
        self._done: dict[int, RequestState] = {}
        self._next_rid = 0

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------

    def submit(self, prompt_ids: list[int], max_new_tokens: int | None = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            RequestState(
                rid=rid,
                prompt=list(prompt_ids),
                max_new_tokens=max_new_tokens or self.cfg.max_new_tokens,
            )
        )
        return rid

    def _prefill_one(self, slot: int, req: RequestState):
        plen = len(req.prompt)
        bucket = max(
            PREFILL_BUCKET,
            (plen + PREFILL_BUCKET - 1) // PREFILL_BUCKET * PREFILL_BUCKET,
        )
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        if bucket not in self._prefill_fns:
            self._prefill_fns[bucket] = jax.jit(
                partial(_prefill, self.target, self.drafter, self.cfg)
            )
        t_c, d_c = self._prefill_fns[bucket](
            self.t_params, self.d_params,
            jnp.asarray(toks), jnp.asarray([plen], jnp.int32),
        )
        # scatter the single-sequence caches into this slot (batch axis=1
        # for stacked cache entries).
        self.t_cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1
            ),
            self.t_cache, t_c,
        )
        self.d_cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1
            ),
            self.d_cache, d_c,
        )
        row = jnp.zeros((self.cfg.max_len,), jnp.int32)
        row = row.at[:plen].set(jnp.asarray(req.prompt, jnp.int32))
        self.seq_buf = self.seq_buf.at[slot].set(row)
        self.lens = self.lens.at[slot].set(plen)
        self.d_lens = self.d_lens.at[slot].set(plen - 1)
        self.active[slot] = True
        self.slot_req[slot] = req

    def _admit(self):
        for slot in range(self.cfg.max_slots):
            if not self.active[slot] and self._queue:
                self._prefill_one(slot, self._queue.pop(0))

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        self._done[req.rid] = req
        self.slot_req[slot] = None
        self.active[slot] = False

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------

    def run(self) -> dict[int, RequestState]:
        """Serve until queue + slots drain. Returns rid -> RequestState."""
        stats = {"iterations": 0, "tokens": 0, "wall_s": 0.0}
        t0 = time.time()
        while self._queue or self.active.any():
            self._admit()
            if not self.active.any():
                break
            self.key, sub = jax.random.split(self.key)
            active = jnp.asarray(self.active)
            (
                self.t_cache, self.d_cache, self.seq_buf,
                self.lens, self.d_lens, out_tokens, num_tokens,
            ) = self._iter_fn(
                self.t_params, self.d_params,
                self.t_cache, self.d_cache,
                self.seq_buf, self.lens, self.d_lens, active, sub,
            )
            stats["iterations"] += 1
            nt = np.asarray(num_tokens)
            ot = np.asarray(out_tokens)
            for slot in range(self.cfg.max_slots):
                if not self.active[slot]:
                    continue
                req = self.slot_req[slot]
                new = ot[slot, : nt[slot]].tolist()
                req.iterations += 1
                req.accepted_total += int(nt[slot]) - 1
                done = False
                for tok in new:
                    req.output.append(tok)
                    if tok == self.cfg.eos_id or (
                        len(req.output) >= req.max_new_tokens
                    ):
                        done = True
                        break
                stats["tokens"] += len(req.output) if done else 0
                if done or int(self.lens[slot]) + self.cfg.gamma + 2 >= self.cfg.max_len:
                    self._retire(slot)
        stats["wall_s"] = time.time() - t0
        self.last_stats = stats
        return dict(self._done)


# ---------------------------------------------------------------------------
# jitted bodies
# ---------------------------------------------------------------------------


def _prefill(target: Model, drafter: Model, cfg: EngineConfig,
             t_params, d_params, tokens, valid_len):
    """Prefill both models through ``valid_len - 1`` tokens: the engine
    invariant is that the last committed token is consumed by the next
    chunk (verify chunk for the target, catch-up chunk for the drafter)."""
    slack = max(cfg.gamma + 1, PREFILL_BUCKET)
    t_cache = target.init_cache(1, cfg.max_len, chunk_slack=slack)
    d_cache = drafter.init_cache(1, cfg.max_len, chunk_slack=slack)
    _, t_cache, _ = target.apply(
        t_params, tokens, cache=t_cache, extras=target.make_extras(1),
        mode="prefill", valid_len=valid_len - 1,
    )
    _, d_cache, _ = drafter.apply(
        d_params, tokens, cache=d_cache, extras=drafter.make_extras(1),
        mode="prefill", valid_len=valid_len - 1,
    )
    return t_cache, d_cache


def _iteration(
    target: Model, drafter: Model, cfg: EngineConfig,
    t_params, d_params, t_cache, d_cache,
    seq_buf, lens, d_lens, active, key,
):
    """One speculative iteration over all slots. Returns updated state plus
    (out_tokens (B, gamma+1), num_tokens (B,)) with num_tokens=0 for
    inactive slots."""
    b = seq_buf.shape[0]
    g = cfg.gamma
    vocab = target.cfg.vocab
    key_d, key_v = jax.random.split(key)

    # ---- 1. drafter catch-up: chunk of up to g+1 tokens from d_lens. ----
    k_catch = g + 1
    idx = d_lens[:, None] + jnp.arange(k_catch)[None]
    catch_toks = jnp.take_along_axis(
        seq_buf, jnp.minimum(idx, seq_buf.shape[1] - 1), axis=1
    )
    n_valid = lens - d_lens  # in [1, g+1]
    d_logits, d_vcache, _ = drafter.apply(
        d_params, catch_toks, cache=d_cache, lens=d_lens,
        mode="verify", valid_len=n_valid,
    )
    d_cache_committed = drafter.commit_cache(d_vcache, n_valid - 1)
    # q(. | committed prefix): logits at index n_valid-1.
    last_q_logits = jnp.take_along_axis(
        d_logits, (n_valid - 1)[:, None, None], axis=1
    )[:, 0]

    # ---- 2. draft gamma tokens. ----
    def probs_of(logits):
        return sampling.logits_to_probs(
            logits[..., :vocab], temperature=cfg.temperature
        )

    q0 = probs_of(last_q_logits)                      # (B, V)
    key_d, sub = jax.random.split(key_d)
    x1 = sampling.categorical(sub, q0)

    def draft_step(carry, i):
        cache, tok, key_i = carry
        key_i, sub = jax.random.split(key_i)
        pos_len = lens + i  # drafter consumed lens+i tokens so far
        logits, cache, _ = drafter.apply(
            d_params, tok[:, None], cache=cache, lens=pos_len, mode="decode"
        )
        q = probs_of(logits[:, 0])
        nxt = sampling.categorical(sub, q)
        return (cache, nxt, key_i), (tok, q)

    (d_cache_drafted, _, _), (draft_toks, q_scan) = jax.lax.scan(
        draft_step, (d_cache_committed, x1, key_d), jnp.arange(g)
    )
    draft_toks = draft_toks.T                          # (B, G): X_1..X_G
    # q_scan[i] = q(. | prefix, X_1..X_{i+1}); verification needs
    # [q0, q(.|X_1), ..., q(.|X^{G-1})].
    q_rows = jnp.concatenate(
        [q0[:, None], jnp.swapaxes(q_scan, 0, 1)[:, : g - 1]], axis=1
    )                                                  # (B, G, V)
    d_cache_next = _restore_ssm(d_cache_drafted, d_cache_committed)

    # ---- 3. target verify chunk [last_token, X_1..X_gamma]. ----
    last_tok = jnp.take_along_axis(seq_buf, (lens - 1)[:, None], axis=1)
    chunk = jnp.concatenate([last_tok, draft_toks], axis=1)  # (B, G+1)
    t_logits, t_vcache, _ = target.apply(
        t_params, chunk, cache=t_cache, lens=lens - 1, mode="verify"
    )
    p_rows = probs_of(t_logits)                         # (B, G+1, V)

    # ---- 4. verification (the paper's algorithms). ----
    verify = verification.get_verifier(cfg.verifier)
    res = verify(key_v, draft_toks, q_rows, p_rows)
    tau = res.num_accepted
    num_tokens = jnp.where(active, res.num_tokens, 0)

    # ---- 5. commit. ----
    t_cache_next = target.commit_cache(t_vcache, tau)
    # inactive slots: freeze everything.
    t_cache_next = jax.tree.map(
        lambda new, old: _mask_batch(new, old, active, axis=1),
        t_cache_next, t_cache,
    )
    d_cache_next = jax.tree.map(
        lambda new, old: _mask_batch(new, old, active, axis=1),
        d_cache_next, d_cache,
    )
    pos = jnp.arange(g + 1)[None]
    write_idx = lens[:, None] + pos
    valid = (pos < num_tokens[:, None]) & active[:, None]
    write_idx = jnp.where(valid, write_idx, seq_buf.shape[1] - 1)
    b_idx = jnp.broadcast_to(jnp.arange(b)[:, None], write_idx.shape)
    seq_buf = seq_buf.at[b_idx, write_idx].set(
        jnp.where(valid, res.tokens, seq_buf[b_idx, write_idx])
    )
    new_lens = jnp.where(active, lens + num_tokens, lens)
    new_d_lens = jnp.where(active, lens, d_lens)
    return (
        t_cache_next, d_cache_next, seq_buf,
        new_lens, new_d_lens, res.tokens, num_tokens,
    )


def _mask_batch(new, old, active, axis):
    shape = [1] * new.ndim
    shape[axis] = active.shape[0]
    return jnp.where(active.reshape(shape), new, old)
