"""Sharding rules: logical parameter axes -> mesh axes, with per-tensor
divisibility fallback (e.g. smollm's 9 heads cannot shard over model=16:
that tensor falls back to replication while its FFN still shards).

Parameter scheme (2-D "TP + FSDP"):
  * model axis: heads / kv_heads / ffn / vocab (tensor parallelism)
  * data axes (+pod): the embed dim of weight matrices (FSDP-style weight
    sharding — XLA inserts per-layer all-gathers). This is what makes the
    123B config fit 16 GB/chip; see EXPERIMENTS.md.

Cache scheme:
  * batch over data axes when divisible; for ``long_500k`` (batch=1) the
    KV/state sequence dim shards over data instead (sequence parallelism
    for decode: XLA turns the attention reduction into an all-reduce).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.attention import KVCache, PagedKV
from repro.models.model import Model
from repro.models.ssm import SSMEntry, SSMVerify
from repro.models.transformer import CrossKV

# logical axis -> preferred mesh axis (None = replicate)
MODEL_AXES = {"heads": "model", "kv_heads": "model", "ffn": "model",
              "vocab": "model", "experts": None, "embed": None}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _mesh_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        import math
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def param_spec(
    logical: tuple, shape: tuple, mesh: Mesh, fsdp: bool = True,
    experts_axis: str | None = None,
) -> P:
    """Map one tensor's logical axes to a PartitionSpec.

    ``experts_axis``: mesh axis for expert parallelism (e.g. "data") —
    expert-sharded weights make expert-grad reduction local instead of a
    full all-reduce over the data axis (see EXPERIMENTS.md §Perf)."""
    dax = data_axes(mesh)
    out: list = []
    used_data = False
    is_expert = "experts" in logical
    for name, dim in zip(logical, shape):
        axis = MODEL_AXES.get(name) if name else None
        if name == "experts" and experts_axis is not None:
            axis = experts_axis
        if axis is not None and dim % _mesh_size(mesh, axis) == 0:
            out.append(axis)
            if axis == "data" or (isinstance(axis, tuple) and "data" in axis):
                used_data = True
        elif (
            fsdp and not used_data and name == "embed"
            and not (is_expert and experts_axis)
            and dim % _mesh_size(mesh, dax) == 0 and dax
        ):
            out.append(dax if len(dax) > 1 else dax[0])
            used_data = True
        else:
            out.append(None)
    return P(*out)


def param_shardings(model: Model, mesh: Mesh, fsdp: bool = True,
                    experts_axis: str | None = None):
    axes = model.logical_axes()
    shapes = model.abstract_params()
    return jax.tree.map(
        lambda ax, sh: NamedSharding(
            mesh, param_spec(ax, sh.shape, mesh, fsdp, experts_axis)
        ),
        axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


_SEQ_SHARD_MIN = 4096  # shard the KV sequence dim only when it is long


def _entry_spec(leaf_shape, batch_dim, seq_dim, model_dim, mesh, shard_seq):
    """Spec for one cache leaf.

    * batch over the data axes when divisible; for batch=1 long-context
      (``shard_seq``) the sequence dim takes the data axes instead;
    * long KV sequence dims additionally shard over "model" (the KV-head
      dim of GQA caches is rarely divisible by model=16, and 32k x 128
      caches otherwise dwarf HBM) — decode attention then runs as a
      partial softmax + all-reduce, flash-decode style;
    * short (ring/window) caches stay unsharded on the sequence dim.
    """
    dax = data_axes(mesh)
    n_data = _mesh_size(mesh, dax)
    n_model = mesh.shape["model"]
    spec = [None] * len(leaf_shape)
    if not shard_seq and leaf_shape[batch_dim] % n_data == 0 and dax:
        spec[batch_dim] = dax if len(dax) > 1 else dax[0]
    elif (
        shard_seq and seq_dim is not None
        and leaf_shape[seq_dim] % n_data == 0 and dax
    ):
        spec[seq_dim] = dax if len(dax) > 1 else dax[0]
    if model_dim is not None and leaf_shape[model_dim] % n_model == 0:
        spec[model_dim] = "model"
    elif (
        seq_dim is not None
        and spec[seq_dim] is None
        and leaf_shape[seq_dim] >= _SEQ_SHARD_MIN
        and leaf_shape[seq_dim] % n_model == 0
    ):
        spec[seq_dim] = "model"
    elif (
        seq_dim is not None
        and shard_seq
        and spec[seq_dim] is not None
        and leaf_shape[seq_dim] % (n_data * n_model) == 0
    ):
        # batch=1 long-context: fold model into the sequence shard too
        cur = spec[seq_dim]
        cur = cur if isinstance(cur, tuple) else (cur,)
        spec[seq_dim] = cur + ("model",)
    return P(*spec)


def cache_shardings(model: Model, mesh: Mesh, cache, shard_seq: bool = False,
                    tp: bool = True):
    """Shardings matching the structure of ``cache`` (committed form).
    Leaves have a leading group dim; batch is dim 1. ``tp=False`` shards
    the batch dim only (pure data-parallel serving)."""

    def one(entry):
        if isinstance(entry, PagedKV):
            # (G, P, page, K, hd): the *page-pool* dim takes the data
            # axes the way per-slot caches shard their batch dim — pages
            # are slot-agnostic, so pool shards stay balanced regardless
            # of which slots are long; KV heads shard over "model".
            return PagedKV(
                k=NamedSharding(mesh, _entry_spec(entry.k.shape, 1, None, 3, mesh, False)),
                v=NamedSharding(mesh, _entry_spec(entry.v.shape, 1, None, 3, mesh, False)),
            )
        if isinstance(entry, KVCache):
            # (G, B, C, K, hd)
            return KVCache(
                k=NamedSharding(mesh, _entry_spec(entry.k.shape, 1, 2, 3, mesh, shard_seq)),
                v=NamedSharding(mesh, _entry_spec(entry.v.shape, 1, 2, 3, mesh, shard_seq)),
            )
        if isinstance(entry, SSMEntry):
            # conv (G, B, w-1, conv_dim); state (G, B, H, P, N)
            return SSMEntry(
                conv=NamedSharding(mesh, _entry_spec(entry.conv.shape, 1, None, 3, mesh, False)),
                state=NamedSharding(mesh, _entry_spec(entry.state.shape, 1, None, 2, mesh, False)),
            )
        if isinstance(entry, CrossKV):
            return CrossKV(
                k=NamedSharding(mesh, _entry_spec(entry.k.shape, 1, None, 3, mesh, False)),
                v=NamedSharding(mesh, _entry_spec(entry.v.shape, 1, None, 3, mesh, False)),
            )
        raise TypeError(type(entry))

    def one_dp(entry):
        def spec(a):
            sp = [None] * a.ndim
            dax = data_axes(mesh)
            if a.shape[1] % _mesh_size(mesh, dax) == 0 and dax:
                sp[1] = dax if len(dax) > 1 else dax[0]
            return NamedSharding(mesh, P(*sp))

        return jax.tree.map(spec, entry)

    return jax.tree.map(
        one if tp else one_dp, cache,
        is_leaf=lambda x: isinstance(
            x, (KVCache, PagedKV, SSMEntry, CrossKV)
        ),
    )


def carve_pods(mesh, prefill_data: int):
    """Split a 2-D ``("data", "model")`` mesh into a (prefill pod,
    decode pod) pair along the data axis: the first ``prefill_data``
    data-rows keep every model column and become the prefill pod, the
    remaining rows the decode pod. Works on a concrete :class:`Mesh`
    (rows of ``mesh.devices`` are physically disjoint device groups —
    the serving engine places the staging executable on one and the
    decode executable on the other) and on an
    :class:`~jax.sharding.AbstractMesh` (the launch dry-run lowers each
    pod's program against its reduced abstract geometry without
    touching device state). Both pods inherit the axis names, so every
    per-pod sharding spec in this module (:func:`param_shardings`,
    :func:`cache_shardings`, ...) applies unchanged — per-pod sharding
    is just the same rules over a smaller data axis."""
    from jax.sharding import AbstractMesh

    n_data = mesh.shape["data"]
    if not 0 < prefill_data < n_data:
        raise ValueError(
            f"prefill_data={prefill_data} must split data={n_data} "
            "into two non-empty pods"
        )
    if set(mesh.axis_names) != {"data", "model"}:
        raise ValueError(
            f"carve_pods needs a ('data', 'model') mesh, got "
            f"{mesh.axis_names}"
        )
    n_model = mesh.shape["model"]
    if isinstance(mesh, AbstractMesh):
        return (
            AbstractMesh((("data", prefill_data), ("model", n_model))),
            AbstractMesh((("data", n_data - prefill_data),
                          ("model", n_model))),
        )
    devs = mesh.devices.reshape(n_data, n_model)
    return (
        Mesh(devs[:prefill_data], ("data", "model")),
        Mesh(devs[prefill_data:], ("data", "model")),
    )


def batch_sharding(mesh: Mesh) -> NamedSharding:
    dax = data_axes(mesh)
    return NamedSharding(mesh, P(dax if len(dax) > 1 else dax[0]))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
