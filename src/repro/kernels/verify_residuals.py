"""Pallas TPU kernel: fused residual-mass reduction for block verification.

Computes ``S[r] = sum_v max(p_scale[r] * P[r, v] - Q[r, v], 0)`` for a
batch of (row = (sequence, block-position)) distribution pairs — the heavy
term of Eq. (4)/(3) in the paper. XLA would emit scale-multiply, subtract,
relu and reduce as separate HBM passes over two (B*K, V) arrays with V up
to 256k; this kernel streams one VMEM tile of each operand and reduces in
registers — a single HBM read per operand, no intermediates.

TPU adaptation: vocab tiles are lane-aligned (multiples of 128) and the
row dimension is tiled to the sublane count; the reduction over vocab
tiles runs as the innermost (sequential on-core) grid dimension so the
output block stays resident in VMEM and is accumulated in place.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8       # sublane-aligned rows per program
VOCAB_BLOCK = 2048  # lane-aligned vocab tile (multiple of 128)


def _kernel(scale_ref, p_ref, q_ref, out_ref):
    vj = pl.program_id(1)

    @pl.when(vj == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[...].astype(jnp.float32)          # (ROW_BLOCK, VOCAB_BLOCK)
    q = q_ref[...].astype(jnp.float32)
    s = scale_ref[...].astype(jnp.float32)      # (ROW_BLOCK, 1)
    part = jnp.maximum(s * p - q, 0.0)
    out_ref[...] += jnp.sum(part, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def verify_residual_sums(
    p_scale: jax.Array,  # (B, K)
    p_rows: jax.Array,   # (B, K, V)
    q_rows: jax.Array,   # (B, K, V)
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        # Compiled on TPU; interpret (XLA-lowered emulation of the grid
        # program) everywhere else. Explicit True/False overrides, exposed
        # through the verification backend registry in repro.kernels.ops.
        interpret = jax.default_backend() != "tpu"
    b, k, v = p_rows.shape
    if b * k == 0 or v == 0:
        # Degenerate grid (e.g. greedy-block at gamma=1 has K = 0 middle
        # positions): the reduction over an empty axis is exactly zeros.
        return jnp.zeros((b, k), jnp.float32)
    rows = b * k
    scale = p_scale.reshape(rows, 1)
    p2 = p_rows.reshape(rows, v)
    q2 = q_rows.reshape(rows, v)

    row_blk = min(ROW_BLOCK, rows)
    vocab_blk = min(VOCAB_BLOCK, v)
    pad_r = (-rows) % row_blk
    pad_v = (-v) % vocab_blk
    if pad_r or pad_v:
        # zero-padding is exact: max(s*0 - 0, 0) contributes nothing.
        scale = jnp.pad(scale, ((0, pad_r), (0, 0)))
        p2 = jnp.pad(p2, ((0, pad_r), (0, pad_v)))
        q2 = jnp.pad(q2, ((0, pad_r), (0, pad_v)))
    grid = (scale.shape[0] // row_blk, p2.shape[1] // vocab_blk)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_blk, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((row_blk, vocab_blk), lambda i, j: (i, j)),
            pl.BlockSpec((row_blk, vocab_blk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((row_blk, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((scale.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(scale, p2, q2)
    return out[:rows, 0].reshape(b, k)
