"""Pallas TPU kernel: causal flash attention for prefill/training.

Standard flash-attention-2 style online softmax over KV tiles, with GQA
(the KV-head block index is derived from the query-head program id),
sliding windows and logit softcap. Query tiles are MXU-aligned; the
(m, l, acc) running state lives in VMEM scratch across the innermost KV
grid dimension.

:func:`flash_prefill_paged` is the paged-KV variant used by the serving
engine's chunked prefill and verify chunks: a chunk of ``S`` queries
starting at per-sequence position ``q_start`` attends K/V gathered from
a global page pool, with the pool page for each KV tile resolved in the
grid via the scalar-prefetched page table (see ``flash_decode`` for the
decode-step sibling). All ``G`` query heads of one KV head and all ``S``
chunk positions are folded into one ``(G*S, hd)`` MXU operand; the
per-row query position (``q_start + row % S``) drives causal/window
masking.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_BLOCK = 256
K_BLOCK = 256
_MASK = -1e30
_INIT_M = -1e30


def _kernel(
    q_ref, k_ref, v_ref, out_ref,
    m_ref, l_ref, acc_ref,
    *, window: int, softcap: float, scale: float, blkq: int, blkk: int,
    seq_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _INIT_M)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * blkq + jax.lax.broadcasted_iota(jnp.int32, (blkq, blkk), 0)
    k_pos = ki * blkk + jax.lax.broadcasted_iota(jnp.int32, (blkq, blkk), 1)
    mask = (k_pos <= q_pos) & (k_pos < seq_len)
    if window > 0:
        mask &= q_pos - k_pos < window

    q = q_ref[...].astype(jnp.float32) * scale
    s = jax.lax.dot_general(
        q, k_ref[...].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask, s, _MASK)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _done():
        out_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret")
)
def flash_prefill(
    q: jax.Array,       # (B, S, H, hd)
    k: jax.Array,       # (B, S, Kh, hd)
    v: jax.Array,       # (B, S, Kh, hd)
    window: int = -1,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    blkq = min(Q_BLOCK, s)
    blkk = min(K_BLOCK, s)
    pad_q = (-s) % blkq
    pad_k = (-s) % blkk
    qt = jnp.moveaxis(q, 2, 1)  # (B, H, S, hd)
    kt = jnp.moveaxis(k, 2, 1)  # (B, Kh, S, hd)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _kernel, window=window, softcap=softcap, scale=1.0 / (hd ** 0.5),
        blkq=blkq, blkk=blkk, seq_len=s,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, qt.shape[2] // blkq, kt.shape[2] // blkk),
        in_specs=[
            pl.BlockSpec(
                (None, None, blkq, hd), lambda i, hj, qi, ki: (i, hj, qi, 0)
            ),
            pl.BlockSpec(
                (None, None, blkk, hd),
                lambda i, hj, qi, ki, g=g: (i, hj // g, ki, 0),
            ),
            pl.BlockSpec(
                (None, None, blkk, hd),
                lambda i, hj, qi, ki, g=g: (i, hj // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, None, blkq, hd), lambda i, hj, qi, ki: (i, hj, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blkq, 1), jnp.float32),
            pltpu.VMEM((blkq, 1), jnp.float32),
            pltpu.VMEM((blkq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :s], 1, 2)


def _paged_kernel(
    pt_ref,      # (B, maxp) scalar-prefetch page table
    qstart_ref,  # (B,) scalar-prefetch chunk start positions
    total_ref,   # (B,) scalar-prefetch tokens written per sequence
    q_ref,       # (G*S, hd) — all query heads x chunk positions
    k_ref,       # (page, hd)
    v_ref,       # (page, hd)
    out_ref,     # (G*S, hd)
    m_ref, l_ref, acc_ref,
    *, window: int, softcap: float, scale: float, page: int, s_chunk: int,
):
    b = pl.program_id(0)
    pj = pl.program_id(2)

    @pl.when(pj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _INIT_M)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gs = q_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * scale
    s = jax.lax.dot_general(
        q, k_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # (G*S, page)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    row = jax.lax.broadcasted_iota(jnp.int32, (gs, 1), 0)
    qpos = qstart_ref[b] + row % s_chunk                 # (G*S, 1)
    kpos = pj * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    mask = (
        (kpos < total_ref[b]) & (kpos <= qpos) & (pt_ref[b, pj] >= 0)
    )
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, _MASK)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(pj == pl.num_programs(2) - 1)
    def _done():
        out_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret")
)
def flash_prefill_paged(
    q: jax.Array,           # (B, S, H, hd) — one chunk of queries
    k_pool: jax.Array,      # (P, page, Kh, hd) — global page pool
    v_pool: jax.Array,      # (P, page, Kh, hd)
    page_table: jax.Array,  # (B, maxp) int32; -1 = unmapped
    q_start: jax.Array,     # (B,) position of the chunk's first query
    total: jax.Array,       # (B,) tokens written (valid keys: pos < total)
    window: int = -1,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, hd = q.shape
    page, kh = k_pool.shape[1], k_pool.shape[2]
    g = h // kh
    maxp = page_table.shape[1]
    # (B, S, H, hd) -> (B, Kh, G*S, hd): head-major rows so one KV head's
    # queries are contiguous for the (G*S, hd) x (hd, page) MXU matmul.
    qg = jnp.moveaxis(q.reshape(b, s, kh, g, hd), 1, 3)  # (B, Kh, G, S, hd)
    qg = qg.reshape(b, kh, g * s, hd)

    kernel = functools.partial(
        _paged_kernel, window=window, softcap=softcap,
        scale=1.0 / (hd ** 0.5), page=page, s_chunk=s,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kh, maxp),
        in_specs=[
            pl.BlockSpec(
                (None, None, g * s, hd),
                lambda i, j, pj, pt, qs, tt: (i, j, 0, 0),
            ),
            pl.BlockSpec(
                (None, page, None, hd),
                lambda i, j, pj, pt, qs, tt: (
                    jnp.maximum(pt[i, pj], 0), 0, j, 0
                ),
            ),
            pl.BlockSpec(
                (None, page, None, hd),
                lambda i, j, pj, pt, qs, tt: (
                    jnp.maximum(pt[i, pj], 0), 0, j, 0
                ),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, None, g * s, hd),
            lambda i, j, pj, pt, qs, tt: (i, j, 0, 0),
        ),
        scratch_shapes=[
            pltpu.VMEM((g * s, 1), jnp.float32),
            pltpu.VMEM((g * s, 1), jnp.float32),
            pltpu.VMEM((g * s, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g * s, hd), q.dtype),
        interpret=interpret,
    )(
        page_table.astype(jnp.int32), q_start.astype(jnp.int32),
        total.astype(jnp.int32), qg, k_pool, v_pool,
    )
    out = out.reshape(b, kh, g, s, hd)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, hd)
