"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body in
Python per grid step — bitwise-faithful to the lowering semantics, used
by the allclose tests against ``repro.kernels.ref``.

Importing this module registers the fused residual-sum kernel in the
verification backend registry (``repro.core.verification``):

* ``"pallas"``           — backend auto-detect (compiled kernel on TPU,
                           XLA reference elsewhere); the serving engine's
                           default via ``residual_backend="auto"``.
* ``"pallas_interpret"`` — force the emulated kernel (fidelity tests).
* ``"pallas_compiled"``  — force compiled lowering (TPU only).

``block_verify_fused`` plugs the fused kernel into the paper's block
verification directly (the ``residual_sums`` hook of
``repro.core.verification.block_verify``).
"""

from __future__ import annotations

import functools

import jax

from repro.core import verification
from repro.kernels import flash_decode as _fd
from repro.kernels import flash_prefill as _fp
from repro.kernels import ref as _ref
from repro.kernels import verify_residuals as _vr


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def verify_residual_sums(p_scale, p_rows, q_rows, interpret=None):
    """Fused ``sum_v max(p_scale*P - Q, 0)`` — the engine's default hot
    path. On TPU this is the compiled Pallas kernel; elsewhere (with
    ``interpret`` unset) it falls back to the XLA reference, because
    interpret-mode emulation executes the grid step-by-step and is meant
    for kernel-fidelity tests, not serving throughput. Pass
    ``interpret=True`` to force the emulated kernel."""
    if interpret is None and not _on_tpu():
        return _ref.verify_residual_sums(p_scale, p_rows, q_rows)
    return _vr.verify_residual_sums(
        p_scale, p_rows, q_rows, interpret=interpret
    )


def flash_decode(q, k, v, q_pos, k_pos, window=-1, softcap=0.0):
    return _fd.flash_decode(
        q, k, v, q_pos, k_pos, window=window, softcap=softcap,
        interpret=not _on_tpu(),
    )


def flash_prefill(q, k, v, window=-1, softcap=0.0):
    return _fp.flash_prefill(
        q, k, v, window=window, softcap=softcap, interpret=not _on_tpu()
    )


def flash_decode_paged(
    q, k_pool, v_pool, page_table, q_pos, total,
    window=-1, softcap=0.0, interpret=None,
):
    """Paged decode attention. On TPU: the Pallas kernel resolving pool
    pages via the scalar-prefetched page table. Elsewhere (``interpret``
    unset): the XLA gather reference — interpret-mode emulation is for
    kernel-fidelity tests, not serving throughput."""
    if interpret is None and not _on_tpu():
        return _ref.flash_decode_paged(
            q, k_pool, v_pool, page_table, q_pos, total,
            window=window, softcap=softcap,
        )
    return _fd.flash_decode_paged(
        q, k_pool, v_pool, page_table, q_pos, total,
        window=window, softcap=softcap,
        interpret=bool(interpret) if interpret is not None else False,
    )


def flash_prefill_paged(
    q, k_pool, v_pool, page_table, q_start, total,
    window=-1, softcap=0.0, interpret=None,
):
    """Paged chunked-prefill/verify attention (see flash_decode_paged)."""
    if interpret is None and not _on_tpu():
        return _ref.flash_prefill_paged(
            q, k_pool, v_pool, page_table, q_start, total,
            window=window, softcap=softcap,
        )
    return _fp.flash_prefill_paged(
        q, k_pool, v_pool, page_table, q_start, total,
        window=window, softcap=softcap,
        interpret=bool(interpret) if interpret is not None else False,
    )


def attend_paged(
    q, k_pool, v_pool, page_table, positions, total,
    window=-1, softcap=0.0,
):
    """The serving path's paged-attention entry point (called from
    ``repro.models.attention`` when running on TPU): routes single-token
    chunks to the decode kernel and multi-token verify/prefill chunks to
    the chunked kernel. ``q`` is (B, S, H, hd); returns the same shape."""
    if q.shape[1] == 1:
        out = flash_decode_paged(
            q[:, 0], k_pool, v_pool, page_table, positions[:, 0], total,
            window=window, softcap=softcap,
        )
        return out[:, None]
    return flash_prefill_paged(
        q, k_pool, v_pool, page_table, positions[:, 0], total,
        window=window, softcap=softcap,
    )


@functools.partial(jax.jit, static_argnames=())
def block_verify_fused(key, draft_tokens, q_probs, p_probs):
    """Block verification (Algorithm 2) with the vocab reductions running
    through the fused Pallas kernel (compiled on TPU, emulated elsewhere
    — this entry point always exercises the kernel lowering)."""
    return verification.block_verify(
        key, draft_tokens, q_probs, p_probs,
        residual_sums=_vr.verify_residual_sums,
    )


verification.register_residual_backend("pallas", verify_residual_sums)
verification.register_residual_backend(
    "pallas_interpret",
    functools.partial(verify_residual_sums, interpret=True),
)
verification.register_residual_backend(
    "pallas_compiled",
    functools.partial(verify_residual_sums, interpret=False),
)
