"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body in
Python per grid step — bitwise-faithful to the lowering semantics, used
by the allclose tests against ``repro.kernels.ref``.

``block_verify_fused`` plugs the fused residual-sum kernel into the
paper's block-verification algorithm (the ``residual_sums`` hook in
``repro.core.verification.block_verify``).
"""

from __future__ import annotations

import functools

import jax

from repro.core import verification
from repro.kernels import flash_decode as _fd
from repro.kernels import flash_prefill as _fp
from repro.kernels import verify_residuals as _vr


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def verify_residual_sums(p_scale, p_rows, q_rows):
    return _vr.verify_residual_sums(
        p_scale, p_rows, q_rows, interpret=not _on_tpu()
    )


def flash_decode(q, k, v, q_pos, k_pos, window=-1, softcap=0.0):
    return _fd.flash_decode(
        q, k, v, q_pos, k_pos, window=window, softcap=softcap,
        interpret=not _on_tpu(),
    )


def flash_prefill(q, k, v, window=-1, softcap=0.0):
    return _fp.flash_prefill(
        q, k, v, window=window, softcap=softcap, interpret=not _on_tpu()
    )


@functools.partial(jax.jit, static_argnames=())
def block_verify_fused(key, draft_tokens, q_probs, p_probs):
    """Block verification (Algorithm 2) with the vocab reductions running
    through the fused Pallas kernel."""
    return verification.block_verify(
        key, draft_tokens, q_probs, p_probs,
        residual_sums=verify_residual_sums,
    )
