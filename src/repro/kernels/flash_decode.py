"""Pallas TPU kernel: flash-decode GQA attention over a (ring) KV cache.

One query token per sequence attends a cache of ``C`` slots. The KV length
is tiled; the online-softmax running (max, sum, acc) state stays in VMEM
across KV tiles (innermost sequential grid dim). Supports GQA (all query
heads of one KV head processed together — an (G, hd) x (hd, Ck) MXU
matmul per tile), sliding windows, gemma-style logit softcap, and ring
validity via key positions.

:func:`flash_decode_paged` is the paged-KV variant: K/V live in a global
page pool ``(P, page, Kh, hd)`` shared by all sequences, and the KV tile
for grid step ``(b, j, pj)`` is resolved *in the grid* through the
scalar-prefetched page table — ``page_table[b, pj]`` feeds the BlockSpec
index map, so each sequence DMAs exactly its own pages and the pool
never materializes densely. Tile validity comes from logical positions
(``pj * page + offset``) against the per-sequence total, not from a
stored position array.

This is the target-model hot spot of speculative decoding at decode time:
arithmetic intensity ~ O(G) FLOPs/byte, i.e. HBM-bandwidth-bound; the
kernel exists to reach that bound in one pass rather than XLA's
materialize-scores path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

KV_BLOCK = 512
_MASK = -1e30
_INIT_M = -1e30


def _kernel(
    q_ref,       # (G, hd)
    k_ref,       # (Ck, hd)
    v_ref,       # (Ck, hd)
    kpos_ref,    # (1, Ck)
    qpos_ref,    # (1, 1)
    out_ref,     # (G, hd)
    m_ref, l_ref, acc_ref,        # VMEM scratch
    *, window: int, softcap: float, scale: float,
):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _INIT_M)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale          # (G, hd)
    k = k_ref[...].astype(jnp.float32)                  # (Ck, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # (G, Ck)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    kpos = kpos_ref[...]       # (Ck,) — None block dims are squeezed
    qpos = qpos_ref[0]
    mask = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, :], s, _MASK)

    m_prev = m_ref[...]                                 # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                              # (G, Ck)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(cj == pl.num_programs(2) - 1)
    def _done():
        out_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret")
)
def flash_decode(
    q: jax.Array,       # (B, H, hd)
    k: jax.Array,       # (B, C, Kh, hd)
    v: jax.Array,       # (B, C, Kh, hd)
    q_pos: jax.Array,   # (B,)
    k_pos: jax.Array,   # (B, C)
    window: int = -1,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    b, h, hd = q.shape
    c, kh = k.shape[1], k.shape[2]
    g = h // kh
    blk = min(KV_BLOCK, c)
    pad_c = (-c) % blk
    if pad_c:
        k = jnp.pad(k, ((0, 0), (0, pad_c), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_c), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_c)), constant_values=-1)
    c_pad = k.shape[1]

    qg = q.reshape(b, kh, g, hd)
    kt = jnp.swapaxes(k, 1, 2)  # (B, Kh, C, hd)
    vt = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(
        _kernel, window=window, softcap=softcap, scale=1.0 / (hd ** 0.5)
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kh, c_pad // blk),
        in_specs=[
            pl.BlockSpec((None, None, g, hd), lambda i, j, cj: (i, j, 0, 0)),
            pl.BlockSpec((None, None, blk, hd), lambda i, j, cj: (i, j, cj, 0)),
            pl.BlockSpec((None, None, blk, hd), lambda i, j, cj: (i, j, cj, 0)),
            pl.BlockSpec((None, blk), lambda i, j, cj: (i, cj)),
            pl.BlockSpec((None, 1), lambda i, j, cj: (i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, None, g, hd), lambda i, j, cj: (i, j, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, k_pos, q_pos.reshape(b, 1))
    return out.reshape(b, h, hd)


def _paged_kernel(
    pt_ref,      # (B, maxp) scalar-prefetch page table
    qpos_ref,    # (B,) scalar-prefetch query positions
    total_ref,   # (B,) scalar-prefetch tokens written per sequence
    q_ref,       # (G, hd)
    k_ref,       # (page, hd) — one pool page of this KV head
    v_ref,       # (page, hd)
    out_ref,     # (G, hd)
    m_ref, l_ref, acc_ref,
    *, window: int, softcap: float, scale: float, page: int,
):
    b = pl.program_id(0)
    pj = pl.program_id(2)

    @pl.when(pj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _INIT_M)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale
    s = jax.lax.dot_general(
        q, k_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # (G, page)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    kpos = pj * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    qpos = qpos_ref[b]
    mask = (
        (kpos < total_ref[b]) & (kpos <= qpos) & (pt_ref[b, pj] >= 0)
    )
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, _MASK)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(pj == pl.num_programs(2) - 1)
    def _done():
        out_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret")
)
def flash_decode_paged(
    q: jax.Array,           # (B, H, hd)
    k_pool: jax.Array,      # (P, page, Kh, hd) — global page pool
    v_pool: jax.Array,      # (P, page, Kh, hd)
    page_table: jax.Array,  # (B, maxp) int32; -1 = unmapped
    q_pos: jax.Array,       # (B,) position of the query token
    total: jax.Array,       # (B,) tokens written (valid keys: pos < total)
    window: int = -1,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    b, h, hd = q.shape
    page, kh = k_pool.shape[1], k_pool.shape[2]
    g = h // kh
    maxp = page_table.shape[1]
    qg = q.reshape(b, kh, g, hd)

    kernel = functools.partial(
        _paged_kernel, window=window, softcap=softcap,
        scale=1.0 / (hd ** 0.5), page=page,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kh, maxp),
        in_specs=[
            pl.BlockSpec(
                (None, None, g, hd),
                lambda i, j, pj, pt, qp, tt: (i, j, 0, 0),
            ),
            # KV tile resolved through the page table: unmapped (-1)
            # pages clamp to page 0 and are masked out in the kernel.
            pl.BlockSpec(
                (None, page, None, hd),
                lambda i, j, pj, pt, qp, tt: (
                    jnp.maximum(pt[i, pj], 0), 0, j, 0
                ),
            ),
            pl.BlockSpec(
                (None, page, None, hd),
                lambda i, j, pj, pt, qp, tt: (
                    jnp.maximum(pt[i, pj], 0), 0, j, 0
                ),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, None, g, hd), lambda i, j, pj, pt, qp, tt: (i, j, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        interpret=interpret,
    )(
        page_table.astype(jnp.int32), q_pos.astype(jnp.int32),
        total.astype(jnp.int32), qg, k_pool, v_pool,
    )
    return out.reshape(b, h, hd)
