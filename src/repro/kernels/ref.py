"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

_MASK = -1e30


def verify_residual_sums(
    p_scale: jax.Array,  # (B, K)
    p_rows: jax.Array,   # (B, K, V) target rows
    q_rows: jax.Array,   # (B, K, V) drafter rows
) -> jax.Array:
    """S[b, k] = sum_v max(p_scale[b,k] * P[b,k,v] - Q[b,k,v], 0).

    The vocab-reduction at the heart of block verification (Eq. 4):
    bandwidth-bound over (B, K, V) with V up to 256k."""
    return jnp.sum(
        jnp.maximum(
            p_scale[..., None].astype(jnp.float32) * p_rows.astype(jnp.float32)
            - q_rows.astype(jnp.float32),
            0.0,
        ),
        axis=-1,
    )


def flash_decode(
    q: jax.Array,       # (B, H, hd)
    k: jax.Array,       # (B, C, Kh, hd)
    v: jax.Array,       # (B, C, Kh, hd)
    q_pos: jax.Array,   # (B,) position of the query token
    k_pos: jax.Array,   # (B, C) key positions (negative = invalid slot)
    window: int = -1,
    softcap: float = 0.0,
) -> jax.Array:
    """Single-token GQA decode attention over a (ring) KV cache."""
    b, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.reshape(b, kh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bckd->bkgc", qf, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = (k_pos >= 0) & (k_pos <= q_pos[:, None])
    if window > 0:
        mask &= q_pos[:, None] - k_pos < window
    scores = jnp.where(mask[:, None, None], scores, _MASK)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, hd)


def flash_prefill(
    q: jax.Array,       # (B, S, H, hd)
    k: jax.Array,       # (B, S, Kh, hd)
    v: jax.Array,       # (B, S, Kh, hd)
    window: int = -1,
    softcap: float = 0.0,
) -> jax.Array:
    """Causal (optionally windowed / softcapped) self-attention."""
    b, s, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.reshape(b, s, kh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgd,bckd->bkgsc", qf, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(s)
    mask = pos[None, :, None] >= pos[None, None, :]
    if window > 0:
        mask &= pos[None, :, None] - pos[None, None, :] < window
    scores = jnp.where(mask[:, None, None], scores, _MASK)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsc,bckd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# Paged-KV oracles: XLA gather through the page table, then dense attention.
# ---------------------------------------------------------------------------


def paged_gather(
    k_pool: jax.Array,      # (P, page, Kh, hd)
    v_pool: jax.Array,      # (P, page, Kh, hd)
    page_table: jax.Array,  # (B, maxp) int32; -1 = unmapped
    total: jax.Array,       # (B,) tokens written per sequence
):
    """Materialize each sequence's pages in position order: returns
    ``(k, v, k_pos)`` with shapes (B, maxp*page, Kh, hd) and (B,
    maxp*page); ``k_pos`` is -1 at unwritten/unmapped positions."""
    b, maxp = page_table.shape
    page = k_pool.shape[1]
    mapped = page_table >= 0
    phys = jnp.clip(page_table, 0, k_pool.shape[0] - 1)
    kd = k_pool[phys].reshape(b, maxp * page, *k_pool.shape[2:])
    vd = v_pool[phys].reshape(b, maxp * page, *v_pool.shape[2:])
    pos = jnp.arange(maxp * page)[None]
    valid = (pos < total[:, None]) & jnp.repeat(mapped, page, axis=1)
    return kd, vd, jnp.where(valid, pos, -1)


def flash_decode_paged(
    q: jax.Array,           # (B, H, hd)
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    q_pos: jax.Array,       # (B,)
    total: jax.Array,       # (B,)
    window: int = -1,
    softcap: float = 0.0,
) -> jax.Array:
    kd, vd, k_pos = paged_gather(k_pool, v_pool, page_table, total)
    return flash_decode(
        q, kd, vd, q_pos, k_pos, window=window, softcap=softcap
    )


def flash_prefill_paged(
    q: jax.Array,           # (B, S, H, hd) — chunk of queries
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    q_start: jax.Array,     # (B,) chunk start positions
    total: jax.Array,       # (B,)
    window: int = -1,
    softcap: float = 0.0,
) -> jax.Array:
    """Chunked-prefill/verify attention over the paged pool: S queries at
    positions ``q_start + [0, S)`` attend all written positions."""
    b, s, h, hd = q.shape
    kh = k_pool.shape[2]
    g = h // kh
    kd, vd, k_pos = paged_gather(k_pool, v_pool, page_table, total)
    qf = q.reshape(b, s, kh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgd,bckd->bkgsc", qf, kd.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = q_start[:, None] + jnp.arange(s)[None]       # (B, S)
    mask = (k_pos[:, None, :] >= 0) & (
        k_pos[:, None, :] <= q_pos[:, :, None]
    )
    if window > 0:
        mask &= q_pos[:, :, None] - k_pos[:, None, :] < window
    scores = jnp.where(mask[:, None, None], scores, _MASK)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsc,bckd->bskgd", probs, vd.astype(jnp.float32))
    return out.reshape(b, s, h, hd)
