"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

_MASK = -1e30


def verify_residual_sums(
    p_scale: jax.Array,  # (B, K)
    p_rows: jax.Array,   # (B, K, V) target rows
    q_rows: jax.Array,   # (B, K, V) drafter rows
) -> jax.Array:
    """S[b, k] = sum_v max(p_scale[b,k] * P[b,k,v] - Q[b,k,v], 0).

    The vocab-reduction at the heart of block verification (Eq. 4):
    bandwidth-bound over (B, K, V) with V up to 256k."""
    return jnp.sum(
        jnp.maximum(
            p_scale[..., None].astype(jnp.float32) * p_rows.astype(jnp.float32)
            - q_rows.astype(jnp.float32),
            0.0,
        ),
        axis=-1,
    )


def flash_decode(
    q: jax.Array,       # (B, H, hd)
    k: jax.Array,       # (B, C, Kh, hd)
    v: jax.Array,       # (B, C, Kh, hd)
    q_pos: jax.Array,   # (B,) position of the query token
    k_pos: jax.Array,   # (B, C) key positions (negative = invalid slot)
    window: int = -1,
    softcap: float = 0.0,
) -> jax.Array:
    """Single-token GQA decode attention over a (ring) KV cache."""
    b, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.reshape(b, kh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bckd->bkgc", qf, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = (k_pos >= 0) & (k_pos <= q_pos[:, None])
    if window > 0:
        mask &= q_pos[:, None] - k_pos < window
    scores = jnp.where(mask[:, None, None], scores, _MASK)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, hd)


def flash_prefill(
    q: jax.Array,       # (B, S, H, hd)
    k: jax.Array,       # (B, S, Kh, hd)
    v: jax.Array,       # (B, S, Kh, hd)
    window: int = -1,
    softcap: float = 0.0,
) -> jax.Array:
    """Causal (optionally windowed / softcapped) self-attention."""
    b, s, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.reshape(b, s, kh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgd,bckd->bkgsc", qf, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(s)
    mask = pos[None, :, None] >= pos[None, None, :]
    if window > 0:
        mask &= pos[None, :, None] - pos[None, None, :] < window
    scores = jnp.where(mask[:, None, None], scores, _MASK)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsc,bckd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd)
