"""Grouped-query attention with unified train / prefill / verify / decode
semantics, sliding-window ring-buffer KV caches, paged KV pools and
gemma-style softcaps.

One code path serves every mode:

* ``kv_cache is None``  — training: self-attention among the ``S`` new
  tokens only (causal + window mask).
* ``kv_cache`` is a :class:`KVCache` — dense per-slot cache: the new
  tokens' K/V are scattered into the cache (ring-buffered when the cache
  is shorter than the sequence, i.e. for sliding-window layers), then
  queries attend over the whole cache. This covers prefill (S = prompt),
  speculative verification (S = gamma + 1) and decode (S = 1) uniformly.
* ``kv_cache`` is a :class:`PagedKV` — the serving path for
  global-attention layers: K/V rows live in a **global page pool** shared
  by all slots; a per-slot ``page_table`` (managed by
  ``repro.serving.paging``) maps logical pages (position // page_size)
  to physical pool pages. Writes scatter through the table (positions
  masked by ``write_mask``/unmapped pages are dropped — a shared pool
  cannot be un-written per slot afterwards, unlike the dense cache's
  select-restore); reads gather the slot's pages back into position
  order. On TPU the gather+attend runs as the paged Pallas kernels
  (``repro.kernels.ops``); elsewhere it is an XLA gather feeding the
  *same* ``_sdpa`` as the dense path, which keeps paged and dense
  serving bitwise identical.

The pure-jnp path below is the reference; ``repro.kernels`` provides
Pallas TPU implementations that are swapped in via ``repro.kernels.ops``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import paged_gather
from repro.models import common
from repro.models.common import ModelConfig, Spec

_MASK_VALUE = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (B, C, n_kv, hd)
    v: jax.Array  # (B, C, n_kv, hd)

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def init_kv_cache(
    batch: int, capacity: int, n_kv: int, hd: int, dtype=jnp.float32
) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, n_kv, hd), dtype),
        v=jnp.zeros((batch, capacity, n_kv, hd), dtype),
    )


class PagedKV(NamedTuple):
    """Global K/V page pool for one layer (stacked over layer groups with
    a leading group dim at rest). Slot ownership lives outside, in the
    page table threaded through ``forward``."""

    k: jax.Array  # (P, page_size, n_kv, hd)
    v: jax.Array  # (P, page_size, n_kv, hd)

    @property
    def num_pages(self) -> int:
        return self.k.shape[0]

    @property
    def page_size(self) -> int:
        return self.k.shape[1]



def attn_param_specs(
    cfg: ModelConfig, prefix: tuple[int, ...] = (), cross: bool = False
) -> dict:
    """Param specs for one attention block; ``prefix`` stacks over layers."""
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    pad = (None,) * len(prefix)
    specs = {
        "wq": Spec(prefix + (d, h, hd), "normal", pad + ("embed", "heads", None)),
        "wk": Spec(prefix + (d, k, hd), "normal", pad + ("embed", "kv_heads", None)),
        "wv": Spec(prefix + (d, k, hd), "normal", pad + ("embed", "kv_heads", None)),
        "wo": Spec(prefix + (h, hd, d), "normal", pad + ("heads", None, "embed")),
    }
    if cross:
        specs["gate"] = Spec(prefix + (1,), "zeros", pad + (None,))
    return specs


def _project(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (B, S, D) @ w (D, H, hd) -> (B, S, H, hd)."""
    return jnp.einsum("bsd,dhk->bshk", x, w)


def _scatter_ring(cache: jax.Array, new: jax.Array, positions: jax.Array):
    """Scatter new (B, S, K, hd) rows at slot = position % capacity."""
    cap = cache.shape[1]
    slots = positions % cap  # (B, S)
    b_idx = jnp.broadcast_to(
        jnp.arange(cache.shape[0])[:, None], slots.shape
    )
    return cache.at[b_idx, slots].set(new.astype(cache.dtype))


def _scatter_pages(
    pool: jax.Array,        # (P, page, K, hd)
    new: jax.Array,         # (B, S, K, hd)
    positions: jax.Array,   # (B, S); negative = suppressed write
    page_table: jax.Array,  # (B, max_pages) int32; -1 = unmapped
) -> jax.Array:
    """Scatter new K/V rows into the pool at the physical page resolved
    through the slot's page table. Writes at negative positions, past the
    table, or into unmapped pages are dropped — in the serving engine
    every *committed* position is backed by an allocated page (the runner
    allocates before it writes), so drops only ever hit positions beyond
    a slot's valid frontier, which are rewritten before they are read."""
    ps = pool.shape[1]
    logical = positions // ps
    off = positions % ps  # floor-mod: >= 0 even for suppressed writes
    valid = (positions >= 0) & (logical < page_table.shape[1])
    phys = jnp.take_along_axis(
        page_table, jnp.clip(logical, 0, page_table.shape[1] - 1), axis=1
    )
    valid &= phys >= 0
    phys = jnp.where(valid, phys, pool.shape[0])  # OOB sentinel -> drop
    return pool.at[phys, off].set(new.astype(pool.dtype), mode="drop")


def _ring_key_positions(cap: int, total: jax.Array) -> jax.Array:
    """Position stored in each ring slot given `total` tokens written.

    Slot s holds the largest p < total with p % cap == s (or an invalid
    negative value if nothing was written there yet). total: (B,).
    """
    s = jnp.arange(cap)[None, :]
    t = total[:, None]
    p = t - 1 - ((t - 1 - s) % cap)
    return jnp.where(t > 0, p, -1)  # (B, cap); p < 0 where unwritten


Q_CHUNK = 512  # query-block size for the memory-bounded long-seq path


def _sdpa(
    q: jax.Array,          # (B, S, H, hd)
    k: jax.Array,          # (B, C, K, hd)
    v: jax.Array,          # (B, C, K, hd)
    q_pos: jax.Array,      # (B, S)
    k_pos: jax.Array,      # (B, C)  (negative = invalid)
    window: int,
    softcap: float,
    causal: bool,
) -> jax.Array:
    # Long sequences: scan over query blocks so the scores buffer is
    # O(S * Q_CHUNK) instead of O(S^2) (flash_prefill is the TPU kernel
    # for this; the scan is its XLA-lowerable twin used by the dry-run).
    s = q.shape[1]
    if s > 2 * Q_CHUNK and s % Q_CHUNK == 0:
        nq = s // Q_CHUNK

        def body(_, inp):
            qb, qpb = inp  # (B, Q_CHUNK, H, hd), (B, Q_CHUNK)
            return None, _sdpa_dense(
                qb, k, v, qpb, k_pos, window, softcap, causal
            )

        qs = jnp.moveaxis(
            q.reshape(q.shape[0], nq, Q_CHUNK, *q.shape[2:]), 1, 0
        )
        qps = jnp.moveaxis(q_pos.reshape(q_pos.shape[0], nq, Q_CHUNK), 1, 0)
        _, out = jax.lax.scan(body, None, (qs, qps))
        out = jnp.moveaxis(out, 0, 1)
        return out.reshape(q.shape)
    return _sdpa_dense(q, k, v, q_pos, k_pos, window, softcap, causal)


def _sdpa_dense(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    window: int,
    softcap: float,
    causal: bool,
) -> jax.Array:
    b, s, h, hd = q.shape
    kk = k.shape[2]
    groups = h // kk
    q = q.reshape(b, s, kk, groups, hd)
    scores = jnp.einsum(
        "bskgd,bckd->bkgsc", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(hd).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = k_pos[:, None, :] >= 0
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window > 0:
        mask &= q_pos[:, :, None] - k_pos[:, None, :] < window
    scores = jnp.where(mask[:, None, None], scores, _MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsc,bckd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd)


def attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                 # (B, S, D)
    positions: jax.Array,         # (B, S)
    kv_cache: KVCache | None,
    *,
    window: int = -1,
    causal: bool = True,
    use_rope: bool | None = None,
    mode: str = "train",
    page_table: jax.Array | None = None,
    write_mask: jax.Array | None = None,
) -> tuple[jax.Array, KVCache | None]:
    use_rope = cfg.use_rope if use_rope is None else use_rope
    q = _project(x, p["wq"])
    k = _project(x, p["wk"])
    v = _project(x, p["wv"])
    if use_rope:
        q = common.rope(q, positions, cfg.rope_theta)
        k = common.rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        out = _sdpa(
            q, k, v, positions, positions,
            window, cfg.attn_softcap, causal,
        )
        new_cache = None
    elif isinstance(kv_cache, PagedKV):
        # Serving path through the page pool (any cached mode): scatter
        # the chunk through the page table, then attend over the slot's
        # gathered pages. `write_mask=False` slots must not touch the
        # shared pool (there is no per-slot restore for pooled storage).
        assert page_table is not None, "paged cache needs a page table"
        w_pos = positions
        if write_mask is not None:
            w_pos = jnp.where(write_mask[:, None], positions, -1)
        k_pool = _scatter_pages(kv_cache.k, k, w_pos, page_table)
        v_pool = _scatter_pages(kv_cache.v, v, w_pos, page_table)
        new_cache = PagedKV(k=k_pool, v=v_pool)
        total = positions[:, -1] + 1
        if jax.default_backend() == "tpu":
            from repro.kernels import ops

            out = ops.attend_paged(
                q, k_pool, v_pool, page_table, positions, total,
                window=window, softcap=cfg.attn_softcap,
            )
        else:
            # paged_gather (the kernels' XLA reference oracle — one
            # shared implementation) + the dense path's own _sdpa keeps
            # paged serving bitwise identical to dense serving off-TPU.
            kd, vd, k_pos = paged_gather(k_pool, v_pool, page_table, total)
            out = _sdpa(
                q, kd, vd, positions, k_pos,
                window, cfg.attn_softcap, causal,
            )
    elif mode == "prefill":
        # Prefill always starts at position 0: every needed key is inside
        # this chunk, so attention runs chunk-internal (ring caches shorter
        # than the prompt would have evicted keys early queries need).
        # Only the last `capacity` keys are written to the cache.
        out = _sdpa(
            q, k, v, positions, positions,
            window, cfg.attn_softcap, causal,
        )
        cap = kv_cache.capacity
        s = k.shape[1]
        keep = min(s, cap)
        k_cache = _scatter_ring(kv_cache.k, k[:, s - keep:], positions[:, s - keep:])
        v_cache = _scatter_ring(kv_cache.v, v[:, s - keep:], positions[:, s - keep:])
        new_cache = KVCache(k=k_cache, v=v_cache)
    else:  # verify / decode: scatter into the ring then read it all.
        k_cache = _scatter_ring(kv_cache.k, k, positions)
        v_cache = _scatter_ring(kv_cache.v, v, positions)
        total = positions[:, -1] + 1  # tokens written incl. this chunk
        k_pos = _ring_key_positions(k_cache.shape[1], total)
        out = _sdpa(
            q, k_cache, v_cache, positions, k_pos,
            window, cfg.attn_softcap, causal,
        )
        new_cache = KVCache(k=k_cache, v=v_cache)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, new_cache


def cross_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,             # (B, S, D)
    ctx_k: jax.Array,         # (B, T, n_kv, hd) precomputed context keys
    ctx_v: jax.Array,
    gated: bool = False,
) -> jax.Array:
    """Cross-attention over a fixed context (vision tokens / audio frames).
    Context K/V are computed once at prefill and cached."""
    q = _project(x, p["wq"])
    b, s = x.shape[:2]
    t = ctx_k.shape[1]
    q_pos = jnp.zeros((b, s), jnp.int32)
    k_pos = jnp.zeros((b, t), jnp.int32)
    out = _sdpa(
        q, ctx_k, ctx_v, q_pos, k_pos,
        window=-1, softcap=cfg.attn_softcap, causal=False,
    )
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    if gated:
        y = jnp.tanh(p["gate"].astype(x.dtype)) * y
    return y


def context_kv(cfg: ModelConfig, p: dict, ctx: jax.Array):
    """Project the cross-attention context once: (B, T, D) -> K/V."""
    return _project(ctx, p["wk"]), _project(ctx, p["wv"])
