"""Model facade: init / apply / cache management for every architecture."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import common, transformer
from repro.models.common import ModelConfig


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def param_specs(self):
        return transformer.param_specs(self.cfg)

    def init(self, key: jax.Array):
        return common.materialize(self.param_specs(), key)

    def abstract_params(self):
        return common.spec_shapes(self.param_specs())

    def logical_axes(self):
        return common.spec_axes(self.param_specs())

    def init_cache(
        self, batch: int, max_len: int, dtype=jnp.float32,
        chunk_slack: int = 16, page_pool: tuple[int, int] | None = None,
    ):
        return transformer.init_cache(
            self.cfg, batch, max_len, dtype, chunk_slack,
            page_pool=page_pool,
        )

    def apply(
        self, params, tokens, *, cache=None, lens=None, extras=None,
        mode="train", valid_len=None, last_logits_only=False,
        page_table=None, kv_write_mask=None,
    ):
        return transformer.forward(
            self.cfg, params, tokens,
            cache=cache, lens=lens, extras=extras, mode=mode,
            valid_len=valid_len, last_logits_only=last_logits_only,
            page_table=page_table, kv_write_mask=kv_write_mask,
        )

    def commit_cache(self, cache, tau):
        return transformer.commit_cache(self.cfg, cache, tau)

    def make_extras(self, batch: int, dtype=jnp.float32) -> dict:
        """Stubbed modality-frontend inputs (see DESIGN.md carve-out)."""
        cfg = self.cfg
        extras = {}
        if cfg.family == "vlm":
            extras["vision_embeds"] = jnp.zeros(
                (batch, cfg.n_vision_tokens, cfg.d_model), dtype
            )
        if cfg.family == "encdec":
            extras["audio_frames"] = jnp.zeros(
                (batch, cfg.n_audio_frames, cfg.d_model), dtype
            )
        return extras

    def extras_specs(self, batch: int, dtype=jnp.float32) -> dict:
        return {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype)
            for k, v in self.make_extras(batch, dtype).items()
        }

    def param_count(self) -> int:
        import math

        shapes = jax.tree.leaves(self.abstract_params())
        return sum(math.prod(s.shape) for s in shapes)  # python ints: no overflow
