"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Two execution paths with identical semantics:

* **chunked SSD** (train / prefill): the quadratic-within-chunk, linear-
  across-chunks dual form — matmul-heavy, MXU friendly;
* **recurrent** (decode / speculative verify): per-token state updates.
  In ``verify`` mode the scan emits the state after *every* position so
  the serving engine can roll back to the last accepted draft token
  (speculative decoding rejects suffixes; SSM states, unlike KV caches,
  must be checkpointed explicitly).

The conv cache follows the same pattern: verify mode returns the whole
padded input window so the engine can slice the window ending at the
accepted position.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec


class SSMEntry(NamedTuple):
    """Committed cache: conv tail (B, w-1, conv_dim) + state (B, H, P, N)."""
    conv: jax.Array
    state: jax.Array


class SSMVerify(NamedTuple):
    """Per-step candidates from a verify chunk of length S:
    conv_seq (B, S + w - 1, conv_dim) and states (B, S, H, P, N).
    ``commit(tau)`` selects the cache after consuming position tau."""
    conv_seq: jax.Array
    states: jax.Array


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMEntry:
    return SSMEntry(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.conv_dim), dtype),
        state=jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype
        ),
    )


def commit_ssm(entry: SSMVerify, tau: jax.Array, w: int) -> SSMEntry:
    """Select the committed cache after consuming chunk position ``tau``
    (0-based). conv window = conv_seq[tau+1 : tau+w]."""
    b = entry.states.shape[0]
    state = jnp.take_along_axis(
        entry.states, tau[:, None, None, None, None], axis=1
    )[:, 0]
    offs = tau[:, None] + 1 + jnp.arange(w - 1)[None, :]  # (B, w-1)
    conv = jnp.take_along_axis(
        entry.conv_seq, offs[:, :, None], axis=1
    )
    return SSMEntry(conv=conv, state=state)


def ssm_param_specs(cfg: ModelConfig, prefix: tuple[int, ...] = ()) -> dict:
    d = cfg.d_model
    di, nh = cfg.d_inner, cfg.ssm_heads
    g, n, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    d_in_proj = 2 * di + 2 * g * n + nh
    pad = (None,) * len(prefix)
    return {
        "in_proj": Spec(prefix + (d, d_in_proj), "normal", pad + ("embed", "heads")),
        "conv_w": Spec(prefix + (w, cfg.conv_dim), "normal", pad + (None, "heads"), scale=0.1),
        "conv_b": Spec(prefix + (cfg.conv_dim,), "zeros", pad + ("heads",)),
        "a_log": Spec(prefix + (nh,), "ssm_a", pad + (None,)),
        "d_skip": Spec(prefix + (nh,), "ones", pad + (None,)),
        "dt_bias": Spec(prefix + (nh,), "ssm_dt", pad + (None,)),
        "norm_w": Spec(prefix + (di,), "zeros", pad + ("heads",)),
        "out_proj": Spec(prefix + (di, d), "normal", pad + ("heads", "embed")),
    }


def _conv1d(
    seq: jax.Array, w: jax.Array, b: jax.Array, out_len: int
) -> jax.Array:
    """Causal depthwise conv: seq (B, T, C), w (W, C) -> (B, out_len, C)
    taking the last out_len valid positions."""
    width = w.shape[0]
    t = seq.shape[1]
    start = t - out_len - width + 1
    out = jnp.zeros((seq.shape[0], out_len, seq.shape[2]), jnp.float32)
    for i in range(width):  # static small width (4)
        out = out + seq[:, start + i : start + i + out_len].astype(jnp.float32) * w[i]
    return out + b


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., T) -> (..., T, T) lower-triangular pairwise cumsums:
    out[i, j] = sum_{k in (j, i]} a[k] for j <= i, -inf above diagonal."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(
    x: jax.Array,      # (B, S, H, P) already dt-weighted NOT — raw x
    dt: jax.Array,     # (B, S, H) softplus'd
    a: jax.Array,      # (H,) negative
    b_mat: jax.Array,  # (B, S, N)  (single group)
    c_mat: jax.Array,  # (B, S, N)
    init_state: jax.Array,  # (B, H, P, N)
    chunk: int,
):
    """Chunked SSD dual form. Returns y (B, S, H, P), final state."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 => identity
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    xd = (x * dt[..., None]).reshape(bsz, nc, chunk, h, p)
    da = (dt * a).reshape(bsz, nc, chunk, h)          # (B, C, L, H)
    bm = b_mat.reshape(bsz, nc, chunk, n)
    cm = c_mat.reshape(bsz, nc, chunk, n)

    da_cs = jnp.cumsum(da, axis=2)                    # (B, C, L, H)
    # Intra-chunk (quadratic) term.
    l_mat = jnp.exp(_segsum(jnp.moveaxis(da, -1, -2)))  # (B, C, H, L, L)
    scores = jnp.einsum("bcln,bcmn->bclm", cm, bm)    # (B, C, L, M)
    y_diag = jnp.einsum("bchlm,bclm,bcmhp->bclhp", l_mat, scores, xd)

    # Chunk-boundary states.
    decay_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # (B, C, L, H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", bm, decay_end, xd)

    # Inter-chunk recurrence.
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])         # (B, C, H)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state *entering* the chunk

    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init_state.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)     # (B, C, H, P, N)

    decay_in = jnp.exp(da_cs)                         # (B, C, L, H)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", cm, prev_states, decay_in)

    y = (y_diag + y_off).reshape(bsz, nc * chunk, h, p)[:, :s]
    return y, final_state


def _ssd_recurrent(
    x: jax.Array, dt: jax.Array, a: jax.Array,
    b_mat: jax.Array, c_mat: jax.Array, init_state: jax.Array,
):
    """Per-token recurrence; also returns the state after every step."""

    def step(state, inp):
        xi, dti, bi, ci = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dti * a)                       # (B, H)
        upd = (dti[..., None] * xi)[..., None] * bi[:, None, None, :]
        state = state * decay[..., None, None] + upd   # (B, H, P, N)
        y = jnp.einsum("bhpn,bn->bhp", state, ci)
        return state, (y, state)

    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b_mat, 1, 0).astype(jnp.float32),
        jnp.moveaxis(c_mat, 1, 0).astype(jnp.float32),
    )
    final, (ys, states) = jax.lax.scan(step, init_state.astype(jnp.float32), xs)
    return (
        jnp.moveaxis(ys, 0, 1),       # (B, S, H, P)
        jnp.moveaxis(states, 0, 1),   # (B, S, H, P, N)
        final,
    )


def mamba_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                      # (B, S, D)
    cache: SSMEntry | None,
    mode: str,                         # train | prefill | verify | decode
    valid_len: jax.Array | None = None,  # (B,) valid chunk prefix length
):
    """Full Mamba2 mixer. Returns (y, new_cache) where new_cache is
    SSMEntry (train: None; prefill/decode) or SSMVerify (verify).

    ``valid_len`` masks padded tail positions (engine prefill buckets /
    drafter catch-up chunks): dt is zeroed there, making the state update
    an exact identity, so the state at the last valid position is what a
    shorter chunk would have produced."""
    bsz, s, _ = x.shape
    di, nh, hd = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    g, n, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv

    zxbcdt = x @ p["in_proj"]          # (B, S, 2*di + 2*g*n + nh)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, di + di + 2 * g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    if valid_len is not None:
        dt = jnp.where(
            (jnp.arange(s)[None, :] < valid_len[:, None])[..., None], dt, 0.0
        )
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                     # (nh,)

    # Causal depthwise conv over the xBC channels.
    if cache is None:
        conv_in = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
        init_state = jnp.zeros((bsz, nh, hd, n), jnp.float32)
    else:
        conv_in = jnp.concatenate([cache.conv.astype(xbc.dtype), xbc], axis=1)
        init_state = cache.state
    conv_out = jax.nn.silu(_conv1d(conv_in, p["conv_w"], p["conv_b"], s))
    x_ssm, b_mat, c_mat = jnp.split(conv_out, [di, di + g * n], axis=-1)
    x_ssm = x_ssm.reshape(bsz, s, nh, hd)

    if mode in ("train", "prefill") and s >= cfg.ssm_chunk:
        y, final_state = _ssd_chunked(
            x_ssm, dt, a, b_mat, c_mat, init_state, cfg.ssm_chunk
        )
        states_all = None
    else:
        y, states_all, final_state = _ssd_recurrent(
            x_ssm, dt, a, b_mat, c_mat, init_state
        )

    y = y + p["d_skip"][:, None] * x_ssm.astype(jnp.float32)
    y = y.reshape(bsz, s, di)
    # Gated RMSNorm (mamba2): norm(y * silu(z)).
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["norm_w"])
    out = (y @ p["out_proj"]).astype(x.dtype)

    if cache is None:
        return out, None
    if mode == "verify":
        if states_all is None:
            _, states_all, _ = _ssd_recurrent(
                x_ssm, dt, a, b_mat, c_mat, init_state
            )
        # per-step states in the cache dtype (they are cache entries after
        # commit; keeping them f32 doubles the dominant state traffic)
        return out, SSMVerify(
            conv_seq=conv_in, states=states_all.astype(cache.state.dtype)
        )
    if valid_len is not None:
        # window ending at the last *valid* position, not the padded tail
        offs = valid_len[:, None] + jnp.arange(w - 1)[None, :]
        new_conv = jnp.take_along_axis(conv_in, offs[:, :, None], axis=1)
    else:
        new_conv = conv_in[:, conv_in.shape[1] - (w - 1) :]
    return out, SSMEntry(
        conv=new_conv.astype(cache.conv.dtype),
        state=final_state.astype(cache.state.dtype),
    )
