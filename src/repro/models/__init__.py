from repro.models.common import ModelConfig, drafter_of  # noqa: F401
from repro.models.model import Model  # noqa: F401
