"""Dense (SwiGLU / GELU) and Mixture-of-Experts feed-forward blocks.

The MoE block uses the classic TPU dispatch-einsum formulation
(GShard/Switch): tokens are routed top-k with a capacity limit, dispatched
to per-expert buffers with a one-hot combine tensor, processed by a batched
expert matmul (all experts in one einsum — MXU friendly), and combined
back. Dropped tokens (over capacity) fall through to the residual path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec


def mlp_param_specs(cfg: ModelConfig, prefix: tuple[int, ...] = ()) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pad = (None,) * len(prefix)
    specs = {
        "w_up": Spec(prefix + (d, f), "normal", pad + ("embed", "ffn")),
        "w_down": Spec(prefix + (f, d), "normal", pad + ("ffn", "embed")),
    }
    if cfg.mlp == "swiglu":
        specs["w_gate"] = Spec(prefix + (d, f), "normal", pad + ("embed", "ffn"))
    return specs


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


def moe_param_specs(cfg: ModelConfig, prefix: tuple[int, ...] = ()) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    pad = (None,) * len(prefix)
    return {
        "router": Spec(prefix + (d, e), "normal", pad + ("embed", None)),
        "w_gate": Spec(prefix + (e, d, f), "normal", pad + ("experts", "embed", "ffn")),
        "w_up": Spec(prefix + (e, d, f), "normal", pad + ("experts", "embed", "ffn")),
        "w_down": Spec(prefix + (e, f, d), "normal", pad + ("experts", "ffn", "embed")),
    }


def moe(
    cfg: ModelConfig, p: dict, x: jax.Array, exact: bool = False
) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (y, aux_loss). Top-k routing.

    ``exact=True`` (verify/decode chunks, where the token count is small)
    computes every expert on every token and masks — no capacity drops, so
    the scored next-token distributions are independent of how generation
    is chunked. This is required for the speculative-decoding losslessness
    guarantee: capacity-dropping would make M_b depend on gamma. Train and
    prefill use the capacity-dispatch path (standard TPU MoE).
    """
    if exact:
        # drop-free scoring for the losslessness guarantee; ragged is the
        # optimized form, all-experts ("exact") is the reference.
        if cfg.moe_impl == "ragged":
            return _moe_ragged(cfg, p, x)
        return _moe_exact(cfg, p, x)
    if cfg.moe_impl == "gather":
        return _moe_gather(cfg, p, x)
    if cfg.moe_impl == "ragged":
        return _moe_ragged(cfg, p, x)
    # Group-wise dispatch (GShard): each sequence is a routing group with
    # its own capacity, so the one-hot dispatch tensors stay O(S^2) per
    # group instead of O((B*S)^2) globally — this is what keeps the
    # dispatch einsum ~10% of the expert matmul FLOPs and lets the batch
    # axis shard cleanly over the data mesh axes.
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * s * k / e))

    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, tope = jax.lax.top_k(probs, k)               # (B, S, k)
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

    # Position of each (token, choice) within its expert's per-group buffer.
    sel_onehot = jax.nn.one_hot(tope, e, dtype=jnp.float32)   # (B, S, k, E)
    flat_sel = sel_onehot.reshape(b, s * k, e)
    pos_in_expert = (
        jnp.cumsum(flat_sel, axis=1) - flat_sel
    ).reshape(b, s, k, e)
    pos = jnp.sum(pos_in_expert * sel_onehot, axis=-1)        # (B, S, k)
    keep = pos < cap
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)

    # dispatch (B, S, E, C) one-hot; combine = dispatch * routing weight.
    pos_onehot = jax.nn.one_hot(pos, cap, dtype=jnp.float32)  # (B, S, k, C)
    disp_k = sel_onehot[..., None] * pos_onehot[..., None, :]
    disp_k = disp_k * keep[..., None, None]
    dispatch = jnp.sum(disp_k, axis=2)                        # (B, S, E, C)
    combine = jnp.sum(disp_k * topw[..., None, None], axis=2)

    xin = jnp.einsum("bsec,bsd->becd", dispatch, x)           # (B, E, C, D)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["w_gate"]))
        h = h * jnp.einsum("becd,edf->becf", xin, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", xin, p["w_up"]))
    xout = jnp.einsum("becf,efd->becd", h, p["w_down"])       # (B, E, C, D)
    y = jnp.einsum("bsec,becd->bsd", combine, xout)

    # Switch-style load-balance loss: E * sum_e f_e * m_e.
    density = jnp.mean(sel_onehot[:, :, 0], axis=(0, 1))      # top-1 fraction
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * mean_prob)
    return y.astype(x.dtype), aux


def _router(cfg: ModelConfig, p: dict, x: jax.Array):
    """Shared routing: (B, S, D) -> (probs, topw, tope)."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, tope = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
    return probs, topw, tope


def _moe_gather(cfg: ModelConfig, p: dict, x: jax.Array):
    """Gather/scatter MoE dispatch (beyond-paper optimization, MegaBlocks
    style): instead of materializing the O(S*E*C) one-hot dispatch/combine
    tensors and contracting them on the MXU, build an (E, C) token-index
    table per group and move activations with gathers. Same top-k +
    per-group capacity semantics as the einsum path (bitwise-equal outputs
    up to summation order); HBM traffic drops from O(S*E*C) to O(E*C*D).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * s * k / e))
    probs, topw, tope = _router(cfg, p, x)

    sel_onehot = jax.nn.one_hot(tope, e, dtype=jnp.float32)   # (B, S, k, E)
    flat_sel = sel_onehot.reshape(b, s * k, e)
    pos = (
        (jnp.cumsum(flat_sel, axis=1) - flat_sel).reshape(b, s, k, e)
        * sel_onehot
    ).sum(-1).astype(jnp.int32)                               # (B, S, k)
    keep = pos < cap

    # slot_to_token[b, e, c] = flat (token, choice) index occupying slot c.
    tok_ids = jnp.broadcast_to(
        jnp.arange(s)[None, :, None], (b, s, k)
    ).reshape(b, s * k)
    flat_e = tope.reshape(b, s * k)
    flat_pos = jnp.where(keep, pos, cap).reshape(b, s * k)    # cap = dustbin
    slot_to_token = jnp.zeros((b, e, cap + 1), jnp.int32)
    b_idx = jnp.broadcast_to(jnp.arange(b)[:, None], flat_e.shape)
    slot_to_token = slot_to_token.at[b_idx, flat_e, flat_pos].set(tok_ids)
    slot_valid = jnp.zeros((b, e, cap + 1), bool).at[
        b_idx, flat_e, flat_pos
    ].set(True)
    slot_to_token = slot_to_token[:, :, :cap]
    slot_valid = slot_valid[:, :, :cap]

    xin = jnp.take_along_axis(
        x[:, :, None, :], slot_to_token.reshape(b, -1)[:, :, None, None],
        axis=1,
    )[..., 0, :].reshape(b, e, cap, d)
    xin = jnp.where(slot_valid[..., None], xin, 0.0)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["w_gate"]))
        h = h * jnp.einsum("becd,edf->becf", xin, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", xin, p["w_up"]))
    xout = jnp.einsum("becf,efd->becd", h, p["w_down"])       # (B, E, C, D)

    # combine by gathering each token's k expert outputs back.
    flat_out_idx = (tope * cap + jnp.where(keep, pos, 0)).reshape(b, s * k)
    gathered = jnp.take_along_axis(
        xout.reshape(b, e * cap, d), flat_out_idx[:, :, None], axis=1
    ).reshape(b, s, k, d)
    w = jnp.where(keep, topw, 0.0)
    y = jnp.einsum("bskd,bsk->bsd", gathered, w)

    density = jnp.mean(sel_onehot[:, :, 0], axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * mean_prob)
    return y.astype(x.dtype), aux


def _moe_ragged(cfg: ModelConfig, p: dict, x: jax.Array):
    """Ragged grouped-matmul MoE (beyond-paper optimization): sort the
    (token, choice) pairs by expert and run ``jax.lax.ragged_dot`` over
    contiguous expert groups. Exact top-k semantics with NO capacity drops
    and NO all-experts waste — compute is exactly sum_e count_e rows.
    Used for the verify/decode path where losslessness requires
    drop-free routing (and available everywhere via moe_impl='ragged')."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)
    probs, topw, tope = _router(cfg, p, x)
    flat_e = tope.reshape(t * k)
    flat_w = topw.reshape(t * k)
    order = jnp.argsort(flat_e)                       # stable
    tok_of = order // k                               # source token per row
    xin = jnp.take(xt, tok_of, axis=0)                # (T*k, D)
    counts = jnp.bincount(flat_e, length=e)

    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jax.lax.ragged_dot(xin, p["w_gate"], counts))
        h = h * jax.lax.ragged_dot(xin, p["w_up"], counts)
    else:
        h = jax.nn.gelu(jax.lax.ragged_dot(xin, p["w_up"], counts))
    xout = jax.lax.ragged_dot(h, p["w_down"], counts)  # (T*k, D)
    xout = xout * jnp.take(flat_w, order)[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[tok_of].add(xout)

    density = jnp.mean(
        jax.nn.one_hot(tope[..., 0], e, dtype=jnp.float32).reshape(t, e),
        axis=0,
    )
    aux = e * jnp.sum(density * jnp.mean(probs.reshape(t, e), axis=0))
    return y.reshape(b, s, d).astype(x.dtype), aux


def _moe_exact(cfg: ModelConfig, p: dict, x: jax.Array):
    """All-experts path: exact top-k MoE with no capacity drops."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(b * s, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, tope = jax.lax.top_k(probs, k)
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
    w_full = jnp.sum(
        jax.nn.one_hot(tope, e, dtype=jnp.float32) * topw[..., None], axis=1
    )                                                          # (T, E)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"]))
        h = h * jnp.einsum("td,edf->tef", xt, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("td,edf->tef", xt, p["w_up"]))
    y = jnp.einsum("tef,efd,te->td", h, p["w_down"], w_full)
    return y.reshape(b, s, d).astype(x.dtype), jnp.zeros((), jnp.float32)
