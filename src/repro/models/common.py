"""Shared model components: config dataclass, norms, RoPE, initializers.

The single ``ModelConfig`` covers all six assigned architecture families;
family-specific fields are zero/empty when unused. Configs are frozen and
hashable so they can be jit static arguments.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

VOCAB_PAD = 512  # embeddings padded so the vocab dim shards over the mesh


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"     # einsum (GShard dispatch) | gather
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64
    # --- attention pattern ---
    window_pattern: tuple[int, ...] = (-1,)  # cycled; -1 = global attention
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    post_norms: bool = False     # gemma2-style post-attn/post-mlp norms
    norm: str = "rmsnorm"        # rmsnorm | layernorm | np_layernorm
    mlp: str = "swiglu"          # swiglu | gelu
    rope_theta: float = 10000.0
    use_rope: bool = True        # False -> learned absolute positions
    tie_embeddings: bool = False
    # --- hybrid (zamba2): shared attn block every k mamba layers ---
    hybrid_attn_every: int = 0
    # --- vlm: cross-attention layer every k layers ---
    cross_attn_every: int = 0
    n_vision_tokens: int = 0
    # --- encdec (whisper): encoder stack over stubbed frame embeddings ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 0
    # --- bookkeeping ---
    max_seq: int = 8192
    source: str = ""             # citation for the assigned config

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return (self.vocab + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def ssm_groups(self) -> int:
        return 1

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    def window_of(self, layer_idx: int) -> int:
        return self.window_pattern[layer_idx % len(self.window_pattern)]

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def drafter_of(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family drafter (the paper's 'small LM' pattern)."""
    n_layers = max(2, cfg.n_layers // 8)
    # layer count must respect the arch's pattern period
    period = len(cfg.window_pattern)
    if cfg.family == "vlm":
        period = cfg.cross_attn_every
    n_layers = max(period, (n_layers + period - 1) // period * period)
    d_model = max(128, cfg.d_model // 4)
    n_heads = max(2, cfg.n_heads // 4) if cfg.n_heads else 0
    n_kv = max(1, min(cfg.n_kv, n_heads)) if cfg.n_heads else 0
    # keep n_heads a multiple of n_kv
    if n_heads and n_heads % n_kv:
        n_heads = (n_heads // n_kv) * n_kv or n_kv
    return cfg.with_(
        name=cfg.name + "-drafter",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv=n_kv,
        d_ff=max(256, cfg.d_ff // 4) if cfg.d_ff else 0,
        head_dim=0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        hybrid_attn_every=0 if cfg.family == "hybrid" else cfg.hybrid_attn_every,
        cross_attn_every=cfg.cross_attn_every,
        n_encoder_layers=max(1, cfg.n_encoder_layers // 2)
        if cfg.n_encoder_layers else 0,
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if w is not None:
        x = x * (1.0 + w.astype(jnp.float32))  # gemma-style (1+w) scale
    return x.astype(dtype)


def layernorm(
    x: jax.Array, w: jax.Array | None, b: jax.Array | None, eps: float = 1e-5
) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        x = x * w.astype(jnp.float32)
    if b is not None:
        x = x + b.astype(jnp.float32)
    return x.astype(dtype)


def apply_norm(cfg: ModelConfig, p: dict | None, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["w"] if p else None)
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"] if p else None, p["b"] if p else None)
    if cfg.norm == "np_layernorm":  # olmo: non-parametric LN
        return layernorm(x, None, None)
    raise ValueError(cfg.norm)


def norm_params(cfg: ModelConfig, shape_prefix: tuple[int, ...] = ()):
    """Spec dict for one norm's params (possibly empty for np_layernorm)."""
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"w": Spec(shape_prefix + (d,), "zeros", (None,))}
    if cfg.norm == "layernorm":
        return {
            "w": Spec(shape_prefix + (d,), "ones", (None,)),
            "b": Spec(shape_prefix + (d,), "zeros", (None,)),
        }
    return {}


# ---------------------------------------------------------------------------
# RoPE / positions
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, hd), positions (..., S) -> rotated x."""
    hd = x.shape[-1]
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        -math.log(10000.0) * jnp.arange(0, d, 2, dtype=jnp.float32) / d
    )
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# Parameter specs: shape + init + logical sharding axes, materialized later.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Spec:
    shape: tuple[int, ...]
    init: str                 # normal | zeros | ones | ssm_a | ssm_dt
    axes: tuple[str | None, ...]  # logical axes, same length as shape
    scale: float = 0.02

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "normal":
            return (
                jax.random.normal(key, self.shape, jnp.float32) * self.scale
            )
        if self.init == "zeros":
            return jnp.zeros(self.shape, jnp.float32)
        if self.init == "ones":
            return jnp.ones(self.shape, jnp.float32)
        if self.init == "ssm_a":  # A_log init: log of uniform [1, 16]
            u = jax.random.uniform(key, self.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u)
        if self.init == "ssm_dt":  # dt_bias: softplus^-1(uniform 1e-3..1e-1)
            u = jax.random.uniform(
                key, self.shape, jnp.float32, math.log(1e-3), math.log(1e-1)
            )
            dt = jnp.exp(u)
            return dt + jnp.log(-jnp.expm1(-dt))
        raise ValueError(self.init)


def materialize(specs, key: jax.Array):
    """Turn a pytree of Spec into a pytree of initialized arrays."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda s: isinstance(s, Spec)
    )
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [s.materialize(k) for s, k in zip(leaves, keys)]
    )


def spec_axes(specs):
    """Pytree of logical-axis tuples matching the param tree."""
    return jax.tree.map(
        lambda s: s.axes, specs, is_leaf=lambda s: isinstance(s, Spec)
    )


def spec_shapes(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
        specs,
        is_leaf=lambda s: isinstance(s, Spec),
    )
