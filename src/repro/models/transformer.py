"""The generic decoder stack: every assigned architecture is expressed as a
sequence of *segments*, each a ``lax.scan`` over identical layer *groups*.

Examples
--------
* olmo / smollm / mistral-large: one segment, group = (dense,).
* gemma2: group = (dense[window=4096], dense[global]) — alternating.
* llama4: group = (moe[8192], moe[8192], moe[8192], moe[global]).
* mixtral: group = (moe[4096],).
* mamba2: group = (mamba,).
* zamba2: segment of (mamba x6, shared_attn) groups + a (mamba,) remainder;
  the shared attention block's params are closed over, not scanned.
* llama-3.2-vision: group = (dense x4, cross).
* whisper decoder: group = (encdec,), plus a separate bidirectional
  encoder stack over the stubbed audio-frame embeddings.

Scan-over-groups keeps the lowered HLO O(1) in depth — essential for
compiling 88-layer configs in the multi-pod dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention, common, mlp, ssm
from repro.models.attention import KVCache, PagedKV
from repro.models.common import ModelConfig, Spec
from repro.models.ssm import SSMEntry, SSMVerify

MODES = ("train", "prefill", "verify", "decode")


class CrossKV(NamedTuple):
    """Cached cross-attention context projections (vision/audio)."""
    k: jax.Array  # (B, T, n_kv, hd)
    v: jax.Array


@dataclass(frozen=True)
class LayerDef:
    kind: str            # dense | moe | mamba | shared_attn | cross | encdec
    window: int = -1


@dataclass(frozen=True)
class Segment:
    layers: tuple[LayerDef, ...]
    n_groups: int


def build_plan(cfg: ModelConfig) -> tuple[Segment, ...]:
    fam = cfg.family
    if fam in ("dense", "moe"):
        kind = "dense" if fam == "dense" else "moe"
        pat = cfg.window_pattern
        assert cfg.n_layers % len(pat) == 0, (cfg.name, pat)
        return (
            Segment(
                tuple(LayerDef(kind, w) for w in pat),
                cfg.n_layers // len(pat),
            ),
        )
    if fam == "ssm":
        return (Segment((LayerDef("mamba"),), cfg.n_layers),)
    if fam == "hybrid":
        k = cfg.hybrid_attn_every
        if k <= 0:  # drafter fallback: pure ssm
            return (Segment((LayerDef("mamba"),), cfg.n_layers),)
        full, rem = divmod(cfg.n_layers, k)
        segs = [
            Segment(
                tuple([LayerDef("mamba")] * k)
                + (LayerDef("shared_attn", cfg.window_of(0)),),
                full,
            )
        ]
        if rem:
            segs.append(Segment((LayerDef("mamba"),), rem))
        return tuple(segs)
    if fam == "vlm":
        k = cfg.cross_attn_every
        assert cfg.n_layers % k == 0
        return (
            Segment(
                tuple(LayerDef("dense", cfg.window_of(i)) for i in range(k - 1))
                + (LayerDef("cross"),),
                cfg.n_layers // k,
            ),
        )
    if fam == "encdec":
        return (Segment((LayerDef("encdec"),), cfg.n_layers),)
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _layer_specs(cfg: ModelConfig, ldef: LayerDef, prefix: tuple[int, ...]):
    nrm = lambda: common.norm_params(cfg, prefix)  # noqa: E731
    if ldef.kind in ("dense", "shared_attn"):
        d = {
            "attn": attention.attn_param_specs(cfg, prefix),
            "mlp": mlp.mlp_param_specs(cfg, prefix),
            "ln1": nrm(),
            "ln2": nrm(),
        }
        if cfg.post_norms:
            d["ln1p"] = nrm()
            d["ln2p"] = nrm()
        return d
    if ldef.kind == "moe":
        d = {
            "attn": attention.attn_param_specs(cfg, prefix),
            "moe": mlp.moe_param_specs(cfg, prefix),
            "ln1": nrm(),
            "ln2": nrm(),
        }
        if cfg.post_norms:
            d["ln1p"] = nrm()
            d["ln2p"] = nrm()
        return d
    if ldef.kind == "mamba":
        return {"mixer": ssm.ssm_param_specs(cfg, prefix), "ln": nrm()}
    if ldef.kind == "cross":
        return {
            "attn": attention.attn_param_specs(cfg, prefix, cross=True),
            "mlp": mlp.mlp_param_specs(cfg, prefix),
            "ln1": nrm(),
            "ln2": nrm(),
        }
    if ldef.kind == "encdec":
        return {
            "self_attn": attention.attn_param_specs(cfg, prefix),
            "cross_attn": attention.attn_param_specs(cfg, prefix),
            "mlp": mlp.mlp_param_specs(cfg, prefix),
            "ln1": nrm(),
            "ln2": nrm(),
            "ln3": nrm(),
        }
    raise ValueError(ldef.kind)


def param_specs(cfg: ModelConfig):
    d, vp = cfg.d_model, cfg.padded_vocab
    specs: dict[str, Any] = {
        "embed": Spec((vp, d), "normal", ("vocab", "embed")),
        "final_norm": common.norm_params(cfg),
    }
    if not cfg.use_rope:
        specs["pos_embed"] = Spec((cfg.max_seq, d), "normal", (None, "embed"))
    if not cfg.tie_embeddings:
        specs["lm_head"] = Spec((d, vp), "normal", ("embed", "vocab"))
    segs = []
    for seg in build_plan(cfg):
        prefix = (seg.n_groups,)
        segs.append(
            [
                _layer_specs(cfg, ldef, prefix)
                if ldef.kind != "shared_attn"
                else {}  # params live in specs["shared_attn"]
                for ldef in seg.layers
            ]
        )
    specs["segments"] = segs
    if any(
        l.kind == "shared_attn" for s in build_plan(cfg) for l in s.layers
    ):
        specs["shared_attn"] = _layer_specs(
            cfg, LayerDef("shared_attn"), ()
        )
    if cfg.family == "encdec":
        specs["encoder"] = {
            "layers": [
                _layer_specs(cfg, LayerDef("dense"), (cfg.n_encoder_layers,))
            ],
            "final_norm": common.norm_params(cfg),
        }
    return specs


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


def _stacked_kv(cfg, n_groups, batch, capacity, dtype):
    return KVCache(
        k=jnp.zeros((n_groups, batch, capacity, cfg.n_kv, cfg.hd), dtype),
        v=jnp.zeros((n_groups, batch, capacity, cfg.n_kv, cfg.hd), dtype),
    )


def _stacked_paged_kv(cfg, n_groups, num_pages, page_size, dtype):
    return PagedKV(
        k=jnp.zeros((n_groups, num_pages, page_size, cfg.n_kv, cfg.hd), dtype),
        v=jnp.zeros((n_groups, num_pages, page_size, cfg.n_kv, cfg.hd), dtype),
    )


def _cap_of(window: int, max_len: int, chunk_slack: int) -> int:
    """Ring capacity for a windowed layer: the window itself plus room for
    one in-flight chunk (whose writes must not evict keys its own earliest
    query still needs), rounded up to a multiple of 512 so long ring
    caches stay shardable across the mesh."""
    if window <= 0:
        return max_len
    cap = window + chunk_slack
    if cap >= 4096:
        cap = -(-cap // 512) * 512
    return min(cap, max_len)


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32,
    chunk_slack: int = 16,
    page_pool: tuple[int, int] | None = None,
):
    """Committed-form cache for the whole stack (stacked over groups).
    ``chunk_slack`` must be >= the longest verify/decode chunk (gamma+1).

    ``page_pool=(num_pages, page_size)`` switches every *global*
    (window <= 0) attention layer from a dense per-slot reservation to a
    shared :class:`PagedKV` pool addressed through the page table that
    ``forward`` receives per call. Sliding-window layers keep their dense
    ring buffers: a ring of ``window + slack`` rows is already the
    compressed representation, so paging them buys nothing."""

    def kv_entry(g, window):
        if page_pool is not None and window <= 0:
            return _stacked_paged_kv(cfg, g, page_pool[0], page_pool[1], dtype)
        return _stacked_kv(
            cfg, g, batch, _cap_of(window, max_len, chunk_slack), dtype
        )

    segs = []
    for seg in build_plan(cfg):
        entries = []
        for ldef in seg.layers:
            g = seg.n_groups
            if ldef.kind in ("dense", "moe", "shared_attn"):
                entries.append(kv_entry(g, ldef.window))
            elif ldef.kind == "mamba":
                base = ssm.init_ssm_cache(cfg, batch, dtype)
                entries.append(
                    SSMEntry(
                        conv=jnp.zeros((g,) + base.conv.shape, dtype),
                        state=jnp.zeros((g,) + base.state.shape, dtype),
                    )
                )
            elif ldef.kind == "cross":
                t = cfg.n_vision_tokens
                entries.append(
                    CrossKV(
                        k=jnp.zeros((g, batch, t, cfg.n_kv, cfg.hd), dtype),
                        v=jnp.zeros((g, batch, t, cfg.n_kv, cfg.hd), dtype),
                    )
                )
            elif ldef.kind == "encdec":
                t = cfg.n_audio_frames
                entries.append(
                    {
                        "self": kv_entry(g, ldef.window),
                        "cross": CrossKV(
                            k=jnp.zeros((g, batch, t, cfg.n_kv, cfg.hd), dtype),
                            v=jnp.zeros((g, batch, t, cfg.n_kv, cfg.hd), dtype),
                        ),
                    }
                )
            else:
                raise ValueError(ldef.kind)
        segs.append(entries)
    return {"segments": segs}


def commit_cache(cfg: ModelConfig, cache, tau: jax.Array):
    """Convert a verify-mode cache to committed form: SSM entries select the
    state after the last accepted chunk position; KV entries pass through
    (stale ring slots are masked/overwritten by construction)."""

    def fix(entry):
        if isinstance(entry, SSMVerify):
            return jax.vmap(
                lambda e: ssm.commit_ssm(e, tau, cfg.ssm_conv)
            )(entry)
        return entry

    segs = [
        [
            fix(e) if not isinstance(e, dict)
            else {k: fix(v) for k, v in e.items()}
            for e in seg
        ]
        for seg in cache["segments"]
    ]
    return {"segments": segs}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_layer(
    cfg: ModelConfig,
    ldef: LayerDef,
    p: dict,
    entry,
    x: jax.Array,
    positions: jax.Array,
    mode: str,
    shared: dict | None,
    extras: dict | None,
    valid_len: jax.Array | None = None,
    page_table: jax.Array | None = None,
    kv_write_mask: jax.Array | None = None,
):
    """One layer. Returns (x, new_entry, aux)."""
    aux = jnp.zeros((), jnp.float32)
    nrm = lambda key, h: common.apply_norm(  # noqa: E731
        cfg, p.get(key) if p.get(key) else None, h
    )
    if ldef.kind in ("dense", "moe", "shared_attn"):
        pp = shared if ldef.kind == "shared_attn" else p
        nrmp = lambda key, h: common.apply_norm(  # noqa: E731
            cfg, pp.get(key) if pp.get(key) else None, h
        )
        h, entry = attention.attention(
            cfg, pp["attn"], nrmp("ln1", x), positions, entry,
            window=ldef.window, mode=mode,
            page_table=page_table, write_mask=kv_write_mask,
        )
        if cfg.post_norms:
            h = nrmp("ln1p", h)
        x = x + h
        if ldef.kind == "moe":
            h, aux = mlp.moe(
                cfg, pp["moe"], nrmp("ln2", x),
                exact=mode in ("verify", "decode"),
            )
        else:
            h = mlp.mlp(cfg, pp["mlp"], nrmp("ln2", x))
        if cfg.post_norms:
            h = nrmp("ln2p", h)
        return x + h, entry, aux
    if ldef.kind == "mamba":
        h, entry = ssm.mamba_block(
            cfg, p["mixer"], nrm("ln", x), entry, mode, valid_len=valid_len
        )
        return x + h, entry, aux
    if ldef.kind == "cross":
        if mode in ("train", "prefill"):
            ctx = extras["vision_embeds"]
            k, v = attention.context_kv(cfg, p["attn"], ctx)
            new_entry = CrossKV(k=k, v=v) if entry is not None else None
        else:
            k, v = entry.k, entry.v
            new_entry = entry
        h = attention.cross_attention(
            cfg, p["attn"], nrm("ln1", x), k, v, gated=True
        )
        x = x + h
        return x + mlp.mlp(cfg, p["mlp"], nrm("ln2", x)), new_entry, aux
    if ldef.kind == "encdec":
        self_entry = entry["self"] if entry is not None else None
        h, self_entry = attention.attention(
            cfg, p["self_attn"], nrm("ln1", x), positions, self_entry,
            window=ldef.window, mode=mode,
            page_table=page_table, write_mask=kv_write_mask,
        )
        x = x + h
        cross_entry = entry["cross"] if entry is not None else None
        if mode in ("train", "prefill"):
            ctx = extras["encoder_out"]
            k, v = attention.context_kv(cfg, p["cross_attn"], ctx)
            cross_entry = CrossKV(k=k, v=v)
        h = attention.cross_attention(
            cfg, p["cross_attn"], nrm("ln2", x), cross_entry.k, cross_entry.v
        )
        x = x + h
        x = x + mlp.mlp(cfg, p["mlp"], nrm("ln3", x))
        new_entry = (
            None if entry is None
            else {"self": self_entry, "cross": cross_entry}
        )
        return x, new_entry, aux
    raise ValueError(ldef.kind)


def _run_encoder(cfg: ModelConfig, params: dict, frames: jax.Array):
    """Whisper-style bidirectional encoder over stubbed frame embeddings."""
    x = frames + common.sinusoidal_positions(
        frames.shape[1], cfg.d_model
    ).astype(frames.dtype)
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1])[None], frames.shape[:2]
    )
    enc = params["encoder"]

    def body(h, lp):
        h2, _ = attention.attention(
            cfg, lp["attn"],
            common.apply_norm(cfg, lp.get("ln1") or None, h),
            positions, None, window=-1, causal=False, use_rope=False,
        )
        h = h + h2
        h = h + mlp.mlp(
            cfg, lp["mlp"], common.apply_norm(cfg, lp.get("ln2") or None, h)
        )
        return h, None

    x, _ = jax.lax.scan(body, x, enc["layers"][0])
    return common.apply_norm(cfg, enc["final_norm"] or None, x)


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,            # (B, S) int32
    *,
    cache=None,
    lens: jax.Array | None = None,  # (B,) committed length (cache modes)
    extras: dict | None = None,
    mode: str = "train",
    valid_len: jax.Array | None = None,  # (B,) chunk-valid lengths (SSM
    #                                       dt-masking for padded chunks)
    last_logits_only: bool = False,      # skip the (B, S, V) projection
    page_table: jax.Array | None = None,  # (B, max_pages) for PagedKV
    #                                        cache entries (serving path)
    kv_write_mask: jax.Array | None = None,  # (B,) False = suppress this
    #                                           slot's paged-KV writes
):
    """Returns (logits (B, S, Vp), new_cache, aux)."""
    assert mode in MODES
    b, s = tokens.shape
    if lens is None:
        lens = jnp.zeros((b,), jnp.int32)
    positions = lens[:, None] + jnp.arange(s)[None, :]

    x = params["embed"][tokens]
    if not cfg.use_rope:
        x = x + params["pos_embed"][positions]

    if cfg.family == "encdec" and mode in ("train", "prefill"):
        extras = dict(extras or {})
        extras["encoder_out"] = _run_encoder(
            cfg, params, extras["audio_frames"]
        )

    aux_total = jnp.zeros((), jnp.float32)
    plan = build_plan(cfg)
    new_segments = []
    shared = params.get("shared_attn")
    for si, seg in enumerate(plan):
        p_stack = params["segments"][si]
        c_stack = cache["segments"][si] if cache is not None else None

        def body(h, xs, seg=seg):
            lp, lc = xs
            new_entries, aux = [], jnp.zeros((), jnp.float32)
            for j, ldef in enumerate(seg.layers):
                h, e, a = _apply_layer(
                    cfg, ldef, lp[j], lc[j] if lc is not None else None,
                    h, positions, mode, shared, extras, valid_len,
                    page_table, kv_write_mask,
                )
                new_entries.append(e)
                aux = aux + a
            return h, (new_entries, aux)

        if c_stack is None:
            x, (_, auxs) = jax.lax.scan(
                body, x, (p_stack, [None] * len(seg.layers))
            )
            new_segments.append(None)
        else:
            x, (new_stack, auxs) = jax.lax.scan(body, x, (p_stack, c_stack))
            new_segments.append(new_stack)
        aux_total = aux_total + jnp.sum(auxs)

    x = common.apply_norm(cfg, params["final_norm"] or None, x)
    if last_logits_only:
        x = x[:, -1:]
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    # Mask padded vocabulary columns.
    logits = jnp.where(
        jnp.arange(cfg.padded_vocab)[None, None] < cfg.vocab, logits, -1e30
    )
    new_cache = (
        {"segments": new_segments} if cache is not None else None
    )
    return logits, new_cache, aux_total
