"""Training loop: loss, jitted train_step (also the dry-run entry point),
and a host-side loop used to train the char-LM drafter/target pair."""

from __future__ import annotations

import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training import optim
from repro.training.optim import OptConfig, OptState

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def loss_fn(model: Model, params, batch: dict, extras=None):
    """Mean next-token cross entropy (+ weighted MoE aux)."""
    logits, _, aux = model.apply(
        params, batch["tokens"], extras=extras, mode="train"
    )
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
    loss = jnp.mean(nll)
    return loss + AUX_WEIGHT * aux, (loss, aux)


def make_train_step(
    model: Model, opt_cfg: OptConfig
) -> Callable:
    """Returns train_step(params, opt_state, batch, extras) -> (...)"""

    def train_step(params, opt_state: OptState, batch, extras=None):
        (total, (ce, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, extras), has_aux=True
        )(params)
        params, opt_state, gnorm = optim.apply_updates(
            opt_cfg, params, grads, opt_state
        )
        metrics = {"loss": ce, "aux": aux, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def train(
    model: Model,
    data_iter,
    n_steps: int,
    opt_cfg: OptConfig | None = None,
    seed: int = 0,
    log_every: int = 50,
    params=None,
) -> tuple[dict, list[dict]]:
    """Host training loop; returns (params, metric history)."""
    opt_cfg = opt_cfg or OptConfig(total_steps=n_steps)
    if params is None:
        params = model.init(jax.random.key(seed))
    opt_state = optim.init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    extras = model.make_extras(0) or None

    history = []
    t0 = time.time()
    for step, batch in enumerate(data_iter):
        if step >= n_steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        ex = (
            model.make_extras(batch["tokens"].shape[0])
            if extras is not None else None
        )
        params, opt_state, metrics = step_fn(params, opt_state, batch, ex)
        if step % log_every == 0 or step == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["elapsed_s"] = time.time() - t0
            history.append(m)
    return params, history
