"""Checkpointing: flat-key .npz arrays + a JSON manifest (no orbax)."""

from __future__ import annotations

import json
import os

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        # sorted to match jax.tree flatten order for dict nodes
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(tree)
    return out


def save(path: str, params, meta: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    np.savez(os.path.join(path, "params.npz"), **flat)
    treedef = jax.tree.structure(params)
    manifest = {
        "treedef": str(treedef),
        "n_arrays": len(flat),
        "meta": meta or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load(path: str, like) -> dict:
    """Restore into the structure of ``like`` (an abstract or real tree)."""
    data = np.load(os.path.join(path, "params.npz"))
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    leaves, treedef = jax.tree.flatten(like)
    flat_sorted = _flatten(like)
    # rebuild in tree order
    keys_in_order = list(flat_sorted.keys())
    arrays = [data[k] for k in keys_in_order]
    return jax.tree.unflatten(treedef, arrays)


def load_meta(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["meta"]
