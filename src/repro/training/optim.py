"""AdamW with warmup-cosine schedule and global-norm clipping (no optax
dependency — the substrate is built in-repo per the reproduction brief)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup: int = 50
    total_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_opt_state(params) -> OptState:
    # .copy() forces distinct buffers (JAX caches zero constants; aliased
    # mu/nu buffers would break donation in the jitted train step).
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(lambda x: jnp.zeros_like(x).copy(), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup, 1)
    prog = jnp.clip(
        (step - cfg.warmup) / jnp.maximum(cfg.total_steps - cfg.warmup, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup, warm, 0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(tree))
    )


def apply_updates(
    cfg: OptConfig, params, grads, state: OptState
) -> tuple[dict, OptState, jax.Array]:
    """One AdamW step. Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads
    )
    nu = jax.tree.map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state.nu, grads
    )

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        return p - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        )

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step=step, mu=mu, nu=nu), gnorm
