"""Deterministic token pipeline: corpus -> packed (tokens, labels) batches."""

from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.data.synthetic import generate_corpus
from repro.data.tokenizer import ByteTokenizer


def packed_stream(seed: int, style: str = "mixed") -> np.ndarray:
    """Flat token stream: BOS-joined lines of the synthetic corpus."""
    tok = ByteTokenizer()
    lines = generate_corpus(seed, style=style)
    ids: list[int] = []
    for ln in lines:
        ids.extend(tok.encode(ln, bos=True, eos=True))
    return np.asarray(ids, np.int32)


def batches(
    seed: int,
    batch_size: int,
    seq_len: int,
    n_steps: int,
    style: str = "mixed",
) -> Iterator[dict]:
    """Yields {tokens (B, S), labels (B, S)} — labels are next tokens."""
    stream = packed_stream(seed, style)
    need = batch_size * (seq_len + 1)
    rng = np.random.default_rng(seed + 1)
    n = len(stream) - seq_len - 1
    for _ in range(n_steps):
        starts = rng.integers(0, n, size=batch_size)
        chunk = np.stack([stream[s : s + seq_len + 1] for s in starts])
        yield {
            "tokens": chunk[:, :-1].astype(np.int32),
            "labels": chunk[:, 1:].astype(np.int32),
        }
    del need
