"""Byte-level tokenizer: 256 byte values + BOS/EOS/PAD specials."""

from __future__ import annotations

import numpy as np

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> list[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids) -> str:
        ids = [int(i) for i in np.asarray(ids).reshape(-1) if int(i) < 256]
        return bytes(ids).decode("utf-8", errors="replace")
