"""Byte-level tokenizer: 256 byte values + BOS/EOS/PAD specials."""

from __future__ import annotations

import codecs

import numpy as np

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> list[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids) -> str:
        ids = [int(i) for i in np.asarray(ids).reshape(-1) if int(i) < 256]
        return bytes(ids).decode("utf-8", errors="replace")


class IncrementalDetokenizer:
    """Streaming counterpart of :meth:`ByteTokenizer.decode`: feed
    committed token ids as they arrive, get back the longest decodable
    text suffix. A multi-byte UTF-8 sequence split across streaming
    deltas stays buffered until its continuation bytes land — a naive
    per-delta ``bytes.decode`` would emit replacement chars mid-glyph.
    Specials (BOS/EOS/PAD, ids >= 256) are dropped, matching
    ``decode``. One instance per streamed request; feeds must arrive in
    commit order (the front end's emit callback guarantees this)."""

    def __init__(self, errors: str = "replace"):
        self._decoder = codecs.getincrementaldecoder("utf-8")(errors)

    def feed(self, ids) -> str:
        data = bytes(int(i) for i in ids if 0 <= int(i) < 256)
        return self._decoder.decode(data, False)

    def flush(self) -> str:
        """Final call: decode any buffered incomplete tail (per the
        error policy) and reset for reuse."""
        return self._decoder.decode(b"", True)
