"""Seeded synthetic corpora.

The paper evaluates on 8 NLP datasets; we cannot ship those, so we
generate deterministic corpora with enough structure for a small
transformer to learn (and for a drafter to partially agree with a target
— the axis the paper's experiments sweep). Styles:

* ``prose``  — template-grammar sentences over a Zipfian word list;
* ``math``   — grade-school-style arithmetic lines (GSM8K stand-in);
* ``mixed``  — interleaving of the two.
"""

from __future__ import annotations

import numpy as np

_CONSONANTS = "bcdfghjklmnpqrstvwz"
_VOWELS = "aeiou"


def _word_list(rng: np.random.Generator, n: int) -> list[str]:
    words = []
    for _ in range(n):
        syll = rng.integers(1, 4)
        w = "".join(
            rng.choice(list(_CONSONANTS)) + rng.choice(list(_VOWELS))
            for _ in range(syll)
        )
        words.append(w)
    return words


def _prose_line(rng: np.random.Generator, words: list[str], zipf_p) -> str:
    n = int(rng.integers(4, 12))
    idx = rng.choice(len(words), size=n, p=zipf_p)
    toks = [words[i] for i in idx]
    return " ".join(toks).capitalize() + "."


def _math_line(rng: np.random.Generator) -> str:
    a, b = int(rng.integers(2, 99)), int(rng.integers(2, 99))
    op = rng.choice(["+", "-", "*"])
    val = {"+": a + b, "-": a - b, "*": a * b}[op]
    return f"Q: what is {a} {op} {b}? A: {val}."


def generate_corpus(
    seed: int, n_lines: int = 4000, style: str = "mixed"
) -> list[str]:
    rng = np.random.default_rng(seed)
    words = _word_list(rng, 256)
    ranks = np.arange(1, len(words) + 1, dtype=np.float64)
    zipf_p = (1.0 / ranks) / np.sum(1.0 / ranks)
    lines = []
    for _ in range(n_lines):
        if style == "prose" or (style == "mixed" and rng.random() < 0.5):
            lines.append(_prose_line(rng, words, zipf_p))
        else:
            lines.append(_math_line(rng))
    return lines


def generate_prompts(seed: int, n: int, style: str = "mixed") -> list[str]:
    """Held-out prompt prefixes for serving benchmarks."""
    lines = generate_corpus(seed + 10_000, n, style)
    return [ln[: max(8, len(ln) // 2)] for ln in lines]
