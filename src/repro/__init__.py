"""repro: production-grade JAX reproduction of
"Block Verification Accelerates Speculative Decoding" (ICLR 2025).
"""

__version__ = "1.0.0"
