"""Speculative-decoding simulator over tabular oracle models.

This is the measurement harness for the paper's *algorithmic* claims:
block efficiency (expected decoded tokens per serial target call),
losslessness, and the token/block/greedy comparisons of Tables 1 and 3.
The real batched serving system lives in ``repro.serving``; this module
isolates the verification algorithms from model-execution concerns so the
distributional properties can be tested exactly and fast.

Greedy block verification (Appendix C)
--------------------------------------
Algorithm 6 replaces the target distribution with Eq. (23)'s *joint-ratio*
modification after every iteration, and the modifications nest. We
implement this faithfully with a stack of "modification layers": layer
``l`` is created when an iteration rejects with ``tau < gamma - 1`` and is
parameterized by

* ``rem``: how many upcoming positions it still covers
  (initially ``gamma - tau - 1``), and
* ``rho``: the running ratio T_{l-1}(path | anchor) / M_s(path | anchor)
  accumulated along the realized output path since the layer's anchor,
  where T_{l-1} is the effective target *below* this layer.

The effective target row at a position is then computed bottom-up:
``row_0 = M_b`` and ``row_l = normalize(max(rho_l * row_{l-1} - M_s, 0))``
for each active layer. Because every new layer's window provably outlives
all existing ones (new rem = gamma - n > old rem - n), layers expire in
creation order and at most ``gamma - 1`` are active at once; we keep
``gamma`` fixed slots sorted by remaining length.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sampling, verification
from repro.core.oracle import TabularLM


class SimState(NamedTuple):
    key: jax.Array
    ctx_t: jax.Array       # (B,) target-context codes
    ctx_d: jax.Array       # (B,) drafter-context codes
    layer_rem: jax.Array   # (B, D) remaining window per modification layer
    layer_rho: jax.Array   # (B, D) running joint ratio per layer


def init_state(key: jax.Array, batch: int, gamma: int) -> SimState:
    return SimState(
        key=key,
        ctx_t=jnp.zeros((batch,), jnp.int32),
        ctx_d=jnp.zeros((batch,), jnp.int32),
        layer_rem=jnp.zeros((batch, gamma), jnp.int32),
        layer_rho=jnp.ones((batch, gamma), jnp.float32),
    )


def _sort_layers(state: SimState) -> SimState:
    """Sort layer slots by remaining window ascending (expired slots last),
    so a static bottom-up application order is valid for the iteration."""
    key = jnp.where(state.layer_rem > 0, state.layer_rem, 10**6)
    order = jnp.argsort(key, axis=1)
    return state._replace(
        layer_rem=jnp.take_along_axis(state.layer_rem, order, axis=1),
        layer_rho=jnp.take_along_axis(state.layer_rho, order, axis=1),
    )


def _effective_stack(
    base_row: jax.Array,   # (B, V) M_b(.|ctx)
    q_row: jax.Array,      # (B, V) M_s(.|ctx)
    rho: jax.Array,        # (B, D)
    active: jax.Array,     # (B, D) bool
) -> jax.Array:
    """Rows fed into each layer, bottom-up: (B, D+1, V); [:, -1] is the
    effective (top) target row."""
    d = rho.shape[1]
    rows = [base_row]
    for l in range(d):
        new = sampling.normalize(
            jnp.maximum(rho[:, l, None] * rows[-1] - q_row, 0.0),
            fallback=rows[-1],
        )
        rows.append(jnp.where(active[:, l, None], new, rows[-1]))
    return jnp.stack(rows, axis=1)


def _draft_and_score(
    key: jax.Array,
    target: TabularLM,
    drafter: TabularLM,
    state: SimState,
    gamma: int,
    greedy: bool,
):
    """Sample a draft block; collect drafter rows, effective target rows and
    (greedy) the full layer-input row stacks along the path."""
    d = state.layer_rem.shape[1]
    rem0 = state.layer_rem  # (B, D), sorted ascending among active

    def step(carry, inp):
        ctx_t, ctx_d, rho = carry
        key_i, pos = inp
        q_row = drafter.next_probs(ctx_d)
        base = target.next_probs(ctx_t)
        active = pos < rem0  # (B, D)
        if greedy:
            stack = _effective_stack(base, q_row, rho, active)
        else:
            stack = jnp.broadcast_to(
                base[:, None], (base.shape[0], d + 1, base.shape[1])
            )
        top = stack[:, -1]
        tok = sampling.categorical(key_i, q_row)
        if greedy:
            in_tok = jnp.take_along_axis(
                stack[:, :d], tok[:, None, None].repeat(d, 1), axis=2
            )[..., 0]                                   # (B, D) rows_l(tok)
            q_tok = jnp.take_along_axis(q_row, tok[:, None], axis=1)
            factor = jnp.where(active, in_tok / jnp.maximum(q_tok, 1e-30), 1.0)
            rho = rho * factor
        carry = (target.advance(ctx_t, tok), drafter.advance(ctx_d, tok), rho)
        return carry, (tok, q_row, top, stack)

    keys = jax.random.split(key, gamma)
    carry0 = (state.ctx_t, state.ctx_d, state.layer_rho)
    (ctx_t_end, ctx_d_end, _), (toks, q_rows, tops, stacks) = jax.lax.scan(
        step, carry0, (keys, jnp.arange(gamma))
    )
    # Final (offset gamma) rows. Layers never cover offset >= gamma, so the
    # effective row equals the base target row there.
    q_last = drafter.next_probs(ctx_d_end)
    p_last = target.next_probs(ctx_t_end)

    draft_tokens = toks.T                                   # (B, G)
    q_rows = jnp.swapaxes(q_rows, 0, 1)                     # (B, G, V)
    q_ext = jnp.concatenate([q_rows, q_last[:, None]], 1)   # (B, G+1, V)
    p_rows = jnp.concatenate(
        [jnp.swapaxes(tops, 0, 1), p_last[:, None]], axis=1
    )                                                       # (B, G+1, V)
    stacks = jnp.swapaxes(stacks, 0, 1)                     # (B, G, D+1, V)
    return draft_tokens, q_rows, q_ext, p_rows, stacks


def _advance_contexts(target, drafter, state, tokens, num_tokens, gamma):
    def step(carry, pos):
        ctx_t, ctx_d = carry
        tok = tokens[:, pos]
        take = pos < num_tokens
        ctx_t = jnp.where(take, target.advance(ctx_t, tok), ctx_t)
        ctx_d = jnp.where(take, drafter.advance(ctx_d, tok), ctx_d)
        return (ctx_t, ctx_d), None

    (ctx_t, ctx_d), _ = jax.lax.scan(
        step, (state.ctx_t, state.ctx_d), jnp.arange(gamma + 1)
    )
    return ctx_t, ctx_d


def _roll_layers(
    state: SimState,
    res: verification.VerifyResult,
    draft_tokens: jax.Array,
    q_rows: jax.Array,   # (B, G, V)
    q_ext: jax.Array,    # (B, G+1, V)
    p_rows: jax.Array,   # (B, G+1, V) effective rows along the path
    stacks: jax.Array,   # (B, G, D+1, V) layer-input rows along the path
    gamma: int,
) -> tuple[jax.Array, jax.Array]:
    """Update (rem, rho) of existing layers along the accepted path and
    append the new layer created by this iteration's rejection."""
    b, d = state.layer_rem.shape
    tau = res.num_accepted
    n = res.num_tokens
    bonus = jnp.take_along_axis(res.tokens, tau[:, None], axis=1)[:, 0]

    rem0 = state.layer_rem                      # (B, D)
    pos = jnp.arange(gamma)[None, :, None]      # (1, G, 1)
    active_pos = pos < rem0[:, None, :]         # (B, G, D)

    # Per-position per-layer ratio factors along the draft path.
    tok_b = draft_tokens[:, :, None, None].repeat(d, 2)     # (B, G, D, 1)
    in_tok = jnp.take_along_axis(stacks[:, :, :d], tok_b, axis=3)[..., 0]
    q_tok = jnp.take_along_axis(q_rows, draft_tokens[..., None], axis=2)
    factors = jnp.where(
        active_pos, in_tok / jnp.maximum(q_tok, 1e-30), 1.0
    )                                           # (B, G, D)
    # Product over accepted draft positions i < tau.
    cum = jnp.cumprod(factors, axis=1)
    cum = jnp.concatenate([jnp.ones((b, 1, d), jnp.float32), cum], axis=1)
    prefix_prod = jnp.take_along_axis(
        cum, tau[:, None, None].repeat(d, 2), axis=1
    )[:, 0]                                     # (B, D)

    # Bonus-token factor at offset tau (identity beyond any window or at
    # offset gamma, where no layer is ever active).
    stacks_ext = jnp.concatenate(
        [stacks, jnp.broadcast_to(
            p_rows[:, gamma][:, None, None], (b, 1, d + 1, p_rows.shape[-1])
        )], axis=1
    )                                           # (B, G+1, D+1, V)
    stack_tau = jnp.take_along_axis(
        stacks_ext, tau[:, None, None, None].repeat(d + 1, 2)
        .repeat(stacks_ext.shape[-1], 3), axis=1
    )[:, 0]                                     # (B, D+1, V)
    in_bonus = jnp.take_along_axis(
        stack_tau[:, :d], bonus[:, None, None].repeat(d, 1), axis=2
    )[..., 0]                                   # (B, D)
    q_bonus = jnp.take_along_axis(
        jnp.take_along_axis(
            q_ext, tau[:, None, None].repeat(q_ext.shape[-1], 2), axis=1
        )[:, 0],
        bonus[:, None], axis=1,
    )                                           # (B, 1)
    bonus_active = tau[:, None] < rem0
    bonus_factor = jnp.where(
        bonus_active, in_bonus / jnp.maximum(q_bonus, 1e-30), 1.0
    )

    rho = state.layer_rho * prefix_prod * bonus_factor
    rem = jnp.maximum(rem0 - n[:, None], 0)
    rho = jnp.where(rem > 0, rho, 1.0)

    # New layer: rho0 = T_top(X^tau, Y | anchor) / M_s(X^tau, Y | anchor).
    p_tok = jnp.take_along_axis(
        p_rows[:, :gamma], draft_tokens[..., None], axis=2
    )[..., 0]
    ratio_path = jnp.where(
        q_tok[..., 0] > 0, p_tok / jnp.maximum(q_tok[..., 0], 1e-30), 0.0
    )
    cum_top = jnp.concatenate(
        [jnp.ones((b, 1), jnp.float32), jnp.cumprod(ratio_path, axis=1)],
        axis=1,
    )
    top_prefix = jnp.take_along_axis(cum_top, tau[:, None], axis=1)[:, 0]
    top_bonus = jnp.take_along_axis(
        stack_tau[:, d], bonus[:, None], axis=1
    )[:, 0]
    rho0 = top_prefix * top_bonus / jnp.maximum(q_bonus[:, 0], 1e-30)
    m_new = res.mod_remaining                   # gamma - tau - 1 (>= 0)

    # Insert into the slot with the smallest remaining window (an expired
    # one is guaranteed to exist: at most gamma-1 layers are active).
    slot = jnp.argmin(rem, axis=1)
    onehot = jax.nn.one_hot(slot, d, dtype=bool)
    insert = (m_new > 0)[:, None] & onehot
    rem = jnp.where(insert, m_new[:, None], rem)
    rho = jnp.where(insert, rho0[:, None], rho)
    return rem, rho


def _one_iteration(
    state: SimState, target: TabularLM, drafter: TabularLM, gamma: int,
    verifier_name: str,
):
    greedy = verifier_name == "greedy_block"
    verify = verification.get_verifier(verifier_name)
    state = _sort_layers(state)
    key, key_draft, key_verify = jax.random.split(state.key, 3)
    draft_tokens, q_rows, q_ext, p_rows, stacks = _draft_and_score(
        key_draft, target, drafter, state, gamma, greedy
    )
    res = verify(key_verify, draft_tokens, q_rows, p_rows)
    ctx_t, ctx_d = _advance_contexts(
        target, drafter, state, res.tokens, res.num_tokens, gamma
    )
    if greedy:
        rem, rho = _roll_layers(
            state, res, draft_tokens, q_rows, q_ext, p_rows, stacks, gamma
        )
    else:
        rem, rho = state.layer_rem, state.layer_rho
    new_state = SimState(
        key=key, ctx_t=ctx_t, ctx_d=ctx_d, layer_rem=rem, layer_rho=rho
    )
    return new_state, res


@functools.partial(
    jax.jit, static_argnames=("gamma", "verifier_name", "batch", "n_iters")
)
def block_efficiency(
    key: jax.Array,
    target: TabularLM,
    drafter: TabularLM,
    gamma: int,
    verifier_name: str,
    batch: int = 512,
    n_iters: int = 64,
) -> jax.Array:
    """Average decoded tokens per target call (= E[tau] + 1) over
    ``batch`` independent chains and ``n_iters`` SpecDec iterations."""
    state = init_state(key, batch, gamma)

    def step(st, _):
        st, res = _one_iteration(st, target, drafter, gamma, verifier_name)
        return st, res.num_tokens

    _, nums = jax.lax.scan(step, state, None, length=n_iters)
    return jnp.mean(nums.astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=("gamma", "verifier_name", "n_samples", "length"),
)
def specdec_rollout(
    key: jax.Array,
    target: TabularLM,
    drafter: TabularLM,
    gamma: int,
    verifier_name: str,
    n_samples: int,
    length: int,
) -> jax.Array:
    """Run ``n_samples`` independent SpecDec chains and return the first
    ``length`` output tokens of each — the losslessness witness."""
    state = init_state(key, n_samples, gamma)
    buf = jnp.zeros((n_samples, length + gamma + 1), jnp.int32)
    count = jnp.zeros((n_samples,), jnp.int32)

    def step(carry, _):
        st, buf, count = carry
        frozen = count >= length  # chain already emitted `length` tokens

        st, res = _one_iteration(st, target, drafter, gamma, verifier_name)
        # Frozen chains keep iterating (their state updates are harmless)
        # but their writes are redirected to a per-row dustbin slot (the
        # last buffer column, which is never read back: valid writes stop
        # at length - 1 + gamma = buflen - 2).
        pos = jnp.arange(gamma + 1)[None, :]
        valid = (pos < res.num_tokens[:, None]) & (~frozen[:, None])
        write_idx = jnp.where(valid, count[:, None] + pos, buf.shape[1] - 1)
        b_idx = jnp.broadcast_to(
            jnp.arange(n_samples)[:, None], write_idx.shape
        )
        buf = buf.at[b_idx, write_idx].set(res.tokens)
        count = jnp.where(frozen, count, count + res.num_tokens)
        return (st, buf, count), None

    (state, buf, count), _ = jax.lax.scan(
        step, (state, buf, count), None, length=length
    )
    return buf[:, :length]
