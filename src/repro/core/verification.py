"""Draft-verification algorithms for speculative decoding.

Implements, in batched JAX:

* ``token_verify``        — Algorithm 1 (Leviathan et al., 2022), the
                            standard independent per-token accept/reject.
* ``block_verify``        — Algorithm 2, the paper's contribution: joint
                            (coupled) verification of the whole block.
                            Lossless (Thm 1) and optimal (Thm 2).
* ``greedy_block_verify`` — Algorithm 4 (Appendix C): accepts more tokens
                            per iteration but requires the caller to apply
                            the distribution modification (Algorithm 5)
                            for the next ``gamma - tau - 1`` positions.
* ``multipath_greedy_verify`` — greedy multi-path verification over K
                            i.i.d. draft paths (Thomas & Pal / SpecTr-GBV
                            direction, PAPERS.md): per position, the alive
                            paths' candidates are tried greedily in path
                            order under recursive residual rejection, the
                            longest accepted path is committed, and the
                            correction token is drawn from the exact
                            multi-path residual. Lossless for any K; the
                            serving engine uses it when ``num_paths > 1``
                            (K = 1 routes to the single-path verifiers).

Shapes (``B`` = batch, ``G`` = gamma = draft length, ``V`` = vocab):

* ``draft_tokens``: ``(B, G)`` int32 — tokens sampled from the drafter.
* ``q_probs``:      ``(B, G, V)``    — drafter next-token distributions
                                       M_s(. | c, X^i) for i = 0..G-1.
* ``p_probs``:      ``(B, G+1, V)``  — target next-token distributions
                                       M_b(. | c, X^i) for i = 0..G.

All three return a :class:`VerifyResult` whose ``tokens[:, :num_tokens]``
are the decoded tokens for this iteration: ``tau`` accepted draft tokens
followed by one bonus/corrected token. Functions are pure and jit-safe.

Structure
---------
The inputs every algorithm needs — the gathered per-draft-token target /
drafter probabilities and their ratios — are computed once into a
:class:`VerifyContext` and shared. The heavy vocab reduction
``S = sum_v max(p_scale * P - Q, 0)`` (Eq. 3/4) is pluggable through the
**residual-sums backend registry**: ``"jnp"`` is the pure-XLA reference,
``"pallas"`` (registered by :mod:`repro.kernels.ops` on import) streams
the distributions through the fused TPU kernel. ``resolve_residual_sums``
picks the backend; the serving engine defaults to ``"auto"`` which routes
through the Pallas entry point whenever the kernels package is present.

Every algorithm is split into pure **probability surfaces** (acceptance
probabilities, residual/bonus distributions — deterministic functions of
the context) and a thin sampling layer that draws uniforms against them.
The exact-distribution test harness (``tests/test_lossless.py``)
marginalizes the *same surface functions* over all draft outcomes in
float64 and checks the committed-token distribution equals the target
model's autoregressive distribution — so losslessness is asserted about
this implementation, not a parallel reimplementation. All internal dtypes
follow the input probabilities (float32 in serving; float64 under
``jax_enable_x64`` in the harness).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sampling

_EPS = 1e-30

# (p_scale (B, K), p_rows (B, K, V), q_rows (B, K, V)) -> (B, K)
ResidualSums = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]


class VerifyResult(NamedTuple):
    tokens: jax.Array        # (B, G+1) int32; valid prefix of length num_tokens
    num_accepted: jax.Array  # (B,) int32 — tau, number of accepted draft tokens
    num_tokens: jax.Array    # (B,) int32 — tau + 1 (accepted + bonus token)
    mod_remaining: jax.Array  # (B,) int32 — greedy only: positions whose target
    #                           distribution must be modified (Algorithm 5);
    #                           zero for token/block verification.


class VerifyContext(NamedTuple):
    """Inputs shared by all three verification algorithms, computed once:
    float32 distributions plus the gathered per-draft-token probabilities
    and their M_b/M_s ratios."""

    draft_tokens: jax.Array  # (B, G) int32
    q_probs: jax.Array       # (B, G, V) float32
    p_probs: jax.Array       # (B, G+1, V) float32
    p_tok: jax.Array         # (B, G) — M_b at the draft tokens
    q_tok: jax.Array         # (B, G) — M_s at the draft tokens
    ratio: jax.Array         # (B, G) — M_b/M_s (0 where q_tok == 0)

    @property
    def gamma(self) -> int:
        return self.draft_tokens.shape[1]


def _gather(probs: jax.Array, tokens: jax.Array) -> jax.Array:
    """probs (B, K, V), tokens (B, K) -> (B, K) probs of the given tokens."""
    return jnp.take_along_axis(probs, tokens[..., None], axis=-1)[..., 0]


def _row_at(x: jax.Array, idx: jax.Array) -> jax.Array:
    """x (B, K, V), idx (B,) -> (B, V) row x[b, idx[b]]."""
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def _assemble(
    draft_tokens: jax.Array, bonus: jax.Array, tau: jax.Array
) -> jax.Array:
    """Lay out [X_1..X_tau, Y, pad...] as an (B, G+1) int32 array."""
    b, g = draft_tokens.shape
    pos = jnp.arange(g + 1)[None, :]
    padded = jnp.concatenate(
        [draft_tokens, jnp.zeros((b, 1), draft_tokens.dtype)], axis=1
    )
    out = jnp.where(pos < tau[:, None], padded, 0)
    out = jnp.where(pos == tau[:, None], bonus[:, None], out)
    return out.astype(jnp.int32)


def _ratios(p_tok: jax.Array, q_tok: jax.Array) -> jax.Array:
    """M_b/M_s at the draft tokens; q == 0 (never drafted) -> ratio 0.

    A drafter cannot emit a zero-probability token, so q_tok == 0 only
    happens with adversarial inputs; following the paper's reference
    implementation (non-finite ratio => reject) we map it to ratio 0.
    """
    return jnp.where(q_tok > 0, p_tok / jnp.maximum(q_tok, _EPS), 0.0)


def _guard_nonfinite(q_probs: jax.Array) -> jax.Array:
    """Zero out drafter rows containing non-finite mass.

    A corrupted drafter row (NaN/inf logits upstream) would poison the
    accept/reject arithmetic for its whole block.  Zeroing the row keeps
    every fallback inside the verification rule itself: ``_ratios`` maps
    q == 0 to ratio 0, so token verification rejects at that position
    (tau stops there) and block verification's Eq.-8 products are 0 from
    it onward; the bonus/correction token then samples from
    ``normalize(max(scale·p − 0, 0)) = p`` — a pure target-distribution
    resample.  The affected step stays exactly lossless (the committed
    token is target-distributed conditioned on the prefix), which is why
    ``tests/test_lossless.py`` passes with this guard installed.  Finite
    inputs are untouched bitwise.
    """
    row_ok = jnp.all(jnp.isfinite(q_probs), axis=-1, keepdims=True)
    return jnp.where(row_ok, q_probs, jnp.zeros_like(q_probs))


def make_context(
    draft_tokens: jax.Array, q_probs: jax.Array, p_probs: jax.Array
) -> VerifyContext:
    """Build the shared verification context (one gather per model).

    Probabilities are computed in float32, except float64 inputs (under
    ``jax_enable_x64``) which are kept — the exact lossless harness
    marginalizes these surfaces at float64.
    """
    g = draft_tokens.shape[1]
    dt = jnp.promote_types(jnp.result_type(q_probs, p_probs), jnp.float32)
    q_probs = _guard_nonfinite(q_probs.astype(dt))
    p_probs = p_probs.astype(dt)
    p_tok = _gather(p_probs[:, :g], draft_tokens)
    q_tok = _gather(q_probs, draft_tokens)
    return VerifyContext(
        draft_tokens=draft_tokens,
        q_probs=q_probs,
        p_probs=p_probs,
        p_tok=p_tok,
        q_tok=q_tok,
        ratio=_ratios(p_tok, q_tok),
    )


# ---------------------------------------------------------------------------
# Residual-sums backend registry
# ---------------------------------------------------------------------------


def default_residual_sums(
    p_scale: jax.Array, p_rows: jax.Array, q_rows: jax.Array
) -> jax.Array:
    """Pure-jnp reference: ``sum_v max(p_scale * P - Q, 0)`` -> (B, K)."""
    return jnp.sum(
        jnp.maximum(p_scale[..., None] * p_rows - q_rows, 0.0), axis=-1
    )


_RESIDUAL_BACKENDS: dict[str, ResidualSums] = {"jnp": default_residual_sums}


def register_residual_backend(name: str, fn: ResidualSums) -> None:
    """Register a fused implementation of the Eq. 3/4 vocab reduction.
    ``repro.kernels.ops`` registers ``"pallas"`` (and its explicit
    interpret/compiled variants) on import."""
    _RESIDUAL_BACKENDS[name] = fn


def residual_backends() -> list[str]:
    return sorted(_RESIDUAL_BACKENDS)


def resolve_residual_sums(name: str = "auto") -> ResidualSums:
    """Resolve a backend name to a residual-sums callable.

    ``"auto"`` prefers the Pallas entry point in ``repro.kernels.ops``
    — which itself picks compiled-on-TPU vs XLA-reference-elsewhere —
    and falls back to ``"jnp"`` if the kernels package cannot be
    imported. ``None`` is deliberately NOT accepted here: in
    ``get_verifier``/``EngineConfig`` it means "plain jnp default",
    and silently auto-resolving it would invert that meaning.
    """
    if name is None:
        raise ValueError(
            "residual backend None means 'plain jnp default' at the "
            "verifier level; pass 'auto' (or an explicit backend) here"
        )
    if name == "auto":
        try:
            import repro.kernels.ops  # noqa: F401  (registers "pallas")
        except ImportError:
            return _RESIDUAL_BACKENDS["jnp"]
        return _RESIDUAL_BACKENDS.get("pallas", _RESIDUAL_BACKENDS["jnp"])
    if name not in _RESIDUAL_BACKENDS:
        # Late registration: the kernels module may simply not be imported.
        try:
            import repro.kernels.ops  # noqa: F401
        except ImportError:
            pass
    if name not in _RESIDUAL_BACKENDS:
        raise ValueError(
            f"unknown residual backend {name!r}; "
            f"choose from {residual_backends()} or 'auto'"
        )
    return _RESIDUAL_BACKENDS[name]


# ---------------------------------------------------------------------------
# Algorithm 1 — token verification
# ---------------------------------------------------------------------------


def token_accept_probs(ctx: VerifyContext) -> jax.Array:
    """Algorithm 1 acceptance surface: a_i = min(1, M_b/M_s at X_i),
    i = 1..G. The i-th draft token is accepted iff u_i <= a_i AND all
    earlier tokens were accepted (first rejection stops the block)."""
    return jnp.minimum(ctx.ratio, 1.0)


def token_bonus_dist(ctx: VerifyContext, tau: jax.Array) -> jax.Array:
    """Algorithm 1 bonus surface: the distribution of the (tau+1)-th
    committed token — the token residual norm(max(M_b - M_s, 0)) (Eq. 2)
    after a rejection, M_b(.|X^G) itself after a full accept."""
    g = ctx.gamma
    p_tau = _row_at(ctx.p_probs, tau)  # (B, V): M_b(.|c, X^tau)
    q_tau = _row_at(ctx.q_probs, jnp.minimum(tau, g - 1))
    residual = sampling.normalize(
        jnp.maximum(p_tau - q_tau, 0.0), fallback=p_tau
    )
    return jnp.where((tau == g)[:, None], p_tau, residual)


def token_verify_ctx(key: jax.Array, ctx: VerifyContext) -> VerifyResult:
    """Algorithm 1: accept X_i independently w.p. min(1, p/q); stop at the
    first rejection; bonus token from the token residual (Eq. 2)."""
    b, g = ctx.draft_tokens.shape
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g))

    accept = u <= token_accept_probs(ctx)
    # tau = number of leading accepts.
    tau = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)

    bonus = sampling.categorical(key_y, token_bonus_dist(ctx, tau))

    return VerifyResult(
        tokens=_assemble(ctx.draft_tokens, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        mod_remaining=jnp.zeros((b,), jnp.int32),
    )


def token_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
) -> VerifyResult:
    return token_verify_ctx(key, make_context(draft_tokens, q_probs, p_probs))


# ---------------------------------------------------------------------------
# Algorithm 2 — block verification (the paper's contribution)
# ---------------------------------------------------------------------------


def _block_ps(ratio: jax.Array) -> jax.Array:
    """p_i = min(p_{i-1} * r_i, 1) scan (Eq. 8). ratio (B, G) -> (B, G)."""
    b = ratio.shape[0]

    def step(p_prev, r_i):
        p_i = jnp.minimum(p_prev * r_i, 1.0)
        return p_i, p_i

    _, ps = jax.lax.scan(step, jnp.ones((b,), ratio.dtype), ratio.T)
    return ps.T  # (B, G): p_1 .. p_G


def _block_surfaces(
    ctx: VerifyContext, residual_sums: ResidualSums | None
) -> tuple[jax.Array, jax.Array]:
    """Algorithm 2 acceptance surface: ``(h, p_full)`` where ``h[:, i-1]``
    is the Eq.-4 acceptance probability h_i (i = 1..G; tau is the largest
    accepted index) and ``p_full[:, i]`` is the block scale p_i (Eq. 8,
    p_0 = 1) the bonus residual is scaled by."""
    b, g = ctx.draft_tokens.shape
    ps = _block_ps(ctx.ratio)                 # (B, G): p_1..p_G
    p_full = jnp.concatenate(
        [jnp.ones((b, 1), ps.dtype), ps], axis=1
    )

    sums = residual_sums or default_residual_sums
    # S_i for i = 0..G-1 : conditioning on X^i uses row i of p_probs/q_probs,
    # scaled by p_i (Eq. 4). Row G has no drafter distribution (no residual).
    s_all = sums(p_full[:, :g], ctx.p_probs[:, :g], ctx.q_probs)  # (B, G)

    # Acceptance probabilities h_i for i = 1..G (Eq. 4; h_G = p_G).
    p_i = ps[:, : g - 1]                      # p_1..p_{G-1}
    s_i = s_all[:, 1:g]                       # S_1..S_{G-1}
    h_mid = jnp.where(
        p_i >= 1.0, 1.0, s_i / jnp.maximum(s_i + 1.0 - p_i, _EPS)
    )
    h = jnp.concatenate([h_mid, ps[:, g - 1 :]], axis=1)  # (B, G): h_1..h_G
    return h, p_full


def block_accept_probs(
    ctx: VerifyContext, residual_sums: ResidualSums | None = None
) -> jax.Array:
    """Eq.-4 acceptance probabilities h_1..h_G; tau = max accepted index
    over independent coins u_i <= h_i (Algorithm 2)."""
    return _block_surfaces(ctx, residual_sums)[0]


def block_bonus_dist(ctx: VerifyContext, tau: jax.Array) -> jax.Array:
    """Algorithm 2 bonus surface: block residual norm(max(p_tau * M_b -
    M_s, 0)) (Eq. 3) after a partial accept, M_b(.|X^G) after a full one.
    Needs only the Eq.-8 scale scan, not the Eq.-4 residual reductions."""
    b = ctx.draft_tokens.shape[0]
    ps = _block_ps(ctx.ratio)
    p_full = jnp.concatenate([jnp.ones((b, 1), ps.dtype), ps], axis=1)
    return _block_bonus_from(ctx, tau, p_full)


def _block_bonus_from(
    ctx: VerifyContext, tau: jax.Array, p_full: jax.Array
) -> jax.Array:
    g = ctx.gamma
    p_tau_scale = jnp.take_along_axis(p_full, tau[:, None], axis=1)[:, 0]
    p_row = _row_at(ctx.p_probs, tau)
    q_row = _row_at(ctx.q_probs, jnp.minimum(tau, g - 1))
    residual = sampling.normalize(
        jnp.maximum(p_tau_scale[:, None] * p_row - q_row, 0.0), fallback=p_row
    )
    return jnp.where((tau == g)[:, None], p_row, residual)


def block_verify_ctx(
    key: jax.Array,
    ctx: VerifyContext,
    residual_sums: ResidualSums | None = None,
) -> VerifyResult:
    """Algorithm 2: block verification over a shared context.

    ``residual_sums(p_scale, p_rows, q_rows) -> (B, K)`` overrides the
    vocab reductions ``sum_x max(p_scale*P - Q, 0)`` (e.g. with the fused
    Pallas kernel via the backend registry); default is the jnp reference.
    """
    b, g = ctx.draft_tokens.shape
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g))

    h, p_full = _block_surfaces(ctx, residual_sums)

    accept = u <= h
    idx = jnp.arange(1, g + 1)[None, :]
    tau = jnp.max(jnp.where(accept, idx, 0), axis=1)  # longest accepted block

    # Bonus token: from M_b(.|X^G) when tau == G, else block residual (Eq. 3).
    bonus = sampling.categorical(key_y, _block_bonus_from(ctx, tau, p_full))

    return VerifyResult(
        tokens=_assemble(ctx.draft_tokens, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        mod_remaining=jnp.zeros((b,), jnp.int32),
    )


def block_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
    residual_sums: ResidualSums | None = None,
) -> VerifyResult:
    return block_verify_ctx(
        key, make_context(draft_tokens, q_probs, p_probs),
        residual_sums=residual_sums,
    )


# ---------------------------------------------------------------------------
# Algorithm 4 — greedy block verification
# ---------------------------------------------------------------------------


def greedy_block_verify_ctx(
    key: jax.Array,
    ctx: VerifyContext,
    residual_sums: ResidualSums | None = None,
) -> VerifyResult:
    """Algorithm 4 (Appendix C): greedy block verification.

    Accepts at least as many tokens as block verification in a single
    iteration (Thm 3) but is only lossless when the caller modifies the
    target distribution for the next ``mod_remaining`` positions according
    to Algorithm 5 (see ``modified_target_row``).

    The h_i denominator ``sum_v max(Q - s*P, 0)`` is derived from the
    numerator through the exact identity
    ``sum max(Q - sP, 0) = sum max(sP - Q, 0) - (s - 1)`` (both P and Q
    sum to one), so one residual reduction — routable through the fused
    backend — serves both.
    """
    b, g = ctx.draft_tokens.shape
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g))

    # ptilde_i = prod_{j<=i} r_j, no clipping (Appendix C).
    ptilde = jnp.cumprod(ctx.ratio, axis=1)                  # (B, G): i=1..G
    ptilde_full = jnp.concatenate(
        [jnp.ones((b, 1), jnp.float32), ptilde], axis=1
    )

    # h_i for i = 1..G-1 (Algorithm 4 line 5).
    sums = residual_sums or default_residual_sums
    scale = ptilde[:, : g - 1]                               # ptilde_1..G-1
    num = sums(scale, ctx.p_probs[:, 1:g], ctx.q_probs[:, 1:g])
    den = jnp.maximum(num - scale + 1.0, 0.0)
    h_mid = jnp.where(den > _EPS, num / jnp.maximum(den, _EPS), jnp.inf)
    h_last = jnp.minimum(ptilde[:, g - 1 :], 1.0)            # accept X^G step
    h = jnp.concatenate([h_mid, h_last], axis=1)

    accept = u <= h
    idx = jnp.arange(1, g + 1)[None, :]
    tau = jnp.max(jnp.where(accept, idx, 0), axis=1)

    pt_tau = jnp.take_along_axis(ptilde_full, tau[:, None], axis=1)[:, 0]
    p_row = _row_at(ctx.p_probs, tau)
    q_row = _row_at(ctx.q_probs, jnp.minimum(tau, g - 1))
    residual = sampling.normalize(
        jnp.maximum(pt_tau[:, None] * p_row - q_row, 0.0), fallback=p_row
    )
    bonus_dist = jnp.where((tau == g)[:, None], p_row, residual)
    bonus = sampling.categorical(key_y, bonus_dist)

    mod_remaining = jnp.where(tau == g, 0, g - tau - 1).astype(jnp.int32)
    return VerifyResult(
        tokens=_assemble(ctx.draft_tokens, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        mod_remaining=jnp.maximum(mod_remaining, 0),
    )


def greedy_block_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
    residual_sums: ResidualSums | None = None,
) -> VerifyResult:
    return greedy_block_verify_ctx(
        key, make_context(draft_tokens, q_probs, p_probs),
        residual_sums=residual_sums,
    )


def modified_target_row(
    p_row: jax.Array, q_row: jax.Array
) -> jax.Array:
    """Algorithm 5 (Eq. 23): the modified target distribution used for the
    ``mod_remaining`` positions after a greedy-block-verification step:
    M_new ∝ max(M_b - M_s, 0), falling back to M_b when M_b == M_s."""
    return sampling.normalize(jnp.maximum(p_row - q_row, 0.0), fallback=p_row)


# ---------------------------------------------------------------------------
# Greedy multi-path verification (K i.i.d. draft paths)
# ---------------------------------------------------------------------------


class MultiVerifyContext(NamedTuple):
    """Inputs for multi-path verification: K draft paths forked from the
    same committed prefix, each drafted **independently** from the drafter
    (i.i.d. path samples — exactly what the serving runner's page-table
    fork produces), with each path's own per-position drafter and target
    rows."""

    draft_tokens: jax.Array  # (B, K, G) int32
    q_probs: jax.Array       # (B, K, G, V)   — M_s rows along each path
    p_probs: jax.Array       # (B, K, G+1, V) — M_b rows along each path

    @property
    def num_paths(self) -> int:
        return self.draft_tokens.shape[1]

    @property
    def gamma(self) -> int:
        return self.draft_tokens.shape[2]


class MultiVerifyResult(NamedTuple):
    tokens: jax.Array        # (B, G+1) int32; valid prefix of num_tokens
    num_accepted: jax.Array  # (B,) int32 — accepted draft tokens (tau)
    num_tokens: jax.Array    # (B,) int32 — tau + 1
    winner: jax.Array        # (B,) int32 — path whose prefix was committed
    #                          (lowest-indexed alive path; its target-pass
    #                          state is the one the caller must commit)


def make_multi_context(
    draft_tokens: jax.Array, q_probs: jax.Array, p_probs: jax.Array
) -> MultiVerifyContext:
    dt = jnp.promote_types(jnp.result_type(q_probs, p_probs), jnp.float32)
    return MultiVerifyContext(
        draft_tokens=draft_tokens,
        q_probs=_guard_nonfinite(q_probs.astype(dt)),
        p_probs=p_probs.astype(dt),
    )


def multipath_rrs_tables(
    p_row: jax.Array,   # (B, V) target row at the committed prefix
    q_row: jax.Array,   # (B, V) drafter row at the committed prefix
    num_paths: int,
    residual_sums: ResidualSums | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Recursive-residual constants for one position.

    With m candidates (each an i.i.d. draw from ``q_row``) already
    rejected, the conditional law of the committed token is the residual
    ``r_m = u_m / Z_m`` with ``u_m = max(P - c_m * Q, 0)`` — the same
    closed form as the paper's block residual (Eq. 3) with scale folded
    into ``c_m``:

        c_0 = 0,  Z_0 = 1,  c_{m+1} = c_m + Z_m,
        Z_m = sum_v max(P(v) - c_m * Q(v), 0).

    Each ``Z_m`` is one Eq.-4-style vocab reduction; for ``c_m > 0`` it is
    routed through the residual-sums backend via the identity
    ``sum max(P - cQ, 0) = c * sum max((1/c) P - Q, 0)``, so the fused
    Pallas kernel scores every path's residual sums. Returns ``(c, z)``,
    each ``(B, num_paths + 1)``.
    """
    sums = residual_sums or default_residual_sums
    b = p_row.shape[0]
    dt = p_row.dtype
    cs = [jnp.zeros((b,), dt)]
    zs = [jnp.ones((b,), dt)]
    for _ in range(num_paths):
        c_m = cs[-1] + zs[-1]  # >= 1: Z_0 = 1 and Z_m >= 0
        z_m = c_m * sums(
            (1.0 / c_m)[:, None], p_row[:, None], q_row[:, None]
        )[:, 0]
        cs.append(c_m)
        zs.append(z_m)
    return jnp.stack(cs, axis=1), jnp.stack(zs, axis=1)


def multipath_accept_prob(
    p_tok: jax.Array, q_tok: jax.Array, c_m: jax.Array, z_m: jax.Array
) -> jax.Array:
    """Acceptance probability of a candidate token (drafter prob
    ``q_tok``, target prob ``p_tok``) after ``m`` rejections at this
    position: ``min(1, u_m(x) / (Z_m * q(x)))``. ``q == 0`` (never
    drafted) maps to 0, mirroring :func:`_ratios`."""
    u = jnp.maximum(p_tok - c_m * q_tok, 0.0)
    a = jnp.minimum(u / jnp.maximum(z_m * q_tok, _EPS), 1.0)
    return jnp.where(q_tok > 0, a, 0.0)


def multipath_residual_dist(
    p_row: jax.Array, q_row: jax.Array, c_m: jax.Array
) -> jax.Array:
    """The exact correction distribution after all ``m`` alive candidates
    rejected at a position: norm(max(P - c_m * Q, 0)), falling back to P
    on (unreachable) zero residual mass."""
    return sampling.normalize(
        jnp.maximum(p_row - c_m[:, None] * q_row, 0.0), fallback=p_row
    )


def multipath_greedy_verify_ctx(
    key: jax.Array,
    mctx: MultiVerifyContext,
    residual_sums: ResidualSums | None = None,
) -> MultiVerifyResult:
    """Greedy multi-path verification.

    Position by position, the candidates of the still-alive paths (those
    whose prefix equals the committed tokens so far) are tried greedily in
    path-index order under recursive residual rejection: candidate j+1 is
    accepted w.p. ``min(1, r_m(x)/q(x))`` where ``r_m`` is the residual of
    the target row after the m previous rejections (closed form in
    :func:`multipath_rrs_tables`). Accepting extends the committed path —
    paths whose token at this position differs die; rejecting all alive
    candidates ends the block with a correction token drawn from the exact
    residual ``r_m``. A fully-accepted block earns the usual bonus token
    from ``M_b(.|X^G)`` of the winning path.

    Lossless: conditioned on the committed prefix, each committed token is
    distributed exactly as the target row (the RRS chain realizes a sample
    from ``P`` out of i.i.d. ``Q``-candidates plus one residual draw), the
    same per-step invariant token/block verification satisfy. At K = 1 the
    rule reduces to token-level verification — the serving engine
    therefore routes ``num_paths == 1`` through the configured single-path
    verifier and uses this rule only for true forks.
    """
    b, k, g = mctx.draft_tokens.shape
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g, k))

    alive = jnp.ones((b, k), bool)
    rep = jnp.zeros((b,), jnp.int32)       # lowest-indexed alive path
    done = jnp.zeros((b,), bool)
    tau = jnp.zeros((b,), jnp.int32)
    bonus_row = jnp.zeros_like(mctx.p_probs[:, 0, 0])
    ys = []
    for i in range(g):
        # All alive paths share the committed prefix, so the
        # representative's rows ARE the conditional rows at that prefix.
        sel = rep[:, None, None]
        p_i = jnp.take_along_axis(mctx.p_probs[:, :, i], sel, axis=1)[:, 0]
        q_i = jnp.take_along_axis(mctx.q_probs[:, :, i], sel, axis=1)[:, 0]
        c_tab, z_tab = multipath_rrs_tables(p_i, q_i, k, residual_sums)

        acc = jnp.zeros((b,), bool)
        m = jnp.zeros((b,), jnp.int32)     # rejections so far, this position
        y = jnp.zeros((b,), jnp.int32)
        for j in range(k):
            cand = mctx.draft_tokens[:, j, i]
            eligible = alive[:, j] & ~acc & ~done
            c_m = jnp.take_along_axis(c_tab, m[:, None], axis=1)[:, 0]
            z_m = jnp.take_along_axis(z_tab, m[:, None], axis=1)[:, 0]
            p_tok = jnp.take_along_axis(p_i, cand[:, None], axis=1)[:, 0]
            q_tok = jnp.take_along_axis(q_i, cand[:, None], axis=1)[:, 0]
            a = multipath_accept_prob(p_tok, q_tok, c_m, z_m)
            take = eligible & (u[:, i, j] <= a)
            y = jnp.where(take, cand, y)
            acc = acc | take
            m = m + (eligible & ~take)

        # All alive candidates rejected: the block ends here; correction
        # token from the exact residual after m rejections.
        rejected = ~acc & ~done
        c_f = jnp.take_along_axis(c_tab, m[:, None], axis=1)[:, 0]
        res_row = multipath_residual_dist(p_i, q_i, c_f)
        bonus_row = jnp.where(rejected[:, None], res_row, bonus_row)

        tau = tau + acc
        alive = jnp.where(
            acc[:, None],
            alive & (mctx.draft_tokens[:, :, i] == y[:, None]),
            alive,
        )
        rep = jnp.where(acc, jnp.argmax(alive, axis=1).astype(jnp.int32), rep)
        ys.append(y)
        done = done | rejected

    # Fully accepted blocks: bonus from M_b(.|X^G) of the winning path.
    p_last = jnp.take_along_axis(
        mctx.p_probs[:, :, g], rep[:, None, None], axis=1
    )[:, 0]
    bonus_row = jnp.where(done[:, None], bonus_row, p_last)
    bonus = sampling.categorical(key_y, bonus_row)

    committed = jnp.stack(ys, axis=1)  # (B, G); junk past tau is masked
    return MultiVerifyResult(
        tokens=_assemble(committed, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        winner=rep,
    )


def multipath_greedy_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
    residual_sums: ResidualSums | None = None,
) -> MultiVerifyResult:
    return multipath_greedy_verify_ctx(
        key, make_multi_context(draft_tokens, q_probs, p_probs),
        residual_sums=residual_sums,
    )


def get_multipath_verifier(residual_backend: str | None = None):
    """Context-based multi-path verifier ``verify(key, mctx)`` with the
    residual reductions bound to a backend (``None`` = plain jnp)."""
    if residual_backend is None:
        return multipath_greedy_verify_ctx
    return partial(
        multipath_greedy_verify_ctx,
        residual_sums=resolve_residual_sums(residual_backend),
    )


# ---------------------------------------------------------------------------
# Verifier lookup
# ---------------------------------------------------------------------------

_VERIFIERS = {
    "token": token_verify,
    "block": block_verify,
    "greedy_block": greedy_block_verify,
}

_CTX_VERIFIERS = {
    "token": token_verify_ctx,
    "block": block_verify_ctx,
    "greedy_block": greedy_block_verify_ctx,
}


def get_verifier(name: str, residual_backend: str | None = None):
    """Return ``verify(key, draft_tokens, q_probs, p_probs)``.

    With ``residual_backend`` set (e.g. ``"auto"``, ``"pallas"``, ``"jnp"``)
    the block/greedy vocab reductions are bound to that backend; ``None``
    keeps the plain jnp default (back-compat).
    """
    if name not in _VERIFIERS:
        raise ValueError(
            f"unknown verifier {name!r}; choose from {sorted(_VERIFIERS)}"
        )
    fn = _VERIFIERS[name]
    if residual_backend is not None and name in ("block", "greedy_block"):
        fn = partial(fn, residual_sums=resolve_residual_sums(residual_backend))
    return fn


def get_ctx_verifier(name: str, residual_backend: str | None = None):
    """Context-based variant: ``verify(key, ctx)`` for callers that build a
    :class:`VerifyContext` themselves (the serving runner)."""
    if name not in _CTX_VERIFIERS:
        raise ValueError(
            f"unknown verifier {name!r}; choose from {sorted(_CTX_VERIFIERS)}"
        )
    fn = _CTX_VERIFIERS[name]
    if residual_backend is not None and name in ("block", "greedy_block"):
        fn = partial(fn, residual_sums=resolve_residual_sums(residual_backend))
    return fn
