"""Draft-verification algorithms for speculative decoding.

Implements, in batched JAX:

* ``token_verify``        — Algorithm 1 (Leviathan et al., 2022), the
                            standard independent per-token accept/reject.
* ``block_verify``        — Algorithm 2, the paper's contribution: joint
                            (coupled) verification of the whole block.
                            Lossless (Thm 1) and optimal (Thm 2).
* ``greedy_block_verify`` — Algorithm 4 (Appendix C): accepts more tokens
                            per iteration but requires the caller to apply
                            the distribution modification (Algorithm 5)
                            for the next ``gamma - tau - 1`` positions.

Shapes (``B`` = batch, ``G`` = gamma = draft length, ``V`` = vocab):

* ``draft_tokens``: ``(B, G)`` int32 — tokens sampled from the drafter.
* ``q_probs``:      ``(B, G, V)``    — drafter next-token distributions
                                       M_s(. | c, X^i) for i = 0..G-1.
* ``p_probs``:      ``(B, G+1, V)``  — target next-token distributions
                                       M_b(. | c, X^i) for i = 0..G.

All three return a :class:`VerifyResult` whose ``tokens[:, :num_tokens]``
are the decoded tokens for this iteration: ``tau`` accepted draft tokens
followed by one bonus/corrected token. Functions are pure and jit-safe.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sampling

_EPS = 1e-30


class VerifyResult(NamedTuple):
    tokens: jax.Array        # (B, G+1) int32; valid prefix of length num_tokens
    num_accepted: jax.Array  # (B,) int32 — tau, number of accepted draft tokens
    num_tokens: jax.Array    # (B,) int32 — tau + 1 (accepted + bonus token)
    mod_remaining: jax.Array  # (B,) int32 — greedy only: positions whose target
    #                           distribution must be modified (Algorithm 5);
    #                           zero for token/block verification.


def _gather(probs: jax.Array, tokens: jax.Array) -> jax.Array:
    """probs (B, K, V), tokens (B, K) -> (B, K) probs of the given tokens."""
    return jnp.take_along_axis(probs, tokens[..., None], axis=-1)[..., 0]


def _row_at(x: jax.Array, idx: jax.Array) -> jax.Array:
    """x (B, K, V), idx (B,) -> (B, V) row x[b, idx[b]]."""
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def _assemble(
    draft_tokens: jax.Array, bonus: jax.Array, tau: jax.Array
) -> jax.Array:
    """Lay out [X_1..X_tau, Y, pad...] as an (B, G+1) int32 array."""
    b, g = draft_tokens.shape
    pos = jnp.arange(g + 1)[None, :]
    padded = jnp.concatenate(
        [draft_tokens, jnp.zeros((b, 1), draft_tokens.dtype)], axis=1
    )
    out = jnp.where(pos < tau[:, None], padded, 0)
    out = jnp.where(pos == tau[:, None], bonus[:, None], out)
    return out.astype(jnp.int32)


def _ratios(p_tok: jax.Array, q_tok: jax.Array) -> jax.Array:
    """M_b/M_s at the draft tokens; q == 0 (never drafted) -> ratio 0.

    A drafter cannot emit a zero-probability token, so q_tok == 0 only
    happens with adversarial inputs; following the paper's reference
    implementation (non-finite ratio => reject) we map it to ratio 0.
    """
    return jnp.where(q_tok > 0, p_tok / jnp.maximum(q_tok, _EPS), 0.0)


def token_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
) -> VerifyResult:
    """Algorithm 1: accept X_i independently w.p. min(1, p/q); stop at the
    first rejection; bonus token from the token residual (Eq. 2)."""
    b, g = draft_tokens.shape
    q_probs = q_probs.astype(jnp.float32)
    p_probs = p_probs.astype(jnp.float32)
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g))

    p_tok = _gather(p_probs[:, :g], draft_tokens)
    q_tok = _gather(q_probs, draft_tokens)
    ratio = _ratios(p_tok, q_tok)
    accept = u <= jnp.minimum(ratio, 1.0)
    # tau = number of leading accepts.
    tau = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)

    p_tau = _row_at(p_probs, tau)  # (B, V): M_b(.|c, X^tau)
    q_tau = _row_at(q_probs, jnp.minimum(tau, g - 1))
    residual = sampling.normalize(
        jnp.maximum(p_tau - q_tau, 0.0), fallback=p_tau
    )
    bonus_dist = jnp.where((tau == g)[:, None], p_tau, residual)
    bonus = sampling.categorical(key_y, bonus_dist)

    return VerifyResult(
        tokens=_assemble(draft_tokens, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        mod_remaining=jnp.zeros((b,), jnp.int32),
    )


def _block_ps(ratio: jax.Array) -> jax.Array:
    """p_i = min(p_{i-1} * r_i, 1) scan (Eq. 8). ratio (B, G) -> (B, G)."""
    b = ratio.shape[0]

    def step(p_prev, r_i):
        p_i = jnp.minimum(p_prev * r_i, 1.0)
        return p_i, p_i

    _, ps = jax.lax.scan(step, jnp.ones((b,), jnp.float32), ratio.T)
    return ps.T  # (B, G): p_1 .. p_G


def block_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
    residual_sums: Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
    | None = None,
) -> VerifyResult:
    """Algorithm 2 (the paper's contribution): block verification.

    ``residual_sums(p_scale, p_rows, q_rows) -> (B, K)`` optionally
    overrides the vocab reductions ``sum_x max(p_scale*P - Q, 0)`` with a
    fused implementation (the Pallas kernel in repro.kernels); the default
    is the pure-jnp expression.
    """
    b, g = draft_tokens.shape
    q_probs = q_probs.astype(jnp.float32)
    p_probs = p_probs.astype(jnp.float32)
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g))

    p_tok = _gather(p_probs[:, :g], draft_tokens)
    q_tok = _gather(q_probs, draft_tokens)
    ratio = _ratios(p_tok, q_tok)

    ps = _block_ps(ratio)                     # (B, G): p_1..p_G
    p_full = jnp.concatenate([jnp.ones((b, 1), jnp.float32), ps], axis=1)

    def _default_sums(p_scale, p_rows, q_rows):
        return jnp.sum(
            jnp.maximum(p_scale[..., None] * p_rows - q_rows, 0.0), axis=-1
        )

    sums = residual_sums or _default_sums
    # S_i for i = 0..G-1 : conditioning on X^i uses row i of p_probs/q_probs,
    # scaled by p_i (Eq. 4). Row G has no drafter distribution (no residual).
    s_all = sums(p_full[:, :g], p_probs[:, :g], q_probs)  # (B, G)

    # Acceptance probabilities h_i for i = 1..G (Eq. 4; h_G = p_G).
    p_i = ps[:, : g - 1]                      # p_1..p_{G-1}
    s_i = s_all[:, 1:g]                       # S_1..S_{G-1}
    h_mid = jnp.where(
        p_i >= 1.0, 1.0, s_i / jnp.maximum(s_i + 1.0 - p_i, _EPS)
    )
    h = jnp.concatenate([h_mid, ps[:, g - 1 :]], axis=1)  # (B, G): h_1..h_G

    accept = u <= h
    idx = jnp.arange(1, g + 1)[None, :]
    tau = jnp.max(jnp.where(accept, idx, 0), axis=1)  # longest accepted block

    # Bonus token: from M_b(.|X^G) when tau == G, else block residual (Eq. 3).
    p_tau_scale = jnp.take_along_axis(p_full, tau[:, None], axis=1)[:, 0]
    p_row = _row_at(p_probs, tau)
    q_row = _row_at(q_probs, jnp.minimum(tau, g - 1))
    residual = sampling.normalize(
        jnp.maximum(p_tau_scale[:, None] * p_row - q_row, 0.0), fallback=p_row
    )
    bonus_dist = jnp.where((tau == g)[:, None], p_row, residual)
    bonus = sampling.categorical(key_y, bonus_dist)

    return VerifyResult(
        tokens=_assemble(draft_tokens, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        mod_remaining=jnp.zeros((b,), jnp.int32),
    )


def greedy_block_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
) -> VerifyResult:
    """Algorithm 4 (Appendix C): greedy block verification.

    Accepts at least as many tokens as block verification in a single
    iteration (Thm 3) but is only lossless when the caller modifies the
    target distribution for the next ``mod_remaining`` positions according
    to Algorithm 5 (see ``modified_target_row``).
    """
    b, g = draft_tokens.shape
    q_probs = q_probs.astype(jnp.float32)
    p_probs = p_probs.astype(jnp.float32)
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g))

    p_tok = _gather(p_probs[:, :g], draft_tokens)
    q_tok = _gather(q_probs, draft_tokens)
    ratio = _ratios(p_tok, q_tok)
    # ptilde_i = prod_{j<=i} r_j, no clipping (Appendix C).
    ptilde = jnp.cumprod(ratio, axis=1)                      # (B, G): i=1..G
    ptilde_full = jnp.concatenate(
        [jnp.ones((b, 1), jnp.float32), ptilde], axis=1
    )

    # h_i for i = 1..G-1 (Algorithm 4 line 5).
    scale = ptilde[:, : g - 1, None]                         # ptilde_1..G-1
    p_rows = p_probs[:, 1:g]
    q_rows = q_probs[:, 1:g]
    num = jnp.sum(jnp.maximum(scale * p_rows - q_rows, 0.0), axis=-1)
    den = jnp.sum(jnp.maximum(q_rows - scale * p_rows, 0.0), axis=-1)
    h_mid = jnp.where(den > _EPS, num / jnp.maximum(den, _EPS), jnp.inf)
    h_last = jnp.minimum(ptilde[:, g - 1 :], 1.0)            # accept X^G step
    h = jnp.concatenate([h_mid, h_last], axis=1)

    accept = u <= h
    idx = jnp.arange(1, g + 1)[None, :]
    tau = jnp.max(jnp.where(accept, idx, 0), axis=1)

    pt_tau = jnp.take_along_axis(ptilde_full, tau[:, None], axis=1)[:, 0]
    p_row = _row_at(p_probs, tau)
    q_row = _row_at(q_probs, jnp.minimum(tau, g - 1))
    residual = sampling.normalize(
        jnp.maximum(pt_tau[:, None] * p_row - q_row, 0.0), fallback=p_row
    )
    bonus_dist = jnp.where((tau == g)[:, None], p_row, residual)
    bonus = sampling.categorical(key_y, bonus_dist)

    mod_remaining = jnp.where(tau == g, 0, g - tau - 1).astype(jnp.int32)
    return VerifyResult(
        tokens=_assemble(draft_tokens, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        mod_remaining=jnp.maximum(mod_remaining, 0),
    )


def modified_target_row(
    p_row: jax.Array, q_row: jax.Array
) -> jax.Array:
    """Algorithm 5 (Eq. 23): the modified target distribution used for the
    ``mod_remaining`` positions after a greedy-block-verification step:
    M_new ∝ max(M_b - M_s, 0), falling back to M_b when M_b == M_s."""
    return sampling.normalize(jnp.maximum(p_row - q_row, 0.0), fallback=p_row)


_VERIFIERS = {
    "token": token_verify,
    "block": block_verify,
    "greedy_block": greedy_block_verify,
}


def get_verifier(name: str):
    if name not in _VERIFIERS:
        raise ValueError(
            f"unknown verifier {name!r}; choose from {sorted(_VERIFIERS)}"
        )
    return _VERIFIERS[name]
