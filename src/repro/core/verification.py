"""Draft-verification algorithms for speculative decoding.

Implements, in batched JAX:

* ``token_verify``        — Algorithm 1 (Leviathan et al., 2022), the
                            standard independent per-token accept/reject.
* ``block_verify``        — Algorithm 2, the paper's contribution: joint
                            (coupled) verification of the whole block.
                            Lossless (Thm 1) and optimal (Thm 2).
* ``greedy_block_verify`` — Algorithm 4 (Appendix C): accepts more tokens
                            per iteration but requires the caller to apply
                            the distribution modification (Algorithm 5)
                            for the next ``gamma - tau - 1`` positions.

Shapes (``B`` = batch, ``G`` = gamma = draft length, ``V`` = vocab):

* ``draft_tokens``: ``(B, G)`` int32 — tokens sampled from the drafter.
* ``q_probs``:      ``(B, G, V)``    — drafter next-token distributions
                                       M_s(. | c, X^i) for i = 0..G-1.
* ``p_probs``:      ``(B, G+1, V)``  — target next-token distributions
                                       M_b(. | c, X^i) for i = 0..G.

All three return a :class:`VerifyResult` whose ``tokens[:, :num_tokens]``
are the decoded tokens for this iteration: ``tau`` accepted draft tokens
followed by one bonus/corrected token. Functions are pure and jit-safe.

Structure
---------
The inputs every algorithm needs — the gathered per-draft-token target /
drafter probabilities and their ratios — are computed once into a
:class:`VerifyContext` and shared. The heavy vocab reduction
``S = sum_v max(p_scale * P - Q, 0)`` (Eq. 3/4) is pluggable through the
**residual-sums backend registry**: ``"jnp"`` is the pure-XLA reference,
``"pallas"`` (registered by :mod:`repro.kernels.ops` on import) streams
the distributions through the fused TPU kernel. ``resolve_residual_sums``
picks the backend; the serving engine defaults to ``"auto"`` which routes
through the Pallas entry point whenever the kernels package is present.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sampling

_EPS = 1e-30

# (p_scale (B, K), p_rows (B, K, V), q_rows (B, K, V)) -> (B, K)
ResidualSums = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]


class VerifyResult(NamedTuple):
    tokens: jax.Array        # (B, G+1) int32; valid prefix of length num_tokens
    num_accepted: jax.Array  # (B,) int32 — tau, number of accepted draft tokens
    num_tokens: jax.Array    # (B,) int32 — tau + 1 (accepted + bonus token)
    mod_remaining: jax.Array  # (B,) int32 — greedy only: positions whose target
    #                           distribution must be modified (Algorithm 5);
    #                           zero for token/block verification.


class VerifyContext(NamedTuple):
    """Inputs shared by all three verification algorithms, computed once:
    float32 distributions plus the gathered per-draft-token probabilities
    and their M_b/M_s ratios."""

    draft_tokens: jax.Array  # (B, G) int32
    q_probs: jax.Array       # (B, G, V) float32
    p_probs: jax.Array       # (B, G+1, V) float32
    p_tok: jax.Array         # (B, G) — M_b at the draft tokens
    q_tok: jax.Array         # (B, G) — M_s at the draft tokens
    ratio: jax.Array         # (B, G) — M_b/M_s (0 where q_tok == 0)

    @property
    def gamma(self) -> int:
        return self.draft_tokens.shape[1]


def _gather(probs: jax.Array, tokens: jax.Array) -> jax.Array:
    """probs (B, K, V), tokens (B, K) -> (B, K) probs of the given tokens."""
    return jnp.take_along_axis(probs, tokens[..., None], axis=-1)[..., 0]


def _row_at(x: jax.Array, idx: jax.Array) -> jax.Array:
    """x (B, K, V), idx (B,) -> (B, V) row x[b, idx[b]]."""
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def _assemble(
    draft_tokens: jax.Array, bonus: jax.Array, tau: jax.Array
) -> jax.Array:
    """Lay out [X_1..X_tau, Y, pad...] as an (B, G+1) int32 array."""
    b, g = draft_tokens.shape
    pos = jnp.arange(g + 1)[None, :]
    padded = jnp.concatenate(
        [draft_tokens, jnp.zeros((b, 1), draft_tokens.dtype)], axis=1
    )
    out = jnp.where(pos < tau[:, None], padded, 0)
    out = jnp.where(pos == tau[:, None], bonus[:, None], out)
    return out.astype(jnp.int32)


def _ratios(p_tok: jax.Array, q_tok: jax.Array) -> jax.Array:
    """M_b/M_s at the draft tokens; q == 0 (never drafted) -> ratio 0.

    A drafter cannot emit a zero-probability token, so q_tok == 0 only
    happens with adversarial inputs; following the paper's reference
    implementation (non-finite ratio => reject) we map it to ratio 0.
    """
    return jnp.where(q_tok > 0, p_tok / jnp.maximum(q_tok, _EPS), 0.0)


def make_context(
    draft_tokens: jax.Array, q_probs: jax.Array, p_probs: jax.Array
) -> VerifyContext:
    """Build the shared verification context (one gather per model)."""
    g = draft_tokens.shape[1]
    q_probs = q_probs.astype(jnp.float32)
    p_probs = p_probs.astype(jnp.float32)
    p_tok = _gather(p_probs[:, :g], draft_tokens)
    q_tok = _gather(q_probs, draft_tokens)
    return VerifyContext(
        draft_tokens=draft_tokens,
        q_probs=q_probs,
        p_probs=p_probs,
        p_tok=p_tok,
        q_tok=q_tok,
        ratio=_ratios(p_tok, q_tok),
    )


# ---------------------------------------------------------------------------
# Residual-sums backend registry
# ---------------------------------------------------------------------------


def default_residual_sums(
    p_scale: jax.Array, p_rows: jax.Array, q_rows: jax.Array
) -> jax.Array:
    """Pure-jnp reference: ``sum_v max(p_scale * P - Q, 0)`` -> (B, K)."""
    return jnp.sum(
        jnp.maximum(p_scale[..., None] * p_rows - q_rows, 0.0), axis=-1
    )


_RESIDUAL_BACKENDS: dict[str, ResidualSums] = {"jnp": default_residual_sums}


def register_residual_backend(name: str, fn: ResidualSums) -> None:
    """Register a fused implementation of the Eq. 3/4 vocab reduction.
    ``repro.kernels.ops`` registers ``"pallas"`` (and its explicit
    interpret/compiled variants) on import."""
    _RESIDUAL_BACKENDS[name] = fn


def residual_backends() -> list[str]:
    return sorted(_RESIDUAL_BACKENDS)


def resolve_residual_sums(name: str = "auto") -> ResidualSums:
    """Resolve a backend name to a residual-sums callable.

    ``"auto"`` prefers the Pallas entry point in ``repro.kernels.ops``
    — which itself picks compiled-on-TPU vs XLA-reference-elsewhere —
    and falls back to ``"jnp"`` if the kernels package cannot be
    imported. ``None`` is deliberately NOT accepted here: in
    ``get_verifier``/``EngineConfig`` it means "plain jnp default",
    and silently auto-resolving it would invert that meaning.
    """
    if name is None:
        raise ValueError(
            "residual backend None means 'plain jnp default' at the "
            "verifier level; pass 'auto' (or an explicit backend) here"
        )
    if name == "auto":
        try:
            import repro.kernels.ops  # noqa: F401  (registers "pallas")
        except ImportError:
            return _RESIDUAL_BACKENDS["jnp"]
        return _RESIDUAL_BACKENDS.get("pallas", _RESIDUAL_BACKENDS["jnp"])
    if name not in _RESIDUAL_BACKENDS:
        # Late registration: the kernels module may simply not be imported.
        try:
            import repro.kernels.ops  # noqa: F401
        except ImportError:
            pass
    if name not in _RESIDUAL_BACKENDS:
        raise ValueError(
            f"unknown residual backend {name!r}; "
            f"choose from {residual_backends()} or 'auto'"
        )
    return _RESIDUAL_BACKENDS[name]


# ---------------------------------------------------------------------------
# Algorithm 1 — token verification
# ---------------------------------------------------------------------------


def token_verify_ctx(key: jax.Array, ctx: VerifyContext) -> VerifyResult:
    """Algorithm 1: accept X_i independently w.p. min(1, p/q); stop at the
    first rejection; bonus token from the token residual (Eq. 2)."""
    b, g = ctx.draft_tokens.shape
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g))

    accept = u <= jnp.minimum(ctx.ratio, 1.0)
    # tau = number of leading accepts.
    tau = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)

    p_tau = _row_at(ctx.p_probs, tau)  # (B, V): M_b(.|c, X^tau)
    q_tau = _row_at(ctx.q_probs, jnp.minimum(tau, g - 1))
    residual = sampling.normalize(
        jnp.maximum(p_tau - q_tau, 0.0), fallback=p_tau
    )
    bonus_dist = jnp.where((tau == g)[:, None], p_tau, residual)
    bonus = sampling.categorical(key_y, bonus_dist)

    return VerifyResult(
        tokens=_assemble(ctx.draft_tokens, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        mod_remaining=jnp.zeros((b,), jnp.int32),
    )


def token_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
) -> VerifyResult:
    return token_verify_ctx(key, make_context(draft_tokens, q_probs, p_probs))


# ---------------------------------------------------------------------------
# Algorithm 2 — block verification (the paper's contribution)
# ---------------------------------------------------------------------------


def _block_ps(ratio: jax.Array) -> jax.Array:
    """p_i = min(p_{i-1} * r_i, 1) scan (Eq. 8). ratio (B, G) -> (B, G)."""
    b = ratio.shape[0]

    def step(p_prev, r_i):
        p_i = jnp.minimum(p_prev * r_i, 1.0)
        return p_i, p_i

    _, ps = jax.lax.scan(step, jnp.ones((b,), jnp.float32), ratio.T)
    return ps.T  # (B, G): p_1 .. p_G


def block_verify_ctx(
    key: jax.Array,
    ctx: VerifyContext,
    residual_sums: ResidualSums | None = None,
) -> VerifyResult:
    """Algorithm 2: block verification over a shared context.

    ``residual_sums(p_scale, p_rows, q_rows) -> (B, K)`` overrides the
    vocab reductions ``sum_x max(p_scale*P - Q, 0)`` (e.g. with the fused
    Pallas kernel via the backend registry); default is the jnp reference.
    """
    b, g = ctx.draft_tokens.shape
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g))

    ps = _block_ps(ctx.ratio)                 # (B, G): p_1..p_G
    p_full = jnp.concatenate([jnp.ones((b, 1), jnp.float32), ps], axis=1)

    sums = residual_sums or default_residual_sums
    # S_i for i = 0..G-1 : conditioning on X^i uses row i of p_probs/q_probs,
    # scaled by p_i (Eq. 4). Row G has no drafter distribution (no residual).
    s_all = sums(p_full[:, :g], ctx.p_probs[:, :g], ctx.q_probs)  # (B, G)

    # Acceptance probabilities h_i for i = 1..G (Eq. 4; h_G = p_G).
    p_i = ps[:, : g - 1]                      # p_1..p_{G-1}
    s_i = s_all[:, 1:g]                       # S_1..S_{G-1}
    h_mid = jnp.where(
        p_i >= 1.0, 1.0, s_i / jnp.maximum(s_i + 1.0 - p_i, _EPS)
    )
    h = jnp.concatenate([h_mid, ps[:, g - 1 :]], axis=1)  # (B, G): h_1..h_G

    accept = u <= h
    idx = jnp.arange(1, g + 1)[None, :]
    tau = jnp.max(jnp.where(accept, idx, 0), axis=1)  # longest accepted block

    # Bonus token: from M_b(.|X^G) when tau == G, else block residual (Eq. 3).
    p_tau_scale = jnp.take_along_axis(p_full, tau[:, None], axis=1)[:, 0]
    p_row = _row_at(ctx.p_probs, tau)
    q_row = _row_at(ctx.q_probs, jnp.minimum(tau, g - 1))
    residual = sampling.normalize(
        jnp.maximum(p_tau_scale[:, None] * p_row - q_row, 0.0), fallback=p_row
    )
    bonus_dist = jnp.where((tau == g)[:, None], p_row, residual)
    bonus = sampling.categorical(key_y, bonus_dist)

    return VerifyResult(
        tokens=_assemble(ctx.draft_tokens, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        mod_remaining=jnp.zeros((b,), jnp.int32),
    )


def block_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
    residual_sums: ResidualSums | None = None,
) -> VerifyResult:
    return block_verify_ctx(
        key, make_context(draft_tokens, q_probs, p_probs),
        residual_sums=residual_sums,
    )


# ---------------------------------------------------------------------------
# Algorithm 4 — greedy block verification
# ---------------------------------------------------------------------------


def greedy_block_verify_ctx(
    key: jax.Array,
    ctx: VerifyContext,
    residual_sums: ResidualSums | None = None,
) -> VerifyResult:
    """Algorithm 4 (Appendix C): greedy block verification.

    Accepts at least as many tokens as block verification in a single
    iteration (Thm 3) but is only lossless when the caller modifies the
    target distribution for the next ``mod_remaining`` positions according
    to Algorithm 5 (see ``modified_target_row``).

    The h_i denominator ``sum_v max(Q - s*P, 0)`` is derived from the
    numerator through the exact identity
    ``sum max(Q - sP, 0) = sum max(sP - Q, 0) - (s - 1)`` (both P and Q
    sum to one), so one residual reduction — routable through the fused
    backend — serves both.
    """
    b, g = ctx.draft_tokens.shape
    key_u, key_y = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, g))

    # ptilde_i = prod_{j<=i} r_j, no clipping (Appendix C).
    ptilde = jnp.cumprod(ctx.ratio, axis=1)                  # (B, G): i=1..G
    ptilde_full = jnp.concatenate(
        [jnp.ones((b, 1), jnp.float32), ptilde], axis=1
    )

    # h_i for i = 1..G-1 (Algorithm 4 line 5).
    sums = residual_sums or default_residual_sums
    scale = ptilde[:, : g - 1]                               # ptilde_1..G-1
    num = sums(scale, ctx.p_probs[:, 1:g], ctx.q_probs[:, 1:g])
    den = jnp.maximum(num - scale + 1.0, 0.0)
    h_mid = jnp.where(den > _EPS, num / jnp.maximum(den, _EPS), jnp.inf)
    h_last = jnp.minimum(ptilde[:, g - 1 :], 1.0)            # accept X^G step
    h = jnp.concatenate([h_mid, h_last], axis=1)

    accept = u <= h
    idx = jnp.arange(1, g + 1)[None, :]
    tau = jnp.max(jnp.where(accept, idx, 0), axis=1)

    pt_tau = jnp.take_along_axis(ptilde_full, tau[:, None], axis=1)[:, 0]
    p_row = _row_at(ctx.p_probs, tau)
    q_row = _row_at(ctx.q_probs, jnp.minimum(tau, g - 1))
    residual = sampling.normalize(
        jnp.maximum(pt_tau[:, None] * p_row - q_row, 0.0), fallback=p_row
    )
    bonus_dist = jnp.where((tau == g)[:, None], p_row, residual)
    bonus = sampling.categorical(key_y, bonus_dist)

    mod_remaining = jnp.where(tau == g, 0, g - tau - 1).astype(jnp.int32)
    return VerifyResult(
        tokens=_assemble(ctx.draft_tokens, bonus, tau),
        num_accepted=tau,
        num_tokens=tau + 1,
        mod_remaining=jnp.maximum(mod_remaining, 0),
    )


def greedy_block_verify(
    key: jax.Array,
    draft_tokens: jax.Array,
    q_probs: jax.Array,
    p_probs: jax.Array,
    residual_sums: ResidualSums | None = None,
) -> VerifyResult:
    return greedy_block_verify_ctx(
        key, make_context(draft_tokens, q_probs, p_probs),
        residual_sums=residual_sums,
    )


def modified_target_row(
    p_row: jax.Array, q_row: jax.Array
) -> jax.Array:
    """Algorithm 5 (Eq. 23): the modified target distribution used for the
    ``mod_remaining`` positions after a greedy-block-verification step:
    M_new ∝ max(M_b - M_s, 0), falling back to M_b when M_b == M_s."""
    return sampling.normalize(jnp.maximum(p_row - q_row, 0.0), fallback=p_row)


# ---------------------------------------------------------------------------
# Verifier lookup
# ---------------------------------------------------------------------------

_VERIFIERS = {
    "token": token_verify,
    "block": block_verify,
    "greedy_block": greedy_block_verify,
}

_CTX_VERIFIERS = {
    "token": token_verify_ctx,
    "block": block_verify_ctx,
    "greedy_block": greedy_block_verify_ctx,
}


def get_verifier(name: str, residual_backend: str | None = None):
    """Return ``verify(key, draft_tokens, q_probs, p_probs)``.

    With ``residual_backend`` set (e.g. ``"auto"``, ``"pallas"``, ``"jnp"``)
    the block/greedy vocab reductions are bound to that backend; ``None``
    keeps the plain jnp default (back-compat).
    """
    if name not in _VERIFIERS:
        raise ValueError(
            f"unknown verifier {name!r}; choose from {sorted(_VERIFIERS)}"
        )
    fn = _VERIFIERS[name]
    if residual_backend is not None and name in ("block", "greedy_block"):
        fn = partial(fn, residual_sums=resolve_residual_sums(residual_backend))
    return fn


def get_ctx_verifier(name: str, residual_backend: str | None = None):
    """Context-based variant: ``verify(key, ctx)`` for callers that build a
    :class:`VerifyContext` themselves (the serving runner)."""
    if name not in _CTX_VERIFIERS:
        raise ValueError(
            f"unknown verifier {name!r}; choose from {sorted(_CTX_VERIFIERS)}"
        )
    fn = _CTX_VERIFIERS[name]
    if residual_backend is not None and name in ("block", "greedy_block"):
        fn = partial(fn, residual_sums=resolve_residual_sums(residual_backend))
    return fn
