"""Sampling primitives shared by the drafter, target and verification paths.

All functions are jit-friendly and operate on batched arrays. Probabilities
are float32; zero-probability entries are handled exactly (categorical
sampling goes through log-space with -inf for zeros).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def logits_to_probs(
    logits: jax.Array,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
) -> jax.Array:
    """Convert raw logits to a sampling distribution.

    temperature == 0.0 means greedy (a point mass on the argmax), matching
    the convention in the speculative-decoding literature.
    """
    logits = logits.astype(jnp.float32)
    if temperature == 0.0:
        am = jnp.argmax(logits, axis=-1)
        return jax.nn.one_hot(am, logits.shape[-1], dtype=jnp.float32)
    logits = logits / jnp.asarray(temperature, jnp.float32)
    if top_k and top_k > 0 and top_k < logits.shape[-1]:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, _NEG_INF, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        sorted_probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(sorted_probs, axis=-1)
        # Keep the smallest prefix with cumulative mass >= top_p.
        keep = cum - sorted_probs < top_p
        threshold = jnp.min(
            jnp.where(keep, sorted_logits, jnp.inf), axis=-1, keepdims=True
        )
        logits = jnp.where(logits < threshold, _NEG_INF, logits)
    return jax.nn.softmax(logits, axis=-1)


def categorical(key: jax.Array, probs: jax.Array) -> jax.Array:
    """Sample token ids from (possibly unnormalized) probability rows."""
    logp = jnp.log(jnp.maximum(probs, 0.0))
    return jax.random.categorical(key, logp, axis=-1)


def gumbel_argmax(key: jax.Array, probs: jax.Array) -> jax.Array:
    """Alias kept for clarity in kernels/serving code paths."""
    return categorical(key, probs)


def normalize(weights: jax.Array, fallback: jax.Array) -> jax.Array:
    """Normalize non-negative weights rows; rows with ~zero mass fall back.

    `fallback` must itself be a valid distribution (e.g. the target model
    row). Used for residual distributions where the residual mass can be
    exactly zero (drafter == target on that row).
    """
    z = jnp.sum(weights, axis=-1, keepdims=True)
    safe = weights / jnp.maximum(z, 1e-30)
    return jnp.where(z > 1e-12, safe, fallback)
