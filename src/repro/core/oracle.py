"""Tabular oracle language models.

An order-``k`` Markov model over a small vocabulary, stored as an explicit
conditional table ``(V**k, V)``. These make the paper's distributional
claims *exactly* checkable:

* closed-form expected accepted tokens per iteration for token / block /
  ideal verification (used to reproduce the Section 2 motivating example
  10/9 vs 11/9 vs 12/9 and to cross-check Monte-Carlo simulation);
* exact losslessness tests (the joint output distribution of speculative
  decoding can be compared against M_b^ell by enumeration).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from dataclasses import field as dataclass_field

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TabularLM:
    """Order-``order`` Markov LM: ``table[ctx_code]`` is the next-token
    distribution, where ``ctx_code`` encodes the last ``order`` tokens in
    base ``vocab`` (rolling)."""

    table: jax.Array  # (vocab**order, vocab) float32, rows sum to 1
    order: int = dataclass_field(metadata=dict(static=True))

    @property
    def vocab(self) -> int:
        return self.table.shape[-1]

    @property
    def n_contexts(self) -> int:
        return self.table.shape[0]

    def next_probs(self, ctx_code: jax.Array) -> jax.Array:
        """ctx_code (B,) int32 -> (B, V)."""
        return self.table[ctx_code]

    def advance(self, ctx_code: jax.Array, token: jax.Array) -> jax.Array:
        """Roll the context code forward by one token."""
        return (ctx_code * self.vocab + token) % self.n_contexts

    def sample(self, key: jax.Array, ctx_code: jax.Array) -> jax.Array:
        return jax.random.categorical(
            key, jnp.log(jnp.maximum(self.next_probs(ctx_code), 1e-30))
        )


def random_lm(key: jax.Array, vocab: int, order: int, concentration: float = 1.0) -> TabularLM:
    """Random Dirichlet conditional table."""
    n_ctx = vocab**order
    table = jax.random.dirichlet(
        key, jnp.full((vocab,), concentration), shape=(n_ctx,)
    )
    return TabularLM(table=table.astype(jnp.float32), order=order)


def perturbed_drafter(
    key: jax.Array, target: TabularLM, alpha: float, concentration: float = 1.0
) -> TabularLM:
    """A drafter of controllable quality: (1-alpha)*target + alpha*noise.

    ``alpha`` plays the role the paper sweeps via drafter size
    (PALM-2-XXS vs XXXS): smaller alpha = better drafter.
    """
    noise = jax.random.dirichlet(
        key, jnp.full((target.vocab,), concentration), shape=(target.n_contexts,)
    )
    table = (1.0 - alpha) * target.table + alpha * noise.astype(jnp.float32)
    table = table / jnp.sum(table, axis=-1, keepdims=True)
    return TabularLM(table=table, order=target.order)


def section2_models() -> tuple[TabularLM, TabularLM]:
    """The paper's Section 2 example: context-independent two-token models.
    M_b(A)=1/3, M_b(B)=2/3; M_s(A)=2/3, M_s(B)=1/3."""
    target = TabularLM(jnp.array([[1 / 3, 2 / 3]], jnp.float32), order=0)
    drafter = TabularLM(jnp.array([[2 / 3, 1 / 3]], jnp.float32), order=0)
    return target, drafter


# ---------------------------------------------------------------------------
# Closed-form expectations (enumeration over draft paths).
# ---------------------------------------------------------------------------


def _paths(vocab: int, length: int):
    return itertools.product(range(vocab), repeat=length)


def _path_probs(lm: TabularLM, ctx0: int, path) -> tuple[float, list[np.ndarray]]:
    """Joint probability of ``path`` under ``lm`` plus the conditional rows
    visited along it (rows at i = 0..len(path))."""
    table = np.asarray(lm.table, dtype=np.float64)
    ctx = ctx0
    prob = 1.0
    rows = []
    for tok in path:
        rows.append(table[ctx])
        prob *= float(table[ctx][tok])
        ctx = (ctx * lm.vocab + tok) % lm.n_contexts
    rows.append(table[ctx])
    return prob, rows


def exact_expected_accepted(
    target: TabularLM,
    drafter: TabularLM,
    gamma: int,
    kind: str,
    ctx0: int = 0,
) -> float:
    """E[tau] = sum_ell Pr(tau >= ell), enumerated over all draft paths.

    kind: 'token'  -> Pr(tau>=ell | X^ell) = prod_i min(1, r_i)
          'block'  -> Pr(tau>=ell | X^ell) = p_ell(X^ell)   (Lemma 3)
          'ideal'  -> sum_ell sum_{x^ell} min(M_s, M_b)      (Lemma 7/8;
                      equals the optimum over full-information couplings,
                      achieved per-iteration by greedy block verification)
    """
    assert target.vocab == drafter.vocab and target.order == drafter.order
    total = 0.0
    for ell in range(1, gamma + 1):
        for path in _paths(target.vocab, ell):
            qs_prob, q_rows = _path_probs(drafter, ctx0, path)
            pb_prob, p_rows = _path_probs(target, ctx0, path)
            if qs_prob <= 0.0:
                continue
            if kind == "token":
                acc = 1.0
                for i, tok in enumerate(path):
                    acc *= min(1.0, p_rows[i][tok] / q_rows[i][tok])
            elif kind == "block":
                acc = 1.0
                for i, tok in enumerate(path):
                    acc = min(acc * p_rows[i][tok] / q_rows[i][tok], 1.0)
            elif kind == "ideal":
                acc = min(1.0, pb_prob / qs_prob)
            else:
                raise ValueError(kind)
            total += qs_prob * acc
    return total


def exact_multipath_expected_accepted(
    target: TabularLM,
    drafter: TabularLM,
    gamma: int,
    num_paths: int,
    ctx0: int = 0,
) -> float:
    """E[tau] for greedy multi-path verification, enumerated exactly over
    all ``num_paths`` i.i.d. draft paths and all accept/reject branches.

    Independent float64 reimplementation of the rule in
    ``repro.core.verification.multipath_greedy_verify`` (per-position
    recursive residual rejection over the alive path set, greedy in path
    order) — the implementation-coupled marginalization lives in
    ``tests/test_lossless.py``; this closed form cross-checks it and the
    Monte-Carlo behaviour of the batched verifier.
    """
    assert target.vocab == drafter.vocab and target.order == drafter.order
    v = target.vocab
    t_tab = np.asarray(target.table, np.float64)
    d_tab = np.asarray(drafter.table, np.float64)
    t_tab = t_tab / t_tab.sum(-1, keepdims=True)
    d_tab = d_tab / d_tab.sum(-1, keepdims=True)
    n_ctx = target.n_contexts

    def rrs_tables(p_row, q_row, k):
        cs, zs = [0.0], [1.0]
        for _ in range(k):
            c = cs[-1] + zs[-1]
            cs.append(c)
            zs.append(float(np.maximum(p_row - c * q_row, 0.0).sum()))
        return cs, zs

    total = 0.0
    for paths in itertools.product(
        _paths(v, gamma), repeat=num_paths
    ):
        qprob = 1.0
        for path in paths:
            ctx = ctx0
            for tok in path:
                qprob *= d_tab[ctx][tok]
                ctx = (ctx * v + tok) % n_ctx
        if qprob <= 0.0:
            continue

        def walk(i, alive, ctx, mass):
            nonlocal total
            if i == gamma or mass == 0.0:
                return
            p_row, q_row = t_tab[ctx], d_tab[ctx]
            cs, zs = rrs_tables(p_row, q_row, len(alive))
            m, reach = 0, 1.0
            for j in alive:
                x = paths[j][i]
                u = max(p_row[x] - cs[m] * q_row[x], 0.0)
                # Z_m == 0 means the residual is exhausted (u == 0 for
                # every token): reject, like the JAX implementation.
                denom = zs[m] * q_row[x]
                a = min(1.0, u / denom) if denom > 0.0 else 0.0
                if a > 0.0:
                    branch = mass * reach * a
                    total += branch  # tau >= i + 1 along this branch
                    walk(
                        i + 1,
                        [l for l in alive if paths[l][i] == x],
                        (ctx * v + x) % n_ctx,
                        branch,
                    )
                reach *= 1.0 - a
                m += 1

        walk(0, list(range(num_paths)), ctx0, qprob)
    return total


def exact_output_distribution(
    target: TabularLM,
    drafter: TabularLM,
    gamma: int,
    length: int,
    verifier,
    n_samples: int,
    key: jax.Array,
) -> np.ndarray:
    """Monte-Carlo joint distribution of the first ``length`` output tokens
    of speculative decoding (one full SpecDec run per sample), flattened to
    a vector over vocab**length outcomes. Used by losslessness tests."""
    from repro.core import simulate  # local import to avoid cycle

    toks = simulate.specdec_rollout(
        key, target, drafter, gamma, verifier, n_samples, length
    )
    toks = np.asarray(toks)  # (n_samples, length)
    codes = np.zeros(n_samples, np.int64)
    for j in range(length):
        codes = codes * target.vocab + toks[:, j]
    counts = np.bincount(codes, minlength=target.vocab**length)
    return counts / n_samples


def target_joint_distribution(
    target: TabularLM, length: int, ctx0: int = 0
) -> np.ndarray:
    """Exact joint distribution of the first ``length`` tokens under M_b."""
    out = np.zeros(target.vocab**length)
    for code, path in enumerate(_paths(target.vocab, length)):
        prob, _ = _path_probs(target, ctx0, path)
        out[code] = prob
    return out
