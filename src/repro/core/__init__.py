"""Core: the paper's contribution (verification algorithms) + harnesses."""

from repro.core.verification import (  # noqa: F401
    VerifyContext,
    VerifyResult,
    block_verify,
    get_ctx_verifier,
    get_verifier,
    greedy_block_verify,
    make_context,
    register_residual_backend,
    residual_backends,
    resolve_residual_sums,
    token_verify,
)
