"""Core: the paper's contribution (verification algorithms) + harnesses."""

from repro.core.verification import (  # noqa: F401
    VerifyResult,
    block_verify,
    get_verifier,
    greedy_block_verify,
    token_verify,
)
