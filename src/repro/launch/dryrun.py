import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, print memory/cost analysis, extract roofline terms.

Must be run as a module (the XLA_FLAGS lines above execute before any jax
import): ``PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b
--shape train_4k --mesh single``. Results accumulate as JSON under
``results/dryrun/`` so the full sweep is resumable.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.launch import roofline, shapes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model import Model  # noqa: E402


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            variant: str = "base") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    cfg = registry.get_config(arch)
    model = Model(cfg)
    shape = shapes.SHAPES[shape_name]

    t0 = time.time()
    fn, args, in_shardings, out_shardings = shapes.build(
        model, mesh, shape_name, variant
    )
    # jax >= 0.5 spells this jax.set_mesh; the Mesh context manager is the
    # 0.4.x equivalent.
    mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    with mesh_ctx:
        lowered = jax.jit(
            fn, in_shardings=in_shardings, out_shardings=out_shardings
        ).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict]
        cost = cost[0]
    hlo = compiled.as_text()
    coll = roofline.collective_bytes(hlo)
    coll_total = sum(coll.values())

    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    terms = roofline.roofline_terms(flops, hbm_bytes, coll_total, n_chips)

    n_tokens = shape.global_batch * (
        shape.seq_len if shape.kind == "train" else
        shape.seq_len if shape.kind == "prefill" else shapes.GAMMA + 1
    )
    mflops = roofline.model_flops(cfg, n_tokens, train=shape.kind == "train")

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": variant,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll_total,
        "collectives": coll,
        "bytes_per_device": {
            "argument": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak": int(getattr(mem, "peak_memory_in_bytes", 0) or (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            )),
        },
        "model_flops": mflops,
        # cost_analysis flops are per-device; model_flops is global.
        "useful_flops_ratio": (
            mflops / (flops * n_chips) if flops else 0.0
        ),
        **terms,
    }

    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{rec['mesh']}__{variant}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    combos = shapes.pairs()
    if args.arch != "all":
        combos = [(a, s) for a, s in combos if a == args.arch]
    if args.shape != "all":
        combos = [(a, s) for a, s in combos if s == args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape_name in combos:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            fname = os.path.join(
                args.out, f"{arch}__{shape_name}__{mesh_name}__{args.variant}.json"
            )
            if args.skip_existing and os.path.exists(fname):
                print(f"SKIP {arch} {shape_name} {mesh_name}")
                continue
            try:
                rec = run_one(arch, shape_name, mp, args.out, args.variant)
                print(
                    f"OK   {arch:24s} {shape_name:12s} {mesh_name:6s} "
                    f"compile={rec['compile_s']:.0f}s "
                    f"peak/dev={rec['bytes_per_device']['peak']/2**30:.2f}GiB "
                    f"terms(c/m/x)="
                    f"{rec['compute_s']:.3e}/{rec['memory_s']:.3e}/"
                    f"{rec['collective_s']:.3e} -> {rec['bottleneck']}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape_name, mesh_name, repr(e)))
                print(f"FAIL {arch} {shape_name} {mesh_name}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
