"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table."""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir: str, variant: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if variant is None or r.get("variant") == variant:
            recs.append(r)
    return recs


def fmt_table(recs: list[dict], mesh: str = "single") -> str:
    """Analytic compute/memory terms + HLO-derived collective term (see
    roofline.py for why the HLO flops/bytes cannot be primary)."""
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["shape"], r["arch"]))
    out = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| roofline frac | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        c = r.get("analytic_compute_s", r["compute_s"])
        m = r.get("analytic_memory_s", r["memory_s"])
        x = r["collective_s"]
        frac = c / max(c, m, x)
        out.append(
            f"| {r['arch']} | {r['shape']} | {c:.3e} "
            f"| {m:.3e} | {x:.3e} "
            f"| **{r.get('bottleneck_analytic', r['bottleneck'])}** "
            f"| {frac:.3f} "
            f"| {r['bytes_per_device']['peak']/2**30:.2f} |"
        )
    return "\n".join(out)


def fmt_dryrun_summary(recs: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | chips | compile s | peak GiB/dev "
        "| HLO GFLOPs | coll MiB (ag/ar/rs/a2a/cp) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        c = r["collectives"]
        coll = "/".join(
            f"{c.get(k, 0)/2**20:.0f}"
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute")
        )
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_chips']} "
            f"| {r['compile_s']:.0f} "
            f"| {r['bytes_per_device']['peak']/2**30:.2f} "
            f"| {r['flops']/1e9:.0f} | {coll} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--kind", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.out, args.variant)
    if args.kind == "roofline":
        print(fmt_table(recs, args.mesh))
    else:
        print(fmt_dryrun_summary(recs))


if __name__ == "__main__":
    main()
