"""The four assigned input shapes and the per-(arch, shape) step builders.

Each builder returns (fn, input_specs, in_shardings) ready for
``jax.jit(fn, in_shardings=...).lower(*input_specs)``. No device arrays
are ever created — everything is ShapeDtypeStruct.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import verification
from repro.distributed import sharding as shd
from repro.models import drafter_of
from repro.models.model import Model
from repro.serving import paging
from repro.serving import runner as serving_runner
from repro.serving.batch import BatchState, StageState
from repro.serving.engine import EngineConfig
from repro.serving.runner import StepOutputs
from repro.training import optim
from repro.training import train as training
from repro.training.optim import OptConfig


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

# long_500k runs only for architectures with sub-quadratic context
# (see DESIGN.md §4): SSM state, sliding windows, or chunked attention.
LONG_OK = {
    "mamba2-370m", "zamba2-1.2b", "mixtral-8x22b",
    "llama4-scout-17b-a16e", "gemma2-9b",
}

GAMMA = 4          # draft length in the speculative serve step
SERVE_DTYPE = jnp.bfloat16

# ---------------------------------------------------------------------------
# Perf-iteration variants (EXPERIMENTS.md section Perf). "base" is the
# paper-faithful baseline; the others are hypothesis-driven changes.
# ---------------------------------------------------------------------------
VARIANTS: dict[str, dict] = {
    "base": {},
    # MoE dispatch via gather/scatter index tables instead of one-hot
    # dispatch einsums (kills the O(S*E*C) HBM traffic).
    "gather-moe": {"cfg": {"moe_impl": "gather"}},
    # Serving: replicate params across the data axes (no per-layer FSDP
    # all-gathers) — only valid when the model fits; applied to serve
    # steps of models < 4 GiB bf16.
    "replicated-serve": {"serve_fsdp": False},
    # MoE via jax.lax.ragged_dot grouped matmuls: exact top-k with no
    # capacity drops and no all-experts waste in the decode/verify path.
    "ragged-moe": {"cfg": {"moe_impl": "ragged"}},
    # Expert parallelism for MoE training: shard the expert dim over the
    # data axis (16 experts == 16 data shards for llama4) so expert-grad
    # reduction is local; tokens all-to-all to expert owners instead.
    "expert-parallel": {"experts_axis": "data"},
    # EP + gather dispatch.
    "ep-gather": {"experts_axis": "data", "cfg": {"moe_impl": "gather"}},
    # Serving small models: fully replicated params (no TP, no FSDP) —
    # pure data parallelism; kills the per-layer partial-sum all-reduces
    # that dominate the mamba2 decode step.
    "pure-dp-serve": {"serve_fsdp": False, "serve_tp": False},
    # Both.
    "combined": {"cfg": {"moe_impl": "gather"}, "serve_fsdp": False},
    # Serving through the paged KV pool: global-attention layers read
    # K/V via per-slot page tables (XLA gather path off-TPU), the page
    # pool shards (pages over data axes) and the in-step allocator ops
    # lower with the program — HLO bytes/collective accounting covers
    # the gather path, not just the dense-cache serve step.
    "paged-serve": {"serve_paged": True},
    # Disaggregated async prefill: lower the detached background
    # prefill program (runner.stage_prefill_body) over the staging
    # lanes instead of the decode step — the second executable of the
    # two-program serve loop, so its HLO bytes/collectives are
    # accounted separately from decode's.
    "async-prefill": {"serve_paged": True, "serve_async_stage": True},
    # Device-disaggregated prefill: carve the mesh into a prefill pod
    # and a decode pod (sharding.carve_pods along the data axis) and
    # lower the staging executable AGAINST THE PREFILL POD ONLY, over
    # the prefill pod's own (smaller) page pool — the decode pod
    # dispatches zero prefill programs by construction, which
    # test_launch asserts structurally off the returned shardings.
    "disagg-prefill": {
        "serve_paged": True, "serve_async_stage": True, "serve_disagg": True,
    },
}


def pairs():
    """All (arch, shape) dry-run combinations."""
    from repro.configs import registry

    out = []
    for arch in registry.ASSIGNED:
        for shape in SHAPES.values():
            if shape.name == "long_500k" and arch not in LONG_OK:
                continue
            out.append((arch, shape.name))
    return out


def _specs_like(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if not isinstance(x, jax.ShapeDtypeStruct) else x,
        tree,
    )


def _max_len_for(cfg, shape: ShapeCfg) -> int:
    # rounded so the cache sequence dim stays divisible by the data axes
    # (sequence-sharded caches for batch=1 long-context)
    need = shape.seq_len + GAMMA + 2
    return -(-need // 512) * 512


def _bf16_params(model: Model):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, SERVE_DTYPE),
        model.abstract_params(),
    )


def build_train_step(model: Model, mesh, shape: ShapeCfg, opts=None):
    """train_step(params, opt_state, batch, extras) with FSDP+TP sharding."""
    opts = opts or {}
    cfg = model.cfg.with_(max_seq=max(model.cfg.max_seq, shape.seq_len + 8),
                          **opts.get("cfg", {}))
    model = Model(cfg)
    opt_cfg = OptConfig(total_steps=1000)
    step = training.make_train_step(model, opt_cfg)

    p_shard = shd.param_shardings(
        model, mesh, experts_axis=opts.get("experts_axis")
    )
    opt_shard = optim.OptState(
        step=shd.replicated(mesh), mu=p_shard, nu=p_shard
    )
    bsh = shd.batch_sharding(mesh)
    batch_shard = {"tokens": bsh, "labels": bsh}

    params = model.abstract_params()
    opt_state = optim.OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=params, nu=params
    )
    batch = {
        "tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32
        ),
        "labels": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32
        ),
    }
    extras = model.extras_specs(shape.global_batch)
    extras_shard = {k: bsh for k in extras} or None
    args = (params, opt_state, batch, extras or None)
    shardings = (p_shard, opt_shard, batch_shard, extras_shard)
    rep = shd.replicated(mesh)
    out_shardings = (
        p_shard, opt_shard,
        {"loss": rep, "aux": rep, "grad_norm": rep},
    )
    return step, args, shardings, out_shardings


def build_prefill_step(model: Model, mesh, shape: ShapeCfg, opts=None):
    """Batched prefill: tokens (B, S) -> (last logits, filled cache)."""
    opts = opts or {}
    cfg = model.cfg.with_(max_seq=max(model.cfg.max_seq, shape.seq_len + 8),
                          **opts.get("cfg", {}))
    model = Model(cfg)
    max_len = _max_len_for(cfg, shape)

    def prefill(params, tokens, extras):
        cache = model.init_cache(
            tokens.shape[0], max_len, dtype=SERVE_DTYPE,
            chunk_slack=GAMMA + 1,
        )
        logits, cache, _ = model.apply(
            params, tokens, cache=cache, extras=extras, mode="prefill",
            last_logits_only=True,
        )
        return logits[:, -1], cache

    p_shard = shd.param_shardings(model, mesh)
    bsh = shd.batch_sharding(mesh)
    tokens = jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len), jnp.int32
    )
    extras = model.extras_specs(shape.global_batch, SERVE_DTYPE)
    extras_shard = {k: bsh for k in extras} or None
    args = (_bf16_params(model), tokens, extras or None)
    shardings = (p_shard, bsh, extras_shard)
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(
            shape.global_batch, max_len, SERVE_DTYPE, GAMMA + 1
        )
    )
    c_shard = shd.cache_shardings(model, mesh, cache_abs, shard_seq=False)
    out_shardings = (bsh, c_shard)
    return prefill, args, shardings, out_shardings


def build_serve_step(model: Model, mesh, shape: ShapeCfg, opts=None):
    """The speculative serve step (the paper's pipeline): one full
    iteration — drafter catch-up + draft, target verify chunk over the
    (seq_len)-token cache, block verification, commit."""
    opts = opts or {}
    cfg = model.cfg.with_(max_seq=max(model.cfg.max_seq, shape.seq_len + 8),
                          **opts.get("cfg", {}))
    model = Model(cfg)
    drafter = Model(
        drafter_of(cfg).with_(max_seq=cfg.max_seq)
    )
    b = shape.global_batch
    max_len = _max_len_for(cfg, shape)
    # residual_backend="jnp": the dry-run lowers for XLA cost/collective
    # analysis on host platforms; the fused Pallas path is exercised by the
    # serving engine and the kernels benches.
    # The default lowers the dense-cache serve step; the "paged-serve"
    # variant lowers the page-pool engine instead (gather path + in-step
    # allocator, pool sharded pages-over-data) so HLO bytes/collective
    # accounting covers both memory modes.
    paged = bool(opts.get("serve_paged", False))
    stage_async = bool(opts.get("serve_async_stage", False))
    disagg = bool(opts.get("serve_disagg", False))
    e_cfg = EngineConfig(
        gamma=GAMMA, verifier="block", max_slots=b, max_len=max_len,
        temperature=1.0, residual_backend="jnp", paged=paged,
        prefill_chunk=GAMMA + 1,  # page slack == the serve chunk slack
        async_prefill=stage_async, stage_slots=b, disaggregated=disagg,
    )
    verify = verification.get_ctx_verifier(
        e_cfg.verifier, residual_backend=e_cfg.residual_backend
    )
    page_spec = paging.spec_of(e_cfg)
    if disagg:
        # The disagg variant lowers the PREFILL POD's executable: carve
        # the pods (1/4 of the data axis prefills — an 8/24 split on the
        # fake 32-device mesh) and size everything to the prefill pod's
        # own staging pool. The decode pod's program is exactly the
        # paged-serve step on its own submesh — nothing prefill-shaped
        # lowers there.
        page_spec = paging.stage_spec_of(e_cfg)
        mesh, _decode_mesh = shd.carve_pods(
            mesh, max(1, mesh.shape["data"] // 4)
        )
    page_pool = (
        (page_spec.num_pages, page_spec.page_size)
        if page_spec is not None else None
    )
    shard_seq = b == 1  # long_500k: sequence-sharded caches

    def serve_step(t_params, d_params, t_cache, d_cache, batch, key):
        key = jax.random.wrap_key_data(key)
        return serving_runner.decode_body(
            model, drafter, e_cfg, verify,
            t_params, d_params, t_cache, d_cache, batch, key,
        )

    t_cache = jax.eval_shape(
        lambda: model.init_cache(
            b, max_len, SERVE_DTYPE, GAMMA + 1, page_pool=page_pool
        )
    )
    d_cache = jax.eval_shape(
        lambda: drafter.init_cache(
            b, max_len, SERVE_DTYPE, GAMMA + 1, page_pool=page_pool
        )
    )
    fsdp = opts.get("serve_fsdp", True)
    if opts.get("serve_tp", True):
        t_p = shd.param_shardings(model, mesh, fsdp=fsdp)
        d_p = shd.param_shardings(drafter, mesh, fsdp=fsdp)
    else:  # fully replicated params (pure data-parallel serving)
        rep_ = shd.replicated(mesh)
        t_p = jax.tree.map(lambda _: rep_, model.abstract_params())
        d_p = jax.tree.map(lambda _: rep_, drafter.abstract_params())
    cache_tp = opts.get("serve_tp", True)
    t_c = shd.cache_shardings(
        model, mesh, t_cache, shard_seq=shard_seq, tp=cache_tp
    )
    d_c = shd.cache_shardings(
        drafter, mesh, d_cache, shard_seq=shard_seq, tp=cache_tp
    )
    bsh = shd.batch_sharding(mesh)
    rep = shd.replicated(mesh)
    b_or_rep = bsh if b > 1 else rep

    slot_i32 = jax.ShapeDtypeStruct((b,), jnp.int32)
    slot_bool = jax.ShapeDtypeStruct((b,), jnp.bool_)
    table_spec = table_shard = used_spec = used_shard = None
    pool_spec = pool_shard = None
    if page_spec is not None:
        # Page tables follow the slot dim like seq_buf; the free list /
        # refcounts are tiny bookkeeping arrays, replicated (the pooled
        # K/V itself shards pages-over-data via cache_shardings).
        table_spec = jax.ShapeDtypeStruct(
            (b, page_spec.max_pages), jnp.int32
        )
        table_shard = b_or_rep
        used_spec, used_shard = slot_i32, rep
        pool_spec = paging.PagePool(
            free_stack=jax.ShapeDtypeStruct(
                (page_spec.num_pages,), jnp.int32
            ),
            free_count=jax.ShapeDtypeStruct((), jnp.int32),
            ref=jax.ShapeDtypeStruct((page_spec.num_pages,), jnp.int32),
            cached=jax.ShapeDtypeStruct((page_spec.num_pages,), jnp.bool_),
            staged=jax.ShapeDtypeStruct((page_spec.num_pages,), jnp.bool_),
        )
        pool_shard = paging.PagePool(
            free_stack=rep, free_count=rep, ref=rep, cached=rep, staged=rep
        )
    if stage_async:
        # The async-prefill variant lowers the DETACHED background
        # prefill program over the staging lanes (one lane per batch
        # row here): StageState follows the batch dim like seq_buf,
        # the shared pool's bookkeeping stays replicated (pooled K/V
        # itself shards pages-over-data via cache_shardings).
        def stage_step(t_params, d_params, t_cache_, d_cache_, stage, pool):
            return serving_runner.stage_prefill_body(
                model, drafter, e_cfg, page_spec,
                t_params, d_params, t_cache_, d_cache_, stage, pool,
            )

        stage_specs = StageState(
            seq_buf=jax.ShapeDtypeStruct((b, max_len), jnp.int32),
            plen=slot_i32, pos=slot_i32,
            active=slot_bool, ready=slot_bool, hold=slot_bool,
            page_table=table_spec, pages_used=used_spec,
        )
        stage_shard = StageState(
            seq_buf=b_or_rep, plen=rep, pos=rep, active=rep, ready=rep,
            hold=rep, page_table=table_shard, pages_used=rep,
        )
        args = (
            _bf16_params(model), _bf16_params(drafter),
            t_cache, d_cache, stage_specs, pool_spec,
        )
        shardings = (t_p, d_p, t_c, d_c, stage_shard, pool_shard)
        out_shardings = (t_c, d_c, stage_shard, pool_shard)
        return stage_step, args, shardings, out_shardings
    batch_specs = BatchState(
        seq_buf=jax.ShapeDtypeStruct((b, max_len), jnp.int32),
        lens=slot_i32, d_lens=slot_i32, t_pref=slot_i32,
        active=slot_bool, ready=slot_bool, hold=slot_bool,
        out_start=slot_i32, max_new=slot_i32,
        page_table=table_spec, pages_used=used_spec, pool=pool_spec,
    )
    batch_shard = BatchState(
        seq_buf=b_or_rep, lens=rep, d_lens=rep, t_pref=rep,
        active=rep, ready=rep, hold=rep, out_start=rep, max_new=rep,
        page_table=table_shard, pages_used=used_shard, pool=pool_shard,
    )
    args = (
        _bf16_params(model), _bf16_params(drafter),
        t_cache, d_cache, batch_specs,
        jax.ShapeDtypeStruct((2,), jnp.uint32),          # key (raw)
    )
    shardings = (t_p, d_p, t_c, d_c, batch_shard, rep)
    out_shardings = (
        t_c, d_c, batch_shard,
        StepOutputs(tokens=b_or_rep, n_keep=rep, num_tokens=rep, done=rep),
    )
    return serve_step, args, shardings, out_shardings


def build(model: Model, mesh, shape_name: str, variant: str = "base"):
    shape = SHAPES[shape_name]
    opts = VARIANTS[variant]
    if shape.kind == "train":
        return build_train_step(model, mesh, shape, opts)
    if shape.kind == "prefill":
        return build_prefill_step(model, mesh, shape, opts)
    return build_serve_step(model, mesh, shape, opts)
