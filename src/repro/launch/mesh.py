"""Production mesh construction (see MULTI-POD DRY-RUN in the brief).

A function, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py sets
XLA_FLAGS for 512 host devices).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_disaggregated_meshes(
    n_prefill: int, *, model: int = 1, devices=None
):
    """Carve the device set into a (prefill mesh, decode mesh) pair for
    ``EngineConfig(disaggregated=True)``: the first ``n_prefill``
    devices become the prefill pod, the rest the decode pod, each
    reshaped ``(pod_size // model, model)`` over ``("data", "model")``
    axes. The two pods are disjoint by construction, so the staging
    prefill executable and the decode executable never contend for a
    chip — the N:M prefill:decode provisioning ratio is just
    ``n_prefill`` against the remainder."""
    from jax.sharding import Mesh
    import numpy as np

    devices = list(jax.devices() if devices is None else devices)
    if not 0 < n_prefill < len(devices):
        raise ValueError(
            f"n_prefill={n_prefill} must split {len(devices)} devices "
            "into two non-empty pods"
        )
    pods = []
    for group in (devices[:n_prefill], devices[n_prefill:]):
        if len(group) % model:
            raise ValueError(
                f"pod of {len(group)} devices not divisible by "
                f"model={model}"
            )
        arr = np.asarray(group).reshape(len(group) // model, model)
        pods.append(Mesh(arr, ("data", "model")))
    return tuple(pods)


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
