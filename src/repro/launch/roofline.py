"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), all in seconds:

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

``cost_analysis()`` provides FLOPs/bytes; collective bytes are parsed from
the optimized HLO text by summing operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops.
"""

from __future__ import annotations

import re

from repro.launch import mesh as mesh_consts

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=(]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)


def _tensor_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op, by kind.

    '-start' variants are counted once ('-done' carries no shape work);
    output bytes are the standard proxy for data moved per participant.
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # skip the -done halves (they would double count)
        line = m.group(0)
        if f"{kind}-done(" in line:
            continue
        out[kind] += _tensor_bytes(shape_str)
    return out


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    n_chips: int,
    links_per_chip: int = 4,
) -> dict:
    """NOTE: XLA's ``cost_analysis()`` on a partitioned module reports
    PER-DEVICE flops/bytes, and HLO shapes are post-partition, so the
    collective bytes parsed from the text are per-device too. The terms
    are therefore per-chip step times directly — no further division by
    ``n_chips``."""
    del n_chips
    compute_s = flops / mesh_consts.PEAK_FLOPS_BF16
    memory_s = hbm_bytes / mesh_consts.HBM_BW
    collective_s = coll_bytes / (links_per_chip * mesh_consts.ICI_BW)
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    terms["bottleneck"] = max(terms, key=terms.get).replace("_s", "")
    return terms


def model_flops(cfg, n_tokens: int, train: bool) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful-FLOPs estimate
    (2*N*D forward-only for serving steps), GLOBAL across chips."""
    n = active_param_count(cfg)
    mult = 6.0 if train else 2.0
    return mult * float(n) * n_tokens


def analytic_costs(cfg, shape, n_chips: int, gamma: int = 4,
                   ragged_moe: bool = False, moe_impl: str = "einsum") -> dict:
    """Analytic per-chip flops / HBM bytes for one step.

    Why analytic: XLA's ``cost_analysis()`` counts while-loop (lax.scan)
    bodies ONCE, not x trip-count, so scan-over-layers models report
    ~1/n_layers of their real flops/bytes (a finding documented in
    EXPERIMENTS.md). Collectives mostly operate on full stacked tensors
    outside the loops, so the HLO-parsed collective bytes stay valid.

    Model (documented approximations):
      flops  = matmul flops (6ND train / 2ND serve, MoE active-only,
               all-experts for the drop-free decode scoring path unless
               ``ragged_moe``) + attention O(S_eff) scores;
      bytes  = param-shard traffic (params read + grad/opt update for
               train; read-per-step for serve) + KV/state cache traffic +
               activation I/O at 2 bytes/elem.
    """
    from repro.models.model import Model
    from repro.models.common import drafter_of as _drafter_of

    train = shape.kind == "train"
    b = shape.global_batch
    s = shape.seq_len
    par_bytes = 4 if train else 2

    def one_model(c, tokens, scoring_all_experts):
        n_active = active_param_count(c)
        n_total = Model(c).param_count()
        n_eff = n_active
        if c.n_experts and scoring_all_experts and not ragged_moe:
            n_eff = n_total  # drop-free all-experts scoring path
        mult = 6.0 if train else 2.0
        flops = mult * n_eff * tokens
        # attention scores: 4 flops per (q, kv) pair per head-dim element
        # (QK^T + PV), x3 for the backward pass in training; causal /
        # windowed kv length averaged as min(S, window) (upper bound).
        if c.n_heads:
            kv_eff = sum(
                min(s, c.window_of(i)) if c.window_of(i) > 0 else s
                for i in range(c.n_layers)
            )
            # kv_eff already sums over layers
            flops += (3.0 if train else 1.0) * 4.0 * tokens * kv_eff * (
                c.n_heads * c.hd
            )
        bytes_params = n_total * par_bytes * (3.0 if train else 1.0)
        return flops, bytes_params

    t_tokens = b * (s if train or shape.kind == "prefill" else gamma + 1)
    flops, pbytes = one_model(cfg, t_tokens, shape.kind == "decode")
    if shape.kind == "decode":  # speculative step includes the drafter
        d_cfg = _drafter_of(cfg)
        d_flops, d_bytes = one_model(d_cfg, b * 2 * gamma, False)
        flops += d_flops
        pbytes += d_bytes
    # cache traffic (decode reads the whole cache once per step)
    cache_bytes = 0.0
    if shape.kind == "decode":
        kv_eff = 0.0
        if cfg.n_heads:
            for i in range(cfg.n_layers):
                w = cfg.window_of(i)
                kv_eff += min(s, w) if w > 0 else s
            cache_bytes += 2 * b * kv_eff * cfg.n_kv * cfg.hd * 2
        if cfg.ssm_state:
            n_m = cfg.n_layers if cfg.family == "ssm" else (
                cfg.n_layers - cfg.n_layers // max(cfg.hybrid_attn_every, 1)
                if cfg.hybrid_attn_every else cfg.n_layers
            )
            cache_bytes += (
                2 * b * n_m * cfg.ssm_heads * cfg.ssm_head_dim
                * cfg.ssm_state * (gamma + 1) * 2
            )
    # activation I/O: ~12 tensor touches of (tokens, d_model) per layer
    act_bytes = 12.0 * t_tokens * cfg.d_model * cfg.n_layers * 2
    if train:
        act_bytes *= 3.0
    # MoE dispatch traffic (train/prefill): the einsum path reads+writes
    # the O(B*S*E*C) one-hot dispatch AND combine tensors; the gather path
    # only moves the (E*C) index tables and gathered activations.
    moe_bytes = 0.0
    if cfg.n_experts and shape.kind != "decode":
        c_cap = cfg.capacity_factor * s * cfg.top_k / cfg.n_experts
        per_layer = (
            4.0 * b * s * cfg.n_experts * c_cap * 4      # dispatch+combine
            if moe_impl == "einsum"
            else 4.0 * b * cfg.n_experts * c_cap * cfg.d_model * 2
        )
        moe_bytes = per_layer * cfg.n_layers * (3.0 if train else 1.0)
    total_bytes = pbytes + cache_bytes + act_bytes + moe_bytes
    return {
        "analytic_flops_per_chip": flops / n_chips,
        "analytic_bytes_per_chip": total_bytes / n_chips,
        "analytic_compute_s": flops / n_chips / mesh_consts.PEAK_FLOPS_BF16,
        "analytic_memory_s": total_bytes / n_chips / mesh_consts.HBM_BW,
    }


def active_param_count(cfg) -> int:
    """Parameters touched per token (MoE counts top_k of n_experts)."""
    from repro.models.model import Model

    model = Model(cfg)
    total = model.param_count()
    if cfg.n_experts and cfg.top_k:
        # expert FFN params scale down by top_k / n_experts
        expert = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts * cfg.n_layers
        if cfg.mlp != "swiglu":
            expert = 2 * cfg.d_model * cfg.d_ff * cfg.n_experts * cfg.n_layers
        total = total - expert + expert * cfg.top_k // cfg.n_experts
    return total
