"""Section 2 motivating example: exact expected accepted tokens
(10/9 token, 11/9 block, 12/9 ideal) + Monte-Carlo confirmation."""

from __future__ import annotations

import jax

from repro.core import oracle, simulate


def run(quick: bool = True):
    rows = []
    target, drafter = oracle.section2_models()
    for kind, paper in [("token", 10 / 9), ("block", 11 / 9), ("ideal", 12 / 9)]:
        exact = oracle.exact_expected_accepted(target, drafter, 2, kind)
        rows.append(
            {
                "name": f"motivating/{kind}",
                "exact_E_accepted": round(exact, 6),
                "paper_value": round(paper, 6),
                "match": abs(exact - paper) < 1e-6,
            }
        )
    n = 20_000 if quick else 200_000
    for name in ["token", "block"]:
        be = float(
            simulate.block_efficiency(
                jax.random.key(0), target, drafter, 2, name,
                batch=n, n_iters=16,
            )
        )
        rows.append({"name": f"motivating/mc_{name}", "block_efficiency": round(be, 4)})
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
