"""Benchmark driver: one function per paper table/figure.

``python -m benchmarks.run``            -- quick pass (CI-sized)
``python -m benchmarks.run --full``     -- paper-sized statistics
``python -m benchmarks.run --only table1``
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        gamma_sweep, greedy_table3, kernels_bench, motivating, table1,
        wallclock,
    )

    suites = {
        "motivating": motivating.run,        # paper Section 2
        "table1": table1.run,                # paper Table 1 (block efficiency)
        "gamma_sweep": gamma_sweep.run,      # paper Figures 3/4
        "greedy_table3": greedy_table3.run,  # paper Table 3 (Appendix C)
        "wallclock": wallclock.run,          # paper Table 1 (wall clock)
        "kernels": kernels_bench.run,        # kernel/verifier microbench
        "kernels_paged": kernels_bench.run_paged,  # paged vs dense attn
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    failures = 0
    for name, fn in suites.items():
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        try:
            for row in fn(quick=quick):
                print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"BENCH FAILURE {name}: {e!r}", flush=True)
        print(f"== {name} done in {time.time()-t0:.1f}s ==\n", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
