"""Paper Table 3: block efficiency of token vs block vs greedy block
verification (gamma=8), greedy with the faithful Algorithm-5/6 nested
distribution modification."""

from __future__ import annotations

import numpy as np
import jax

from benchmarks import common
from repro.core import simulate


def run(quick: bool = True, gamma: int = 8):
    batch, iters = (256, 24) if quick else (1024, 64)
    rows = []
    agg = {"token": [], "block": [], "greedy_block": []}
    for ds in common.DATASETS:
        target, draft = common.dataset_pair(ds, "XXS")
        bes = {}
        for name in agg:
            be = float(simulate.block_efficiency(
                jax.random.key(1), target, draft, gamma, name,
                batch=batch, n_iters=iters,
            ))
            bes[name] = be
            agg[name].append(be)
        rows.append({
            "name": f"table3/{ds}",
            "tokenv": round(bes["token"], 3),
            "blockv": round(bes["block"], 3),
            "greedy": round(bes["greedy_block"], 3),
        })
    rows.append({
        "name": "table3/ordering",
        "avg_token": round(float(np.mean(agg["token"])), 3),
        "avg_greedy": round(float(np.mean(agg["greedy_block"])), 3),
        "avg_block": round(float(np.mean(agg["block"])), 3),
        "paper_ordering_token_le_greedy_le_block": bool(
            np.mean(agg["token"]) - 0.05
            <= np.mean(agg["greedy_block"])
            <= np.mean(agg["block"]) + 0.05
        ),
    })
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
