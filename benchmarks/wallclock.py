"""Wall-clock speedup (paper Table 1 right half): byte-level char-LM pair
trained in-repo, served on CPU with the real engine. Reports tokens/s for
autoregressive baseline vs SpecDec with token / block / greedy
multi-path (num_paths=2, CoW-forked page tables) verification, plus a
repeated-prefix workload measuring the cross-request prefix cache (hit
rate + prefill-token savings), and writes the machine-readable
``results/BENCH_serving.json`` artifact the perf trajectory tracks
across PRs — including the per-step allocation telemetry (pool
occupancy + preemption counts per decode step) the over-subscription
policies are tuned from. ``run_prefix_smoke`` is the CI entry point
that refreshes only the prefix-cache section.

Checkpoints are cached under results/charlm/ so repeated benchmark runs
skip training.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import zlib

import jax
import numpy as np

from repro.configs import registry
from repro.data import pipeline
from repro.data.synthetic import generate_prompts
from repro.data.tokenizer import ByteTokenizer
from repro.models import Model
from repro.serving.baseline import autoregressive_decode
from repro.serving.engine import EngineConfig, SpecEngine
from repro.serving.faults import (
    SITE_ALLOC_DENY,
    SITE_NONFINITE_LOGITS,
    SITE_POD_DISPATCH,
    SITE_TRANSFER_DELAY,
    SITE_TRANSFER_LOSS,
    FaultPlan,
)
from repro.serving.frontend import (
    ServingFrontend,
    _poisson_arrivals,
    replay_open_loop,
)
from repro.training import checkpoint
from repro.training import train as training
from repro.training.optim import OptConfig

CKPT_DIR = "results/charlm"


def _get_models(train_steps: int = 300):
    tgt = Model(registry.get_config("charlm-target"))
    drf = Model(registry.get_config("charlm-drafter"))
    out = {}
    for tag, model, steps in [
        ("target", tgt, train_steps), ("drafter", drf, train_steps),
    ]:
        path = os.path.join(CKPT_DIR, tag)
        # zlib.crc32 is a stable digest; builtin hash() is salted per
        # process, which made init (and thus every cache-miss run)
        # nondeterministic across invocations.
        like = model.init(jax.random.key(zlib.crc32(tag.encode()) % 2**31))
        if os.path.exists(os.path.join(path, "params.npz")):
            try:
                out[tag] = checkpoint.load(path, like)
                continue
            except ValueError:
                pass
        data = pipeline.batches(
            seed=0, batch_size=8, seq_len=96, n_steps=steps
        )
        params, hist = training.train(
            model, data, n_steps=steps, params=like,
            opt_cfg=OptConfig(lr=1e-3, warmup=20, total_steps=steps),
        )
        checkpoint.save(path, params, {"loss": hist[-1]["loss"]})
        out[tag] = params
    return tgt, drf, out["target"], out["drafter"]


def run(quick: bool = True, gamma: int = 4, temperature: float = 0.8):
    """NOTE on the baseline comparison: this container is CPU (compute
    bound), so a verify chunk of gamma+1 tokens costs ~(gamma+1)x one
    decode step and SpecDec cannot beat plain AR in absolute tokens/s —
    that speedup needs memory-bound accelerator serving (the dry-run /
    roofline sections cover the TPU side). What IS hardware-independent
    is the token-vs-block comparison: identical pipelines differing only
    in the verification algorithm, which is the paper's contribution."""
    n_prompts, max_new, seeds = (10, 80, (0, 1)) if quick else (12, 96, (0, 1, 2))
    steps = 200 if quick else 400
    tgt, drf, tp, dp = _get_models(steps)
    tok = ByteTokenizer()
    prompts = [
        tok.encode(p)[:24] for p in generate_prompts(1, n_prompts)
    ]

    # autoregressive baseline
    _, base_wall = autoregressive_decode(
        tgt, tp, prompts, max_new, temperature=temperature, max_len=256
    )
    base_tps = n_prompts * max_new / base_wall

    rows = [{
        "name": "wallclock/baseline_ar",
        "tokens_per_s": round(base_tps, 1),
        "speedup": 1.0,
    }]
    results = {}
    bench = {
        "bench": "serving",
        "config": {
            "gamma": gamma, "temperature": temperature,
            "n_prompts": n_prompts, "max_new_tokens": max_new,
            "seeds": list(seeds), "train_steps": steps,
            "target_params": tgt.param_count(),
            "drafter_params": drf.param_count(),
            # Engine memory mode: tokens/s comparisons across PRs must
            # not conflate paging changes with verifier changes.
            "paged": EngineConfig.paged,
            "page_size": EngineConfig.page_size,
            "num_pages": EngineConfig.num_pages,
            "prefix_cache": EngineConfig.prefix_cache,
        },
        "baseline_ar": {"tokens_per_s": base_tps},
        "verifiers": {},
    }
    # (report name, engine kwargs): the multipath entry serves the same
    # workload through K=2 CoW-forked draft paths per slot.
    runs = [
        ("token", dict(verifier="token")),
        ("block", dict(verifier="block")),
        ("multipath_k2", dict(verifier="block", num_paths=2)),
    ]
    for name, kwargs in runs:
        cfg = EngineConfig(
            gamma=gamma, max_slots=n_prompts,
            max_len=256, temperature=temperature, max_new_tokens=max_new,
            **kwargs,
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        # warm compile with a throwaway request
        eng.submit(prompts[0], max_new_tokens=2)
        eng.run()
        wall = acc = iters = tokens = 0.0
        alloc_steps, preemptions = [], 0
        for seed in seeds:
            eng.reset(seed=seed)
            for p in prompts:
                eng.submit(p)
            out = eng.run()
            wall += eng.last_stats["wall_s"]
            acc += sum(r.accepted_total for r in out.values())
            iters += sum(r.iterations for r in out.values())
            tokens += sum(len(r.output) for r in out.values())
            # Concatenate seed runs into one monotone series: offset
            # step numbers and the cumulative preemption counter by the
            # previous runs' totals so the per-step curve never jumps
            # backwards across seed boundaries.
            step0 = alloc_steps[-1]["step"] if alloc_steps else 0
            alloc_steps.extend(
                {**s, "step": s["step"] + step0,
                 "preemptions": s["preemptions"] + preemptions}
                for s in eng.last_stats["alloc_trace"]
            )
            preemptions += eng.last_stats["preemptions"]
        be = (acc + iters) / iters
        tps = tokens / wall
        results[name] = (tps, be)
        bench["verifiers"][name] = {
            "num_paths": cfg.num_paths,
            "tokens_per_s": tps,
            "block_efficiency": be,
            "acceptance_rate": acc / (iters * gamma) if iters else 0.0,
            "cpu_speedup_vs_ar": tps / base_tps if base_tps else 0.0,
            # Per-step allocation telemetry (host-mirror pool occupancy;
            # preemptions are cumulative within each seed's run).
            "alloc": _summarize_alloc(alloc_steps, preemptions),
        }
        rows.append({
            "name": f"wallclock/spec_{name}",
            "tokens_per_s": round(tps, 1),
            "cpu_speedup": round(tps / base_tps, 2),
            "block_efficiency": round(be, 3),
            # memory-bound accelerator model: one verify chunk ~ one decode
            # step; drafter cost ~ gamma * (drafter/target param ratio).
            "modeled_tpu_speedup": round(
                be / (1.0 + gamma * drf.param_count() / tgt.param_count()), 2
            ),
        })
    # Repeated-prefix workload: the chat-system-prompt traffic pattern
    # the cross-request prefix cache exists for.
    bench["prefix_cache"], pc_row = _prefix_cache_bench(
        tgt, drf, tp, dp, gamma=gamma, temperature=temperature,
        max_new=max_new // 2,
    )
    rows.append(pc_row)
    # Mixed cold-prompt workload: what disaggregated async prefill buys.
    bench["async_prefill"], ap_row = _async_prefill_bench(
        tgt, drf, tp, dp, gamma=gamma, max_new=32,
    )
    rows.append(ap_row)
    # Same-burst workload: what live prefix sharing buys.
    bench["live_share"], ls_row = _live_share_bench(
        tgt, drf, tp, dp, gamma=gamma, max_new=24,
    )
    rows.append(ls_row)
    # Dual-pod workload: page transfer at adoption vs shared-pool flip.
    bench["disagg"], dg_row = _disagg_bench(
        tgt, drf, tp, dp, gamma=gamma, max_new=32,
    )
    rows.append(dg_row)
    if results["token"][0] > 0:
        bench["block_over_token"] = {
            "wallclock_pct": (
                results["block"][0] / results["token"][0] - 1
            ) * 100,
            "be_improve_pct": (
                results["block"][1] / results["token"][1] - 1
            ) * 100,
        }
        rows.append({
            "name": "wallclock/block_over_token_pct",
            "wallclock_pct": round(
                (results["block"][0] / results["token"][0] - 1) * 100, 2
            ),
            "be_improve_pct": round(
                (results["block"][1] / results["token"][1] - 1) * 100, 2
            ),
            "paper_range_pct": "5-8 (wall clock), 7-10 (BE)",
        })
    _write_bench(bench)
    return rows


def _prefix_cache_bench(
    tgt, drf, tp, dp, gamma: int, temperature: float, max_new: int,
    n_prompts: int = 8, shared_tokens: int = 32,
):
    """Serve a repeated-prefix workload (every prompt opens with the same
    ``shared_tokens``-token system preamble, served twice) with the
    prefix cache off and on. Reports the hit rate and the prefill-token
    savings — the quantities ``results/BENCH_serving.json`` tracks for
    the cache across PRs."""
    tok = ByteTokenizer()
    preamble = tok.encode(
        "system: you are a concise byte-level assistant. answer briefly. "
    )[:shared_tokens]
    assert len(preamble) == shared_tokens
    prompts = [
        preamble + tok.encode(p)[:12]
        for p in generate_prompts(3, n_prompts)
    ]
    out = {}
    for pc in (False, True):
        cfg = EngineConfig(
            gamma=gamma, verifier="block", max_slots=2, max_len=256,
            temperature=temperature, max_new_tokens=max_new,
            page_size=16, prefix_cache=pc,
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        eng.submit(prompts[0], max_new_tokens=2)  # warm compile
        eng.run()
        eng.reset(seed=0)
        prefill = tokens = wall = hits = misses = 0
        for _round in range(2):  # the second pass re-serves every prompt
            for p in prompts:
                eng.submit(p)
            res = eng.run()
            prefill += eng.last_stats["prefill_tokens"]
            tokens += sum(len(r.output) for r in res.values())
            wall += eng.last_stats["wall_s"]
            pcs = eng.last_stats.get("prefix_cache")
            if pcs is not None:
                hits += pcs["hits"]
                misses += pcs["misses"]
        out[pc] = dict(
            prefill=prefill, tokens=tokens, wall=wall,
            hits=hits, misses=misses,
        )
    hit_rate = out[True]["hits"] / max(out[True]["hits"]
                                       + out[True]["misses"], 1)
    saved_pct = (1 - out[True]["prefill"] / out[False]["prefill"]) * 100
    bench = {
        "workload": {
            "n_prompts": n_prompts, "rounds": 2,
            "shared_prefix_tokens": shared_tokens,
            "max_new_tokens": max_new,
        },
        "prefix_cache_hit_rate": hit_rate,
        "prefill_tokens": out[True]["prefill"],
        "prefill_tokens_uncached": out[False]["prefill"],
        "prefill_tokens_saved_pct": saved_pct,
        "tokens_per_s": out[True]["tokens"] / out[True]["wall"],
        "tokens_per_s_uncached": out[False]["tokens"] / out[False]["wall"],
    }
    row = {
        "name": "wallclock/prefix_cache",
        "hit_rate": round(hit_rate, 3),
        "prefill_saved_pct": round(saved_pct, 1),
        "tokens_per_s": round(bench["tokens_per_s"], 1),
    }
    return bench, row


def _async_prefill_bench(
    tgt, drf, tp, dp, gamma: int, max_new: int,
    n_cold: int = 4, warm_per_cold: int = 3,
    cold_tokens: int = 160, warm_tokens: int = 8,
    max_slots: int = 4, repeats: int = 3,
):
    """Serve a mixed cold-prompt workload — each long uncached prompt
    followed by a stream of short warm ones, several times more
    requests than decode slots — through the serial and the
    disaggregated engine at identical configs (temperature 0, so both
    must commit bit-identical tokens). In the serial engine every cold
    admission squats a decode slot for its whole multi-chunk prefill
    AND injects its chunks into the decode loop, so decode iterations
    run with half-empty batches; the async engine prefills cold
    prompts in the staging lane, keeping all ``max_slots`` decode
    lanes full of ready warm work. Reports
    decode tokens/s (aggregate + per-request mean), mean TTFT with its
    queue/prefill/decode breakdown, the lane-interaction counters
    (``prefill_stall_steps`` vs ``overlap_steps``), and the
    deterministic program-dispatch counts (the async engine needs
    FEWER decode iterations — fuller batches — and fewer prefill
    dispatches — the staging lanes batch cold chunks the serial
    engine's squatted decode slots serialize) — the quantities the
    ``async_prefill`` section of ``results/BENCH_serving.json`` tracks
    across PRs.

    Timing protocol: both engines are measured in ``repeats``
    ALTERNATING trials and every timing metric independently reports
    its best trial (max throughput / min latency) — wall clock on
    shared runners is noisy in ways that can dwarf the effect, and
    best-of-N interleaved is the standard robust estimator (both
    engines face the same environment drift). Dispatch counts and the
    bit-identity check are trial-invariant."""
    tok = ByteTokenizer()
    n_warm = n_cold * warm_per_cold
    warm_txt = generate_prompts(5, n_warm)
    cold_txt = generate_prompts(7, n_cold)
    prompts = []
    wi = 0
    for i in range(n_cold):
        # Repeat the seed text however often its length requires —
        # generate_prompts can emit lines as short as 8 chars, so a
        # fixed repetition count cannot guarantee cold_tokens bytes.
        base = tok.encode(cold_txt[i] + " ")
        cold = (base * (cold_tokens // len(base) + 1))[:cold_tokens]
        assert len(cold) == cold_tokens
        prompts.append(cold)                              # cold, long
        for _ in range(warm_per_cold):                    # warm stream
            prompts.append(tok.encode(warm_txt[wi])[:warm_tokens])
            wi += 1
    engines = {}
    for async_p in (False, True):
        cfg = EngineConfig(
            gamma=gamma, verifier="block", max_slots=max_slots,
            max_len=256, temperature=0.0, max_new_tokens=max_new,
            prefill_chunk=8, async_prefill=async_p, stage_slots=2,
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        eng.submit(prompts[0], max_new_tokens=2)  # warm compile
        eng.run()
        engines[async_p] = eng

    def trial(async_p):
        eng = engines[async_p]
        eng.reset(seed=0)
        rids = [eng.submit(p) for p in prompts]
        res = eng.run()
        metrics = eng.request_metrics()
        stats = eng.last_stats
        return {
            "outputs": [res[r].output for r in rids],
            "decode_tokens_per_s": stats["tokens"] / stats["wall_s"],
            "request_decode_tps_mean": _mean(
                [m["tokens_per_s"] for m in metrics]
            ),
            "ttft_mean_s": _mean([m["ttft_s"] for m in metrics]),
            "ttft_queue_mean_s": _mean([m["ttft_queue_s"] for m in metrics]),
            "ttft_prefill_mean_s": _mean(
                [m["ttft_prefill_s"] for m in metrics]
            ),
            "ttft_decode_mean_s": _mean(
                [m["ttft_decode_s"] for m in metrics]
            ),
            "decode_iterations": stats["iterations"],
            "prefill_steps": stats["prefill_steps"],
            "prefill_stall_steps": stats["prefill_stall_steps"],
            "overlap_steps": stats["overlap_steps"],
            "adoptions": stats["adoptions"],
        }

    trials = {False: [], True: []}
    for _ in range(repeats):
        for async_p in (False, True):
            trials[async_p].append(trial(async_p))
    # Per-metric robust selection: every timing metric independently
    # takes its best trial (max for throughput, min for latency) — a
    # single hiccup inside one engine's fastest-overall trial must not
    # poison an unrelated gated metric.
    t_max = ("decode_tokens_per_s", "request_decode_tps_mean")
    t_min = ("ttft_mean_s", "ttft_queue_mean_s",
             "ttft_prefill_mean_s", "ttft_decode_mean_s")
    out = {}
    for async_p in (False, True):
        runs = trials[async_p]
        # Deterministic quantities must not vary across trials.
        for r in runs[1:]:
            assert r["outputs"] == runs[0]["outputs"]
            assert r["decode_iterations"] == runs[0]["decode_iterations"]
            assert r["prefill_steps"] == runs[0]["prefill_steps"]
        best = dict(runs[0])
        for k in t_max:
            best[k] = max(r[k] for r in runs)
        for k in t_min:
            best[k] = min(r[k] for r in runs)
        out[async_p] = best
    # The disaggregation must be invisible in the tokens (temperature 0).
    assert out[True]["outputs"] == out[False]["outputs"], (
        "async prefill changed committed tokens"
    )
    bench = {
        "workload": {
            "n_cold": n_cold, "n_warm": n_warm,
            "cold_prompt_tokens": cold_tokens,
            "warm_prompt_tokens": warm_tokens,
            "max_new_tokens": max_new,
            "max_slots": max_slots, "stage_slots": 2,
        },
        "bit_identical": True,
        "timing_repeats": repeats,
        "serial": {k: v for k, v in out[False].items() if k != "outputs"},
        "async": {k: v for k, v in out[True].items() if k != "outputs"},
        "decode_tokens_per_s_gain": (
            out[True]["decode_tokens_per_s"]
            / out[False]["decode_tokens_per_s"]
        ),
        "ttft_mean_gain": (
            out[False]["ttft_mean_s"] / out[True]["ttft_mean_s"]
        ),
        # Deterministic (timing-independent) structural wins: fuller
        # decode batches -> fewer decode iterations for the same
        # tokens; staging lanes batch cold chunks -> fewer prefill
        # dispatches.
        "decode_iterations_saved": (
            out[False]["decode_iterations"] - out[True]["decode_iterations"]
        ),
        "prefill_dispatches_saved": (
            out[False]["prefill_steps"] - out[True]["prefill_steps"]
        ),
    }
    row = {
        "name": "wallclock/async_prefill",
        "decode_tps_serial": round(out[False]["decode_tokens_per_s"], 1),
        "decode_tps_async": round(out[True]["decode_tokens_per_s"], 1),
        "ttft_serial_s": round(out[False]["ttft_mean_s"], 3),
        "ttft_async_s": round(out[True]["ttft_mean_s"], 3),
        "overlap_steps": out[True]["overlap_steps"],
    }
    return bench, row


def _disagg_bench(
    tgt, drf, tp, dp, gamma: int, max_new: int,
    n_cold: int = 4, warm_per_cold: int = 3,
    cold_tokens: int = 160, warm_tokens: int = 8,
    max_slots: int = 4, repeats: int = 3,
):
    """Serve the mixed cold-prompt workload through the shared-pool
    async engine and the device-disaggregated engine (prefill pod /
    decode pod, page transfer at adoption) at identical configs,
    temperature 0. The two engines run the SAME two-program schedule —
    disaggregation only changes WHERE the staging executable runs and
    how its pages reach the decode pool (explicit pack → device_put →
    unpack instead of a mask flip on a shared pool) — so committed
    tokens must be bit-identical and the transfer schedule must be
    deterministic. Reports decode tokens/s, mean TTFT with the 4-part
    breakdown (queue / prefill / transfer / decode), the per-pod
    program-dispatch counts, and the transfer telemetry
    (``transfers`` / ``transfer_bytes``) — the quantities the
    ``disagg`` section of ``results/BENCH_serving.json`` tracks across
    PRs.

    Structural gate: the disagg engine's decode-iteration count can
    never exceed the async engine's on this workload — staging lanes no
    longer charge the decode pool before adoption, so the decode
    scheduler only ever sees MORE headroom. Timing gates use best-of-N
    alternating trials, as in :func:`_async_prefill_bench`."""
    tok = ByteTokenizer()
    n_warm = n_cold * warm_per_cold
    warm_txt = generate_prompts(5, n_warm)
    cold_txt = generate_prompts(7, n_cold)
    prompts = []
    wi = 0
    for i in range(n_cold):
        base = tok.encode(cold_txt[i] + " ")
        cold = (base * (cold_tokens // len(base) + 1))[:cold_tokens]
        assert len(cold) == cold_tokens
        prompts.append(cold)
        for _ in range(warm_per_cold):
            prompts.append(tok.encode(warm_txt[wi])[:warm_tokens])
            wi += 1
    engines = {}
    for disagg in (False, True):
        cfg = EngineConfig(
            gamma=gamma, verifier="block", max_slots=max_slots,
            max_len=256, temperature=0.0, max_new_tokens=max_new,
            prefill_chunk=8, async_prefill=True, stage_slots=2,
            disaggregated=disagg,
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        eng.submit(prompts[0], max_new_tokens=2)  # warm compile
        eng.run()
        engines[disagg] = eng

    def trial(disagg):
        eng = engines[disagg]
        eng.reset(seed=0)
        rids = [eng.submit(p) for p in prompts]
        res = eng.run()
        metrics = eng.request_metrics()
        stats = eng.last_stats
        return {
            "outputs": [res[r].output for r in rids],
            "decode_tokens_per_s": stats["tokens"] / stats["wall_s"],
            "ttft_mean_s": _mean([m["ttft_s"] for m in metrics]),
            "ttft_transfer_mean_s": _mean(
                [m["ttft_transfer_s"] for m in metrics]
            ),
            "decode_iterations": stats["iterations"],
            "prefill_steps": stats["prefill_steps"],
            "overlap_steps": stats["overlap_steps"],
            "adoptions": stats["adoptions"],
            "transfers": stats["transfers"],
            "transfer_bytes": stats["transfer_bytes"],
            "preemptions": stats["preemptions"],
        }

    trials = {False: [], True: []}
    for _ in range(repeats):
        for disagg in (False, True):
            trials[disagg].append(trial(disagg))
    out = {}
    for disagg in (False, True):
        runs = trials[disagg]
        # Deterministic quantities must not vary across trials — the
        # transfer schedule in particular is a pure function of the
        # admission order, never of device timing.
        for r in runs[1:]:
            assert r["outputs"] == runs[0]["outputs"]
            assert r["decode_iterations"] == runs[0]["decode_iterations"]
            assert r["transfers"] == runs[0]["transfers"]
            assert r["transfer_bytes"] == runs[0]["transfer_bytes"]
        best = dict(runs[0])
        best["decode_tokens_per_s"] = max(
            r["decode_tokens_per_s"] for r in runs
        )
        for k in ("ttft_mean_s", "ttft_transfer_mean_s"):
            vals = [r[k] for r in runs if r[k] is not None]
            best[k] = min(vals) if vals else None
        out[disagg] = best
    # Moving prefill to its own pod must be invisible in the tokens.
    assert out[True]["outputs"] == out[False]["outputs"], (
        "disaggregation changed committed tokens"
    )
    # Every adoption in the disagg engine rode a completed transfer.
    assert out[True]["transfers"] == out[True]["adoptions"], out[True]
    assert out[False]["transfers"] == 0, out[False]
    bench = {
        "workload": {
            "n_cold": n_cold, "n_warm": n_warm,
            "cold_prompt_tokens": cold_tokens,
            "warm_prompt_tokens": warm_tokens,
            "max_new_tokens": max_new,
            "max_slots": max_slots, "stage_slots": 2,
        },
        "bit_identical": True,
        "timing_repeats": repeats,
        "n_devices": jax.device_count(),
        "async": {k: v for k, v in out[False].items() if k != "outputs"},
        "disagg": {k: v for k, v in out[True].items() if k != "outputs"},
        "decode_tokens_per_s_ratio": (
            out[True]["decode_tokens_per_s"]
            / out[False]["decode_tokens_per_s"]
        ),
        # Structural invariant (deterministic): the disagg decode pool
        # never pays for staging pages, so its scheduler can only pack
        # batches at least as full as the shared-pool engine's.
        "decode_iterations_saved": (
            out[False]["decode_iterations"] - out[True]["decode_iterations"]
        ),
    }
    row = {
        "name": "wallclock/disagg",
        "decode_tps_async": round(out[False]["decode_tokens_per_s"], 1),
        "decode_tps_disagg": round(out[True]["decode_tokens_per_s"], 1),
        "transfers": out[True]["transfers"],
        "transfer_bytes": out[True]["transfer_bytes"],
        "ttft_transfer_s": out[True]["ttft_transfer_mean_s"],
    }
    return bench, row


def _live_share_bench(
    tgt, drf, tp, dp, gamma: int, max_new: int,
    n_prompts: int = 8, prompt_tokens: int = 65,
    max_slots: int = 4, page_size: int = 8, repeats: int = 2,
):
    """Serve a same-burst workload — ``n_prompts`` IDENTICAL cold
    prompts submitted together, the thundering-herd traffic pattern
    live prefix sharing exists for — with ``live_share`` off and on,
    through both the serial and the disaggregated engine (all four at
    ``prefix_cache=True``, temperature 0). ``prompt_tokens - 1`` is a
    page multiple, so the whole consumable prompt is shareable and the
    burst costs exactly ONE prefill's worth of tokens with sharing on.

    The gated quantities are deterministic program-dispatch counts:
    prefill tokens strictly reduced in both engines (down to exactly
    ``prompt_tokens - 1``), prefill dispatches strictly reduced in the
    async engine (staging waves overlap decode, so the unshared engine
    cannot reuse wave-1 pages it has not parked yet) and never
    increased in the serial engine (serial prefill batches all slots
    into the same dispatches, so step counts tie), and committed
    tokens bit-identical across all four engines. p50/p95 TTFT per
    mode (best of ``repeats`` alternating trials) is reported for the
    trajectory, not gated — wall clock on shared runners is noisy."""
    tok = ByteTokenizer()
    base = tok.encode(generate_prompts(9, 1)[0] + " ")
    prompt = (base * (prompt_tokens // len(base) + 1))[:prompt_tokens]
    assert len(prompt) == prompt_tokens
    assert (prompt_tokens - 1) % page_size == 0
    engines = {}
    for async_p in (False, True):
        for live in (False, True):
            cfg = EngineConfig(
                gamma=gamma, verifier="block", max_slots=max_slots,
                max_len=256, temperature=0.0, max_new_tokens=max_new,
                prefill_chunk=16, page_size=page_size,
                prefix_cache=True, live_share=live,
                async_prefill=async_p, stage_slots=2,
            )
            eng = SpecEngine(tgt, drf, tp, dp, cfg)
            eng.submit(prompt, max_new_tokens=2)  # warm compile
            eng.run()
            engines[async_p, live] = eng

    def trial(async_p, live):
        eng = engines[async_p, live]
        eng.reset(seed=0)
        rids = [eng.submit(list(prompt)) for _ in range(n_prompts)]
        res = eng.run()
        stats = eng.last_stats
        ttfts = [m["ttft_s"] for m in eng.request_metrics()]
        return {
            "outputs": [res[r].output for r in rids],
            "prefill_tokens": stats["prefill_tokens"],
            "prefill_steps": stats["prefill_steps"],
            "live_hits": stats["prefix_cache"]["live_hits"],
            "cache_hits": stats["prefix_cache"]["hits"],
            "decode_tokens_per_s": stats["tokens"] / stats["wall_s"],
            "ttft_p50_s": _pctl(ttfts, 0.50),
            "ttft_p95_s": _pctl(ttfts, 0.95),
        }

    trials = {k: [] for k in engines}
    for _ in range(repeats):
        for k in engines:
            trials[k].append(trial(*k))
    out = {}
    for k, runs in trials.items():
        for r in runs[1:]:  # deterministic quantities never vary
            assert r["outputs"] == runs[0]["outputs"]
            assert r["prefill_tokens"] == runs[0]["prefill_tokens"]
            assert r["prefill_steps"] == runs[0]["prefill_steps"]
        best = dict(runs[0])
        best["decode_tokens_per_s"] = max(
            r["decode_tokens_per_s"] for r in runs
        )
        for key in ("ttft_p50_s", "ttft_p95_s"):
            best[key] = min(r[key] for r in runs)
        out[k] = best
    # Sharing must be invisible in the committed tokens (temperature 0).
    first = out[False, False]["outputs"]
    assert all(v["outputs"] == first for v in out.values()), (
        "live sharing changed committed tokens"
    )
    modes = {}
    for async_p, name in ((False, "serial"), (True, "async")):
        ref, live = out[async_p, False], out[async_p, True]
        modes[name] = {
            "ref": {k: v for k, v in ref.items() if k != "outputs"},
            "live": {k: v for k, v in live.items() if k != "outputs"},
            "prefill_tokens_saved": (
                ref["prefill_tokens"] - live["prefill_tokens"]
            ),
            "prefill_dispatches_saved": (
                ref["prefill_steps"] - live["prefill_steps"]
            ),
        }
    bench = {
        "workload": {
            "n_prompts": n_prompts, "prompt_tokens": prompt_tokens,
            "identical_prompts": True, "max_new_tokens": max_new,
            "max_slots": max_slots, "stage_slots": 2,
            "page_size": page_size,
        },
        "bit_identical": True,
        "timing_repeats": repeats,
        # one prefill's worth for the whole burst
        "shared_span_tokens": prompt_tokens - 1,
        **modes,
    }
    row = {
        "name": "wallclock/live_share",
        "prefill_tokens_ref": out[False, False]["prefill_tokens"],
        "prefill_tokens_live": out[False, True]["prefill_tokens"],
        "async_dispatches_saved": modes["async"][
            "prefill_dispatches_saved"
        ],
        "ttft_p95_live_s": round(out[False, True]["ttft_p95_s"], 3),
    }
    return bench, row


def _pctl(xs, q):
    xs = sorted(x for x in xs if x is not None)
    if not xs:
        return None
    return xs[min(int(round(q * (len(xs) - 1))), len(xs) - 1)]


def _mean(xs):
    xs = [x for x in xs if x is not None]
    return sum(xs) / len(xs) if xs else None


def run_async_smoke(train_steps: int = 120):
    """CI smoke: train (or load) the char-LM pair, run ONLY the mixed
    cold-prompt workload, and refresh the ``async_prefill`` section of
    ``results/BENCH_serving.json`` in place. Fails if the async engine's
    decode throughput under concurrent prefill regresses below the
    serial engine's, if mean TTFT stops improving, or if the engines
    diverge token-wise (asserted inside the bench)."""
    tgt, drf, tp, dp = _get_models(train_steps)
    bench_ap, row = _async_prefill_bench(tgt, drf, tp, dp, gamma=4, max_new=32)
    # Regression-gate BEFORE touching the tracked artifact. The
    # structural gates are deterministic (program-dispatch counts don't
    # depend on the runner's timing noise); the timing gates use
    # min-of-N alternating trials with a small slack factor.
    assert bench_ap["decode_iterations_saved"] > 0, bench_ap
    assert bench_ap["prefill_dispatches_saved"] > 0, bench_ap
    assert bench_ap["async"]["overlap_steps"] > 0, bench_ap
    assert bench_ap["async"]["prefill_stall_steps"] == 0, bench_ap
    assert bench_ap["decode_tokens_per_s_gain"] >= 0.97, bench_ap
    assert bench_ap["ttft_mean_gain"] >= 0.97, bench_ap
    path = "results/BENCH_serving.json"
    bench = {"bench": "serving"}
    if os.path.exists(path):
        with open(path) as f:
            bench = json.load(f)
    bench["async_prefill"] = bench_ap
    _write_bench(bench, path)
    return row


def run_live_share_smoke(train_steps: int = 120):
    """CI smoke: train (or load) the char-LM pair, run ONLY the
    same-burst workload, and refresh the ``live_share`` section of
    ``results/BENCH_serving.json`` in place. Fails if live sharing
    stops strictly reducing prefill tokens (in either engine) down to
    one prefill's worth for the burst, stops strictly reducing prefill
    dispatches in the async engine (or increases them in the serial
    one), stops hitting live spans, or perturbs committed tokens
    (bit-identity is asserted inside the bench)."""
    tgt, drf, tp, dp = _get_models(train_steps)
    bench_ls, row = _live_share_bench(tgt, drf, tp, dp, gamma=4, max_new=24)
    # Regression-gate BEFORE touching the tracked artifact; every gate
    # is a deterministic dispatch/token count, immune to runner noise.
    for mode in ("serial", "async"):
        m = bench_ls[mode]
        assert m["prefill_tokens_saved"] > 0, (mode, m)
        assert m["live"]["prefill_tokens"] == (
            bench_ls["shared_span_tokens"]
        ), (mode, m)
        assert m["prefill_dispatches_saved"] >= 0, (mode, m)
        assert m["live"]["live_hits"] > 0, (mode, m)
    assert bench_ls["async"]["prefill_dispatches_saved"] > 0, bench_ls
    path = "results/BENCH_serving.json"
    bench = {"bench": "serving"}
    if os.path.exists(path):
        with open(path) as f:
            bench = json.load(f)
    bench["live_share"] = bench_ls
    _write_bench(bench, path)
    return row


def run_disagg_smoke(train_steps: int = 120):
    """CI smoke: train (or load) the char-LM pair, run ONLY the
    dual-pod workload, and refresh the ``disagg`` section of
    ``results/BENCH_serving.json`` in place. Fails if the
    disaggregated engine perturbs committed tokens (bit-identity is
    asserted inside the bench), if the transfer schedule stops being
    deterministic across trials (also asserted inside), if the decode
    pod starts paying iterations for staging (decode iterations must
    never exceed the shared-pool async engine's), if an adoption ever
    lands without a completed page transfer, or if decode throughput
    regresses materially. Intended to run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` so the two
    pods are distinct (fake CPU) devices; it degrades gracefully to a
    single device (same schedule, same gates — only the device_put
    becomes a no-op copy)."""
    tgt, drf, tp, dp = _get_models(train_steps)
    bench_dg, row = _disagg_bench(tgt, drf, tp, dp, gamma=4, max_new=32)
    # Regression-gate BEFORE touching the tracked artifact. Structural
    # gates are deterministic dispatch/transfer counts; the throughput
    # gate uses best-of-N alternating trials with slack for runner
    # noise (the pack/device_put/unpack work is real extra compute on
    # one CPU, but it overlaps decode — it must never cost more than a
    # small constant factor).
    assert bench_dg["decode_iterations_saved"] >= 0, bench_dg
    assert bench_dg["disagg"]["transfers"] > 0, bench_dg
    assert bench_dg["disagg"]["transfer_bytes"] > 0, bench_dg
    assert bench_dg["disagg"]["overlap_steps"] > 0, bench_dg
    assert bench_dg["decode_tokens_per_s_ratio"] >= 0.90, bench_dg
    path = "results/BENCH_serving.json"
    bench = {"bench": "serving"}
    if os.path.exists(path):
        with open(path) as f:
            bench = json.load(f)
    bench["disagg"] = bench_dg
    _write_bench(bench, path)
    return row


def run_prefix_smoke(train_steps: int = 120):
    """CI smoke: train (or load) the char-LM pair, run ONLY the
    repeated-prefix workload, and refresh the ``prefix_cache`` section
    of ``results/BENCH_serving.json`` in place (other sections are
    preserved so the smoke job never clobbers the full bench rows)."""
    tgt, drf, tp, dp = _get_models(train_steps)
    bench_pc, row = _prefix_cache_bench(
        tgt, drf, tp, dp, gamma=4, temperature=0.8, max_new=40,
    )
    # Regression-gate BEFORE touching the tracked artifact, so a failed
    # smoke never clobbers the last-good numbers.
    assert bench_pc["prefix_cache_hit_rate"] > 0
    assert bench_pc["prefill_tokens"] < bench_pc["prefill_tokens_uncached"]
    path = "results/BENCH_serving.json"
    bench = {"bench": "serving"}
    if os.path.exists(path):
        with open(path) as f:
            bench = json.load(f)
    bench["prefix_cache"] = bench_pc
    _write_bench(bench, path)
    return row


def _openloop_bench(
    tgt, drf, tp, dp,
    gamma: int = 4,
    max_new: int = 24,
    n_requests: int = 30,
    mean_interarrival_s: float = 0.004,
    slo_ttft_s: float = 2.0,
    seed: int = 0,
):
    """Open-loop Poisson traffic through the continuous-batching front
    end (ISSUE 8's tentpole workload) in two phases:

    1. **Identity gate** — the same prompt set served batch-submitted
       vs streamed through :class:`ServingFrontend` with staggered
       arrivals, temperature 0: committed tokens must be bit-identical
       (the front end changes WHEN requests enter the scheduler, never
       what the verifiers commit).
    2. **Tail latency under load** — a seeded Poisson arrival schedule
       (mean interarrival far below the CPU service rate, so the queue
       saturates) with two priority classes mapped onto two tenants:
       ``gold`` (priority 0, fair-share weight 2) is every third
       arrival, ``free`` (priority 1, weight 1) the rest. Reports
       p50/p99/mean TTFT per class and overall, plus
       goodput-under-SLO: output tokens from requests whose TTFT met
       ``slo_ttft_s``, per wall-clock second, with the attainment
       fraction.

    Open-loop means arrivals never wait for service — exactly the
    regime where strict classes must hold gold's tail down while free
    traffic queues."""
    tok = ByteTokenizer()
    prompts = [
        tok.encode(t)[:16] for t in generate_prompts(11, n_requests)
    ]
    cfg = EngineConfig(
        gamma=gamma, verifier="block", max_slots=4, max_len=128,
        temperature=0.0, max_new_tokens=max_new, prefill_chunk=8,
        async_prefill=True, stage_slots=2,
    )
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    eng.submit(prompts[0], max_new_tokens=2)
    eng.run()  # warm the compile caches outside every timed window

    # -- phase 1: streamed ≡ batch bit-identity -------------------------
    eng.reset(seed=seed)
    rids = [eng.submit(list(p)) for p in prompts]
    ref_out = [eng.run()[r].output for r in rids]
    eng.reset(seed=seed)
    fe = ServingFrontend(eng, tokenizer=tok).start()
    handles = []
    for i, p in enumerate(prompts):
        handles.append(fe.submit(list(p)))
        if i % 3 == 0:
            time.sleep(0.002)  # arrive mid-flight, not as one batch
    res = fe.drain()
    streamed_out = [res[h.rid].output for h in handles]
    bit_identical = streamed_out == ref_out

    # -- phase 2: Poisson open loop, two classes / two tenants ----------
    rng = np.random.default_rng(seed)
    arrivals = _poisson_arrivals(rng, n_requests, mean_interarrival_s)
    tenant_of = [
        "gold" if i % 3 == 0 else "free" for i in range(n_requests)
    ]
    requests = [
        {
            "prompt": list(prompts[i]),
            "priority": 0 if tenant_of[i] == "gold" else 1,
            "tenant": tenant_of[i],
        }
        for i in range(n_requests)
    ]
    eng.reset(seed=seed)
    fe = ServingFrontend(
        eng, tokenizer=tok, tenant_weights={"gold": 2.0, "free": 1.0}
    ).start()
    t0 = time.perf_counter()
    handles = replay_open_loop(fe, requests, arrivals)
    res = fe.drain()
    wall = time.perf_counter() - t0
    by_rid = {h.rid: tenant for h, tenant in zip(handles, tenant_of)}

    metrics = eng.request_metrics()
    classes = {}
    for tenant in ("gold", "free"):
        ttfts = [
            m["ttft_s"] for m in metrics if by_rid[m["rid"]] == tenant
        ]
        classes[tenant] = {
            "n": len(ttfts),
            "ttft_p50_s": _pctl(ttfts, 0.50),
            "ttft_p99_s": _pctl(ttfts, 0.99),
            "ttft_mean_s": _mean(ttfts),
        }
    all_ttfts = [m["ttft_s"] for m in metrics]
    in_slo = [
        m for m in metrics
        if m["ttft_s"] is not None and m["ttft_s"] <= slo_ttft_s
    ]
    goodput = sum(m["output_len"] for m in in_slo) / wall
    bench_ol = {
        "workload": {
            "n_requests": n_requests,
            "mean_interarrival_s": mean_interarrival_s,
            "arrival_span_s": arrivals[-1],
            "max_new_tokens": max_new,
            "gamma": gamma,
            "max_slots": cfg.max_slots,
            "slo_ttft_s": slo_ttft_s,
            "tenant_weights": {"gold": 2.0, "free": 1.0},
            "seed": seed,
        },
        "bit_identical": bit_identical,
        "wall_s": wall,
        # Saturation factor >> 1 means service took far longer than the
        # arrival span — the queue genuinely built up, so the per-class
        # tail comparison below measures scheduling, not idle latency.
        "saturation_factor": wall / max(arrivals[-1], 1e-9),
        "completed": len(metrics),
        "ttft_p50_s": _pctl(all_ttfts, 0.50),
        "ttft_p99_s": _pctl(all_ttfts, 0.99),
        "ttft_mean_s": _mean(all_ttfts),
        "classes": classes,
        "goodput_tokens_per_s": goodput,
        "slo_attainment": len(in_slo) / max(len(metrics), 1),
        "tokens_per_s": sum(m["output_len"] for m in metrics) / wall,
    }
    row = {
        "name": "wallclock/openloop",
        "bit_identical": bit_identical,
        "ttft_p50_s": bench_ol["ttft_p50_s"],
        "ttft_p99_s": bench_ol["ttft_p99_s"],
        "gold_ttft_p99_s": classes["gold"]["ttft_p99_s"],
        "free_ttft_p99_s": classes["free"]["ttft_p99_s"],
        "goodput_tokens_per_s": round(goodput, 1),
        "slo_attainment": round(bench_ol["slo_attainment"], 3),
    }
    return bench_ol, row


def run_openloop_smoke(train_steps: int = 120):
    """CI smoke: train (or load) the char-LM pair, run the open-loop
    Poisson workload through the continuous-batching front end, and
    refresh the ``openloop`` section of ``results/BENCH_serving.json``
    in place. Fails if streamed submission stops being bit-identical to
    batch submission at temperature 0, if any recorded TTFT percentile
    is missing/non-finite, if every request stopped completing, or if
    the high-priority tenant's p99 TTFT stops beating best-effort
    traffic under saturation (the whole point of the class tier)."""
    tgt, drf, tp, dp = _get_models(train_steps)
    bench_ol, row = _openloop_bench(tgt, drf, tp, dp)
    # Regression-gate BEFORE touching the tracked artifact.
    assert bench_ol["bit_identical"] is True, bench_ol
    assert bench_ol["completed"] == bench_ol["workload"]["n_requests"], bench_ol
    for section in [bench_ol] + list(bench_ol["classes"].values()):
        for k in ("ttft_p50_s", "ttft_p99_s"):
            v = section[k]
            assert v is not None and math.isfinite(v) and v >= 0, (k, section)
    assert bench_ol["saturation_factor"] > 1.5, bench_ol
    assert (
        bench_ol["classes"]["gold"]["ttft_p99_s"]
        < bench_ol["classes"]["free"]["ttft_p99_s"]
    ), bench_ol["classes"]
    assert bench_ol["goodput_tokens_per_s"] >= 0, bench_ol
    path = "results/BENCH_serving.json"
    bench = {"bench": "serving"}
    if os.path.exists(path):
        with open(path) as f:
            bench = json.load(f)
    bench["openloop"] = bench_ol
    _write_bench(bench, path)
    return row


def _chaos_bench(
    tgt, drf, tp, dp,
    gamma: int = 4,
    max_new: int = 24,
    n_cold: int = 3, warm_per_cold: int = 3,
    cold_tokens: int = 96, warm_tokens: int = 8,
    max_slots: int = 4,
):
    """Chaos run: the mixed cold/warm workload through the
    device-disaggregated engine under a deterministic fault plan firing
    EVERY registered site (lost + delayed transfers, pod dispatch
    failures past the downgrade limit, transient allocator denials,
    non-finite drafter rows), plus one mid-flight cancellation and one
    impossible-deadline request. Two phases:

    1. **Fault-free reference** — same prompts, ``faults=None``:
       committed tokens + TTFT tail to compare against.
    2. **Chaos** — the full plan. The gates (applied by
       :func:`run_chaos_smoke`): every non-cancelled request reaches a
       terminal state, all survivors — including fault-AFFECTED ones,
       at temperature 0 — commit bit-identical output, the pool audit
       never repairs anything (zero leaks, checked after every unwind
       and at quiesce), both pods drain to reset geometry, and p99 TTFT
       inflates by at most a bounded factor (the ladder retries/fails
       over instead of stalling).
    """
    import jax.numpy as jnp

    tok = ByteTokenizer()
    n_warm = n_cold * warm_per_cold
    warm_txt = generate_prompts(5, n_warm)
    cold_txt = generate_prompts(7, n_cold)
    prompts = []
    wi = 0
    for i in range(n_cold):
        base = tok.encode(cold_txt[i] + " ")
        cold = (base * (cold_tokens // len(base) + 1))[:cold_tokens]
        prompts.append(cold)
        for _ in range(warm_per_cold):
            prompts.append(tok.encode(warm_txt[wi])[:warm_tokens])
            wi += 1
    cfg = EngineConfig(
        gamma=gamma, verifier="block", max_slots=max_slots,
        max_len=256, temperature=0.0, max_new_tokens=max_new,
        prefill_chunk=8, async_prefill=True, stage_slots=2,
        disaggregated=True,
    )
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    eng.submit(prompts[0], max_new_tokens=2)  # warm compile
    eng.run()

    # -- phase 1: fault-free reference ----------------------------------
    eng.reset(seed=0)
    rids = [eng.submit(list(p)) for p in prompts]
    ref_res = eng.run()
    ref_out = [ref_res[r].output for r in rids]
    ref_ttfts = [m["ttft_s"] for m in eng.request_metrics()]
    ref_p99 = _pctl(ref_ttfts, 0.99)

    # -- phase 2: chaos -------------------------------------------------
    plan = FaultPlan.make(
        seed=0,
        rates={
            # Loss below 1.0: a lost transfer's lane fails over and the
            # pod downgrade then stops staging entirely, so losing EVERY
            # early transfer would starve the delay site of dispatches.
            SITE_TRANSFER_LOSS: 0.4,
            SITE_TRANSFER_DELAY: 1.0,
            SITE_POD_DISPATCH: 1.0,
            SITE_ALLOC_DENY: 0.5,
            SITE_NONFINITE_LOGITS: 0.5,
        },
        max_per_site=2,
        # retries=0: every lost transfer walks the WHOLE ladder
        # (timeout -> failover -> decode-pod prefill) so the smoke
        # exercises the floor, not just the retry rung.
        transfer_timeout_iters=2, transfer_max_retries=0,
        pod_failure_limit=2,
    )
    eng.cfg = dataclasses.replace(eng.cfg, faults=plan)
    eng.reset(seed=0)
    rids = [eng.submit(list(p)) for p in prompts]
    doomed = eng.submit(prompts[-1][:4], deadline_s=1e-9)
    cancel_rid = rids[0]  # first cold prompt: mid-staging at pump 2
    calls = {"n": 0}

    def pump():
        calls["n"] += 1
        if calls["n"] == 2:
            eng.cancel(cancel_rid)
        return False

    res = eng.serve(pump=pump)
    stats = eng.last_stats
    eng.cfg = dataclasses.replace(eng.cfg, faults=None)

    survivors_identical = all(
        list(res[r].output) == ref_out[i]
        for i, r in enumerate(rids)
        if not (r == cancel_rid and res[r].finish_reason == "cancelled")
    )
    all_terminal = all(res[r].finished for r in rids) and (
        res[doomed].finish_reason == "deadline"
    )
    chaos_ttfts = [m["ttft_s"] for m in eng.request_metrics()]
    chaos_p99 = _pctl(chaos_ttfts, 0.99)
    pool = eng.batch.pool
    spool = eng.stage_pool
    drained = (
        int(pool.free_count) + int(jnp.sum(pool.cached))
        == pool.free_stack.shape[0]
        and not bool(jnp.any(pool.staged))
        and int(spool.free_count) == spool.free_stack.shape[0]
        and int(jnp.max(spool.ref)) == 0
    )
    bench = {
        "workload": {
            "n_requests": len(prompts) + 1,
            "n_cold": n_cold, "n_warm": n_warm,
            "cold_prompt_tokens": cold_tokens,
            "warm_prompt_tokens": warm_tokens,
            "max_new_tokens": max_new,
            "max_slots": max_slots, "stage_slots": 2,
            "cancelled_requests": 1, "deadline_requests": 1,
        },
        "plan": {
            "seed": plan.seed,
            "rates": dict(plan.rates),
            "max_per_site": plan.max_per_site,
            "transfer_timeout_iters": plan.transfer_timeout_iters,
            "transfer_max_retries": plan.transfer_max_retries,
            "pod_failure_limit": plan.pod_failure_limit,
        },
        "fault_injections": stats["fault_injections"],
        "transfer_retries": stats["transfer_retries"],
        "failovers": stats["failovers"],
        "pod_failures": stats["pod_failures"],
        "downgraded": stats["downgraded"],
        "cancelled": stats["cancelled"],
        "deadline_shed": stats["deadline_shed"],
        "audit_repairs": stats["audit_repairs"],
        "all_terminal": all_terminal,
        "survivors_bit_identical": survivors_identical,
        "pools_drained": drained,
        "ref_ttft_p99_s": ref_p99,
        "chaos_ttft_p99_s": chaos_p99,
        "ttft_p99_inflation": (
            chaos_p99 / ref_p99 if ref_p99 else None
        ),
    }
    row = {
        "name": "wallclock/chaos",
        "sites_fired": len(stats["fault_injections"]),
        "failovers": stats["failovers"],
        "downgraded": stats["downgraded"],
        "audit_repairs": stats["audit_repairs"],
        "survivors_bit_identical": survivors_identical,
        "ttft_p99_inflation": (
            round(chaos_p99 / ref_p99, 2) if ref_p99 else None
        ),
    }
    return bench, row


def run_chaos_smoke(train_steps: int = 120):
    """CI smoke (blocking): train (or load) the char-LM pair, run the
    chaos workload (:func:`_chaos_bench`), and refresh the ``chaos``
    section of ``results/BENCH_serving.json`` in place. Fails if any
    request fails to reach a terminal state, if a surviving request's
    committed tokens diverge from the fault-free run, if the pool audit
    ever had to repair anything (a leak — the unwind paths must be
    exact, the audit is a net not a mop), if either pod's pool fails to
    drain, if the plan stops actually exercising every registered fault
    site, or if p99 TTFT inflates beyond the bounded-degradation
    envelope (the ladder must retry/fail over, never stall)."""
    tgt, drf, tp, dp = _get_models(train_steps)
    bench_ch, row = _chaos_bench(tgt, drf, tp, dp)
    # Regression-gate BEFORE touching the tracked artifact.
    assert bench_ch["all_terminal"] is True, bench_ch
    assert bench_ch["survivors_bit_identical"] is True, bench_ch
    assert bench_ch["audit_repairs"] == 0, bench_ch
    assert bench_ch["pools_drained"] is True, bench_ch
    assert len(bench_ch["fault_injections"]) == 5, bench_ch
    assert bench_ch["failovers"] >= 1, bench_ch
    assert bench_ch["downgraded"] is True, bench_ch
    assert bench_ch["cancelled"] == 1, bench_ch
    assert bench_ch["deadline_shed"] >= 1, bench_ch
    # Bounded degradation: chaos adds retries/failovers, not stalls.
    # The factor is generous (CI wall clock is noisy and the reference
    # p99 is small); the absolute floor keeps tiny references from
    # making the ratio meaningless.
    assert bench_ch["chaos_ttft_p99_s"] is not None, bench_ch
    assert (
        bench_ch["chaos_ttft_p99_s"]
        <= 8.0 * bench_ch["ref_ttft_p99_s"] + 1.0
    ), bench_ch
    path = "results/BENCH_serving.json"
    bench = {"bench": "serving"}
    if os.path.exists(path):
        with open(path) as f:
            bench = json.load(f)
    bench["chaos"] = bench_ch
    _write_bench(bench, path)
    return row


def _summarize_alloc(steps: list[dict], preemptions: int) -> dict:
    """Compress the engine's per-step allocation trace into the artifact:
    occupancy statistics, the worst-case budget headroom, preemption
    count, plus the (downsampled) per-step series itself."""
    if not steps:
        return {"steps": 0, "preemptions": preemptions}
    occ = [s["occupancy_pages"] for s in steps]
    worst = [s["worst_case_pages"] for s in steps]
    stride = max(len(steps) // 200, 1)  # keep the artifact bounded
    sampled = steps[::stride]
    if sampled[-1] is not steps[-1]:
        sampled.append(steps[-1])  # anchor the series' freshest sample
    return {
        "steps": len(steps),
        "num_pages": steps[-1]["num_pages"],
        "occupancy_pages_mean": sum(occ) / len(occ),
        "occupancy_pages_max": max(occ),
        "worst_case_pages_max": max(worst),
        "preemptions": preemptions,
        "per_step": [
            {k: s[k] for k in
             ("step", "occupancy_pages", "active_slots", "preemptions")}
            for s in sampled
        ],
    }


def _write_bench(bench: dict, path: str = "results/BENCH_serving.json"):
    """Persist the machine-readable serving-perf artifact (tokens/s for
    AR vs token vs block verification, acceptance rates, config)."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
