"""Paper Table 1: block efficiency, TokenV vs BlockV, gamma=8, per dataset,
with multi-seed mean +/- std. (Wall-clock analog: benchmarks/wallclock.py.)"""

from __future__ import annotations

import numpy as np
import jax

from benchmarks import common
from repro.core import simulate


def run(quick: bool = True, gamma: int = 8, drafter: str = "XXS"):
    batch, iters = (256, 24) if quick else (2048, 64)
    seeds = [0, 1, 2]
    rows = []
    improvements = []
    for ds in common.DATASETS:
        target, draft = common.dataset_pair(ds, drafter)
        bes = {"token": [], "block": []}
        for s in seeds:
            for name in bes:
                bes[name].append(float(simulate.block_efficiency(
                    jax.random.key(s), target, draft, gamma, name,
                    batch=batch, n_iters=iters,
                )))
        tok = np.array(bes["token"])
        blk = np.array(bes["block"])
        imp = (blk / tok - 1.0) * 100
        improvements.append(imp.mean())
        rows.append({
            "name": f"table1/{ds}",
            "tokenv_be": f"{tok.mean():.3f}±{tok.std():.3f}",
            "blockv_be": f"{blk.mean():.3f}±{blk.std():.3f}",
            "improve_pct": f"{imp.mean():.2f}±{imp.std():.2f}",
        })
    rows.append({
        "name": "table1/average_improve_pct",
        "value": round(float(np.mean(improvements)), 2),
        "paper_avg_improve_pct": 8.30,
    })
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
