"""Paper Figures 3/4: average block efficiency and relative improvement
for gamma in {2,4,6,8} under two drafter-quality tiers (XXS / XXXS)."""

from __future__ import annotations

import numpy as np
import jax

from benchmarks import common
from repro.core import simulate


def run(quick: bool = True):
    batch, iters = (256, 24) if quick else (1024, 64)
    gammas = [2, 4, 6, 8]
    rows = []
    for drafter in ["XXS", "XXXS"]:
        prev_imp = None
        for gamma in gammas:
            toks, blks = [], []
            for ds in common.DATASETS:
                target, draft = common.dataset_pair(ds, drafter)
                toks.append(float(simulate.block_efficiency(
                    jax.random.key(0), target, draft, gamma, "token",
                    batch=batch, n_iters=iters)))
                blks.append(float(simulate.block_efficiency(
                    jax.random.key(0), target, draft, gamma, "block",
                    batch=batch, n_iters=iters)))
            tok, blk = np.mean(toks), np.mean(blks)
            imp = (blk / tok - 1) * 100
            rows.append({
                "name": f"gamma_sweep/{drafter}/g{gamma}",
                "tokenv_be": round(tok, 3),
                "blockv_be": round(blk, 3),
                "improve_pct": round(imp, 2),
                "improvement_grows": (
                    None if prev_imp is None else bool(imp >= prev_imp - 0.3)
                ),
            })
            prev_imp = imp
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
