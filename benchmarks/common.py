"""Shared benchmark utilities: the synthetic 'dataset' suite.

The paper evaluates on 8 NLP datasets with PALM-2 models. Our stand-ins
are oracle model pairs whose (entropy, drafter-agreement) profile is swept
the same way the paper sweeps datasets and drafter sizes; dataset names
are kept for table alignment (see DESIGN.md §6).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import oracle

# name -> (seed, concentration, rho, alpha).
#
# Drafters are "bimodal": on a fraction ``rho`` of contexts (easy tokens)
# the drafter agrees with the target exactly; on the rest it is an
# ``alpha``-perturbed mixture. Both knobs are CALIBRATED (see
# EXPERIMENTS.md) so that at gamma=8 each dataset matches the paper's
# Table-1 operating point in BOTH coordinates — TokenV block efficiency
# AND BlockV relative improvement. (A single-knob Dirichlet-mixture
# drafter can match the BE but overshoots the improvement 2x: the gain of
# block verification is governed by the dispersion structure of the
# likelihood ratios, not by the acceptance rate alone.)
DATASETS = {
    "LM1B": (11, 0.6, 0.345, 0.9),        # BE 3.18/3.21, +8.6%/+8.68%
    "GPT-Prompt": (22, 0.8, 0.404, 0.9),  # BE 3.40/3.41, +9.9%/+10.06%
    "WebQA": (33, 0.5, 0.471, 0.9),       # BE 3.39/3.44, +7.2%/+7.53%
    "PIQA": (44, 0.7, 0.442, 0.9),        # BE 3.40/3.40, +9.3%/+8.3%
    "ShareGPT": (55, 0.9, 0.397, 0.9),    # BE 3.33/3.34, +10.7%/+8.45%
    "XSum": (66, 0.6, 0.546, 0.9),        # BE 3.46/3.49, +8.2%/+7.63%
    "GSM8K": (77, 0.4, 0.412, 0.7),       # BE 3.82/3.81, +8.0%/+8.74%
    "WMT-DeEn": (88, 1.0, 0.286, 0.9),    # BE 3.15/3.19, +12.9%/+7.0%
}

# drafter quality tiers (paper: PALM-2-XXS vs the weaker XXXS). XXXS
# agrees on slightly fewer contexts AND its hard-context distribution is
# sharpened (overconfidently wrong — ratios near 0, less partial credit
# for block verification). Calibrated to the paper's XXXS gamma=8 row:
# avg token BE 2.45 (paper 2.57), BlockV improvement +6.3% (paper +6.27%),
# reproducing Figure 4's ordering: the better drafter gains MORE.
DRAFTERS = {"XXS": (0.0, 1.0), "XXXS": (0.06, 2.5)}  # (drop rho, sharpen)


def dataset_pair(name: str, drafter: str = "XXS", vocab=16, order=2):
    seed, conc, rho, alpha = DATASETS[name]
    drho, sharp = DRAFTERS[drafter]
    rho = max(0.02, rho - drho)
    kt, _ = jax.random.split(jax.random.key(seed))
    target = oracle.random_lm(kt, vocab, order, conc)
    k1, k2 = jax.random.split(jax.random.key(seed + 1))
    noise = jax.random.dirichlet(
        k1, jnp.ones(vocab), (target.n_contexts,)
    )
    hard = (1 - alpha) * target.table + alpha * noise.astype(jnp.float32)
    if sharp != 1.0:
        hard = jnp.power(hard, sharp)
    hard = hard / jnp.sum(hard, axis=-1, keepdims=True)
    easy = jax.random.uniform(k2, (target.n_contexts, 1)) < rho
    draft = oracle.TabularLM(
        table=jnp.where(easy, target.table, hard), order=order
    )
    return target, draft


def timeit(fn, n_warmup=1, n_iter=3) -> float:
    """Median wall time in microseconds."""
    for _ in range(n_warmup):
        fn()
    times = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
