"""Kernel/verification microbenchmarks.

Three claims measured:
* the paper's "no additional computation cost": block verification's
  per-call overhead vs token verification at serving shapes;
* the fused-residual roofline estimate for the Pallas kernel (bytes
  touched / HBM bandwidth on the TPU target; on CPU we report the
  XLA-compiled reference timing — interpret-mode timings are meaningless);
* the paged-attention kernels (``flash_decode_paged`` /
  ``flash_prefill_paged``): their in-grid page resolution — the KV
  tile's pool page resolved through the scalar-prefetched page table —
  is validated against the DENSE kernels at matched shapes (same K/V
  content, pool pages scrambled), and timed compiled on TPU / in
  interpret mode elsewhere (off-TPU the reported ``ref_us_per_call``
  XLA-gather timing is the meaningful number; interpret timings only
  prove the lowering runs).

``--block-shape-sweep`` additionally times the paged kernels over a
grid of KV tile shapes (the pool page geometry) — see
:func:`run_block_shape_sweep`.

``--compiled-json PATH`` (e.g. ``results/BENCH_kernels.json``) writes a
machine-readable record of the sweep: execution mode
(compiled-vs-interpret and which timing column is meaningful there),
every candidate KV tile / page geometry with its timings, and the
best-shape selection per kernel — so the chosen page geometry is a
tracked artifact, not a console line.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.core import verification
from repro.kernels import ops, ref
from repro.launch.mesh import HBM_BW


def _paged_from_dense(key, b, c, kh, hd, page):
    """A dense (B, C) K/V cache and its paged twin: the pool holds the
    same rows split into pages, physical ids deliberately scrambled so
    the kernels' in-grid table resolution is actually exercised."""
    maxp = c // page
    kd = jax.random.normal(key, (2, b, c, kh, hd))
    perm = jax.random.permutation(
        jax.random.fold_in(key, 1), b * maxp
    ).astype(jnp.int32)
    table = perm.reshape(b, maxp)
    pools = jnp.zeros((2, b * maxp, page, kh, hd))
    rows = kd.reshape(2, b * maxp, page, kh, hd)
    pools = pools.at[:, table.reshape(-1)].set(rows)
    return kd[0], kd[1], pools[0], pools[1], table


def run_paged(quick: bool = True):
    """Paged-vs-dense kernel identity + timing at matched shapes
    (ROADMAP: wire ``flash_*_paged`` into the kernel benches)."""
    on_tpu = jax.default_backend() == "tpu"
    interp = None if on_tpu else True  # compiled on TPU, interpret off
    rows = []
    shapes = [(4, 256, 8, 2, 64, 32)] if quick else [
        (4, 256, 8, 2, 64, 32), (8, 512, 8, 4, 64, 64),
    ]
    key = jax.random.key(7)
    for b, c, h, kh, hd, page in shapes:
        key = jax.random.fold_in(key, c)
        k1, k2 = jax.random.split(key)
        kd, vd, k_pool, v_pool, table = _paged_from_dense(
            k1, b, c, kh, hd, page
        )
        lens = jnp.asarray([c - 1 - (i * 13) % (c // 3) for i in range(b)])
        k_pos = jnp.broadcast_to(jnp.arange(c)[None], (b, c))
        k_pos = jnp.where(k_pos < lens[:, None], k_pos, -1)

        # decode: one query token at position lens-1
        q1 = jax.random.normal(k2, (b, h, hd))
        dense = ops.flash_decode(q1, kd, vd, lens - 1, k_pos)
        paged = ops.flash_decode_paged(
            q1, k_pool, v_pool, table, lens - 1, lens, interpret=interp,
        )
        err = float(jnp.max(jnp.abs(paged - dense)))
        assert err < 2e-5, ("paged decode deviates from dense", err)
        fn = jax.jit(lambda q: ops.flash_decode_paged(
            q, k_pool, v_pool, table, lens - 1, lens, interpret=interp,
        ))
        us = timeit(lambda: jax.block_until_ready(fn(q1)))
        rfn = jax.jit(lambda q: ref.flash_decode_paged(
            q, k_pool, v_pool, table, lens - 1, lens
        ))
        rus = timeit(lambda: jax.block_until_ready(rfn(q1)))
        rows.append({
            "name": f"kernels/paged_decode_B{b}_C{c}_pg{page}",
            "max_abs_diff_vs_dense": err,
            "us_per_call": round(us, 1),
            "ref_us_per_call": round(rus, 1),
            "mode": "compiled" if on_tpu else "interpret",
        })

        # chunked verify/prefill: gamma+1 = 5 query tokens at positions
        # lens-s .. lens-1; every chunk row must equal the matched dense
        # single-token decode at its position (a causal chunk is exactly
        # per-row decode over the shared cache).
        s = 5
        qs = jax.random.normal(jax.random.fold_in(k2, 1), (b, s, h, hd))
        paged = ops.flash_prefill_paged(
            qs, k_pool, v_pool, table, lens - s, lens, interpret=interp,
        )
        err = 0.0
        for i in range(s):
            dense = ops.flash_decode(
                qs[:, i], kd, vd, lens - s + i, k_pos
            )
            err = max(err, float(jnp.max(jnp.abs(paged[:, i] - dense))))
        assert err < 2e-5, ("paged prefill deviates from dense", err)
        fn = jax.jit(lambda q: ops.flash_prefill_paged(
            q, k_pool, v_pool, table, lens - s, lens, interpret=interp,
        ))
        us = timeit(lambda: jax.block_until_ready(fn(qs)))
        rfn = jax.jit(lambda q: ref.flash_prefill_paged(
            q, k_pool, v_pool, table, lens - s, lens
        ))
        rus = timeit(lambda: jax.block_until_ready(rfn(qs)))
        rows.append({
            "name": f"kernels/paged_prefill_B{b}_S{s}_C{c}_pg{page}",
            "max_abs_diff_vs_dense": err,
            "us_per_call": round(us, 1),
            "ref_us_per_call": round(rus, 1),
            "mode": "compiled" if on_tpu else "interpret",
        })
    return rows


def run_block_shape_sweep(quick: bool = True):
    """``--block-shape-sweep``: time the paged kernels over a grid of
    KV tile shapes. For ``flash_decode_paged`` / ``flash_prefill_paged``
    the KV tile IS the pool page — the grid's innermost dimension walks
    ``page_table[b, pj]`` and each step DMAs one ``(page, hd)`` tile per
    KV head — so the sweep serves the same cache repaged at each
    candidate size and reports per-call latency (compiled on TPU;
    interpret elsewhere, where the XLA-gather ``ref_us_per_call`` is the
    meaningful number, same caveat as :func:`run_paged`). Identity vs
    the dense kernels is asserted at every shape, so the sweep doubles
    as coverage that the in-grid page resolution holds across tile
    geometries (including the (8, 128) f32 min-tile floor: pages below
    8 rows would pad the sublane dimension and are not swept)."""
    on_tpu = jax.default_backend() == "tpu"
    interp = None if on_tpu else True
    b, c, h, kh, hd = (4, 256, 8, 2, 64) if quick else (8, 1024, 8, 4, 64)
    pages = [16, 32, 64, 128] if quick else [16, 32, 64, 128, 256]
    s = 5  # verify-chunk rows (gamma + 1) for the chunked kernel
    key = jax.random.key(11)
    rows = []
    for page in pages:
        if c % page:
            continue
        key = jax.random.fold_in(key, page)
        k1, k2 = jax.random.split(key)
        kd, vd, k_pool, v_pool, table = _paged_from_dense(
            k1, b, c, kh, hd, page
        )
        lens = jnp.asarray([c - 1 - (i * 13) % (c // 3) for i in range(b)])
        k_pos = jnp.broadcast_to(jnp.arange(c)[None], (b, c))
        k_pos = jnp.where(k_pos < lens[:, None], k_pos, -1)
        q1 = jax.random.normal(k2, (b, h, hd))
        qs = jax.random.normal(jax.random.fold_in(k2, 1), (b, s, h, hd))
        for name, q, run_paged_fn, run_ref_fn, check in [
            (
                "decode", q1,
                lambda q, p=(k_pool, v_pool, table): ops.flash_decode_paged(
                    q, *p, lens - 1, lens, interpret=interp,
                ),
                lambda q, p=(k_pool, v_pool, table): ref.flash_decode_paged(
                    q, *p, lens - 1, lens,
                ),
                lambda o: float(jnp.max(jnp.abs(
                    o - ops.flash_decode(q1, kd, vd, lens - 1, k_pos)
                ))),
            ),
            (
                "prefill", qs,
                lambda q, p=(k_pool, v_pool, table): ops.flash_prefill_paged(
                    q, *p, lens - s, lens, interpret=interp,
                ),
                lambda q, p=(k_pool, v_pool, table): ref.flash_prefill_paged(
                    q, *p, lens - s, lens,
                ),
                lambda o: max(
                    float(jnp.max(jnp.abs(o[:, i] - ops.flash_decode(
                        qs[:, i], kd, vd, lens - s + i, k_pos
                    ))))
                    for i in range(s)
                ),
            ),
        ]:
            err = check(run_paged_fn(q))
            assert err < 2e-5, ("paged deviates from dense", name, page, err)
            fn = jax.jit(run_paged_fn)
            us = timeit(lambda: jax.block_until_ready(fn(q)))
            rfn = jax.jit(run_ref_fn)
            rus = timeit(lambda: jax.block_until_ready(rfn(q)))
            rows.append({
                "name": f"kernels/sweep_{name}_B{b}_C{c}_pg{page}",
                "kv_tile": [page, hd],
                "max_abs_diff_vs_dense": err,
                "us_per_call": round(us, 1),
                "ref_us_per_call": round(rus, 1),
                "mode": "compiled" if on_tpu else "interpret",
            })
    # flag the best tile per kernel so the sweep output is directly
    # actionable (on CPU this ranks the XLA reference, see docstring)
    col = "us_per_call" if on_tpu else "ref_us_per_call"
    for kind in ("decode", "prefill"):
        best = min(
            (r for r in rows if f"sweep_{kind}" in r["name"]),
            key=lambda r: r[col],
        )
        best["best_in_sweep"] = True
    return rows


def run(quick: bool = True):
    rows = []
    shapes = [(8, 4, 32_000)] if quick else [
        (8, 4, 32_000), (32, 8, 32_000), (8, 8, 256_000),
    ]
    key = jax.random.key(0)
    for b, g, v in shapes:
        k1, k2, k3, kk = jax.random.split(key, 4)
        q = jax.random.dirichlet(k1, jnp.ones(v), (b, g))
        p = jax.random.dirichlet(k2, jnp.ones(v), (b, g + 1))
        toks = jax.random.randint(k3, (b, g), 0, v)

        for name in ["token", "block"]:
            fn = jax.jit(verification.get_verifier(name))
            us = timeit(
                lambda fn=fn: jax.block_until_ready(fn(kk, toks, q, p))
            )
            rows.append({
                "name": f"kernels/verify_{name}_B{b}_G{g}_V{v}",
                "us_per_call": round(us, 1),
            })

        # fused residual reduction: CPU-compiled reference timing + the
        # TPU roofline bound for the same bytes.
        ps = jax.random.uniform(kk, (b, g))
        fn = jax.jit(ref.verify_residual_sums)
        us = timeit(lambda: jax.block_until_ready(fn(ps, p[:, :g], q)))
        hbm_bytes = 2 * b * g * v * 4
        rows.append({
            "name": f"kernels/residual_sums_B{b}_G{g}_V{v}",
            "us_per_call": round(us, 1),
            "tpu_roofline_us": round(hbm_bytes / HBM_BW * 1e6, 2),
        })
    return rows


def write_compiled_json(path: str, quick: bool = True) -> dict:
    """``--compiled-json``: run the KV-tile sweep and persist it. The
    document records the execution mode (so a reader never compares
    interpret-mode numbers against compiled ones), the timing column
    that is meaningful on this backend, every swept page geometry, and
    the per-kernel best shape."""
    import json
    import os

    on_tpu = jax.default_backend() == "tpu"
    rows = run_block_shape_sweep(quick=quick)
    doc = {
        "bench": "kernels",
        "backend": jax.default_backend(),
        "mode": "compiled" if on_tpu else "interpret",
        "timing_column": "us_per_call" if on_tpu else "ref_us_per_call",
        "rows": rows,
        "best": {
            kind: {
                "name": r["name"],
                "kv_tile": r["kv_tile"],
                "page": r["kv_tile"][0],
                "us_per_call": r["us_per_call"],
                "ref_us_per_call": r["ref_us_per_call"],
            }
            for kind in ("decode", "prefill")
            for r in rows
            if r.get("best_in_sweep") and f"sweep_{kind}" in r["name"]
        },
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--block-shape-sweep", action="store_true",
        help="sweep the paged kernels over a grid of KV tile shapes "
             "(compiled on TPU / interpret elsewhere)",
    )
    ap.add_argument(
        "--compiled-json", metavar="PATH",
        help="run the KV-tile sweep and write mode + per-shape timings "
             "+ best-shape selection as JSON (e.g. "
             "results/BENCH_kernels.json)",
    )
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.compiled_json:
        doc = write_compiled_json(args.compiled_json, quick=args.quick)
        print(f"wrote {args.compiled_json}: mode={doc['mode']}, "
              f"best={doc['best']}")
    elif args.block_shape_sweep:
        for r in run_block_shape_sweep(quick=args.quick):
            print(r)
    else:
        for r in run(quick=args.quick):
            print(r)
