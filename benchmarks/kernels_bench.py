"""Kernel/verification microbenchmarks.

Two claims measured:
* the paper's "no additional computation cost": block verification's
  per-call overhead vs token verification at serving shapes;
* the fused-residual roofline estimate for the Pallas kernel (bytes
  touched / HBM bandwidth on the TPU target; on CPU we report the
  XLA-compiled reference timing — interpret-mode timings are meaningless).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.core import verification
from repro.kernels import ref
from repro.launch.mesh import HBM_BW


def run(quick: bool = True):
    rows = []
    shapes = [(8, 4, 32_000)] if quick else [
        (8, 4, 32_000), (32, 8, 32_000), (8, 8, 256_000),
    ]
    key = jax.random.key(0)
    for b, g, v in shapes:
        k1, k2, k3, kk = jax.random.split(key, 4)
        q = jax.random.dirichlet(k1, jnp.ones(v), (b, g))
        p = jax.random.dirichlet(k2, jnp.ones(v), (b, g + 1))
        toks = jax.random.randint(k3, (b, g), 0, v)

        for name in ["token", "block"]:
            fn = jax.jit(verification.get_verifier(name))
            us = timeit(
                lambda fn=fn: jax.block_until_ready(fn(kk, toks, q, p))
            )
            rows.append({
                "name": f"kernels/verify_{name}_B{b}_G{g}_V{v}",
                "us_per_call": round(us, 1),
            })

        # fused residual reduction: CPU-compiled reference timing + the
        # TPU roofline bound for the same bytes.
        ps = jax.random.uniform(kk, (b, g))
        fn = jax.jit(ref.verify_residual_sums)
        us = timeit(lambda: jax.block_until_ready(fn(ps, p[:, :g], q)))
        hbm_bytes = 2 * b * g * v * 4
        rows.append({
            "name": f"kernels/residual_sums_B{b}_G{g}_V{v}",
            "us_per_call": round(us, 1),
            "tpu_roofline_us": round(hbm_bytes / HBM_BW * 1e6, 2),
        })
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(r)
