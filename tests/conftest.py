"""Shared test config: clear JAX compilation caches between test modules.

The suite compiles ~60 distinct model configurations; a single pytest
process would otherwise accumulate compiled executables until the host
OOMs (LLVM "Cannot allocate memory" cascades).
"""

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    yield
    jax.clear_caches()
    gc.collect()
