"""Shared test config: clear JAX compilation caches between test modules.

The suite compiles ~60 distinct model configurations; a single pytest
process would otherwise accumulate compiled executables until the host
OOMs (LLVM "Cannot allocate memory" cascades).
"""

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    yield
    jax.clear_caches()
    gc.collect()


def hypothesis_stub():
    """Drop-in (given, settings, st) for environments without hypothesis:
    property-based cases are skipped with a clear reason, deterministic
    cases in the same module keep running."""

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        def __getattr__(self, _name):
            def _strategy(*_a, **_k):
                return None

            return _strategy

    return given, settings, _Strategies()
