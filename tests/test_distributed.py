"""Distribution-layer tests: sharding rules (AbstractMesh — no devices
needed), HLO collective-bytes parsing, and a real miniature dry-run in a
subprocess with 8 forced host devices."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding as shd
from repro.launch import roofline
from repro.models import Model

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


class TestParamSpecs:
    def test_ffn_sharded_heads_replicated_when_indivisible(self):
        # smollm: 9 heads % 16 != 0 -> replicate; ffn 1536 % 16 == 0 -> shard
        spec_q = shd.param_spec(
            (None, "embed", "heads", None), (30, 576, 9, 64), MESH
        )
        assert "model" not in jax.tree.leaves(spec_q)
        spec_up = shd.param_spec((None, "embed", "ffn"), (30, 576, 1536), MESH)
        assert spec_up[2] == "model"

    def test_fsdp_on_embed_dim(self):
        spec = shd.param_spec(
            (None, "embed", "ffn"), (88, 12288, 28672), MESH
        )
        assert spec == P(None, "data", "model")
        spec_mp = shd.param_spec(
            (None, "embed", "ffn"), (88, 12288, 28672), MESH_MP
        )
        assert spec_mp == P(None, ("pod", "data"), "model")

    def test_vocab_shards(self):
        spec = shd.param_spec(("vocab", "embed"), (256512, 3584), MESH)
        assert spec[0] == "model"

    def test_all_archs_have_valid_specs(self):
        for name in registry.ASSIGNED:
            model = Model(registry.get_config(name))
            axes = model.logical_axes()
            shapes = model.abstract_params()
            specs = jax.tree.map(
                lambda ax, sh: shd.param_spec(ax, sh.shape, MESH),
                axes, shapes,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, (str, type(None))) for e in x
                ),
            )
            for spec, sh in zip(
                jax.tree.leaves(
                    specs, is_leaf=lambda x: isinstance(x, P)
                ),
                jax.tree.leaves(shapes),
            ):
                for ax, dim in zip(spec, sh.shape):
                    if ax == "model":
                        assert dim % 16 == 0, (name, sh.shape, spec)


class TestCollectiveParser:
    HLO = textwrap.dedent("""
    ENTRY main {
      %ag = f32[16,4096]{1,0} all-gather(f32[1,4096]{1,0} %x), dimensions={0}
      %ar = bf16[256,128]{1,0} all-reduce(bf16[256,128]{1,0} %y), to_apply=%add
      %rs = f32[2,64]{1,0} reduce-scatter(f32[32,64]{1,0} %z), dimensions={0}
      %cp-start = (f32[8,8]{1,0}, f32[8,8]{1,0}) collective-permute-start(f32[8,8]{1,0} %w)
      %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0} %a, f32[64,128]{1,0} %b)
    }
    """)

    def test_bytes_by_kind(self):
        got = roofline.collective_bytes(self.HLO)
        assert got["all-gather"] == 16 * 4096 * 4
        assert got["all-reduce"] == 256 * 128 * 2
        assert got["reduce-scatter"] == 2 * 64 * 4
        assert got["collective-permute"] == 2 * 8 * 8 * 4
        assert got["all-to-all"] == 0

    def test_roofline_terms(self):
        # flops are per-device (see roofline.roofline_terms docstring)
        t = roofline.roofline_terms(
            flops=197e12, hbm_bytes=0, coll_bytes=0, n_chips=256
        )
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["bottleneck"] == "compute"


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from repro.configs import registry
from repro.distributed import sharding as shd
from repro.models import Model
from repro.training import optim, train as training
from repro.training.optim import OptConfig

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = registry.smoke_config("ARCH").with_(d_model=256, vocab=512)
model = Model(cfg)
step = training.make_train_step(model, OptConfig())
p_shard = shd.param_shardings(model, mesh)
opt_shard = optim.OptState(step=shd.replicated(mesh), mu=p_shard, nu=p_shard)
bsh = shd.batch_sharding(mesh)
params = model.abstract_params()
opt = optim.OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=params, nu=params)
batch = {k: jax.ShapeDtypeStruct((4, 64), jnp.int32) for k in ("tokens", "labels")}
extras = model.extras_specs(4)
mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
with mesh_ctx:
    lowered = jax.jit(
        step,
        in_shardings=(p_shard, opt_shard, {k: bsh for k in batch},
                      {k: bsh for k in extras} or None),
    ).lower(params, opt, batch, extras or None)
    compiled = lowered.compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict]
    cost = cost[0]
print(json.dumps({"flops": float(cost.get("flops", 0))}))
"""


@pytest.mark.parametrize("arch", ["smollm-135m", "mixtral-8x22b", "mamba2-370m"])
def test_mini_dryrun_8_devices(arch):
    """Real lower+compile of a smoke train step on a (2, 4) mesh."""
    code = MINI_DRYRUN.replace("ARCH", arch)
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)), timeout=420,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
