"""Paged KV cache tests: allocator invariants, paged-vs-dense engine
identity (greedy and sampled), over-subscription with preemption +
recompute-on-resume, ring wraparound for sliding-window layers, and
page-pool sharding specs."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import registry
from repro.models import Model
from repro.serving import paging
from repro.serving.engine import EngineConfig, SpecEngine

SPEC = paging.PageSpec(page_size=4, num_pages=16, max_pages=6)


def _mk(num_slots=3, spec=SPEC):
    table, used = paging.init_tables(spec, num_slots)
    return table, used, paging.init_pool(spec)


class TestAllocator:
    def test_ensure_grows_to_cover_length(self):
        table, used, pool = _mk()
        mask = jnp.array([True, False, True])
        table, used, pool, ok = paging.ensure(
            SPEC, table, used, pool, jnp.array([9, 99, 1]), mask
        )
        assert used.tolist() == [3, 0, 1]  # ceil(9/4), untouched, ceil(1/4)
        assert bool(jnp.all(ok))
        assert int(pool.free_count) == 16 - 4
        # mapped prefix, -1 tail
        assert int(jnp.sum(table[0] >= 0)) == 3
        assert int(jnp.sum(table[1] >= 0)) == 0
        # distinct physical pages across slots
        pages = [int(p) for p in table[table >= 0]]
        assert len(pages) == len(set(pages))

    def test_ensure_is_monotone_and_idempotent(self):
        table, used, pool = _mk()
        mask = jnp.array([True, True, True])
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.array([8, 8, 8]), mask
        )
        before = table.copy()
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.array([5, 8, 2]), mask
        )  # shrinking requests never free pages
        assert used.tolist() == [2, 2, 2]
        assert bool(jnp.all(table == before))

    def test_all_or_nothing_when_pool_dry(self):
        spec = paging.PageSpec(page_size=4, num_pages=3, max_pages=3)
        table, used, pool = _mk(2, spec)
        table, used, pool, ok = paging.ensure(
            spec, table, used, pool, jnp.array([8, 8]),
            jnp.array([True, True]),
        )
        # slot 0 gets its 2 pages; slot 1 (2 needed, 1 left) gets none
        assert ok.tolist() == [True, False]
        assert used.tolist() == [2, 0]
        assert int(pool.free_count) == 1

    def test_release_returns_pages_and_clears_table(self):
        table, used, pool = _mk()
        mask3 = jnp.array([True, True, True])
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.array([12, 8, 4]), mask3
        )
        table, used, pool = paging.release(
            SPEC, table, used, pool, jnp.array([True, False, True])
        )
        assert int(pool.free_count) == 16 - 2  # only slot 1 keeps pages
        assert used.tolist() == [0, 2, 0]
        assert bool(jnp.all(table[0] == -1)) and bool(jnp.all(table[2] == -1))
        # freed pages are allocatable again and never collide with slot 1
        table, used, pool, ok = paging.ensure(
            SPEC, table, used, pool, jnp.array([24, 8, 24]), mask3
        )
        assert bool(jnp.all(ok))
        pages = [int(p) for p in table[table >= 0]]
        assert len(pages) == len(set(pages))

    def test_spec_of_geometry_and_budget(self):
        cfg = EngineConfig(
            gamma=3, max_slots=2, max_len=96, prefill_chunk=16,
            paged=True, page_size=16,
        )
        spec = paging.spec_of(cfg)
        assert spec.max_pages == -(-(96 + 16) // 16)  # slack = chunk = 16
        assert spec.num_pages == 2 * spec.max_pages   # fully provisioned
        budget = paging.PageBudget(spec, gamma=3)
        budget.note_admit(0, 5)
        budget.note_commit(0, 4)
        assert budget.slot_len[0] == 9
        assert not budget.needs_preemption()
        budget.note_release(0)
        assert budget.used_worst() == 0

    def test_spec_of_rejects_pool_smaller_than_one_slot(self):
        cfg = EngineConfig(
            gamma=3, max_slots=2, max_len=96, paged=True, page_size=16,
            num_pages=2,
        )
        with pytest.raises(AssertionError):
            paging.spec_of(cfg)


def _models(name="smollm-135m", seed=0):
    cfg = registry.smoke_config(name)
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    tgt = Model(cfg)
    drf = Model(cfg.with_(d_model=128, d_ff=256 if cfg.d_ff else 0,
                          name=cfg.name + "-d"))
    kt, kd = jax.random.split(jax.random.key(seed))
    return tgt, drf, tgt.init(kt), drf.init(kd)


def _serve(tgt, drf, tp, dp, cfg, prompts):
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    rids = [eng.submit(p) for p in prompts]
    out = eng.run()
    return eng, [out[r] for r in rids]


PROMPTS = [[5, 3, 8, 1, 2], [9, 9, 2, 4, 4], [1, 2, 3], [7, 7, 7, 7]]


class TestPagedEngineIdentity:
    @pytest.mark.parametrize("temperature", [0.0, 0.8])
    def test_paged_equals_dense(self, temperature):
        """Fully provisioned pool: the paged engine must commit exactly
        the dense engine's tokens — greedy AND sampled (same PRNG keys,
        bitwise-equal logits through the gather path)."""
        tgt, drf, tp, dp = _models(seed=3)
        outs = {}
        for paged in (False, True):
            cfg = EngineConfig(
                gamma=3, verifier="block", max_slots=2, max_len=96,
                temperature=temperature, max_new_tokens=12, paged=paged,
                page_size=16,
            )
            _, reqs = _serve(tgt, drf, tp, dp, cfg, PROMPTS)
            outs[paged] = [r.output for r in reqs]
        assert outs[True] == outs[False]

    def test_oversubscribed_pool_preempts_and_stays_lossless(self):
        """Pool smaller than slots x max_len: decode outgrows the pool,
        the engine preempts (recompute-on-resume), and committed tokens
        still exactly match a dense run."""
        tgt, drf, tp, dp = _models(seed=3)
        base = dict(
            gamma=3, verifier="block", max_slots=3, max_len=96,
            temperature=0.0, max_new_tokens=40,
        )
        _, ref = _serve(
            tgt, drf, tp, dp, EngineConfig(paged=False, **base), PROMPTS
        )
        cfg = EngineConfig(paged=True, page_size=16, num_pages=8, **base)
        spec = paging.spec_of(cfg)
        assert spec.num_pages < cfg.max_slots * spec.max_pages  # oversub
        eng, got = _serve(tgt, drf, tp, dp, cfg, PROMPTS)
        assert eng.last_stats["preemptions"] > 0
        assert sum(r.preemptions for r in got) > 0
        for r_ref, r_got in zip(ref, got):
            assert r_got.output == r_ref.output
            assert len(r_got.output) == 40

    def test_token_and_block_verifiers_paged(self):
        """Both lossless verifiers stay lossless through the paged path."""
        tgt, drf, tp, dp = _models()
        outs = {}
        for verifier in ("token", "block"):
            cfg = EngineConfig(
                gamma=4, verifier=verifier, max_slots=2, max_len=128,
                temperature=0.0, max_new_tokens=16, paged=True,
            )
            _, reqs = _serve(tgt, drf, tp, dp, cfg, PROMPTS[:2])
            outs[verifier] = [r.output for r in reqs]
        assert outs["token"] == outs["block"]


def _greedy_reference(model, params, prompt, n_new):
    seq = list(prompt)
    extras = model.make_extras(1)
    for _ in range(n_new):
        logits, _, _ = model.apply(
            params, jnp.asarray([seq], jnp.int32), extras=extras,
            mode="train",
        )
        seq.append(int(jnp.argmax(logits[0, -1, : model.cfg.vocab])))
    return seq[len(prompt):]


class TestRingWraparound:
    def test_windowed_layers_decode_past_ring_capacity(self):
        """Sliding-window rings stay exact after wrapping: decode far
        enough that total length exceeds window + chunk_slack (the ring
        capacity), for both the paged engine (windowed layers keep dense
        rings) and the dense engine."""
        tgt, drf, tp, dp = _models("mixtral-8x22b")  # smoke window = 32
        window = tgt.cfg.window_pattern[0]
        assert window > 0
        prompt = [3, 1, 4, 1, 5]
        n_new = 56  # total 61 > window 32 + slack (gamma+1=4 -> cap 48)
        ref = _greedy_reference(tgt, tp, prompt, n_new)
        for paged in (False, True):
            cfg = EngineConfig(
                gamma=3, verifier="block", max_slots=1, max_len=96,
                temperature=0.0, max_new_tokens=n_new, paged=paged,
            )
            _, (req,) = _serve(tgt, drf, tp, dp, cfg, [prompt])
            assert req.output[:n_new] == ref, paged


class TestPagedSharding:
    def test_pool_page_dim_takes_data_axes(self):
        from repro.distributed import sharding as shd
        from repro.models.attention import PagedKV

        mesh = AbstractMesh((("data", 16), ("model", 16)))
        model = Model(registry.get_config("smollm-135m"))
        cache = jax.eval_shape(
            lambda: model.init_cache(
                4, 4096, chunk_slack=16, page_pool=(1024, 16)
            )
        )
        shards = shd.cache_shardings(model, mesh, cache)
        pools = [
            e for seg in shards["segments"] for e in seg
            if isinstance(e, PagedKV)
        ]
        assert pools, "smollm global layers should be paged"
        spec = pools[0].k.spec
        # (G, P, page, K, hd): pages over data; n_kv=3 % 16 != 0 ->
        # head dim replicated
        assert spec == P(None, "data", None, None, None)
