"""Paged KV cache tests: allocator invariants, copy-on-write page
sharing (refcounts, fork/write/release leak-freedom, sibling isolation),
paged-vs-dense engine identity (greedy and sampled), multi-path engine
identity and pool drain, over-subscription with preemption +
recompute-on-resume, ring wraparound for sliding-window layers, and
page-pool sharding specs."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from repro.configs import registry
from repro.models import Model
from repro.models.attention import PagedKV
from repro.serving import paging
from repro.serving.engine import EngineConfig, SpecEngine
from repro.serving.runner import _apply_pool_copies

SPEC = paging.PageSpec(page_size=4, num_pages=16, max_pages=6)


def _mk(num_slots=3, spec=SPEC):
    table, used = paging.init_tables(spec, num_slots)
    return table, used, paging.init_pool(spec)


class TestAllocator:
    def test_ensure_grows_to_cover_length(self):
        table, used, pool = _mk()
        mask = jnp.array([True, False, True])
        table, used, pool, ok = paging.ensure(
            SPEC, table, used, pool, jnp.array([9, 99, 1]), mask
        )
        assert used.tolist() == [3, 0, 1]  # ceil(9/4), untouched, ceil(1/4)
        assert bool(jnp.all(ok))
        assert int(pool.free_count) == 16 - 4
        # mapped prefix, -1 tail
        assert int(jnp.sum(table[0] >= 0)) == 3
        assert int(jnp.sum(table[1] >= 0)) == 0
        # distinct physical pages across slots
        pages = [int(p) for p in table[table >= 0]]
        assert len(pages) == len(set(pages))

    def test_ensure_is_monotone_and_idempotent(self):
        table, used, pool = _mk()
        mask = jnp.array([True, True, True])
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.array([8, 8, 8]), mask
        )
        before = table.copy()
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.array([5, 8, 2]), mask
        )  # shrinking requests never free pages
        assert used.tolist() == [2, 2, 2]
        assert bool(jnp.all(table == before))

    def test_all_or_nothing_when_pool_dry(self):
        spec = paging.PageSpec(page_size=4, num_pages=3, max_pages=3)
        table, used, pool = _mk(2, spec)
        table, used, pool, ok = paging.ensure(
            spec, table, used, pool, jnp.array([8, 8]),
            jnp.array([True, True]),
        )
        # slot 0 gets its 2 pages; slot 1 (2 needed, 1 left) gets none
        assert ok.tolist() == [True, False]
        assert used.tolist() == [2, 0]
        assert int(pool.free_count) == 1

    def test_release_returns_pages_and_clears_table(self):
        table, used, pool = _mk()
        mask3 = jnp.array([True, True, True])
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.array([12, 8, 4]), mask3
        )
        table, used, pool = paging.release(
            SPEC, table, used, pool, jnp.array([True, False, True])
        )
        assert int(pool.free_count) == 16 - 2  # only slot 1 keeps pages
        assert used.tolist() == [0, 2, 0]
        assert bool(jnp.all(table[0] == -1)) and bool(jnp.all(table[2] == -1))
        # freed pages are allocatable again and never collide with slot 1
        table, used, pool, ok = paging.ensure(
            SPEC, table, used, pool, jnp.array([24, 8, 24]), mask3
        )
        assert bool(jnp.all(ok))
        pages = [int(p) for p in table[table >= 0]]
        assert len(pages) == len(set(pages))

    def test_spec_of_geometry_and_budget(self):
        cfg = EngineConfig(
            gamma=3, max_slots=2, max_len=96, prefill_chunk=16,
            paged=True, page_size=16,
        )
        spec = paging.spec_of(cfg)
        assert spec.max_pages == -(-(96 + 16) // 16)  # slack = chunk = 16
        assert spec.num_pages == 2 * spec.max_pages   # fully provisioned
        budget = paging.PageBudget(spec, gamma=3)
        budget.note_admit(0, 5)
        budget.note_commit(0, 4)
        assert budget.slot_len[0] == 9
        assert not budget.needs_preemption()
        budget.note_release(0)
        assert budget.used_worst() == 0

    def test_spec_of_rejects_pool_smaller_than_one_slot(self):
        cfg = EngineConfig(
            gamma=3, max_slots=2, max_len=96, paged=True, page_size=16,
            num_pages=2,
        )
        with pytest.raises(AssertionError):
            paging.spec_of(cfg)


def _pool_invariant(spec, pool):
    """No leaks, ever: free pages + referenced pages == the pool, the
    free stack holds exactly the unreferenced page ids, refcounts are
    non-negative."""
    free = int(pool.free_count)
    live = int(jnp.sum(pool.ref > 0))
    assert free + live == spec.num_pages, (free, live, spec.num_pages)
    assert bool(jnp.all(pool.ref >= 0))
    stack_ids = {int(x) for x in pool.free_stack[:free]}
    live_ids = {p for p in range(spec.num_pages) if int(pool.ref[p]) > 0}
    assert len(stack_ids) == free  # distinct
    assert stack_ids.isdisjoint(live_ids)


class TestCoW:
    def test_fork_bumps_refcounts_release_drains_to_zero(self):
        spec = paging.PageSpec(page_size=4, num_pages=16, max_pages=6)
        table, used, pool = _mk(1, spec)
        table, used, pool, _ = paging.ensure(
            spec, table, used, pool, jnp.array([10]), jnp.array([True])
        )
        assert bool(jnp.all(pool.ref[table[0, :3]] == 1))
        pt, pu, pool = paging.fork(
            spec, table, used, pool, 3, jnp.array([True])
        )
        # the slot's 1 claim per page became 3 path claims
        assert bool(jnp.all(pool.ref[table[0, :3]] == 3))
        assert int(pool.free_count) == 16 - 3
        _pool_invariant(spec, pool)
        pt = pt.reshape(3, spec.max_pages)
        pu = pu.reshape(3)
        # releasing the aliased rows decrements once each; the last
        # release returns the pages — refcounts back to zero.
        pt, pu, pool = paging.release(
            spec, pt, pu, pool, jnp.array([True, True, False])
        )
        assert bool(jnp.all(pool.ref[table[0, :3]] == 1))
        assert int(pool.free_count) == 16 - 3  # still claimed by path 2
        pt, pu, pool = paging.release(
            spec, pt, pu, pool, jnp.array([False, False, True])
        )
        assert int(jnp.max(pool.ref)) == 0
        assert int(pool.free_count) == 16
        _pool_invariant(spec, pool)

    def test_cow_write_does_not_perturb_sibling_paths(self):
        """A path writing into a (CoW-remapped) shared page never changes
        what its sibling reads through ITS table — and the shared prefix
        outside the write window stays physically shared."""
        spec = paging.PageSpec(page_size=4, num_pages=16, max_pages=6)
        table, used, pool = _mk(1, spec)
        table, used, pool, _ = paging.ensure(
            spec, table, used, pool, jnp.array([6]), jnp.array([True])
        )
        # committed KV content: pool leaf (G=1, P, page, 1, 1)
        k0 = jnp.arange(16 * 4, dtype=jnp.float32).reshape(1, 16, 4, 1, 1)
        cache = {"segments": [[PagedKV(k=k0, v=-k0)]]}

        pt, pu, pool = paging.fork(spec, table, used, pool, 2, jnp.array([True]))
        pt = pt.reshape(2, spec.max_pages)
        pu = pu.reshape(2)
        pt, pu, pool, src, dst, ok = paging.cow_ensure(
            spec, pt, pu, pool,
            jnp.array([5, 5]), jnp.array([9, 9]), jnp.array([True, True]),
            max_write_pages=2,
        )
        assert ok.tolist() == [True, True]
        _pool_invariant(spec, pool)
        p0, p1 = int(table[0, 0]), int(table[0, 1])
        # page 0 is outside the write window: still shared by both paths
        assert int(pt[0, 0]) == p0 and int(pt[1, 0]) == p0
        assert int(pool.ref[p0]) == 2
        # page 1 was shared and in the window: remapped to private copies
        assert int(pt[0, 1]) != p1 and int(pt[1, 1]) != p1
        assert int(pt[0, 1]) != int(pt[1, 1])
        # both paths grew a private speculative page 2
        assert int(pt[0, 2]) != int(pt[1, 2])
        assert pu.tolist() == [3, 3]
        # the fully-CoW'd source page was freed in the same call
        assert int(pool.ref[p1]) == 0

        cache = _apply_pool_copies(cache, src, dst)
        leaf = cache["segments"][0][0]
        # copies carry the committed content of the source page
        assert bool(jnp.all(leaf.k[0, int(pt[1, 1])] == k0[0, p1]))
        # path 0 writes into its copy (position 5 = logical page 1, off 1)
        k_new = leaf.k.at[0, int(pt[0, 1]), 1].set(999.0)
        # sibling's view through ITS table is untouched
        assert bool(jnp.all(k_new[0, int(pt[1, 1])] == k0[0, p1]))
        assert bool(jnp.all(k_new[0, p0] == k0[0, p0]))

    def test_cow_unshared_pages_write_in_place(self):
        """A row whose pages are exclusively owned (refcount 1) gets no
        copies from cow_ensure — only growth."""
        spec = paging.PageSpec(page_size=4, num_pages=8, max_pages=4)
        table, used, pool = _mk(1, spec)
        table, used, pool, _ = paging.ensure(
            spec, table, used, pool, jnp.array([6]), jnp.array([True])
        )
        before = table.copy()
        table, used, pool, src, dst, ok = paging.cow_ensure(
            spec, table, used, pool,
            jnp.array([5]), jnp.array([9]), jnp.array([True]),
            max_write_pages=2,
        )
        assert bool(ok[0]) and used.tolist() == [3]
        assert bool(jnp.all(src == -1)) and bool(jnp.all(dst == -1))
        assert bool(jnp.all(table[0, :2] == before[0, :2]))
        _pool_invariant(spec, pool)

    def _random_lifecycle(self, seed):
        import numpy as np

        rng = np.random.RandomState(seed)
        spec = paging.PageSpec(page_size=4, num_pages=32, max_pages=6)
        b = 3
        table, used, pool = _mk(b, spec)
        lens = np.zeros(b, int)
        for _ in range(12):
            op = rng.randint(3)
            slot = rng.randint(b)
            onehot = jnp.arange(b) == slot
            if op == 0:  # grow
                lens[slot] = min(lens[slot] + rng.randint(1, 8), 20)
                table, used, pool, _ = paging.ensure(
                    spec, table, used, pool,
                    jnp.asarray(lens, jnp.int32), onehot,
                )
            elif op == 1 and lens[slot] > 0:  # fork / cow / adopt / release
                k = rng.randint(2, 4)
                pt, pu, pool = paging.fork(spec, table, used, pool, k, onehot)
                pt = pt.reshape(b * k, spec.max_pages)
                pu = pu.reshape(b * k)
                wb = jnp.asarray(
                    np.repeat(np.maximum(lens - 1, 0), k), jnp.int32
                )
                nl = jnp.asarray(np.repeat(lens + 4, k), jnp.int32)
                mask = jnp.repeat(onehot, k)
                pt, pu, pool, _, _, ok = paging.cow_ensure(
                    spec, pt, pu, pool, wb, nl, mask, max_write_pages=3
                )
                winner = rng.randint(k)
                if bool(jnp.all(jnp.where(mask, ok, True))):
                    w_tab = pt.reshape(b, k, -1)[:, winner]
                    w_used = pu.reshape(b, k)[:, winner]
                    table = jnp.where(onehot[:, None], w_tab, table)
                    used = jnp.where(onehot, w_used, used)
                    keep = jnp.tile(jnp.arange(k), (b,)) == winner
                    rel = mask & ~keep
                else:  # could not fork: adopt path 0, drop the rest
                    w_tab = pt.reshape(b, k, -1)[:, 0]
                    w_used = pu.reshape(b, k)[:, 0]
                    table = jnp.where(onehot[:, None], w_tab, table)
                    used = jnp.where(onehot, w_used, used)
                    rel = mask & (jnp.tile(jnp.arange(k), (b,)) != 0)
                pt, pu, pool = paging.release(spec, pt, pu, pool, rel)
            else:  # retire
                lens[slot] = 0
                table, used, pool = paging.release(
                    spec, table, used, pool, onehot
                )
            _pool_invariant(spec, pool)
        table, used, pool = paging.release(
            spec, table, used, pool, jnp.ones(b, bool)
        )
        assert int(pool.free_count) == spec.num_pages
        assert int(jnp.max(pool.ref)) == 0

    def test_random_fork_write_release_never_leaks(self):
        for seed in (0, 1, 2, 3):
            self._random_lifecycle(seed)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_random_fork_write_release_never_leaks_property(self, seed):
        self._random_lifecycle(seed)


def _models(name="smollm-135m", seed=0):
    cfg = registry.smoke_config(name)
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    tgt = Model(cfg)
    drf = Model(cfg.with_(d_model=128, d_ff=256 if cfg.d_ff else 0,
                          name=cfg.name + "-d"))
    kt, kd = jax.random.split(jax.random.key(seed))
    return tgt, drf, tgt.init(kt), drf.init(kd)


def _serve(tgt, drf, tp, dp, cfg, prompts):
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    rids = [eng.submit(p) for p in prompts]
    out = eng.run()
    return eng, [out[r] for r in rids]


PROMPTS = [[5, 3, 8, 1, 2], [9, 9, 2, 4, 4], [1, 2, 3], [7, 7, 7, 7]]


class TestPagedEngineIdentity:
    @pytest.mark.parametrize("temperature", [0.0, 0.8])
    def test_paged_equals_dense(self, temperature):
        """Fully provisioned pool: the paged engine must commit exactly
        the dense engine's tokens — greedy AND sampled (same PRNG keys,
        bitwise-equal logits through the gather path)."""
        tgt, drf, tp, dp = _models(seed=3)
        outs = {}
        for paged in (False, True):
            cfg = EngineConfig(
                gamma=3, verifier="block", max_slots=2, max_len=96,
                temperature=temperature, max_new_tokens=12, paged=paged,
                page_size=16,
            )
            _, reqs = _serve(tgt, drf, tp, dp, cfg, PROMPTS)
            outs[paged] = [r.output for r in reqs]
        assert outs[True] == outs[False]

    def test_oversubscribed_pool_preempts_and_stays_lossless(self):
        """Pool smaller than slots x max_len: decode outgrows the pool,
        the engine preempts (recompute-on-resume), and committed tokens
        still exactly match a dense run."""
        tgt, drf, tp, dp = _models(seed=3)
        base = dict(
            gamma=3, verifier="block", max_slots=3, max_len=96,
            temperature=0.0, max_new_tokens=40,
        )
        _, ref = _serve(
            tgt, drf, tp, dp, EngineConfig(paged=False, **base), PROMPTS
        )
        cfg = EngineConfig(paged=True, page_size=16, num_pages=8, **base)
        spec = paging.spec_of(cfg)
        assert spec.num_pages < cfg.max_slots * spec.max_pages  # oversub
        eng, got = _serve(tgt, drf, tp, dp, cfg, PROMPTS)
        assert eng.last_stats["preemptions"] > 0
        assert sum(r.preemptions for r in got) > 0
        for r_ref, r_got in zip(ref, got):
            assert r_got.output == r_ref.output
            assert len(r_got.output) == 40

    def test_token_and_block_verifiers_paged(self):
        """Both lossless verifiers stay lossless through the paged path."""
        tgt, drf, tp, dp = _models()
        outs = {}
        for verifier in ("token", "block"):
            cfg = EngineConfig(
                gamma=4, verifier=verifier, max_slots=2, max_len=128,
                temperature=0.0, max_new_tokens=16, paged=True,
            )
            _, reqs = _serve(tgt, drf, tp, dp, cfg, PROMPTS[:2])
            outs[verifier] = [r.output for r in reqs]
        assert outs["token"] == outs["block"]


class TestMultiPathEngine:
    def test_temp0_multipath_equals_dense_greedy(self):
        """At temperature 0 all K forked paths draft identically, so the
        multi-path engine must commit EXACTLY the dense engine's greedy
        tokens — any CoW/page-aliasing corruption of the KV would change
        the logits and break this. page_size=8 forces multi-page CoW
        windows."""
        tgt, drf, tp, dp = _models(seed=3)
        base = dict(
            gamma=3, verifier="block", max_slots=2, max_len=96,
            temperature=0.0, max_new_tokens=16,
        )
        _, ref = _serve(
            tgt, drf, tp, dp, EngineConfig(paged=False, **base), PROMPTS
        )
        eng, got = _serve(
            tgt, drf, tp, dp,
            EngineConfig(paged=True, page_size=8, num_paths=2, **base),
            PROMPTS,
        )
        assert [r.output for r in got] == [r.output for r in ref]
        pool = eng.batch.pool
        assert int(pool.free_count) == eng.runner.page_spec.num_pages
        assert int(jnp.max(pool.ref)) == 0

    def test_multipath_sampled_drains_pool_and_emits_budget(self):
        """Sampled multi-path serving: every request completes its full
        budget, refcounts return to zero at retirement, and the per-step
        allocation telemetry is emitted."""
        tgt, drf, tp, dp = _models(seed=3)
        cfg = EngineConfig(
            gamma=3, verifier="block", max_slots=2, max_len=96,
            temperature=0.8, max_new_tokens=12, paged=True, page_size=16,
            num_paths=3,
        )
        eng, got = _serve(tgt, drf, tp, dp, cfg, PROMPTS)
        assert all(len(r.output) == 12 for r in got)
        pool = eng.batch.pool
        assert int(pool.free_count) == eng.runner.page_spec.num_pages
        assert int(jnp.max(pool.ref)) == 0
        trace = eng.last_stats["alloc_trace"]
        assert len(trace) == eng.last_stats["iterations"]
        assert all(
            0 <= t["occupancy_pages"] <= t["worst_case_pages"]
            for t in trace
        )

    def test_multipath_oversubscribed_preempts_and_stays_greedy_exact(self):
        """Over-subscribed pool + multi-path: preemption fires
        (recompute-on-resume) and the committed tokens still exactly
        match the dense greedy run."""
        tgt, drf, tp, dp = _models(seed=3)
        base = dict(
            gamma=3, verifier="block", max_slots=3, max_len=96,
            temperature=0.0, max_new_tokens=56,
        )
        _, ref = _serve(
            tgt, drf, tp, dp, EngineConfig(paged=False, **base), PROMPTS
        )
        cfg = EngineConfig(
            paged=True, page_size=16, num_pages=16, num_paths=2, **base
        )
        spec = paging.spec_of(cfg)
        full = paging.spec_of(
            EngineConfig(paged=True, page_size=16, num_paths=2, **base)
        )
        assert spec.num_pages < full.num_pages  # oversubscribed
        eng, got = _serve(tgt, drf, tp, dp, cfg, PROMPTS)
        assert eng.last_stats["preemptions"] > 0
        for r_ref, r_got in zip(ref, got):
            assert r_got.output == r_ref.output
        assert int(eng.batch.pool.free_count) == spec.num_pages

    def test_num_paths_requires_fully_paged_caches(self):
        tgt, drf, tp, dp = _models("mixtral-8x22b")  # sliding windows
        cfg = EngineConfig(
            gamma=3, max_slots=1, max_len=96, paged=True, num_paths=2,
        )
        with pytest.raises(ValueError, match="fully-paged"):
            SpecEngine(tgt, drf, tp, dp, cfg)
        with pytest.raises(ValueError, match="paged=True"):
            tgt2, drf2, tp2, dp2 = _models()
            SpecEngine(
                tgt2, drf2, tp2, dp2,
                EngineConfig(
                    gamma=3, max_slots=1, max_len=96, paged=False,
                    num_paths=2,
                ),
            )


def _greedy_reference(model, params, prompt, n_new):
    seq = list(prompt)
    extras = model.make_extras(1)
    for _ in range(n_new):
        logits, _, _ = model.apply(
            params, jnp.asarray([seq], jnp.int32), extras=extras,
            mode="train",
        )
        seq.append(int(jnp.argmax(logits[0, -1, : model.cfg.vocab])))
    return seq[len(prompt):]


class TestRingWraparound:
    def test_windowed_layers_decode_past_ring_capacity(self):
        """Sliding-window rings stay exact after wrapping: decode far
        enough that total length exceeds window + chunk_slack (the ring
        capacity), for both the paged engine (windowed layers keep dense
        rings) and the dense engine."""
        tgt, drf, tp, dp = _models("mixtral-8x22b")  # smoke window = 32
        window = tgt.cfg.window_pattern[0]
        assert window > 0
        prompt = [3, 1, 4, 1, 5]
        n_new = 56  # total 61 > window 32 + slack (gamma+1=4 -> cap 48)
        ref = _greedy_reference(tgt, tp, prompt, n_new)
        for paged in (False, True):
            cfg = EngineConfig(
                gamma=3, verifier="block", max_slots=1, max_len=96,
                temperature=0.0, max_new_tokens=n_new, paged=paged,
            )
            _, (req,) = _serve(tgt, drf, tp, dp, cfg, [prompt])
            assert req.output[:n_new] == ref, paged


class TestPagedSharding:
    def test_pool_page_dim_takes_data_axes(self):
        from repro.distributed import sharding as shd
        from repro.models.attention import PagedKV

        mesh = AbstractMesh((("data", 16), ("model", 16)))
        model = Model(registry.get_config("smollm-135m"))
        cache = jax.eval_shape(
            lambda: model.init_cache(
                4, 4096, chunk_slack=16, page_pool=(1024, 16)
            )
        )
        shards = shd.cache_shardings(model, mesh, cache)
        pools = [
            e for seg in shards["segments"] for e in seg
            if isinstance(e, PagedKV)
        ]
        assert pools, "smollm global layers should be paged"
        spec = pools[0].k.spec
        # (G, P, page, K, hd): pages over data; n_kv=3 % 16 != 0 ->
        # head dim replicated
        assert spec == P(None, "data", None, None, None)
