"""Tests for the paper's core claims: Lemma 1, Lemma 3, Theorems 1 and 2."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from repro.core import oracle, sampling, verification

KEY = jax.random.key(0)


def _random_pair(seed, vocab, order=1, alpha=0.5, concentration=1.0):
    kt, kd = jax.random.split(jax.random.key(seed))
    target = oracle.random_lm(kt, vocab, order, concentration)
    drafter = oracle.perturbed_drafter(kd, target, alpha, concentration)
    return target, drafter


# ---------------------------------------------------------------------------
# Section 2 motivating example — exact numbers from the paper.
# ---------------------------------------------------------------------------


class TestSection2:
    def test_token_10_9(self):
        t, d = oracle.section2_models()
        assert oracle.exact_expected_accepted(t, d, 2, "token") == pytest.approx(10 / 9, abs=1e-6)

    def test_block_11_9(self):
        t, d = oracle.section2_models()
        assert oracle.exact_expected_accepted(t, d, 2, "block") == pytest.approx(11 / 9, abs=1e-6)

    def test_ideal_12_9(self):
        t, d = oracle.section2_models()
        assert oracle.exact_expected_accepted(t, d, 2, "ideal") == pytest.approx(12 / 9, abs=1e-6)

    def test_lemma1_token_not_optimal(self):
        t, d = oracle.section2_models()
        tok = oracle.exact_expected_accepted(t, d, 2, "token")
        blk = oracle.exact_expected_accepted(t, d, 2, "block")
        assert blk > tok + 0.05


# ---------------------------------------------------------------------------
# Mechanics of the batched verifiers.
# ---------------------------------------------------------------------------


def _mc_verify(verifier, draft_tokens, q, p, n, seed=0):
    """Run a verifier n times on replicated inputs; return VerifyResult."""
    b = n
    dt = jnp.broadcast_to(draft_tokens, (b,) + draft_tokens.shape[1:])
    qq = jnp.broadcast_to(q, (b,) + q.shape[1:])
    pp = jnp.broadcast_to(p, (b,) + p.shape[1:])
    return verifier(jax.random.key(seed), dt, qq, pp)


class TestMechanics:
    @pytest.mark.parametrize("name", ["token", "block", "greedy_block"])
    def test_shapes_and_ranges(self, name):
        v = verification.get_verifier(name)
        b, g, vocab = 7, 5, 11
        kt, kd, kk = jax.random.split(jax.random.key(3), 3)
        q = jax.random.dirichlet(kd, jnp.ones(vocab), (b, g))
        p = jax.random.dirichlet(kt, jnp.ones(vocab), (b, g + 1))
        toks = jax.random.randint(kk, (b, g), 0, vocab)
        res = v(KEY, toks, q, p)
        assert res.tokens.shape == (b, g + 1)
        assert res.tokens.dtype == jnp.int32
        assert bool(jnp.all((res.num_accepted >= 0) & (res.num_accepted <= g)))
        assert bool(jnp.all(res.num_tokens == res.num_accepted + 1))
        assert bool(jnp.all((res.tokens >= 0) & (res.tokens < vocab)))

    @pytest.mark.parametrize("name", ["token", "block"])
    def test_identical_models_accept_everything(self, name):
        """p == q => every draft token accepted w.p. 1."""
        v = verification.get_verifier(name)
        b, g, vocab = 64, 6, 5
        rows = jax.random.dirichlet(jax.random.key(1), jnp.ones(vocab), (b, g + 1))
        toks = jax.random.randint(jax.random.key(2), (b, g), 0, vocab)
        res = v(KEY, toks, rows[:, :g], rows)
        assert bool(jnp.all(res.num_accepted == g))

    def test_accepted_prefix_is_draft_prefix(self):
        b, g, vocab = 32, 4, 6
        kt, kd, kk = jax.random.split(jax.random.key(5), 3)
        q = jax.random.dirichlet(kd, jnp.ones(vocab), (b, g))
        p = jax.random.dirichlet(kt, jnp.ones(vocab), (b, g + 1))
        toks = jax.random.randint(kk, (b, g), 0, vocab)
        for name in ["token", "block", "greedy_block"]:
            res = verification.get_verifier(name)(KEY, toks, q, p)
            pos = jnp.arange(g + 1)[None, :]
            keep = pos < res.num_accepted[:, None]
            padded = jnp.concatenate([toks, jnp.zeros((b, 1), jnp.int32)], 1)
            assert bool(jnp.all(jnp.where(keep, res.tokens == padded, True)))

    def test_gamma1_token_equals_block(self):
        """At gamma=1 the two algorithms coincide (paper Section 6)."""
        vocab = 8
        kt, kd = jax.random.split(jax.random.key(7))
        q = jax.random.dirichlet(kd, jnp.ones(vocab), (1, 1))
        p = jax.random.dirichlet(kt, jnp.ones(vocab), (1, 2))
        toks = jnp.array([[3]], jnp.int32)
        n = 60_000
        r_tok = _mc_verify(verification.token_verify, toks, q, p, n)
        r_blk = _mc_verify(verification.block_verify, toks, q, p, n)
        a_tok = float(jnp.mean(r_tok.num_accepted))
        a_blk = float(jnp.mean(r_blk.num_accepted))
        assert a_tok == pytest.approx(a_blk, abs=0.01)
        # Output-token distribution identical too.
        for j in range(vocab):
            f_tok = float(jnp.mean(r_tok.tokens[:, 0] == j))
            f_blk = float(jnp.mean(r_blk.tokens[:, 0] == j))
            assert f_tok == pytest.approx(f_blk, abs=0.015)

    def test_zero_q_token_rejected(self):
        """Adversarial draft token with q=0 must be rejected (both algs)."""
        vocab, g = 4, 2
        q = jnp.tile(jnp.array([[1.0, 0.0, 0.0, 0.0]]), (1, g, 1))
        p = jnp.full((1, g + 1, vocab), 0.25)
        toks = jnp.array([[1, 0]], jnp.int32)  # token 1 has q=0
        for name in ["token", "block"]:
            res = _mc_verify(verification.get_verifier(name), toks, q, p, 512)
            assert bool(jnp.all(res.num_accepted == 0))


# ---------------------------------------------------------------------------
# Lemma 3: Pr(tau >= i | X^i) == p_i(X^i) for block verification.
# ---------------------------------------------------------------------------


class TestLemma3:
    def test_acceptance_given_full_block(self):
        """For a FIXED draft block, tau >= i iff some j >= i accepts, so
        Pr(tau >= i | X^gamma) = 1 - prod_{j>=i}(1 - h_j) with h_j from
        Eq. (4). Checks the acceptance mechanics exactly."""
        g, vocab = 4, 5
        kt, kd, kk = jax.random.split(jax.random.key(11), 3)
        q = jax.random.dirichlet(kd, jnp.ones(vocab), (1, g))
        p = jax.random.dirichlet(kt, jnp.ones(vocab), (1, g + 1))
        toks = jax.random.randint(kk, (1, g), 0, vocab)

        qn = np.asarray(q, np.float64)[0]
        pn = np.asarray(p, np.float64)[0]
        tn = np.asarray(toks)[0]
        p_i, ps = 1.0, []
        for i in range(g):
            p_i = min(p_i * pn[i, tn[i]] / qn[i, tn[i]], 1.0)
            ps.append(p_i)
        hs = []
        for i in range(1, g):  # h_i, i = 1..g-1 (Eq. 4)
            s = np.maximum(ps[i - 1] * pn[i] - qn[i], 0.0).sum()
            hs.append(1.0 if ps[i - 1] >= 1.0 else s / (s + 1.0 - ps[i - 1]))
        hs.append(ps[g - 1])  # h_g = p_g

        n = 200_000
        res = _mc_verify(verification.block_verify, toks, q, p, n)
        for i in range(1, g + 1):
            expected = 1.0 - np.prod([1.0 - h for h in hs[i - 1:]])
            freq = float(jnp.mean(res.num_accepted >= i))
            assert freq == pytest.approx(expected, abs=0.01), f"i={i}"

    def test_lemma3_marginal_over_suffix(self):
        """Lemma 3 proper: Pr(tau >= 1 | X_1 = x) = p_1(x) = min(r_1, 1),
        with the draft suffix marginalized out (drafted from M_s)."""
        target, drafter = _random_pair(77, vocab=3, order=1, alpha=0.6)
        gamma, n = 3, 200_000
        key = jax.random.key(21)
        k1, k2 = jax.random.split(key)
        ctx_t = jnp.zeros((n,), jnp.int32)
        ctx_d = jnp.zeros((n,), jnp.int32)
        toks, qs, ps = [], [], []
        for _ in range(gamma):
            k1, sub = jax.random.split(k1)
            q_row = drafter.next_probs(ctx_d)
            ps.append(target.next_probs(ctx_t))
            tok = sampling.categorical(sub, q_row)
            toks.append(tok)
            qs.append(q_row)
            ctx_t = target.advance(ctx_t, tok)
            ctx_d = drafter.advance(ctx_d, tok)
        ps.append(target.next_probs(ctx_t))
        draft = jnp.stack(toks, 1)
        res = verification.block_verify(
            k2, draft, jnp.stack(qs, 1), jnp.stack(ps, 1)
        )
        pn = np.asarray(ps[0], np.float64)[0]
        qn = np.asarray(qs[0], np.float64)[0]
        first = np.asarray(draft[:, 0])
        acc = np.asarray(res.num_accepted >= 1)
        for x in range(3):
            mask = first == x
            if mask.sum() < 1000:
                continue
            p1 = min(pn[x] / qn[x], 1.0)
            assert acc[mask].mean() == pytest.approx(p1, abs=0.01), f"x={x}"


# ---------------------------------------------------------------------------
# Theorem 2 (optimality): E[accepted | block] >= E[accepted | token],
# checked in closed form over random model pairs.
# ---------------------------------------------------------------------------


class TestTheorem2:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        vocab=st.integers(2, 4),
        gamma=st.integers(1, 4),
        alpha=st.floats(0.05, 0.95),
    )
    def test_block_dominates_token_exact(self, seed, vocab, gamma, alpha):
        target, drafter = _random_pair(seed, vocab, order=1, alpha=alpha)
        tok = oracle.exact_expected_accepted(target, drafter, gamma, "token")
        blk = oracle.exact_expected_accepted(target, drafter, gamma, "block")
        ideal = oracle.exact_expected_accepted(target, drafter, gamma, "ideal")
        assert blk >= tok - 1e-9
        assert ideal >= blk - 1e-9  # Lemma 8 upper bound

    def test_mc_matches_exact_expected_accepted(self):
        """The batched verifiers' MC acceptance matches the closed forms."""
        target, drafter = _random_pair(123, vocab=3, order=1, alpha=0.6)
        gamma, n = 3, 150_000
        table_t = np.asarray(target.table)
        table_d = np.asarray(drafter.table)

        # Draft-from-drafter MC through the actual verifier kernels.
        key = jax.random.key(9)
        k1, k2 = jax.random.split(key)
        ctx_t = jnp.zeros((n,), jnp.int32)
        ctx_d = jnp.zeros((n,), jnp.int32)
        toks, qs, ps = [], [], []
        for i in range(gamma):
            k1, sub = jax.random.split(k1)
            q_row = drafter.next_probs(ctx_d)
            ps.append(target.next_probs(ctx_t))
            tok = sampling.categorical(sub, q_row)
            toks.append(tok)
            qs.append(q_row)
            ctx_t = target.advance(ctx_t, tok)
            ctx_d = drafter.advance(ctx_d, tok)
        ps.append(target.next_probs(ctx_t))
        draft = jnp.stack(toks, 1)
        q = jnp.stack(qs, 1)
        p = jnp.stack(ps, 1)
        for name in ["token", "block"]:
            res = verification.get_verifier(name)(k2, draft, q, p)
            mc = float(jnp.mean(res.num_accepted))
            exact = oracle.exact_expected_accepted(target, drafter, gamma, name)
            assert mc == pytest.approx(exact, abs=0.02), name
