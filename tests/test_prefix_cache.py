"""Cross-request prefix caching tests.

Three layers:

* allocator semantics of the ``cached`` page state — release with a
  cache mask parks pages off-stack at refcount 0, claims resurrect
  them, eviction frees them;
* the host-side :class:`~repro.serving.paging.PrefixCache` radix index
  (page-aligned keying, claim pinning, duplicate-content adoption,
  leaf-first LRU eviction);
* the serving engine with ``prefix_cache=True`` — hits skip prefill
  tokens while staying bit-identical to the uncached engine (greedy AND
  sampled), preemption resume re-claims its own prefix, eviction
  pressure never leaks pages, and the "device allocation can never
  fail" invariant holds under randomized PageBudget-admitted traffic
  (the hypothesis property form of the docstring claim).
"""

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from repro.configs import registry
from repro.models import Model
from repro.serving import paging
from repro.serving.engine import EngineConfig, SpecEngine

SPEC = paging.PageSpec(page_size=4, num_pages=16, max_pages=6)


def _mk(num_slots=2, spec=SPEC):
    table, used = paging.init_tables(spec, num_slots)
    return table, used, paging.init_pool(spec)


def _pool_invariant(spec, pool, cache=None):
    """free + referenced + parked-cached == the pool; the stack holds
    exactly the free ids (disjoint from referenced and cached pages);
    the device ``cached`` set mirrors the host index page-for-page."""
    free = int(pool.free_count)
    ref = np.asarray(pool.ref)
    cached = np.asarray(pool.cached)
    live = int((ref > 0).sum())
    parked = int(((ref == 0) & cached).sum())
    assert free + live + parked == spec.num_pages, (free, live, parked)
    assert (ref >= 0).all()
    stack = {int(x) for x in pool.free_stack[:free]}
    assert len(stack) == free
    assert not stack & {p for p in range(spec.num_pages) if ref[p] > 0}
    assert not stack & {p for p in range(spec.num_pages) if cached[p]}
    if cache is not None:
        assert set(cache.by_page) == {
            p for p in range(spec.num_pages) if cached[p]
        }


class TestCachedPageState:
    def test_release_with_cache_mask_parks_pages(self):
        table, used, pool = _mk()
        table, used, pool, ok = paging.ensure(
            SPEC, table, used, pool, jnp.array([10, 0]),
            jnp.array([True, False]),
        )
        assert bool(jnp.all(ok)) and used.tolist() == [3, 0]
        ids = [int(p) for p in table[0, :3]]
        cache_cols = jnp.zeros((2, SPEC.max_pages), bool).at[0, :2].set(True)
        table, used, pool = paging.release(
            SPEC, table, used, pool, jnp.array([True, False]),
            cache_cols=cache_cols,
        )
        # pages 0,1 parked (cached, ref 0, off stack); page 2 freed
        assert int(pool.free_count) == 16 - 2
        assert int(jnp.max(pool.ref)) == 0
        assert [bool(pool.cached[p]) for p in ids] == [True, True, False]
        _pool_invariant(SPEC, pool)

    def test_claim_resurrects_and_evict_frees(self):
        table, used, pool = _mk()
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.array([8, 0]),
            jnp.array([True, False]),
        )
        ids = [int(p) for p in table[0, :2]]
        cc = jnp.zeros((2, SPEC.max_pages), bool).at[0, :2].set(True)
        table, used, pool = paging.release(
            SPEC, table, used, pool, jnp.array([True, False]), cache_cols=cc
        )
        # a later slot claims the parked run: refcounts bump, no popping
        table, used, pool = paging.host_claim_prefix(
            SPEC, table, used, pool, 1, ids
        )
        assert used.tolist() == [0, 2]
        assert [int(pool.ref[p]) for p in ids] == [1, 1]
        assert int(pool.free_count) == 16 - 2
        _pool_invariant(SPEC, pool)
        # release WITHOUT re-caching: cached pages still never hit the
        # stack (the index owns them until eviction)
        table, used, pool = paging.release(
            SPEC, table, used, pool, jnp.array([False, True])
        )
        assert int(pool.free_count) == 16 - 2
        assert int(jnp.max(pool.ref)) == 0
        _pool_invariant(SPEC, pool)
        # eviction is the only path back to free
        pool = paging.host_evict(SPEC, pool, ids)
        assert int(pool.free_count) == 16
        assert not bool(jnp.any(pool.cached))
        _pool_invariant(SPEC, pool)

    def test_shared_claim_refcounts(self):
        """Two live slots claiming the same cached run: ref 2; releases
        drop to 1 then park at 0."""
        table, used, pool = _mk(3)
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.array([8, 0, 0]),
            jnp.array([True, False, False]),
        )
        ids = [int(p) for p in table[0, :2]]
        cc = jnp.zeros((3, SPEC.max_pages), bool).at[0, :2].set(True)
        table, used, pool = paging.release(
            SPEC, table, used, pool, jnp.array([True, False, False]),
            cache_cols=cc,
        )
        for slot in (1, 2):
            table, used, pool = paging.host_claim_prefix(
                SPEC, table, used, pool, slot, ids
            )
        assert [int(pool.ref[p]) for p in ids] == [2, 2]
        cc = jnp.zeros((3, SPEC.max_pages), bool).at[1:, :2].set(True)
        table, used, pool = paging.release(
            SPEC, table, used, pool, jnp.array([False, True, True]),
            cache_cols=cc,
        )
        assert int(jnp.max(pool.ref)) == 0
        assert int(pool.free_count) == 16 - 2  # still parked, no leak
        _pool_invariant(SPEC, pool)


class TestPrefixIndex:
    def test_lookup_caps_below_last_prompt_token(self):
        cache = paging.PrefixCache(SPEC)
        toks = list(range(12))
        cache.insert(toks[:8], [3, 5])
        # 9 tokens: (9-1)//4 = 2 full pages claimable
        assert [n.page for n in cache.lookup(toks[:9])] == [3, 5]
        # 8 tokens: position 7 must be rewritten -> only 1 page
        assert [n.page for n in cache.lookup(toks[:8])] == [3]
        # diverging second page stops the walk
        other = toks[:4] + [99, 99, 99, 99, 0]
        assert [n.page for n in cache.lookup(other)] == [3]
        assert cache.lookup([7]) == []

    def test_insert_adopts_and_rejects_duplicates(self):
        cache = paging.PrefixCache(SPEC)
        toks = list(range(8))
        assert cache.insert(toks, [2, 4]) == [True, True]
        # identical content arriving on different physical pages: the
        # index keeps the first copy, the second releases normally
        assert cache.insert(toks, [7, 9]) == [False, False]
        assert [n.page for n in cache.lookup(toks + [0])] == [2, 4]
        # a claimed re-insert (same ids) is re-adopted
        assert cache.insert(toks, [2, 4]) == [True, True]
        assert cache.cached_pages == 2

    def test_claims_pin_and_propagate(self):
        cache = paging.PrefixCache(SPEC)
        toks = list(range(12))
        cache.insert(toks, [1, 2, 3])
        path = cache.lookup(toks + [0])
        cache.claim(path)
        assert cache.reclaimable_pages() == 0  # whole path pinned
        assert cache.evict_lru(3) == []
        cache.release_claims(path)
        assert cache.reclaimable_pages() == 3

    def test_evict_lru_leaf_first(self):
        cache = paging.PrefixCache(SPEC)
        a = [0] * 8
        b = [0] * 4 + [1] * 4
        cache.insert(a, [10, 11])     # shared first page 10
        cache.insert(b, [10, 12])
        # touch branch b more recently
        cache.claim(cache.lookup(b + [0]))
        cache.release_claims(cache.lookup(b + [0]))
        # first eviction must take the LRU *leaf* (11), never the shared
        # interior page 10 (its children would become unreachable)
        assert cache.evict_lru(1) == [11]
        assert cache.evict_lru(2) == [12, 10]
        assert cache.cached_pages == 0


# ---------------------------------------------------------------------------
# Engine-level tests
# ---------------------------------------------------------------------------


def _models(name="smollm-135m", seed=0):
    cfg = registry.smoke_config(name)
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    tgt = Model(cfg)
    drf = Model(cfg.with_(d_model=128, d_ff=256 if cfg.d_ff else 0,
                          name=cfg.name + "-d"))
    kt, kd = jax.random.split(jax.random.key(seed))
    return tgt, drf, tgt.init(kt), drf.init(kd)


def _serve(eng, prompts):
    rids = [eng.submit(p) for p in prompts]
    out = eng.run()
    return [out[r] for r in rids]


# Two prompt families sharing a >= 2-page prefix at page_size=8.
SHARED = [5, 3, 8, 1, 2, 9, 4, 6, 7, 7, 1, 3, 2, 8, 9, 5]  # 2 pages
PROMPTS = [
    SHARED + [11, 12, 13, 14],
    SHARED + [21, 22, 23],
    SHARED + [31],
]


class TestEnginePrefixCache:
    BASE = dict(
        gamma=3, verifier="block", max_slots=1, max_len=96,
        temperature=0.0, max_new_tokens=10, paged=True, page_size=8,
    )

    def test_hits_skip_prefill_and_stay_greedy_identical(self):
        """max_slots=1 serializes the requests, so request 2+ admit after
        request 1 retired and cached its prefix: strictly fewer prompt
        tokens are prefilled (the claim starts prefill at the first
        uncached position) and committed tokens match the uncached
        engine exactly."""
        tgt, drf, tp, dp = _models(seed=3)
        ref_eng = SpecEngine(
            tgt, drf, tp, dp, EngineConfig(prefix_cache=False, **self.BASE)
        )
        ref = _serve(ref_eng, PROMPTS)
        ref_prefill = ref_eng.last_stats["prefill_tokens"]

        eng = SpecEngine(
            tgt, drf, tp, dp, EngineConfig(prefix_cache=True, **self.BASE)
        )
        got = _serve(eng, PROMPTS)
        assert [r.output for r in got] == [r.output for r in ref]
        stats = eng.last_stats
        # requests 2 and 3 each claimed the 2 shared pages (16 tokens)
        assert stats["prefix_cache"]["hits"] == 2
        assert stats["prefix_cache"]["claimed_tokens"] == 32
        assert stats["prefill_tokens"] == ref_prefill - 32
        assert stats["prefill_tokens"] < ref_prefill

    def test_cross_run_hits_and_sampled_bitwise_identity(self):
        """The index persists across run() calls; with the single-slot
        sequential workload the decode key stream is untouched by how
        much prefill ran, so even SAMPLED outputs are bit-identical to
        the uncached engine."""
        tgt, drf, tp, dp = _models(seed=3)
        outs = {}
        for pc in (False, True):
            cfg = EngineConfig(
                **{**self.BASE, "temperature": 0.8}, prefix_cache=pc
            )
            eng = SpecEngine(tgt, drf, tp, dp, cfg)
            eng.reset(seed=5)
            first = [r.output for r in _serve(eng, PROMPTS[:1])]
            second = [r.output for r in _serve(eng, PROMPTS)]
            outs[pc] = (first, second)
            if pc:
                s = eng.last_stats["prefix_cache"]
                assert s["hits"] == 3  # every prompt reused the prefix
        assert outs[True] == outs[False]

    def test_full_prefix_hit_admits_ready(self):
        """A prompt whose first plen-1 tokens are all cached skips
        prefill entirely (ready at admission)."""
        tgt, drf, tp, dp = _models(seed=3)
        eng = SpecEngine(
            tgt, drf, tp, dp, EngineConfig(prefix_cache=True, **self.BASE)
        )
        prompt = SHARED + [42]  # plen 17; plen-1 = 16 = 2 full pages
        _serve(eng, [prompt])
        base_prefill = eng.last_stats["prefill_tokens"]
        assert base_prefill == 16
        _serve(eng, [prompt])
        assert eng.last_stats["prefill_tokens"] == 0
        assert eng.last_stats["prefill_steps"] == 0
        ref_eng = SpecEngine(
            tgt, drf, tp, dp, EngineConfig(prefix_cache=False, **self.BASE)
        )
        a = _serve(ref_eng, [prompt])
        b = _serve(ref_eng, [prompt])
        eng2 = SpecEngine(
            tgt, drf, tp, dp, EngineConfig(prefix_cache=True, **self.BASE)
        )
        x = _serve(eng2, [prompt])
        y = _serve(eng2, [prompt])
        assert [r.output for r in x] == [r.output for r in a]
        assert [r.output for r in y] == [r.output for r in b]

    def test_eviction_pressure_no_leaked_pages(self):
        """A pool too small to keep every retired prefix forces LRU
        eviction; afterwards every page is either free or accounted to
        the index — zero refcounts, no limbo pages — and outputs still
        match the uncached engine."""
        tgt, drf, tp, dp = _models(seed=3)
        base = dict(self.BASE, max_new_tokens=8)
        prompts = [
            [f + 1] * 8 + [f + 1, 9, f + 2, 7]  # distinct 1-page prefixes
            for f in range(6)
        ] + [PROMPTS[0], PROMPTS[1]]
        cfg = EngineConfig(prefix_cache=True, num_pages=16, **base)
        spec = paging.spec_of(cfg)
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        got = _serve(eng, prompts)
        ref = _serve(
            SpecEngine(tgt, drf, tp, dp,
                       EngineConfig(prefix_cache=False, num_pages=16, **base)),
            prompts,
        )
        assert [r.output for r in got] == [r.output for r in ref]
        stats = eng.last_stats
        assert stats["prefix_cache"]["evicted_pages"] > 0
        pool = eng.batch.pool
        assert int(jnp.max(pool.ref)) == 0
        _pool_invariant(spec, pool, eng.prefix_cache)
        assert (
            int(pool.free_count) + eng.prefix_cache.cached_pages
            == spec.num_pages
        )

    def test_preemption_resume_reclaims_own_prefix(self):
        """Over-subscribed pool: preempted requests park their committed
        pages and their resume claims them back — committed tokens still
        exactly match the dense engine."""
        tgt, drf, tp, dp = _models(seed=3)
        base = dict(
            gamma=3, verifier="block", max_slots=3, max_len=96,
            temperature=0.0, max_new_tokens=40, page_size=16,
        )
        dense = SpecEngine(
            tgt, drf, tp, dp, EngineConfig(paged=False, **base)
        )
        ref = _serve(dense, [p[:8] for p in PROMPTS])
        cfg = EngineConfig(
            paged=True, num_pages=8, prefix_cache=True, **base
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        got = _serve(eng, [p[:8] for p in PROMPTS])
        assert eng.last_stats["preemptions"] > 0
        assert eng.last_stats["prefix_cache"]["hits"] > 0  # resume claims
        for r_ref, r_got in zip(ref, got):
            assert r_got.output == r_ref.output
        assert int(jnp.max(eng.batch.pool.ref)) == 0
        _pool_invariant(
            paging.spec_of(cfg), eng.batch.pool, eng.prefix_cache
        )

    def test_multipath_with_prefix_cache_temp0(self):
        """CoW multi-path forking composes with claimed prefixes: the
        fork's transient refcount bumps on claimed pages cancel at
        adoption, and temp-0 outputs stay dense-identical."""
        tgt, drf, tp, dp = _models(seed=3)
        base = dict(
            gamma=3, verifier="block", max_slots=1, max_len=96,
            temperature=0.0, max_new_tokens=10, page_size=8,
        )
        dense = SpecEngine(
            tgt, drf, tp, dp, EngineConfig(paged=False, **base)
        )
        ref = [
            [r.output for r in _serve(dense, PROMPTS[:1])],
            [r.output for r in _serve(dense, PROMPTS)],
        ]
        cfg = EngineConfig(
            paged=True, num_paths=2, prefix_cache=True, **base
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        got = [
            [r.output for r in _serve(eng, PROMPTS[:1])],
            [r.output for r in _serve(eng, PROMPTS)],
        ]
        assert got == ref
        assert eng.last_stats["prefix_cache"]["hits"] >= 3
        assert int(jnp.max(eng.batch.pool.ref)) == 0
        _pool_invariant(
            paging.spec_of(cfg), eng.batch.pool, eng.prefix_cache
        )

    def test_prefix_cache_requires_fully_paged(self):
        tgt, drf, tp, dp = _models("mixtral-8x22b")  # sliding windows
        with pytest.raises(ValueError, match="prefix_cache"):
            SpecEngine(
                tgt, drf, tp, dp,
                EngineConfig(
                    gamma=3, max_slots=1, max_len=96, paged=True,
                    prefix_cache=True,
                ),
            )
        with pytest.raises(ValueError, match="paged=True"):
            tgt2, drf2, tp2, dp2 = _models()
            SpecEngine(
                tgt2, drf2, tp2, dp2,
                EngineConfig(
                    gamma=3, max_slots=1, max_len=96, paged=False,
                    prefix_cache=True,
                ),
            )


# ---------------------------------------------------------------------------
# "Device allocation can never fail" — the property form
# ---------------------------------------------------------------------------


def _budget_traffic_lifecycle(seed: int, num_paths: int = 1):
    """Randomized serving traffic driven by the REAL host policy
    (PageBudget admission, LIFO preemption, prefix claims, LRU eviction)
    against the REAL device allocator ops — asserting that ``ensure`` /
    ``cow_ensure`` never return ``ok=False`` for a budgeted slot, the
    docstring invariant the engine's correctness rests on. Mirrors the
    engine loop's ordering exactly: preempt -> admit(+claim) -> evict ->
    allocate -> commit -> retire."""
    rng = np.random.RandomState(seed)
    gamma = 3
    spec = paging.PageSpec(page_size=4, num_pages=40, max_pages=10)
    max_len = 32  # keep one slot's worst case well inside the pool
    budget = paging.PageBudget(spec, gamma, num_paths=num_paths)
    cache = paging.PrefixCache(spec)
    num_slots = 3
    table, used = paging.init_tables(spec, num_slots)
    pool = paging.init_pool(spec)
    shared = [rng.randint(0, 7, size=12).tolist() for _ in range(2)]
    queue: deque = deque()
    # live[slot] = {"tokens": [...], "claims": [...]}
    live: dict[int, dict] = {}
    seq = 0
    admit_order: dict[int, int] = {}

    def release_slot(slot, to_cache=True):
        nonlocal table, used, pool
        st = live.pop(slot)
        cache.release_claims(st["claims"])
        cc = np.zeros((num_slots, spec.max_pages), bool)
        if to_cache:
            n_cache = (len(st["tokens"]) - 1) // spec.page_size
            if n_cache > 0:
                ids = [int(p) for p in table[slot, :n_cache]]
                assert all(p >= 0 for p in ids)
                cc[slot, :n_cache] = cache.insert(st["tokens"], ids)
        mask = jnp.arange(num_slots) == slot
        table, used, pool = paging.release(
            spec, table, used, pool, mask, cache_cols=jnp.asarray(cc)
        )
        budget.note_release(slot)
        admit_order.pop(slot)

    for _ in range(60):
        if rng.rand() < 0.6:
            base = shared[rng.randint(2)]
            npages = rng.choice([1, 2, 3])
            tail = rng.randint(0, 7, size=rng.randint(1, 5)).tolist()
            queue.append(base[: npages * spec.page_size] + tail)
        # 1. preemption (engine order: sync, then LIFO preempt)
        while budget.needs_preemption() and len(live) > 1:
            victim = max(live, key=lambda s: admit_order[s])
            st = live[victim]
            queue.appendleft(st["tokens"])
            release_slot(victim)
        # 2. admission (+ prefix claims)
        for slot in range(num_slots):
            if slot not in live and queue:
                if not budget.can_admit(len(queue[0])):
                    break
                toks = queue.popleft()
                nodes = cache.lookup(toks)
                if nodes:
                    cache.claim(nodes)
                    table, used, pool = paging.host_claim_prefix(
                        spec, table, used, pool, slot,
                        [n.page for n in nodes],
                    )
                live[slot] = {"tokens": list(toks), "claims": nodes}
                budget.note_admit(slot, len(toks))
                admit_order[slot] = seq
                seq += 1
        # 3. eviction: restore the free-stack invariant before dispatch
        deficit = budget.evict_deficit(cache.reclaimable_pages())
        if deficit > 0:
            evicted = cache.evict_lru(deficit)
            assert len(evicted) == deficit  # always satisfiable
            pool = paging.host_evict(spec, pool, evicted)
        # 4. the dispatch's allocations must never fail
        lens = jnp.asarray(
            [len(live[s]["tokens"]) if s in live else 0
             for s in range(num_slots)], jnp.int32,
        )
        run = jnp.asarray([s in live for s in range(num_slots)])
        if num_paths == 1:
            table, used, pool, ok = paging.ensure(
                spec, table, used, pool, lens + gamma + 1, run
            )
            assert bool(jnp.all(jnp.where(run, ok, True))), (
                "ensure failed under budget", seed
            )
        else:
            table, used, pool, ok = paging.ensure(
                spec, table, used, pool, lens, run
            )
            assert bool(jnp.all(jnp.where(run, ok, True)))
            k = num_paths
            pt, pu, pool = paging.fork(spec, table, used, pool, k, run)
            pt = pt.reshape(num_slots * k, spec.max_pages)
            pu = pu.reshape(num_slots * k)
            lens_k = jnp.repeat(lens, k)
            run_k = jnp.repeat(run, k)
            w = spec.pages_for(gamma + 1) + 1
            pt, pu, pool, _, _, ok_k = paging.cow_ensure(
                spec, pt, pu, pool,
                jnp.maximum(lens_k - 1, 0), lens_k + gamma, run_k,
                max_write_pages=w,
            )
            assert bool(jnp.all(jnp.where(run_k, ok_k, True))), (
                "cow_ensure failed under budget", seed
            )
            winner = rng.randint(k)
            w_tab = pt.reshape(num_slots, k, -1)[:, winner]
            w_used = pu.reshape(num_slots, k)[:, winner]
            table = jnp.where(run[:, None], w_tab, table)
            used = jnp.where(run, w_used, used)
            keep = jnp.tile(jnp.arange(k), (num_slots,)) == winner
            pt, pu, pool = paging.release(
                spec, pt, pu, pool, run_k & ~keep
            )
        # 5. commit
        for slot in list(live):
            st = live[slot]
            n_new = int(rng.randint(1, gamma + 2))
            st["tokens"].extend(rng.randint(0, 7, size=n_new).tolist())
            budget.note_commit(slot, n_new)
            if len(st["tokens"]) >= max_len or rng.rand() < 0.15:
                release_slot(slot)
        _pool_invariant(spec, pool, cache)

    for slot in list(live):
        release_slot(slot)
    _pool_invariant(spec, pool, cache)
    assert int(jnp.max(pool.ref)) == 0
    assert (
        int(pool.free_count) + cache.cached_pages == spec.num_pages
    )


class TestAllocationNeverFails:
    def test_budget_traffic_deterministic(self):
        for seed in (0, 1, 2):
            _budget_traffic_lifecycle(seed, num_paths=1)
        _budget_traffic_lifecycle(3, num_paths=2)

    @settings(max_examples=12, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        num_paths=st.sampled_from([1, 1, 2]),
    )
    def test_budget_traffic_property(self, seed, num_paths):
        _budget_traffic_lifecycle(seed, num_paths=num_paths)
