"""Exact lossless-guarantee harness.

The repo's central claim is that every verifier — token (Algorithm 1),
block (Algorithm 2, the paper), and greedy multi-path (K forked draft
paths) — leaves the output distribution EXACTLY the target model's
autoregressive distribution. These tests prove it for tiny tabular
models by full marginalization, not Monte Carlo:

* every draft outcome (all ``V**gamma`` paths; all ``V**(K*gamma)``
  joint path tuples for multi-path) is enumerated with its drafter
  probability;
* the accept/reject coins are integrated out exactly through the
  *implementation's own probability surfaces* (``token_accept_probs`` /
  ``block_accept_probs`` / ``multipath_rrs_tables`` + friends from
  ``repro.core.verification``), evaluated in float64 (``jax_enable_x64``
  is switched on for this module);
* the committed-token process is iterated to a fixed output length and
  compared against the target's exact joint distribution to float64
  tolerance.

Every future verifier variant must pass this harness.
"""

import itertools

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

ATOL = 1e-9  # float64 marginalization tolerance


@pytest.fixture(autouse=True, scope="module")
def _enable_x64():
    """Run this module's surfaces in float64; restore float32 after."""
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def _models(seed, vocab, alpha=0.5, concentration=1.0, order=1):
    """Tabular target/drafter pair plus float64-normalized numpy tables
    (the ground truth both the surfaces and the AR reference consume)."""
    from repro.core import oracle

    kt, kd = jax.random.split(jax.random.key(seed))
    target = oracle.random_lm(kt, vocab, order, concentration)
    drafter = oracle.perturbed_drafter(kd, target, alpha, concentration)
    t_tab = np.asarray(target.table, np.float64)
    d_tab = np.asarray(drafter.table, np.float64)
    t_tab = t_tab / t_tab.sum(-1, keepdims=True)
    d_tab = d_tab / d_tab.sum(-1, keepdims=True)
    return target, drafter, t_tab, d_tab


def _rows_along(tab, ctx0, path, vocab):
    """Conditional rows visited drafting ``path`` from ``ctx0`` plus the
    path's probability under ``tab``."""
    n_ctx = tab.shape[0]
    ctx, prob, rows = ctx0, 1.0, []
    for tok in path:
        rows.append(tab[ctx])
        prob *= tab[ctx][tok]
        ctx = (ctx * vocab + tok) % n_ctx
    rows.append(tab[ctx])
    return prob, rows


# ---------------------------------------------------------------------------
# Exact committed-suffix distributions (one verification iteration),
# marginalized through the implementation's probability surfaces.
# ---------------------------------------------------------------------------


def _commit_dist_single(name, t_tab, d_tab, ctx0, gamma, vocab):
    """{committed suffix tuple: probability} for one iteration of token /
    block verification from context ``ctx0``. The suffix is the tau
    accepted draft tokens plus the bonus token."""
    import jax.numpy as jnp

    from repro.core import verification

    paths = list(itertools.product(range(vocab), repeat=gamma))
    qp, qr, pr = [], [], []
    for path in paths:
        qprob, q_rows = _rows_along(d_tab, ctx0, path, vocab)
        _, p_rows = _rows_along(t_tab, ctx0, path, vocab)
        qp.append(qprob)
        qr.append(q_rows[:gamma])
        pr.append(p_rows)
    ctx = verification.make_context(
        jnp.asarray(paths, jnp.int32), jnp.asarray(qr), jnp.asarray(pr)
    )
    assert ctx.q_probs.dtype == jnp.float64  # the point of this module

    if name == "token":
        h = np.asarray(verification.token_accept_probs(ctx), np.float64)
        # First rejection stops the block: tau = leading accepts.
        p_tau = np.zeros((len(paths), gamma + 1))
        run = np.ones(len(paths))
        for t in range(gamma):
            p_tau[:, t] = run * (1.0 - h[:, t])
            run = run * h[:, t]
        p_tau[:, gamma] = run
        bonus = verification.token_bonus_dist
    elif name == "block":
        h = np.asarray(verification.block_accept_probs(ctx), np.float64)
        # Independent coins; tau = largest accepted index.
        p_tau = np.zeros((len(paths), gamma + 1))
        surv = np.ones(len(paths))  # prod_{j > t} (1 - h_j)
        for t in range(gamma, 0, -1):
            p_tau[:, t] = surv * h[:, t - 1]
            surv = surv * (1.0 - h[:, t - 1])
        p_tau[:, 0] = surv
        bonus = verification.block_bonus_dist
    else:
        raise ValueError(name)

    dist: dict[tuple, float] = {}
    for t in range(gamma + 1):
        tau = jnp.full((len(paths),), t, jnp.int32)
        rows = np.asarray(bonus(ctx, tau), np.float64)
        for n, path in enumerate(paths):
            mass = qp[n] * p_tau[n, t]
            if mass <= 0.0:
                continue
            for v in range(vocab):
                if rows[n, v] > 0.0:
                    key = path[:t] + (v,)
                    dist[key] = dist.get(key, 0.0) + mass * rows[n, v]
    return dist


def _commit_dist_multipath(t_tab, d_tab, ctx0, gamma, vocab, num_paths):
    """{committed suffix: probability} for one greedy multi-path
    iteration: enumerate all K i.i.d. draft paths jointly and walk every
    accept/reject branch, with acceptance probabilities and residual
    rows taken from the implementation's surface functions."""
    import jax.numpy as jnp

    from repro.core import verification

    n_ctx = t_tab.shape[0]

    # Per-context surfaces (order-k Markov: rows depend on ctx only).
    tables = {}
    for c in range(n_ctx):
        p_row = jnp.asarray(t_tab[c])[None]
        q_row = jnp.asarray(d_tab[c])[None]
        c_tab, z_tab = verification.multipath_rrs_tables(
            p_row, q_row, num_paths
        )
        res_rows = [
            np.asarray(
                verification.multipath_residual_dist(
                    p_row, q_row, c_tab[:, m]
                ),
                np.float64,
            )[0]
            for m in range(num_paths + 1)
        ]
        acc = np.zeros((num_paths, vocab))
        for m in range(num_paths):
            acc[m] = np.asarray(
                verification.multipath_accept_prob(
                    p_row[0], q_row[0],
                    jnp.full((vocab,), c_tab[0, m]),
                    jnp.full((vocab,), z_tab[0, m]),
                ),
                np.float64,
            )
        tables[c] = (acc, res_rows)

    dist: dict[tuple, float] = {}
    single = list(itertools.product(range(vocab), repeat=gamma))
    for paths in itertools.product(single, repeat=num_paths):
        qprob = 1.0
        for path in paths:
            prob, _ = _rows_along(d_tab, ctx0, path, vocab)
            qprob *= prob
        if qprob <= 0.0:
            continue

        def walk(i, alive, ctx, prefix, mass):
            if i == gamma:  # full accept: bonus from M_b(.|X^gamma)
                for v in range(vocab):
                    if t_tab[ctx][v] > 0.0:
                        key = prefix + (v,)
                        dist[key] = dist.get(key, 0.0) + mass * t_tab[ctx][v]
                return
            acc, res_rows = tables[ctx]
            m, reach = 0, 1.0
            for j in alive:  # greedy: path-index order
                x = paths[j][i]
                a = acc[m, x]
                if a > 0.0:
                    walk(
                        i + 1,
                        [l for l in alive if paths[l][i] == x],
                        (ctx * vocab + x) % n_ctx,
                        prefix + (x,),
                        mass * reach * a,
                    )
                reach *= 1.0 - a
                m += 1
            row = res_rows[m]  # all alive candidates rejected
            for v in range(vocab):
                if row[v] > 0.0:
                    key = prefix + (v,)
                    dist[key] = dist.get(key, 0.0) + mass * reach * row[v]

        walk(0, list(range(num_paths)), ctx0, (), qprob)
    return dist


# ---------------------------------------------------------------------------
# The lossless assertion: iterate the committed-token process to a fixed
# output length; it must equal the target AR joint exactly.
# ---------------------------------------------------------------------------


def _process_dist(commit_of_ctx, t_tab, ctx0, vocab, n_out):
    """Joint distribution of the first ``n_out`` process tokens, where
    ``commit_of_ctx(ctx)`` is one iteration's committed-suffix
    distribution (memoized per context code — every iteration starts at
    a committed prefix whose conditional law is its context's)."""
    n_ctx = t_tab.shape[0]
    cache: dict[int, dict] = {}
    frontier = {((), ctx0): 1.0}
    out: dict[tuple, float] = {}
    while frontier:
        (seq, ctx), mass = frontier.popitem()
        if len(seq) >= n_out:
            key = seq[:n_out]
            out[key] = out.get(key, 0.0) + mass
            continue
        if ctx not in cache:
            cache[ctx] = commit_of_ctx(ctx)
        for suffix, p in cache[ctx].items():
            nctx = ctx
            for tok in suffix:
                nctx = (nctx * vocab + tok) % n_ctx
            k = (seq + suffix, nctx)
            frontier[k] = frontier.get(k, 0.0) + mass * p
    return out


def _target_ar_dist(t_tab, ctx0, vocab, n_out):
    n_ctx = t_tab.shape[0]
    out = {}
    for path in itertools.product(range(vocab), repeat=n_out):
        prob, ctx = 1.0, ctx0
        for tok in path:
            prob *= t_tab[ctx][tok]
            ctx = (ctx * vocab + tok) % n_ctx
        out[path] = prob
    return out


def _assert_lossless(commit_of_ctx, t_tab, vocab, n_out=3, ctx0=0):
    got = _process_dist(commit_of_ctx, t_tab, ctx0, vocab, n_out)
    want = _target_ar_dist(t_tab, ctx0, vocab, n_out)
    assert abs(sum(got.values()) - 1.0) < ATOL
    err = max(abs(got.get(k, 0.0) - want[k]) for k in want)
    assert err < ATOL, f"max deviation {err}"


def _expected_tau(dist):
    """E[tau] of one iteration from its committed-suffix distribution
    (suffix = tau accepted tokens + one bonus token)."""
    return sum(p * (len(s) - 1) for s, p in dist.items())


# ---------------------------------------------------------------------------
# Tests
# ---------------------------------------------------------------------------


class TestSinglePathLossless:
    @pytest.mark.parametrize("name", ["token", "block"])
    @pytest.mark.parametrize("seed,vocab,gamma", [(0, 3, 2), (7, 4, 2), (3, 3, 3)])
    def test_exact_distribution_equality(self, name, seed, vocab, gamma):
        _, _, t_tab, d_tab = _models(seed, vocab)
        _assert_lossless(
            lambda c: _commit_dist_single(name, t_tab, d_tab, c, gamma, vocab),
            t_tab, vocab,
        )

    def test_block_beats_token_through_the_surfaces(self):
        """Theorem 2 through the implementation surfaces: per-iteration
        E[tau] of block >= token, and both match the closed-form oracle."""
        from repro.core import oracle

        target, drafter, t_tab, d_tab = _models(0, 3, alpha=0.6)
        gamma = 3
        e = {
            name: _expected_tau(
                _commit_dist_single(name, t_tab, d_tab, 0, gamma, 3)
            )
            for name in ("token", "block")
        }
        assert e["block"] >= e["token"] - ATOL
        for name in ("token", "block"):
            exact = oracle.exact_expected_accepted(target, drafter, gamma, name)
            assert e[name] == pytest.approx(exact, abs=1e-6), name


class TestMultiPathLossless:
    @pytest.mark.parametrize(
        "seed,vocab,gamma,num_paths",
        [(0, 3, 2, 2), (7, 3, 2, 3), (3, 4, 2, 2), (11, 3, 3, 2)],
    )
    def test_exact_distribution_equality(self, seed, vocab, gamma, num_paths):
        """The committed-token process of greedy multi-path verification
        is EXACTLY the target AR distribution, for every K."""
        _, _, t_tab, d_tab = _models(seed, vocab)
        _assert_lossless(
            lambda c: _commit_dist_multipath(
                t_tab, d_tab, c, gamma, vocab, num_paths
            ),
            t_tab, vocab,
        )

    @pytest.mark.parametrize("seed", [0, 1, 7])
    def test_mean_accepted_beats_single_path_block(self, seed):
        """Acceptance criterion: with K > 1 paths the mean accepted
        tokens per iteration is >= the single-path block verifier on the
        same synthetic workload — and the implementation-marginalized
        E[tau] matches the independent float64 oracle."""
        from repro.core import oracle

        target, drafter, t_tab, d_tab = _models(seed, 3, alpha=0.5)
        gamma = 2
        blk = oracle.exact_expected_accepted(target, drafter, gamma, "block")
        for k in (2, 3):
            dist = _commit_dist_multipath(t_tab, d_tab, 0, gamma, 3, k)
            e_tau = _expected_tau(dist)
            indep = oracle.exact_multipath_expected_accepted(
                target, drafter, gamma, k
            )
            assert e_tau == pytest.approx(indep, abs=1e-9), k
            assert e_tau >= blk - ATOL, (k, e_tau, blk)

    def test_k1_reduces_to_token_verification(self):
        """At K = 1 the greedy multi-path rule IS token verification —
        the reason the engine routes num_paths=1 to the single-path
        verifiers rather than through this rule."""
        _, _, t_tab, d_tab = _models(5, 3)
        d1 = _commit_dist_multipath(t_tab, d_tab, 0, 2, 3, 1)
        dt = _commit_dist_single("token", t_tab, d_tab, 0, 2, 3)
        keys = set(d1) | set(dt)
        err = max(abs(d1.get(s, 0.0) - dt.get(s, 0.0)) for s in keys)
        assert err < ATOL

    def test_batched_verifier_matches_marginalization(self):
        """Monte-Carlo of the jitted multipath_greedy_verify agrees with
        the exactly-marginalized E[tau] — ties the batched scan (alive
        masks, winner tracking, coin wiring) to the surfaces."""
        import jax.numpy as jnp

        from repro.core import sampling, verification

        target, drafter, t_tab, d_tab = _models(0, 3, alpha=0.6)
        gamma, k, n = 2, 2, 60_000
        exact = _expected_tau(
            _commit_dist_multipath(t_tab, d_tab, 0, gamma, 3, k)
        )
        key = jax.random.key(9)
        k1, k2 = jax.random.split(key)
        ctx_d = jnp.zeros((n, k), jnp.int32)
        ctx_t = jnp.zeros((n, k), jnp.int32)
        toks, qs, ps = [], [], []
        for _ in range(gamma):
            k1, sub = jax.random.split(k1)
            q_row = drafter.next_probs(ctx_d)
            ps.append(target.next_probs(ctx_t))
            tok = sampling.categorical(sub, q_row)
            toks.append(tok)
            qs.append(q_row)
            ctx_d = drafter.advance(ctx_d, tok)
            ctx_t = target.advance(ctx_t, tok)
        ps.append(target.next_probs(ctx_t))
        res = jax.jit(verification.multipath_greedy_verify)(
            k2, jnp.stack(toks, 2), jnp.stack(qs, 2), jnp.stack(ps, 2)
        )
        mc = float(jnp.mean(res.num_accepted))
        assert mc == pytest.approx(exact, abs=0.02)
        # The committed prefix is the winning path's draft prefix.
        t = np.asarray(res.tokens)
        w = np.asarray(res.winner)
        tau = np.asarray(res.num_accepted)
        d = np.asarray(jnp.stack(toks, 2))
        for s in range(0, n, 997):
            assert (t[s, : tau[s]] == d[s, w[s], : tau[s]]).all()

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        alpha=st.floats(0.05, 0.95),
        num_paths=st.integers(2, 3),
    )
    def test_lossless_property(self, seed, alpha, num_paths):
        """Property form: exact distribution equality holds for random
        workloads and path counts (randomized in CI via hypothesis)."""
        _, _, t_tab, d_tab = _models(seed, 3, alpha=alpha)
        _assert_lossless(
            lambda c: _commit_dist_multipath(t_tab, d_tab, c, 2, 3, num_paths),
            t_tab, 3, n_out=2,
        )
