"""speclint: golden fixture snippets per pass (violation + clean
pairs), baseline round-trip, suppression handling, call-graph
reachability through module/method indirection — plus the meta-test
that the live tree stays clean modulo the committed baseline.

Pure stdlib: speclint never imports jax, so these tests are cheap.
"""

import json
import textwrap
from pathlib import Path

from repro.tools.speclint import run_speclint
from repro.tools.speclint import baseline as baseline_mod
from repro.tools.speclint.cli import main as speclint_main

REPO = Path(__file__).resolve().parents[1]


def lint(tmp_path, files, passes=None):
    for name, text in files.items():
        p = tmp_path / name
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return run_speclint([tmp_path], root=tmp_path, passes=passes)


def rules(findings):
    return {(f.pass_name, f.rule) for f in findings}


# ---------------------------------------------------------------------------
# prng-discipline
# ---------------------------------------------------------------------------


class TestPrngDiscipline:
    def test_fires_on_prng_in_stage_prefill_copy(self, tmp_path):
        # a PRNG call reached from a stage_prefill_body copy through
        # cross-MODULE indirection: body -> helpers.mix_key -> split
        findings = lint(
            tmp_path,
            {
                "body.py": """
                from helpers import mix_key

                def stage_prefill_body(target, drafter, cfg, spec,
                                       t_params, d_params, t_cache,
                                       d_cache, stage, pool):
                    noise = mix_key(stage)
                    return t_cache, d_cache, stage, pool
                """,
                "helpers.py": """
                import jax

                def mix_key(stage):
                    key = jax.random.key(0)
                    key, sub = jax.random.split(key)
                    return sub
                """,
            },
            passes=["prng-discipline"],
        )
        assert ("prng-discipline", "prng-in-prefill-path") in rules(findings)
        hit = [f for f in findings if f.path == "helpers.py"]
        assert hit and "stage_prefill_body" in hit[0].message

    def test_fires_through_method_indirection(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                import jax

                class Mixer:
                    def mix_noise(self, stage):
                        return jax.random.fold_in(jax.random.key(0), 1)

                def prefill_body(target, drafter, cfg, t_params,
                                 d_params, t_cache, d_cache, batch):
                    m = Mixer()
                    return m.mix_noise(batch)
                """,
            },
            passes=["prng-discipline"],
        )
        assert ("prng-discipline", "prng-in-prefill-path") in rules(findings)

    def test_clean_twin(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "body.py": """
                import jax.numpy as jnp

                def stage_prefill_body(target, drafter, cfg, spec,
                                       t_params, d_params, t_cache,
                                       d_cache, stage, pool):
                    return t_cache, d_cache, stage, pool

                def decode_body(target, drafter, cfg, verify, key):
                    # decode MAY sample; only prefill/staging may not
                    import jax
                    return jax.random.split(key)
                """,
            },
            passes=["prng-discipline"],
        )
        assert findings == []


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------


class TestHostSync:
    def test_fires_on_unannotated_sync_in_serve_loop(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "eng.py": """
                import numpy as np

                class Eng:
                    def _run_serial(self):
                        outs = self.step()
                        toks = np.asarray(outs.tokens)
                        return toks
                """,
            },
            passes=["host-sync"],
        )
        assert ("host-sync", "unannotated-sync") in rules(findings)

    def test_annotation_sanctions_the_sync(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "eng.py": """
                import numpy as np

                class Eng:
                    def _run_serial(self):
                        outs = self.step()
                        # speclint: sync-point(materialize StepOutputs)
                        toks = np.asarray(outs.tokens)
                        return toks
                """,
            },
            passes=["host-sync"],
        )
        assert findings == []

    def test_empty_reason_is_its_own_finding(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "eng.py": """
                import numpy as np

                class Eng:
                    def _run_serial(self):
                        outs = self.step()
                        # speclint: sync-point()
                        toks = np.asarray(outs.tokens)
                        return toks
                """,
            },
            passes=["host-sync"],
        )
        assert rules(findings) == {("host-sync", "empty-sync-reason")}

    def test_sync_reached_through_same_file_helper(self, tmp_path):
        # reachability: root -> self._drain() (method indirection),
        # helper defined in the same file joins the serve-loop scope
        findings = lint(
            tmp_path,
            {
                "eng.py": """
                import numpy as np

                class Eng:
                    def _process(self, outs):
                        return self._drain(outs)

                    def _drain(self, outs):
                        return int(np.asarray(outs.done).sum())
                """,
            },
            passes=["host-sync"],
        )
        assert ("host-sync", "unannotated-sync") in rules(findings)
        assert findings[0].func == "Eng._drain"

    def test_out_of_scope_file_is_not_linted(self, tmp_path):
        # np.asarray outside the serve loop (no sync root in file)
        findings = lint(
            tmp_path,
            {
                "util.py": """
                import numpy as np

                def summarize(outs):
                    return np.asarray(outs.tokens)
                """,
            },
            passes=["host-sync"],
        )
        assert findings == []

    def test_sync_in_jit_body(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                import jax
                import numpy as np

                @jax.jit
                def body(x):
                    return np.asarray(x)
                """,
            },
            passes=["host-sync"],
        )
        assert ("host-sync", "sync-in-jit") in rules(findings)

    def test_array_if_in_jit_body(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                import jax
                import jax.numpy as jnp

                @jax.jit
                def body(x):
                    if x > 0:
                        return x
                    return -x

                @jax.jit
                def fine(x, n: int = 4):
                    if n > 2:          # literal-default knob: static
                        return x * n
                    if x.shape[0] > 1:  # shape read: static
                        return x
                    return x
                """,
            },
            passes=["host-sync"],
        )
        assert rules(findings) == {("host-sync", "array-if")}
        assert all(f.func == "body" for f in findings)


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------


class TestJitPurity:
    def test_fires_on_host_calls(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                import time

                import jax

                @jax.jit
                def body(x):
                    t = time.perf_counter()
                    print(x)
                    return x
                """,
            },
            passes=["jit-purity"],
        )
        got = rules(findings)
        assert ("jit-purity", "host-call-in-jit") in got
        msgs = " ".join(f.message for f in findings)
        assert "time.perf_counter" in msgs and "print" in msgs

    def test_scan_body_is_jitted_and_debug_print_allowed(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                import time

                import jax

                def scan_step(carry, x):
                    time.sleep(0)
                    jax.debug.print("x {}", x)
                    return carry, x

                def outer(xs):
                    return jax.lax.scan(scan_step, 0, xs)
                """,
            },
            passes=["jit-purity"],
        )
        assert len(findings) == 1
        assert "time.sleep" in findings[0].message

    def test_captured_state_mutation(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                import jax

                COUNT = 0

                @jax.jit
                def body(x):
                    global COUNT
                    COUNT += 1
                    return x
                """,
            },
            passes=["jit-purity"],
        )
        assert ("jit-purity", "state-mutation-in-jit") in rules(findings)

    def test_clean_twin(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                import time

                import jax

                @jax.jit
                def body(x):
                    return x + 1

                def host_loop(xs):
                    t0 = time.perf_counter()   # host code: fine
                    print(body(xs))
                    return time.perf_counter() - t0
                """,
            },
            passes=["jit-purity"],
        )
        assert findings == []


# ---------------------------------------------------------------------------
# allocator-discipline
# ---------------------------------------------------------------------------


class TestAllocatorDiscipline:
    def test_device_op_outside_jit_and_pool_write(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                from repro.serving import paging

                def admit_slot(spec, table, used, pool, need, mask):
                    table, used, pool, ok = paging.ensure(
                        spec, table, used, pool, need, mask
                    )
                    pool.free_count = 0
                    return pool._replace(staged=None)
                """,
            },
            passes=["allocator-discipline"],
        )
        got = rules(findings)
        assert ("allocator-discipline", "device-op-outside-jit") in got
        assert ("allocator-discipline", "pool-write-outside-paging") in got

    def test_host_op_in_jit(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                import jax

                from repro.serving import paging

                @jax.jit
                def bad_adopt(pool, sid):
                    return paging.host_adopt_stage(pool, sid)
                """,
            },
            passes=["allocator-discipline"],
        )
        assert rules(findings) == {
            ("allocator-discipline", "host-op-in-jit")
        }

    def test_unpaired_claim(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                from repro.serving import paging

                def admit(pool, prompt):
                    return paging.host_claim_live(pool, prompt)

                def evict(pool, n):
                    return paging.host_evict(pool, n)
                """,
            },
            passes=["allocator-discipline"],
        )
        assert rules(findings) == {
            ("allocator-discipline", "unpaired-claim"),
            ("allocator-discipline", "unpaired-evict"),
        }

    def test_clean_twin(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                import jax

                from repro.serving import paging

                @jax.jit
                def grow(spec, table, used, pool, need, mask):
                    return paging.ensure(spec, table, used, pool, need, mask)

                def admit(sched, pool, prompt):
                    claims = paging.host_claim_live(pool, prompt)
                    sched.note_prefix_claim(claims)
                    return claims

                def shrink(sched, pool, n):
                    freed = paging.host_evict(pool, n)
                    sched.budget.evict_deficit(freed)
                    return freed
                """,
            },
            passes=["allocator-discipline"],
        )
        assert findings == []


# ---------------------------------------------------------------------------
# feature-gating
# ---------------------------------------------------------------------------


class TestFeatureGating:
    def test_fires_on_ungated_reference(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                from repro.serving import runner as serving_runner

                def wire(cfg):
                    return serving_runner.stage_prefill_body
                """,
            },
            passes=["feature-gating"],
        )
        assert rules(findings) == {
            ("feature-gating", "ungated-paged-only")
        }

    def test_gate_in_enclosing_function_sanctions(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "mod.py": """
                from repro.serving import runner as serving_runner
                from repro.serving.runner import _assert_all_paged

                def wire(model, cfg):
                    _assert_all_paged(model, cfg, 4, "target")

                    def stage_step(*args):
                        return serving_runner.stage_prefill_body(*args)

                    return stage_step
                """,
            },
            passes=["feature-gating"],
        )
        assert findings == []


# ---------------------------------------------------------------------------
# fault-site
# ---------------------------------------------------------------------------


class TestFaultSite:
    def test_fires_on_unregistered_site_and_missing_gate(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "eng.py": """
                class Eng:
                    def _admit(self, req, it):
                        # free-hand site string AND no None-guard
                        return self._injector.fires(
                            "transfer_lost", iteration=it, rid=req.rid
                        )
                """,
            },
            passes=["fault-site"],
        )
        assert rules(findings) == {
            ("fault-site", "unregistered-fault-site"),
            ("fault-site", "ungated-fault-site"),
        }

    def test_clean_twin(self, tmp_path):
        # SITE_* constant + is-not-None guard: both rules satisfied,
        # whether the site is the constant or its literal value
        findings = lint(
            tmp_path,
            {
                "eng.py": """
                from repro.serving import faults as faults_mod

                class Eng:
                    def _admit(self, req, it):
                        if self._injector is not None and self._injector.fires(
                            faults_mod.SITE_ALLOC_DENY,
                            iteration=it, rid=req.rid,
                        ):
                            return False
                        return True

                    def _dispatch(self, req, it):
                        if self.cfg.faults is None:
                            return True
                        return not self._injector.fires(
                            "pod_dispatch", iteration=it, rid=req.rid
                        )
                """,
            },
            passes=["fault-site"],
        )
        assert findings == []

    def test_gate_in_enclosing_function_sanctions(self, tmp_path):
        findings = lint(
            tmp_path,
            {
                "eng.py": """
                from repro.serving.faults import SITE_TRANSFER_LOSS

                def make_step(eng):
                    if eng._injector is None:
                        return None

                    def step(req, it):
                        return eng._injector.fires(
                            SITE_TRANSFER_LOSS, iteration=it, rid=req.rid
                        )

                    return step
                """,
            },
            passes=["fault-site"],
        )
        assert findings == []


# ---------------------------------------------------------------------------
# suppression + baseline + CLI
# ---------------------------------------------------------------------------


VIOLATION = """
import numpy as np

class Eng:
    def _run_serial(self):
        outs = self.step()
        toks = np.asarray(outs.tokens){suffix}
        return toks
"""


class TestSuppressionAndBaseline:
    def test_disable_comment_suppresses_named_pass(self, tmp_path):
        files = {
            "eng.py": VIOLATION.format(
                suffix="  # speclint: disable=host-sync"
            )
        }
        assert lint(tmp_path, files, passes=["host-sync"]) == []

    def test_disable_star_and_line_above(self, tmp_path):
        src = VIOLATION.format(suffix="")
        src = src.replace(
            "        toks =",
            "        # speclint: disable=*\n        toks =",
        )
        assert lint(tmp_path, {"eng.py": src}, passes=["host-sync"]) == []

    def test_disable_of_other_pass_does_not_suppress(self, tmp_path):
        files = {
            "eng.py": VIOLATION.format(
                suffix="  # speclint: disable=jit-purity"
            )
        }
        findings = lint(tmp_path, files, passes=["host-sync"])
        assert ("host-sync", "unannotated-sync") in rules(findings)

    def test_baseline_round_trip(self, tmp_path):
        files = {"eng.py": VIOLATION.format(suffix="")}
        findings = lint(tmp_path, files, passes=["host-sync"])
        assert findings
        report = tmp_path / "LINT.json"
        baseline_mod.write_report(findings, report)

        # same tree: everything baselined, nothing new, nothing stale
        again = lint(tmp_path, files, passes=["host-sync"])
        new, old, stale = baseline_mod.split_by_baseline(
            again, baseline_mod.load_fingerprints(report)
        )
        assert new == [] and len(old) == len(findings) and stale == set()

        # fingerprints survive a line-number shift (comment above)
        shifted = "# a new leading comment\n" + textwrap.dedent(
            files["eng.py"]
        )
        (tmp_path / "eng.py").write_text(shifted)
        moved = run_speclint(
            [tmp_path / "eng.py"], root=tmp_path, passes=["host-sync"]
        )
        new, old, _ = baseline_mod.split_by_baseline(
            moved, baseline_mod.load_fingerprints(report)
        )
        assert new == [] and len(old) == len(findings)

    def test_cli_exit_codes(self, tmp_path, capsys):
        (tmp_path / "eng.py").write_text(
            textwrap.dedent(VIOLATION.format(suffix=""))
        )
        report = tmp_path / "LINT.json"
        rc = speclint_main(
            [
                str(tmp_path / "eng.py"),
                "--root",
                str(tmp_path),
                "--json",
                str(report),
            ]
        )
        assert rc == 1
        data = json.loads(report.read_text())
        assert data["total"] >= 1 and data["by_pass"]["host-sync"] >= 1

        rc = speclint_main(
            [
                str(tmp_path / "eng.py"),
                "--root",
                str(tmp_path),
                "--baseline",
                str(report),
            ]
        )
        assert rc == 0
        assert "0 new finding(s)" in capsys.readouterr().out

    def test_unknown_pass_is_usage_error(self, tmp_path):
        assert speclint_main([str(tmp_path), "--passes", "nope"]) == 2


# ---------------------------------------------------------------------------
# meta: the live tree is clean modulo the committed baseline
# ---------------------------------------------------------------------------


class TestLiveTree:
    def test_clean_modulo_committed_baseline(self):
        findings = run_speclint(
            [REPO / "src", REPO / "tests", REPO / "benchmarks"], root=REPO
        )
        known = baseline_mod.load_fingerprints(REPO / "results" / "LINT.json")
        new = [f for f in findings if f.fingerprint not in known]
        assert not new, "new speclint findings:\n" + "\n".join(
            f.render() for f in new
        )

    def test_committed_baseline_is_fresh(self):
        findings = run_speclint(
            [REPO / "src", REPO / "tests", REPO / "benchmarks"], root=REPO
        )
        committed = json.loads(
            (REPO / "results" / "LINT.json").read_text()
        )
        assert {f.fingerprint for f in findings} == {
            f["fingerprint"] for f in committed["findings"]
        }, "results/LINT.json is stale — regenerate with: "
        "python -m repro.tools.speclint src tests benchmarks --json results/LINT.json"
