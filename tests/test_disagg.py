"""Device-disaggregated prefill (prefill pod / decode pod) tests.

Four layers:

* split-pool specs + cross-pool budget — under ``disaggregated=True``
  the decode pool drops the staging headroom term
  (``paging.spec_of``), the prefill pod gets its own fully-provisioned
  pool (``paging.stage_spec_of``: stage_slots * max_pages), and
  adoption becomes a cross-pool budget move (decode ``note_admit`` +
  stage ``note_unstage``) that preserves both pools' never-fail
  invariants;
* the pack/unpack transfer kernels — gathering a staging row's pages
  into a compact buffer and scattering it into freshly-allocated
  decode-pool pages must land bitwise the same K/V the shared-pool
  mask-flip adoption exposes;
* the engine with ``disaggregated=True`` — bit-identical to
  ``async_prefill=True`` (and the serial engine) at temperature 0 on
  concurrent mixed workloads, for sequential sampled runs, on
  over-subscribed pools under preemption, and composed with the prefix
  cache / live sharing; the decode pod dispatches ZERO prefill
  programs (asserted structurally by poisoning the decode-lane prefill
  entry points); transfer telemetry emitted and deterministic;
* the property form: under randomized prompt traffic, no adoption ever
  completes before its transfer was dispatched (the in-flight gate),
  outputs stay identical to the shared-pool engine, and BOTH pools
  drain leak-free.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from test_async_prefill import MIXED, _assert_drained, _models, _serve

from repro.serving import batch as batch_mod
from repro.serving import paging
from repro.serving import runner as runner_mod
from repro.serving.engine import EngineConfig, SpecEngine


def _cfg(mode, **kw):
    base = dict(
        gamma=3, verifier="block", max_slots=2, max_len=96,
        temperature=0.0, max_new_tokens=10, prefill_chunk=4,
        async_prefill=mode != "serial", stage_slots=2,
        disaggregated=mode == "disagg",
    )
    base.update(kw)
    return EngineConfig(**base)


def _assert_stage_drained(eng):
    pool = eng.stage_pool
    assert int(pool.free_count) == pool.free_stack.shape[0]
    assert int(jnp.max(pool.ref)) == 0
    assert not bool(jnp.any(pool.staged))


def _assert_transfer_log_gates_adoption(eng):
    """Every adoption must follow its own transfer dispatch at a
    STRICTLY earlier loop iteration — the host-visible face of the
    never-maps-an-un-arrived-page guarantee (the device-side half is
    the unpack's data dependency on the device_put results)."""
    dispatched = {}
    adoptions = 0
    for event, sid, it in eng._transfer_log:
        if event == "dispatch":
            dispatched[sid] = it
        else:
            assert sid in dispatched, (sid, eng._transfer_log)
            assert it > dispatched.pop(sid), (sid, eng._transfer_log)
            adoptions += 1
    assert adoptions == eng.last_stats["adoptions"]


def _poison_decode_lane_prefill(eng):
    """Structural decode-pod assertion: the decode-lane prefill entry
    points must never be dispatched by a disaggregated run (all prompt
    consumption happens in the staging executable on the prefill pod)."""

    def boom(*_a, **_k):
        raise AssertionError("decode-lane prefill dispatched under disagg")

    eng.runner.prefill_step = boom
    eng.runner._prefill_fn = boom


# ---------------------------------------------------------------------------
# split-pool specs + cross-pool budget
# ---------------------------------------------------------------------------


class TestSplitPoolSpecs:
    KW = dict(gamma=3, max_slots=2, max_len=64, page_size=8, stage_slots=3)

    def test_decode_pool_drops_staging_term(self):
        serial = paging.spec_of(EngineConfig(**self.KW))
        shared = paging.spec_of(
            EngineConfig(async_prefill=True, **self.KW)
        )
        disagg = paging.spec_of(
            EngineConfig(async_prefill=True, disaggregated=True, **self.KW)
        )
        # shared pool reserves headroom for the staging lanes; the
        # disaggregated decode pool is exactly the serial pool
        assert shared.num_pages > serial.num_pages
        assert disagg.num_pages == serial.num_pages
        assert disagg.page_size == shared.page_size
        assert disagg.max_pages == shared.max_pages

    def test_stage_spec_fully_provisions_lanes(self):
        cfg = EngineConfig(async_prefill=True, disaggregated=True, **self.KW)
        stage = paging.stage_spec_of(cfg)
        dec = paging.spec_of(cfg)
        assert stage.num_pages == cfg.stage_slots * dec.max_pages
        # same page geometry: staging tables stay table-compatible with
        # decode tables, only the physical id space differs
        assert stage.page_size == dec.page_size
        assert stage.max_pages == dec.max_pages
        # shared-pool engines stage out of the decode pool itself
        shared_cfg = EngineConfig(async_prefill=True, **self.KW)
        assert paging.stage_spec_of(shared_cfg) == paging.spec_of(shared_cfg)
        assert paging.stage_spec_of(EngineConfig(**self.KW)) is None

    def test_cross_pool_adoption_move_preserves_both_budgets(self):
        cfg = EngineConfig(async_prefill=True, disaggregated=True, **self.KW)
        dec = paging.PageBudget(paging.spec_of(cfg), cfg.gamma)
        stage = paging.PageBudget(paging.stage_spec_of(cfg), cfg.gamma)
        plen = 20
        assert stage.can_admit(plen)
        stage.note_stage(1, plen)
        worst = stage.used_worst()
        assert worst > 0 and dec.used_worst() == 0
        # the engine's adoption order: charge decode BEFORE the unpack
        # dispatch, release the prefill pool after
        assert dec.can_admit(plen)
        dec.note_admit(0, plen)
        stage.note_unstage(1)
        assert stage.used_worst() == 0
        assert dec.used_worst() == worst  # same worst-case, new pool
        dec.note_release(0)
        assert dec.used_worst() == 0

    def test_stage_pool_never_blocks_staging(self):
        """The prefill pod is provisioned for every lane's clamped worst
        case simultaneously — staging admission can never stall on the
        stage budget (adoption is where decode pressure applies)."""
        cfg = EngineConfig(async_prefill=True, disaggregated=True, **self.KW)
        spec = paging.stage_spec_of(cfg)
        b = paging.PageBudget(spec, cfg.gamma)
        for sid in range(cfg.stage_slots):
            assert b.can_admit(cfg.max_len - 1)
            b.note_stage(sid, cfg.max_len - 1)
        assert not b.needs_preemption()


# ---------------------------------------------------------------------------
# pack/unpack transfer round-trip
# ---------------------------------------------------------------------------


SPEC = paging.PageSpec(page_size=4, num_pages=12, max_pages=5)
STAGE_SPEC = paging.PageSpec(page_size=4, num_pages=10, max_pages=5)


def _synthetic_pool_cache(spec, seed):
    """A PagedKV-bearing cache pytree whose pool holds distinguishable
    per-page content."""
    k = jax.random.normal(
        jax.random.key(seed), (1, spec.num_pages, spec.page_size, 2, 3)
    )
    v = k * 2.0 + 1.0
    return {"layer": runner_mod.PagedKV(k=k, v=v)}


class TestPackUnpackRoundTrip:
    def test_transfer_matches_mask_flip_content(self):
        """Pack n staged pages, 'ship' them, unpack into fresh
        decode-pool pages: the decode slot must see bitwise the K/V the
        shared-pool mask flip would have exposed (same logical pages,
        different physical ids)."""
        n = 3
        stage_cache = _synthetic_pool_cache(STAGE_SPEC, 0)
        # stage row owns pages [7, 2, 5] in the PREFILL pool
        staged_ids = jnp.asarray([7, 2, 5], jnp.int32)

        t_packed = runner_mod._pack_stage_pages(stage_cache, staged_ids)
        assert t_packed["layer"].k.shape == (1, n, 4, 2, 3)
        np.testing.assert_array_equal(
            np.asarray(t_packed["layer"].k),
            np.asarray(stage_cache["layer"].k[:, staged_ids]),
        )

        # decode side: empty slot, zeroed pool content
        batch = batch_mod.init_batch(2, 24, SPEC)
        zeros = jax.tree.map(
            jnp.zeros_like, _synthetic_pool_cache(SPEC, 1)
        )
        t_cache, d_cache, batch = runner_mod._unpack_stage_pages(
            SPEC, n, zeros, jax.tree.map(jnp.zeros_like, zeros),
            batch, jnp.asarray(1, jnp.int32), t_packed, t_packed,
        )
        assert int(batch.pages_used[1]) == n
        new_ids = np.asarray(batch.page_table[1, :n])
        assert (new_ids >= 0).all()
        # round-trip identity: decode pool content at the NEW ids ==
        # prefill pool content at the staged ids
        np.testing.assert_array_equal(
            np.asarray(t_cache["layer"].k[:, new_ids]),
            np.asarray(stage_cache["layer"].k[:, staged_ids]),
        )
        np.testing.assert_array_equal(
            np.asarray(t_cache["layer"].v[:, new_ids]),
            np.asarray(stage_cache["layer"].v[:, staged_ids]),
        )
        # pool accounting: n pages allocated, refcounted once
        assert int(batch.pool.free_count) == SPEC.num_pages - n
        assert int(jnp.sum(batch.pool.ref)) == n

    def test_unpack_untouched_rows_and_pages_stay_zero(self):
        n = 2
        stage_cache = _synthetic_pool_cache(STAGE_SPEC, 2)
        packed = runner_mod._pack_stage_pages(
            stage_cache, jnp.asarray([1, 4], jnp.int32)
        )
        batch = batch_mod.init_batch(2, 24, SPEC)
        zeros = jax.tree.map(jnp.zeros_like, _synthetic_pool_cache(SPEC, 1))
        t_cache, _, batch = runner_mod._unpack_stage_pages(
            SPEC, n, zeros, zeros, batch, jnp.asarray(0, jnp.int32),
            packed, packed,
        )
        ids = set(np.asarray(batch.page_table[0, :n]).tolist())
        rest = [p for p in range(SPEC.num_pages) if p not in ids]
        assert not np.asarray(t_cache["layer"].k[:, rest]).any()
        assert int(batch.pages_used[1]) == 0


# ---------------------------------------------------------------------------
# engine identity + telemetry
# ---------------------------------------------------------------------------


class TestDisaggEngineIdentity:
    def test_temp0_concurrent_mixed_workload_tri_identical(self):
        """Serial ≡ shared-pool async ≡ disaggregated, greedy tokens
        bit-for-bit, with the disagg engine moving every adoption over
        an explicit transfer and dispatching ZERO decode-lane prefill
        programs."""
        tgt, drf, tp, dp = _models()
        outs, iters = {}, {}
        for mode in ("serial", "async", "disagg"):
            eng = SpecEngine(tgt, drf, tp, dp, _cfg(mode))
            if mode == "disagg":
                _poison_decode_lane_prefill(eng)
            eng.reset(seed=0)
            rids = [eng.submit(p) for p in MIXED]
            res = eng.run()
            outs[mode] = [res[r].output for r in rids]
            iters[mode] = eng.last_stats["iterations"]
            _assert_drained(eng)
            if mode == "disagg":
                _assert_stage_drained(eng)
                _assert_transfer_log_gates_adoption(eng)
                assert eng.last_stats["adoptions"] == len(MIXED)
                # every multi-token prompt shipped exactly one transfer
                assert eng.last_stats["transfers"] == len(MIXED)
                assert eng.last_stats["transfer_bytes"] > 0
            else:
                assert eng.last_stats["transfers"] == 0
                assert eng.last_stats["transfer_bytes"] == 0
        assert outs["serial"] == outs["async"] == outs["disagg"]
        # page transfers replace mask flips without costing decode
        # iterations (adoption timing is identical by construction)
        assert iters["disagg"] <= iters["async"]

    def test_sequential_sampled_identical(self):
        """Sampled decoding, one request at a time: the PRNG stream and
        every commit must match the shared-pool engine exactly."""
        tgt, drf, tp, dp = _models()
        outs = {}
        for mode in ("async", "disagg"):
            seq = []
            eng = SpecEngine(
                tgt, drf, tp, dp, _cfg(mode, temperature=1.0)
            )
            eng.reset(seed=11)
            for p in (MIXED[1], MIXED[0], MIXED[3]):
                rid = eng.submit(p)
                seq.append(eng.run()[rid].output)
            outs[mode] = seq
        assert outs["async"] == outs["disagg"]

    def test_oversubscribed_pool_preemption_stays_lossless(self):
        """A pool too small for the burst: the disaggregated engine
        sheds decode load (stage kills cannot relieve decode-pool
        pressure — different pools) and still commits the serial
        engine's exact greedy tokens with zero leaked pages in BOTH
        pools."""
        tgt, drf, tp, dp = _models()
        prompts = [
            [(i * 11 + j) % tgt.cfg.vocab for j in range(20)]
            for i in range(5)
        ]
        outs, iters = {}, {}
        for mode in ("serial", "async", "disagg"):
            cfg = _cfg(
                mode, max_slots=3, max_len=80, max_new_tokens=40,
                page_size=4, num_pages=30,
            )
            eng, outs[mode] = _serve(tgt, drf, tp, dp, cfg, prompts)
            iters[mode] = eng.last_stats["iterations"]
            _assert_drained(eng)
            if mode == "disagg":
                _assert_stage_drained(eng)
                _assert_transfer_log_gates_adoption(eng)
        assert outs["serial"] == outs["async"] == outs["disagg"]
        # staging no longer charges the decode pool before adoption, so
        # the disagg engine cannot need MORE decode iterations
        assert iters["disagg"] <= iters["async"]

    @pytest.mark.parametrize(
        "extra",
        [dict(prefix_cache=True), dict(prefix_cache=True, live_share=True)],
        ids=["prefix-cache", "live-share"],
    )
    def test_cache_composition_outputs_identical(self, extra):
        """Prefix cache / live sharing compose: the disagg engine skips
        staging-lane claims (disjoint id spaces) but must still commit
        identical greedy tokens, with every post-adoption index entry
        resolving to decode-pool ids."""
        tgt, drf, tp, dp = _models()
        prompts = MIXED + MIXED[:2]  # repeats make the cache matter
        outs = {}
        for mode in ("async", "disagg"):
            eng, outs[mode] = _serve(
                tgt, drf, tp, dp, _cfg(mode, **extra), prompts
            )
            _assert_drained(eng)
            if mode == "disagg":
                _assert_stage_drained(eng)
                num_pages = eng.runner.page_spec.num_pages
                for nodes in eng._claims.values():
                    assert all(0 <= n.page < num_pages for n in nodes)
        assert outs["async"] == outs["disagg"]

    def test_transfer_telemetry_and_ttft_breakdown(self):
        tgt, drf, tp, dp = _models()
        eng, _ = _serve(tgt, drf, tp, dp, _cfg("disagg"), MIXED)
        stats = eng.last_stats
        transfers0 = stats["transfers"]
        bytes0 = stats["transfer_bytes"]
        assert transfers0 == len(MIXED) and bytes0 > 0
        for m in eng.request_metrics():
            assert m["ttft_transfer_s"] is not None
            assert m["ttft_transfer_s"] >= 0.0
            assert m["ttft_s"] == pytest.approx(
                m["ttft_queue_s"] + m["ttft_prefill_s"]
                + m["ttft_transfer_s"] + m["ttft_decode_s"]
            )
        # transfer counts are deterministic run-to-run
        eng.reset(seed=0)
        for p in MIXED:
            eng.submit(p)
        eng.run()
        assert eng.last_stats["transfers"] == transfers0
        assert eng.last_stats["transfer_bytes"] == bytes0

    def test_kill_mid_transfer_clears_gate_and_counts_only_adoption(self):
        """A staging lane killed while its page transfer is in flight
        must drop its adoption-gate entry (``_transfers``) and must NOT
        count toward ``stats["transfers"]``/``transfer_bytes`` — the
        telemetry counts at adoption, so a killed shipment (whose
        buffers are never unpacked) can't inflate it and the retry's
        re-shipment isn't double-counted."""
        tgt, drf, tp, dp = _models()
        eng = SpecEngine(tgt, drf, tp, dp, _cfg("disagg"))
        eng.reset(seed=0)
        sched = eng.scheduler
        eng.submit(MIXED[1])  # long prompt: the transfer ships pages
        ((sid, req),) = sched.stage_admit()
        eng._stage(sid, req)
        while sched.stage_pending():  # run the background prefill dry
            (
                eng.t_stage_cache, eng.d_stage_cache,
                eng.stage, eng.stage_pool,
            ) = eng.runner.stage_prefill_step(
                eng.t_params_stage, eng.d_params_stage,
                eng.t_stage_cache, eng.d_stage_cache,
                eng.stage, eng.stage_pool,
            )
            sched.note_stage_prefill_dispatch()
        assert sid in sched.ready_q
        eng._dispatch_transfers()  # shipment now in flight
        assert sid in eng._transfers and eng._transfers[sid]["bytes"] > 0
        left = sched.stage_prefill_left(sid)
        sched.kill_stage(sid)
        eng._kill_stage_and_cache(sid, req, left)
        assert sid not in eng._transfers  # gate cleared: no ghost adoption
        _assert_stage_drained(eng)        # shipped pages back in the pool
        res = eng.run()  # retry from the front: re-stage, re-ship, adopt
        assert res[req.rid].finished and res[req.rid].preemptions == 1
        assert eng.last_stats["transfers"] == 1  # only the adopted shipment
        assert eng.last_stats["adoptions"] == 1
        assert eng.last_stats["transfer_bytes"] > 0
        # ...and the kill/retry never perturbs committed tokens.
        _, (ref,) = _serve(tgt, drf, tp, dp, _cfg("async"), [MIXED[1]])
        assert res[req.rid].output == ref

    def test_disaggregated_requires_async_prefill(self):
        tgt, drf, tp, dp = _models()
        with pytest.raises(ValueError, match="async_prefill"):
            SpecEngine(
                tgt, drf, tp, dp,
                EngineConfig(disaggregated=True, async_prefill=False),
            )

    def test_explicit_pod_devices_accepted(self):
        """prefill_mesh / decode_mesh accept a device, a device list,
        or None — identity must hold regardless of placement."""
        tgt, drf, tp, dp = _models()
        devs = jax.devices()
        cfg = _cfg(
            "disagg", prefill_mesh=[devs[-1]], decode_mesh=devs[0]
        )
        eng, outs = _serve(tgt, drf, tp, dp, cfg, MIXED[:3])
        _, ref = _serve(tgt, drf, tp, dp, _cfg("async"), MIXED[:3])
        assert outs == ref
        assert eng._prefill_dev == devs[-1]
        assert eng._decode_dev == devs[0]


# ---------------------------------------------------------------------------
# property: the in-flight gate under randomized traffic
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_transfer_gate_property(seed):
    """Randomized prompt traffic through the REAL disaggregated engine:
    no adoption ever completes before its transfer was dispatched at a
    strictly earlier loop iteration, outputs match the shared-pool
    engine token-for-token, and both pools drain leak-free."""
    rng = np.random.RandomState(seed)
    tgt, drf, tp, dp = _models()
    prompts = [
        rng.randint(0, tgt.cfg.vocab, size=rng.randint(1, 24)).tolist()
        for _ in range(rng.randint(2, 7))
    ]
    outs = {}
    for mode in ("async", "disagg"):
        cfg = _cfg(mode, max_new_tokens=int(rng.randint(4, 12)))
        eng, outs[mode] = _serve(tgt, drf, tp, dp, cfg, prompts)
        _assert_drained(eng)
        if mode == "disagg":
            _assert_stage_drained(eng)
            _assert_transfer_log_gates_adoption(eng)
    assert outs["async"] == outs["disagg"]
