"""Fault-injection, degradation-ladder, and lifecycle-hardening tests.

Pins the fault-tolerance contract from ISSUE 10:

* **Deterministic injection** — ``FaultPlan``/``FaultInjector`` firings
  are a pure function of ``(seed, site, iteration, rid)``; explicit
  schedule triples fire unconditionally, rate-driven firings are capped
  so chaos quiesces.
* **Losslessness under faults** — at temperature 0 every fault site is
  output-invariant: scheduling faults (denied admission, lost/delayed
  transfers, pod dispatch failures) only reshuffle WHEN work runs, and a
  non-finite drafter row makes verification reject the whole block and
  resample the bonus from the raw target row — whose argmax at temp 0 is
  the greedy token.  Survivors (and even affected requests) are
  bit-identical to a fault-free run.
* **Degradation ladder** — lost transfers time out, retry with backoff,
  then fail the lane over to decode-pod prefill; repeated pod failures
  downgrade disagg admissions to the async path.  Either way every
  request completes.
* **Lifecycle hardening** — ``cancel()`` unwinds queued/staged/in-flight
  requests, ``deadline_s`` sheds at admission and retire-check, and the
  pool audit finds zero leaks at quiesce after any of it.
* **Chaos property (hypothesis)** — randomized seeded fault schedules
  plus cancel/deadline traffic: every non-cancelled request completes,
  survivors are bit-identical, ``audit_repairs == 0``.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from test_async_prefill import MIXED, _assert_drained, _models

from repro.serving import ServingFrontend, paging
from repro.serving.engine import EngineConfig, SpecEngine
from repro.serving.faults import (
    SITE_ALLOC_DENY,
    SITE_NONFINITE_LOGITS,
    SITE_POD_DISPATCH,
    SITE_TRANSFER_DELAY,
    SITE_TRANSFER_LOSS,
    SITES,
    FaultInjector,
    FaultPlan,
)
from repro.serving.frontend import StreamDelta


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


_CACHE: dict = {}


def _engine(plan=None, **overrides) -> SpecEngine:
    """One compiled engine per structural config, cached for the module;
    the fault plan is swapped per-test (it is read only at reset and on
    host-side fault branches, never baked into a compiled program)."""
    key = tuple(sorted(overrides.items()))
    if "models" not in _CACHE:
        _CACHE["models"] = _models()
    if key not in _CACHE:
        tgt, drf, tp, dp = _CACHE["models"]
        kw = dict(
            gamma=3, verifier="block", max_slots=2, max_len=96,
            temperature=0.0, max_new_tokens=10, prefill_chunk=4,
        )
        kw.update(overrides)
        _CACHE[key] = SpecEngine(tgt, drf, tp, dp, EngineConfig(**kw))
    eng = _CACHE[key]
    eng.cfg = dataclasses.replace(eng.cfg, faults=plan)
    eng.reset(seed=0)
    return eng


def _disagg_engine(plan=None, **kw) -> SpecEngine:
    return _engine(
        plan, async_prefill=True, stage_slots=2, disaggregated=True, **kw
    )


def _run(eng, prompts, pump=None):
    rids = [eng.submit(p) for p in prompts]
    res = eng.serve(pump=pump) if pump is not None else eng.run()
    return rids, res


def _outputs(rids, res):
    return [list(res[r].output) for r in rids]


def _assert_stage_drained(eng):
    if eng.stage_pool is None:
        return
    pool = eng.stage_pool
    assert int(pool.free_count) == pool.free_stack.shape[0]
    assert int(jnp.max(pool.ref)) == 0
    assert not bool(jnp.any(pool.staged))


_REF: dict = {}


def _reference(kind, prompts):
    """Fault-free outputs for ``prompts``, cached per engine kind."""
    key = (kind, tuple(map(tuple, prompts)))
    if key not in _REF:
        eng = _disagg_engine(None) if kind == "disagg" else _engine(None)
        rids, res = _run(eng, prompts)
        _REF[key] = _outputs(rids, res)
    return _REF[key]


# ---------------------------------------------------------------------------
# FaultPlan / FaultInjector units
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_site_registry_is_validated(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultPlan.make(rates={"bogus": 1.0})
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultPlan.make(schedule=[("bogus", 0, -1)])
        inj = FaultInjector(FaultPlan.make())
        with pytest.raises(ValueError, match="unregistered"):
            inj.fires("bogus", iteration=0, rid=0)

    def test_plan_is_hashable_inside_engine_config(self):
        plan = FaultPlan.make(seed=3, rates={SITE_ALLOC_DENY: 0.5})
        cfg = EngineConfig(gamma=2, max_slots=1, max_len=32, faults=plan)
        assert isinstance(hash(cfg), int)

    def test_schedule_fires_exactly_at_coordinates(self):
        plan = FaultPlan.make(
            schedule=[(SITE_ALLOC_DENY, 3, 7), (SITE_TRANSFER_LOSS, 5, -1)]
        )
        inj = FaultInjector(plan)
        assert not inj.fires(SITE_ALLOC_DENY, iteration=3, rid=8)
        assert not inj.fires(SITE_ALLOC_DENY, iteration=2, rid=7)
        assert inj.fires(SITE_ALLOC_DENY, iteration=3, rid=7)
        # rid = -1 is a wildcard: any request at that iteration.
        assert inj.fires(SITE_TRANSFER_LOSS, iteration=5, rid=123)
        assert inj.fires(SITE_TRANSFER_LOSS, iteration=5, rid=456)
        assert inj.affected_rids(SITE_ALLOC_DENY) == {7}

    def test_rate_firings_are_deterministic_and_capped(self):
        plan = FaultPlan.make(
            seed=11, rates={SITE_POD_DISPATCH: 1.0}, max_per_site=2
        )
        a, b = FaultInjector(plan), FaultInjector(plan)
        hits_a = [a.fires(SITE_POD_DISPATCH, iteration=i, rid=i % 3)
                  for i in range(10)]
        hits_b = [b.fires(SITE_POD_DISPATCH, iteration=i, rid=i % 3)
                  for i in range(10)]
        assert hits_a == hits_b and a.log == b.log
        assert sum(hits_a) == 2  # max_per_site bounds rate-driven chaos
        assert a.stats() == {SITE_POD_DISPATCH: 2}

    def test_speclint_mirror_matches_live_registry(self):
        # speclint is stdlib-only so its fault-site pass carries a
        # mirror of the registry; this is the pin that keeps them in
        # sync when a site is added or renamed.
        from repro.tools.speclint import config as lint_config

        assert lint_config.FAULT_SITES == set(SITES)
        assert lint_config.FAULT_SITE_CONSTS == {
            f"SITE_{s.upper()}" for s in SITES
        }

    def test_different_seeds_decorrelate(self):
        coords = [(s, i, r) for s in SITES for i in range(20) for r in (0, 1)]
        def mask(seed):
            inj = FaultInjector(
                FaultPlan.make(
                    seed=seed, rates={s: 0.5 for s in SITES},
                    max_per_site=10**6,
                )
            )
            return [inj.fires(s, iteration=i, rid=r) for s, i, r in coords]
        assert mask(0) != mask(1)


# ---------------------------------------------------------------------------
# pool audit units
# ---------------------------------------------------------------------------


SPEC = paging.PageSpec(page_size=8, num_pages=12, max_pages=4)


def _mk_pool(rows=2):
    table, used = paging.init_tables(SPEC, rows)
    pool = paging.init_pool(SPEC)
    table, used, pool, ok = paging.ensure(
        SPEC, table, used, pool, jnp.asarray([9] + [0] * (rows - 1)),
        jnp.asarray([True] + [False] * (rows - 1)),
    )
    assert bool(ok[0])
    return table, used, pool


class TestAudit:
    def test_clean_pool_is_bitwise_unchanged(self):
        table, used, pool = _mk_pool()
        healed, report = paging.audit_pool(
            SPEC, pool, page_table=table, pages_used=used, live_rows=(0,)
        )
        assert report["clean"] and report["repairs"] == 0
        for a, b in zip(pool, healed):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_ghost_ref_is_repaired(self):
        table, used, pool = _mk_pool()
        victim = int(pool.free_stack[int(pool.free_count) - 1])
        bad = pool._replace(ref=pool.ref.at[victim].add(1))
        healed, report = paging.audit_pool(
            SPEC, bad, page_table=table, pages_used=used, live_rows=(0,)
        )
        assert not report["clean"] and report["repairs"] > 0
        _, again = paging.audit_pool(
            SPEC, healed, page_table=table, pages_used=used, live_rows=(0,)
        )
        assert again["clean"]

    def test_leaked_page_returns_to_free_stack(self):
        table, used, pool = _mk_pool()
        # Drop row 0 from ground truth without releasing: its pages are
        # now leaked (refcounted but unmapped) and must be reclaimed.
        healed, report = paging.audit_pool(
            SPEC, pool, page_table=table, pages_used=used, live_rows=()
        )
        assert report["leaked_pages"] > 0 and not report["clean"]
        assert int(healed.free_count) == SPEC.num_pages
        assert int(jnp.max(healed.ref)) == 0

    def test_stale_budget_key_dropped(self):
        table, used, pool = _mk_pool()
        budget = paging.PageBudget(SPEC, gamma=3)
        budget.note_admit(0, 9)
        budget.note_admit(1, 9)   # row 1 is not live: stale after a kill
        _, report = paging.audit_pool(
            SPEC, pool, page_table=table, pages_used=used, live_rows=(0,),
            budget=budget,
        )
        assert report["stale_budget_keys"] == 1
        assert set(budget.slot_len) == {0}


# ---------------------------------------------------------------------------
# engine fault plane: losslessness at temperature 0
# ---------------------------------------------------------------------------


class TestEngineFaultPlane:
    PROMPTS = [MIXED[0], MIXED[2], MIXED[4]]

    def test_empty_plan_is_output_identical_noop(self):
        ref = _reference("serial", self.PROMPTS)
        eng = _engine(FaultPlan.make(seed=1))
        rids, res = _run(eng, self.PROMPTS)
        assert _outputs(rids, res) == ref
        assert eng.last_stats["fault_injections"] == {}
        assert eng.last_stats["fault_log"] == []
        assert eng.last_stats["audit_repairs"] == 0

    def test_nonfinite_drafter_rows_bit_identical_at_temp0(self):
        """A corrupted drafter row rejects its whole block and resamples
        the bonus from the raw target row — at temp 0 that argmax IS the
        greedy token, so even AFFECTED requests commit identical output
        (just fewer tokens per step)."""
        ref = _reference("serial", self.PROMPTS)
        plan = FaultPlan.make(
            schedule=[(SITE_NONFINITE_LOGITS, 2, -1),
                      (SITE_NONFINITE_LOGITS, 3, -1)]
        )
        eng = _engine(plan)
        rids, res = _run(eng, self.PROMPTS)
        assert _outputs(rids, res) == ref
        fired = eng.last_stats["fault_injections"]
        assert fired.get(SITE_NONFINITE_LOGITS, 0) >= 1
        assert eng.last_stats["audit_repairs"] == 0
        _assert_drained(eng)

    def test_alloc_denial_delays_admission_not_output(self):
        ref = _reference("serial", self.PROMPTS)
        plan = FaultPlan.make(
            schedule=[(SITE_ALLOC_DENY, 0, -1), (SITE_ALLOC_DENY, 1, -1)]
        )
        eng = _engine(plan)
        rids, res = _run(eng, self.PROMPTS)
        assert _outputs(rids, res) == ref
        assert eng.last_stats["fault_injections"][SITE_ALLOC_DENY] == 2
        assert eng.last_stats["audit_repairs"] == 0


# ---------------------------------------------------------------------------
# degradation ladder (disaggregated engine)
# ---------------------------------------------------------------------------


class TestDegradationLadder:
    def test_transfer_delay_defers_adoption_only(self):
        ref = _reference("disagg", MIXED)
        plan = FaultPlan.make(
            seed=2, rates={SITE_TRANSFER_DELAY: 1.0}, max_per_site=3,
            transfer_delay_iters=2,
        )
        eng = _disagg_engine(plan)
        rids, res = _run(eng, MIXED)
        assert _outputs(rids, res) == ref
        assert eng.last_stats["fault_injections"][SITE_TRANSFER_DELAY] == 3
        assert eng.last_stats["audit_repairs"] == 0
        _assert_drained(eng)
        _assert_stage_drained(eng)

    def test_transfer_loss_times_out_retries_then_fails_over(self):
        """With every transfer lost and zero retries allowed, each lane
        walks the whole ladder: timeout → failover → decode-pod prefill.
        Output stays bit-identical; the pools drain with zero repairs."""
        ref = _reference("disagg", MIXED)
        plan = FaultPlan.make(
            rates={SITE_TRANSFER_LOSS: 1.0}, max_per_site=8,
            transfer_timeout_iters=2, transfer_max_retries=0,
        )
        eng = _disagg_engine(plan)
        rids, res = _run(eng, MIXED)
        stats = eng.last_stats
        assert _outputs(rids, res) == ref
        assert stats["transfer_retries"] >= 1
        assert stats["failovers"] >= 1
        assert any(ev == "failover" for ev, _, _ in eng._transfer_log)
        assert stats["audit_repairs"] == 0
        _assert_drained(eng)
        _assert_stage_drained(eng)

    def test_transfer_loss_with_retries_recovers_without_failover(self):
        """A bounded loss burst (cap < retry budget) re-dispatches and
        lands every transfer without abandoning the disagg path."""
        ref = _reference("disagg", MIXED)
        plan = FaultPlan.make(
            rates={SITE_TRANSFER_LOSS: 1.0}, max_per_site=1,
            transfer_timeout_iters=2, transfer_max_retries=3,
        )
        eng = _disagg_engine(plan)
        rids, res = _run(eng, MIXED)
        stats = eng.last_stats
        assert _outputs(rids, res) == ref
        assert stats["transfer_retries"] == 1
        assert stats["failovers"] == 0
        assert stats["audit_repairs"] == 0

    def test_repeated_pod_failure_downgrades_disagg_to_async(self):
        ref = _reference("disagg", MIXED)
        plan = FaultPlan.make(
            rates={SITE_POD_DISPATCH: 1.0}, max_per_site=2,
            pod_failure_limit=2,
        )
        eng = _disagg_engine(plan)
        rids, res = _run(eng, MIXED)
        stats = eng.last_stats
        assert _outputs(rids, res) == ref
        assert stats["pod_failures"] == 2
        assert stats["downgraded"] is True
        assert ("downgrade", -1) in {(e, s) for e, s, _ in eng._transfer_log}
        assert stats["audit_repairs"] == 0
        _assert_drained(eng)
        _assert_stage_drained(eng)


# ---------------------------------------------------------------------------
# request lifecycle: cancel + deadline + quarantine
# ---------------------------------------------------------------------------


class TestLifecycle:
    PROMPTS = [MIXED[0], MIXED[2], MIXED[4]]

    def test_cancel_queued_before_run(self):
        eng = _engine()
        rids = [eng.submit(p) for p in self.PROMPTS]
        assert eng.cancel(rids[2])
        assert not eng.cancel(rids[2])  # already terminal: idempotent no
        res = eng.run()
        assert res[rids[2]].finish_reason == "cancelled"
        assert res[rids[2]].output == []
        survivors = [_o for r, _o in zip(rids, _outputs(rids, res))
                     if r != rids[2]]
        ref = _reference("serial", self.PROMPTS)
        assert survivors == [ref[0], ref[1]]
        assert eng.last_stats["audit_repairs"] == 0

    def test_cancel_midflight_slot_unwinds_and_survivors_match(self):
        eng = _engine()
        rids = [eng.submit(p) for p in self.PROMPTS]
        calls = {"n": 0}

        def pump():
            calls["n"] += 1
            if calls["n"] == 2:  # rids[0] is riding a decode slot now
                assert eng.cancel(rids[0])
            return False

        res = eng.serve(pump=pump)
        assert res[rids[0]].finish_reason == "cancelled"
        ref = _reference("serial", self.PROMPTS)
        assert _outputs(rids, res)[1:] == ref[1:]
        # a cancelled request streams a PREFIX of its fault-free output
        assert ref[0][: len(res[rids[0]].output)] == res[rids[0]].output
        assert eng.last_stats["cancelled"] == 1
        assert eng.last_stats["audit_repairs"] == 0
        _assert_drained(eng)

    def test_cancel_staged_lane_disagg(self):
        eng = _disagg_engine()
        rids = [eng.submit(p) for p in MIXED]
        calls = {"n": 0}

        def pump():
            calls["n"] += 1
            if calls["n"] == 2:
                eng.cancel(rids[3])  # long prompt: still staging
            return False

        res = eng.serve(pump=pump)
        assert res[rids[3]].finished
        ref = _reference("disagg", MIXED)
        for i, r in enumerate(rids):
            if r == rids[3]:
                continue
            assert list(res[r].output) == ref[i]
        assert eng.last_stats["audit_repairs"] == 0
        _assert_drained(eng)
        _assert_stage_drained(eng)

    def test_deadline_sheds_queued_and_running(self):
        eng = _engine()
        eng.scheduler.clock = _FakeClock()  # 1s per observation
        rids = [
            eng.submit(self.PROMPTS[0]),
            eng.submit(self.PROMPTS[1], deadline_s=0.5),  # sheds at once
        ]
        res = eng.run()
        assert res[rids[1]].finish_reason == "deadline"
        assert res[rids[0]].finished
        assert res[rids[0]].finish_reason not in ("deadline", "cancelled")
        assert eng.last_stats["deadline_shed"] >= 1
        assert eng.last_stats["audit_repairs"] == 0

    def test_submit_rejects_nonpositive_deadline(self):
        eng = _engine()
        with pytest.raises(ValueError, match="deadline_s"):
            eng.submit([1, 2, 3], deadline_s=0.0)

    def test_quarantine_surfaces_error_without_killing_service(self):
        """An admission blow-up quarantines THAT request (terminal
        ``finish_reason="error"`` with the message) while the other
        requests finish normally on the same service loop."""
        eng = _engine()
        rids = [eng.submit(p) for p in self.PROMPTS]
        victim = rids[0]
        real_admit = eng._admit

        def flaky_admit(slot, req):
            if req.rid == victim:
                raise RuntimeError("injected admission failure")
            return real_admit(slot, req)

        eng._admit = flaky_admit
        try:
            res = eng.run()
        finally:
            del eng._admit
        assert res[victim].finish_reason == "error"
        assert "injected admission failure" in res[victim].error
        ref = _reference("serial", self.PROMPTS)
        assert _outputs(rids, res)[1:] == ref[1:]
        assert eng.last_stats["audit_repairs"] == 0
        _assert_drained(eng)


# ---------------------------------------------------------------------------
# front end: cancel marshalling, drain error path, detokenizer flush
# ---------------------------------------------------------------------------


class TestFrontendLifecycle:
    def test_ingress_cancel_retracts_and_streams_terminal_delta(self):
        from repro.data.tokenizer import ByteTokenizer

        fe = ServingFrontend(_engine(), tokenizer=ByteTokenizer())
        fe._closed = False  # accept without spinning the service thread
        h = fe.submit([65, 66])
        # A committed delta carrying only the FIRST byte of a two-byte
        # glyph, then a cancel: the stream must flush the buffered
        # partial glyph at the terminal delta, never leak it.
        h.events.put(StreamDelta(rid=0, tokens=[0xC3], finished=False))
        assert fe.cancel(h)
        assert not fe.cancel(h)  # already terminal
        deltas = list(fe.stream(h, timeout_s=5))
        assert deltas[-1].finished
        assert deltas[-1].text == "�"  # flushed, per errors="replace"
        assert fe.result(h).finish_reason == "cancelled"
        assert not fe._ingress and not fe._cancels

    def test_marshalled_cancel_through_service_thread(self):
        eng = _engine()
        with ServingFrontend(eng) as fe:
            h1 = fe.submit(MIXED[0])
            h2 = fe.submit(MIXED[1])
            fe.cancel(h2)
            s1 = fe.result(h1, timeout_s=120)
            s2 = fe.result(h2, timeout_s=120)
        assert s1.finished and s1.finish_reason != "cancelled"
        assert s2.finish_reason == "cancelled"
        deltas = list(fe.stream(h2, timeout_s=5))
        assert deltas and deltas[-1].finished
        assert eng.last_stats["audit_repairs"] == 0

    def test_drain_error_path_emits_terminal_error_deltas(self):
        import time as _time

        eng = _engine()

        def boom(*_a, **_k):
            raise RuntimeError("injected")

        eng._run_serial = boom  # shadow the bound method on the instance
        try:
            fe = ServingFrontend(eng)
            fe.start()
            try:
                h = fe.submit(MIXED[0])
            except RuntimeError:
                h = None  # loop died before ingress reopened — fine
            deadline = _time.monotonic() + 30
            while fe.running and _time.monotonic() < deadline:
                _time.sleep(0.005)
            if h is not None:
                g = fe.stream(h, timeout_s=5)
                delta = next(g)
                assert delta.finished and "injected" in delta.error
                with pytest.raises(RuntimeError, match="service loop failed"):
                    next(g)
            with pytest.raises(RuntimeError, match="service loop failed"):
                fe.drain()
        finally:
            del eng._run_serial

    def test_frontend_deadline_passthrough(self):
        eng = _engine()
        eng.scheduler.clock = _FakeClock()
        with ServingFrontend(eng) as fe:
            doomed = fe.submit(MIXED[1], deadline_s=0.5)
            ok = fe.submit(MIXED[0])
            s_doomed = fe.result(doomed, timeout_s=120)
            s_ok = fe.result(ok, timeout_s=120)
        assert s_doomed.finish_reason == "deadline"
        assert s_ok.finish_reason not in ("deadline", "cancelled")
        with pytest.raises(ValueError, match="deadline_s"):
            fe.submit([1, 2], deadline_s=-1.0)


# ---------------------------------------------------------------------------
# chaos property: the acceptance gate
# ---------------------------------------------------------------------------


class TestChaosProperty:
    @settings(max_examples=3, deadline=None)
    @given(
        seed=st.integers(0, 2**16 - 1),
        loss=st.booleans(),
        delay=st.booleans(),
        pod=st.booleans(),
        deny=st.booleans(),
        nonfinite=st.booleans(),
        cancel_at=st.integers(0, 3),  # 0 = no cancel this example
        doom=st.booleans(),           # add an impossible-deadline request
    )
    def test_chaos_survivors_bit_identical_zero_leaks(
        self, seed, loss, delay, pod, deny, nonfinite, cancel_at, doom
    ):
        """Randomized seeded fault schedule + cancel/deadline traffic on
        the disaggregated engine: every non-cancelled request reaches a
        terminal state, survivors commit bit-identical output to the
        fault-free run, and the audit finds zero leaks at quiesce."""
        ref = _reference("disagg", MIXED)
        rates = {}
        if loss:
            rates[SITE_TRANSFER_LOSS] = 1.0
        if delay:
            rates[SITE_TRANSFER_DELAY] = 1.0
        if pod:
            rates[SITE_POD_DISPATCH] = 1.0
        if deny:
            rates[SITE_ALLOC_DENY] = 0.5
        if nonfinite:
            rates[SITE_NONFINITE_LOGITS] = 0.3
        plan = FaultPlan.make(
            seed=seed, rates=rates, max_per_site=3,
            transfer_timeout_iters=2, transfer_max_retries=1,
            pod_failure_limit=2,
        )
        eng = _disagg_engine(plan)
        rids = [eng.submit(p) for p in MIXED]
        doomed = eng.submit([1, 2, 3], deadline_s=1e-9) if doom else None
        cancel_rid = rids[1] if cancel_at else None
        calls = {"n": 0}

        def pump():
            calls["n"] += 1
            if cancel_at and calls["n"] == cancel_at:
                eng.cancel(cancel_rid)
            return False

        res = eng.serve(pump=pump)
        stats = eng.last_stats

        # Every request reached a terminal state.
        for r in rids:
            assert res[r].finished, r
        if doomed is not None:
            assert res[doomed].finish_reason == "deadline"

        # Survivors — including fault-AFFECTED requests — bit-identical.
        for i, r in enumerate(rids):
            if r == cancel_rid and res[r].finish_reason == "cancelled":
                continue
            assert list(res[r].output) == ref[i], (i, stats["fault_log"])

        # Zero leaks at quiesce: no audit ever had to repair anything,
        # and both pools drained to their reset geometry.
        assert stats["audit_repairs"] == 0
        _assert_drained(eng)
        _assert_stage_drained(eng)
