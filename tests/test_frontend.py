"""Continuous-batching front end tests.

Pins the four properties ISSUE 8's tentpole must not break:

* **Losslessness across submission schedules** — streamed (staggered)
  submission through :class:`ServingFrontend` is bit-identical to batch
  submission at temperature 0 (serial AND async engines), and
  sequential submission is bit-identical at a sampled temperature (the
  PRNG advances once per decode dispatch with live work; idle service
  iterations dispatch nothing and consume no key splits).
* **Priority classes** — strict-tier admission and class-aware
  preemption ordering.
* **Tenant fairness** — deficit-weighted (stride) shares converge to
  the configured weights under saturation.
* **Streaming frontier** (hypothesis) — the emit cursor never hands out
  an uncommitted token: every streamed delta is already in the device's
  committed ``seq_buf`` span, deltas are disjoint and in order, and
  their concatenation is exactly the final output.
"""

import time

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from repro.configs import registry
from repro.data.tokenizer import ByteTokenizer, IncrementalDetokenizer
from repro.models import Model
from repro.serving import ServingFrontend, batch as batch_mod
from repro.serving.engine import EngineConfig, SpecEngine
from repro.serving.frontend import replay_open_loop
from repro.serving.scheduler import Scheduler

PROMPTS = [[5, 3, 8, 1, 2], [9, 9, 2, 4, 4, 4, 7, 1], [1, 2, 3, 4],
           [7, 7, 7, 2, 1], [8, 8, 1], [2, 4, 6, 8, 10, 12]]


def _models(seed=0):
    cfg = registry.smoke_config("smollm-135m")
    tgt = Model(cfg)
    drf = Model(cfg.with_(d_model=128, d_ff=256, name=cfg.name + "-d"))
    kt, kd = jax.random.split(jax.random.key(seed))
    return tgt, drf, tgt.init(kt), drf.init(kd)


_ENGINES: dict = {}


def _engine(**overrides) -> SpecEngine:
    """One engine per config, cached for the module (compile once;
    every test resets it to a fresh seed)."""
    key = tuple(sorted(overrides.items()))
    if key not in _ENGINES:
        if "models" not in _ENGINES:
            _ENGINES["models"] = _models()
        tgt, drf, tp, dp = _ENGINES["models"]
        kw = dict(
            gamma=3, verifier="block", max_slots=2, max_len=96,
            temperature=0.0, max_new_tokens=10, prefill_chunk=8,
        )
        kw.update(overrides)
        _ENGINES[key] = SpecEngine(tgt, drf, tp, dp, EngineConfig(**kw))
    eng = _ENGINES[key]
    eng.reset(seed=0)
    return eng


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


# ---------------------------------------------------------------------------
# scheduler policy: priority classes + weighted tenant fairness
# ---------------------------------------------------------------------------


class TestSchedulingPolicy:
    def test_priority_class_is_a_strict_tier(self):
        """A premium request submitted LAST still admits before every
        queued best-effort request — classes gate absolutely, they are
        not a tie-break."""
        s = Scheduler(1, default_max_new=8, prefill_chunk=16,
                      clock=_FakeClock())
        s.submit([1, 2], priority=1)
        s.submit([3, 4], priority=1)
        gold = s.submit([5, 6], priority=0)
        ((slot, req),) = s.admit()
        assert req.rid == gold
        s.retire(slot, "length")
        ((_, req2),) = s.admit()  # back to FIFO within the remaining tier
        assert req2.priority == 1 and req2.rid < gold

    def test_preemption_ordering_sheds_lowest_class_lifo(self):
        """Under page pressure victims go lowest-class-first, LIFO
        within a class, and a killed victim resumes ahead of its class
        peers (front requeue, fresh age)."""
        s = Scheduler(4, default_max_new=8, prefill_chunk=16,
                      clock=_FakeClock())
        s.submit([1, 1], priority=0)
        s.submit([2, 2], priority=1)
        s.admit()
        s.submit([3, 3], priority=1)  # newest best-effort
        s.submit([4, 4], priority=0)  # newest overall, but premium
        s.admit()
        order = []
        for _ in range(3):
            v = s.pick_victim()
            order.append(s.slot_req[v].prompt[0])
            s.preempt(v)
        # best-effort LIFO first (3 then 2), then the newest premium (4);
        # the last live slot is never offered.
        assert order == [3, 2, 4]
        assert s.pick_victim() is None
        assert [r.prompt[0] for r in s.queue] == [4, 2, 3]
        assert all(r.age == 0 for r in s.queue)

    def test_tenant_shares_converge_to_weights(self):
        """Stride scheduling: a weight-2 tenant gets exactly twice the
        admissions of a weight-1 tenant under saturation (equal-cost
        requests; aging disabled to isolate the fairness layer)."""
        s = Scheduler(1, default_max_new=8, prefill_chunk=16,
                      clock=_FakeClock(), aging_limit=10**9)
        s.set_tenant_weight("gold", 2.0)
        s.set_tenant_weight("free", 1.0)
        for _ in range(30):
            s.submit([1, 2, 3], max_new_tokens=8, tenant="gold")
            s.submit([1, 2, 3], max_new_tokens=8, tenant="free")
        admits = {"gold": 0, "free": 0}
        for _ in range(30):
            ((slot, req),) = s.admit()
            admits[req.tenant] += 1
            s.retire(slot, "length")
        assert admits == {"gold": 20, "free": 10}

    def test_aging_beats_tenant_fairness_within_a_tier(self):
        """The anti-starvation guarantee survives the fairness layer: a
        request overtaken to aging_limit admits next even while its
        tenant's virtual time says the other tenant should keep
        winning."""
        s = Scheduler(1, default_max_new=8, prefill_chunk=16,
                      clock=_FakeClock(), aging_limit=2)
        s.set_tenant_weight("gold", 10.0)
        for _ in range(3):  # run free's virtual time up to 30
            s.submit([1, 2], tenant="free")
            ((slot, _),) = s.admit()
            s.retire(slot, "length")
        starved = s.submit([1, 2], tenant="free")
        golds = [s.submit([3, 4], tenant="gold") for _ in range(3)]
        admitted = []
        for _ in range(4):
            ((slot, req),) = s.admit()
            admitted.append(req.rid)
            s.retire(slot, "length")
        # gold's weight keeps its vtag below free's throughout, so pure
        # fairness would admit all three golds first; two overtakes age
        # the starved request to the limit and it preempts the order.
        assert admitted == [golds[0], golds[1], starved, golds[2]]

    def test_default_submission_stays_exact_fifo(self):
        """One class, one tenant, no match_fn: the policy stack must
        collapse to the seed scheduler's FIFO (admission order pins
        allocation order, which bit-identity tests depend on)."""
        s = Scheduler(2, default_max_new=8, prefill_chunk=16,
                      clock=_FakeClock())
        rids = [s.submit([i + 1, 2]) for i in range(4)]
        assert [r.rid for _, r in s.admit()] == rids[:2]


# ---------------------------------------------------------------------------
# bit-identity across submission schedules
# ---------------------------------------------------------------------------


class TestBitIdentity:
    @pytest.mark.parametrize("async_prefill", [False, True])
    def test_streamed_equals_batch_at_temp0(self, async_prefill):
        """Open-stream staggered submission through the front end
        commits exactly the tokens batch submission commits."""
        eng = _engine(async_prefill=async_prefill,
                      stage_slots=2 if async_prefill else 0)
        rids = [eng.submit(list(p)) for p in PROMPTS]
        ref = eng.run()
        ref_out = [ref[r].output for r in rids]
        eng.reset(seed=0)
        fe = ServingFrontend(eng).start()
        handles = []
        for i, p in enumerate(PROMPTS):
            handles.append(fe.submit(list(p)))
            if i % 2:
                time.sleep(0.005)  # arrive mid-flight, not as one batch
        res = fe.drain()
        assert [res[h.rid].output for h in handles] == ref_out

    def test_sequential_sampled_equals_engine_runs(self):
        """At a sampled temperature, one-at-a-time submission through
        the idling service loop matches one-at-a-time engine.run()
        calls: idle iterations dispatch nothing, so they consume no PRNG
        splits."""
        eng = _engine(temperature=1.0)
        ref_out = []
        for p in PROMPTS[:3]:
            rid = eng.submit(list(p))
            ref_out.append(eng.run()[rid].output)
        eng.reset(seed=0)
        fe = ServingFrontend(eng).start()
        out = []
        for p in PROMPTS[:3]:
            h = fe.submit(list(p))
            out.append(fe.result(h, timeout_s=120).output)
        fe.drain()
        assert out == ref_out

    def test_openloop_replay_matches_batch(self):
        """The bench's load generator path (replay_open_loop with a
        Poisson schedule) is also bit-identical at temp 0."""
        eng = _engine()
        rids = [eng.submit(list(p)) for p in PROMPTS]
        ref = eng.run()
        eng.reset(seed=0)
        rng = np.random.default_rng(0)
        arrivals = np.cumsum(rng.exponential(0.003, size=len(PROMPTS)))
        fe = ServingFrontend(eng).start()
        handles = replay_open_loop(
            fe, [{"prompt": list(p)} for p in PROMPTS], list(arrivals)
        )
        res = fe.drain()
        assert [res[h.rid].output for h in handles] == \
            [ref[r].output for r in rids]


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------


class TestStreaming:
    def test_stream_deltas_reassemble_exactly(self):
        eng = _engine()
        fe = ServingFrontend(eng, tokenizer=ByteTokenizer()).start()
        tok = ByteTokenizer()
        handles = [fe.submit("hello"), fe.submit("speculative")]
        streamed = []
        for h in handles:
            deltas = list(fe.stream(h))
            assert deltas[-1].finished and not any(
                d.finished for d in deltas[:-1]
            )
            streamed.append(
                ([t for d in deltas for t in d.tokens],
                 "".join(d.text for d in deltas))
            )
        res = fe.drain()
        for h, (tokens, text) in zip(handles, streamed):
            assert tokens == res[h.rid].output
            assert text == tok.decode(res[h.rid].output)

    def test_incremental_detokenizer_buffers_split_glyphs(self):
        detok = IncrementalDetokenizer()
        snowman = "☃".encode()  # 3 bytes
        assert detok.feed([ByteTokenizer.bos_id, snowman[0]]) == ""
        assert detok.feed([snowman[1]]) == ""
        assert detok.feed([snowman[2], ord("!")]) == "☃!"
        assert detok.flush() == ""
        assert detok.feed(snowman[:2]) == ""
        assert detok.flush() != ""  # incomplete tail surfaces at flush

    def test_submit_after_drain_rejected(self):
        eng = _engine()
        fe = ServingFrontend(eng).start()
        fe.submit(PROMPTS[0])
        fe.drain()
        with pytest.raises(RuntimeError, match="not accepting"):
            fe.submit(PROMPTS[1])

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_cursor_never_emits_uncommitted_tokens(self, seed):
        """Drive engine.serve() directly (single-threaded, deterministic)
        with a randomized arrival schedule and adversarially check every
        emit against DEVICE state: each delta must already sit in the
        slot's committed ``seq_buf`` span — i.e. behind the committed
        frontier — and the deltas must be disjoint, in-order, and
        reassemble to the final output."""
        rng = np.random.default_rng(seed)
        eng = _engine()
        n = int(rng.integers(2, 6))
        plan = [
            (int(rng.integers(0, 12)),  # submit at this loop iteration
             [int(t) for t in rng.integers(1, 200, int(rng.integers(1, 9)))],
             int(rng.integers(1, 11)))  # max_new_tokens
            for _ in range(n)
        ]
        seen: dict[int, list[int]] = {}
        iteration = [0]

        def pump() -> bool:
            it = iteration[0]
            iteration[0] += 1
            for at, prompt, max_new in plan:
                if at == it:
                    seen[eng.submit(prompt, max_new)] = []
            return it < 12  # accepting until every arrival has fired

        def emit(req, tokens, finished):
            assert req.emitted == len(req.output)
            assert tokens == req.output[len(seen[req.rid]):]
            for slot, live in enumerate(eng.scheduler.slot_req):
                if live is req:  # still live: check the device frontier
                    frontier = int(np.asarray(
                        batch_mod.committed_frontier(eng.batch)[slot]
                    ))
                    assert len(req.output) <= frontier, (
                        "emitted past the committed frontier"
                    )
                    start = int(np.asarray(eng.batch.out_start[slot]))
                    span = np.asarray(
                        eng.batch.seq_buf[slot, start:start + frontier]
                    )[: len(req.output)]
                    assert list(span) == req.output
            seen[req.rid].extend(tokens)

        results = eng.serve(pump=pump, emit=emit)
        assert set(seen) == set(results)
        for rid, tokens in seen.items():
            assert tokens == results[rid].output


# ---------------------------------------------------------------------------
# error paths
# ---------------------------------------------------------------------------


class TestLifecycle:
    def test_service_error_surfaces_to_drain_and_stream(self):
        eng = _engine()

        def boom(*a, **k):
            raise RuntimeError("injected")

        eng._run_serial = boom  # shadow the bound method on the instance
        try:
            fe = ServingFrontend(eng)
            fe.start()
            h = None
            try:
                h = fe.submit(PROMPTS[0])
            except RuntimeError:
                pass  # loop may already have died and closed ingress
            deadline = time.monotonic() + 30
            while fe.running and time.monotonic() < deadline:
                time.sleep(0.005)
            with pytest.raises(RuntimeError, match="service loop failed"):
                fe.drain()
            if h is not None:
                with pytest.raises(RuntimeError):
                    fe.result(h, timeout_s=5)
        finally:
            del eng._run_serial  # restore for the module's cached engine

    def test_context_manager_drains(self):
        eng = _engine()
        with ServingFrontend(eng) as fe:
            h = fe.submit(PROMPTS[0])
        assert h.done.is_set() and h.state is not None
        assert not fe.running  # service thread joined on exit