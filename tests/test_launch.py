"""Launch-layer structural tests (no 512-device init needed)."""

import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh

from repro.configs import registry
from repro.launch import roofline, shapes


def test_pairs_cover_assignment():
    pairs = shapes.pairs()
    archs = {a for a, _ in pairs}
    assert archs == set(registry.ASSIGNED)
    # every arch has the three universal shapes
    for arch in registry.ASSIGNED:
        got = {s for a, s in pairs if a == arch}
        assert {"train_4k", "prefill_32k", "decode_32k"} <= got
    # long_500k only for sub-quadratic-context archs
    long_archs = {a for a, s in pairs if s == "long_500k"}
    assert long_archs == shapes.LONG_OK
    assert len(pairs) == 35


def test_shape_configs_match_assignment():
    s = shapes.SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_variants_known():
    assert "base" in shapes.VARIANTS
    for v in ["gather-moe", "ragged-moe", "pure-dp-serve", "expert-parallel",
              "paged-serve", "async-prefill", "disagg-prefill"]:
        assert v in shapes.VARIANTS


def test_paged_serve_step_builds_page_pool_specs():
    """The paged-serve dry-run variant must thread page tables + the
    pool free list through the serve step's input specs and shardings
    (the base variant keeps the dense-cache step: no page fields)."""
    from repro.models.attention import PagedKV
    from repro.models.model import Model

    mesh = AbstractMesh((("data", 16), ("model", 16)))
    model = Model(registry.get_config("olmo-1b"))
    shape = shapes.SHAPES["decode_32k"]

    _, args, shardings, out_shardings = shapes.build_serve_step(
        model, mesh, shape, shapes.VARIANTS["paged-serve"]
    )
    batch_specs, batch_shard = args[4], shardings[4]
    assert batch_specs.page_table is not None
    assert batch_specs.page_table.shape[0] == shape.global_batch
    assert batch_specs.pool is not None
    assert batch_specs.pool.free_stack.shape == batch_specs.pool.ref.shape
    assert batch_shard.page_table is not None
    # global-attention layers lower as pooled PagedKV entries
    t_cache = args[2]
    pools = [
        e for seg in t_cache["segments"] for e in seg
        if isinstance(e, PagedKV)
    ]
    assert pools, "olmo global layers should be paged in this variant"
    assert pools[0].k.shape[1] == batch_specs.pool.free_stack.shape[0]

    # base variant unchanged: dense caches, no page bookkeeping
    _, args_b, _, _ = shapes.build_serve_step(model, mesh, shape, {})
    assert args_b[4].page_table is None and args_b[4].pool is None


def test_async_prefill_variant_builds_staging_program_specs():
    """The async-prefill dry-run variant lowers the DETACHED background
    prefill program: its inputs are StageState + the shared pool (with
    the ``staged`` mark array), not BatchState, and its outputs return
    the updated staging lane — the second executable of the two-program
    serve loop."""
    from repro.models.model import Model
    from repro.serving.batch import StageState

    mesh = AbstractMesh((("data", 16), ("model", 16)))
    model = Model(registry.get_config("olmo-1b"))
    shape = shapes.SHAPES["decode_32k"]

    _, args, shardings, out_shardings = shapes.build_serve_step(
        model, mesh, shape, shapes.VARIANTS["async-prefill"]
    )
    stage_specs, pool_spec = args[4], args[5]
    assert isinstance(stage_specs, StageState)
    assert stage_specs.seq_buf.shape[0] == shape.global_batch
    assert stage_specs.page_table is not None
    assert pool_spec.staged.shape == pool_spec.cached.shape
    assert isinstance(out_shardings[2], StageState)
    # the pool rides along as an explicit output (threaded to decode)
    assert out_shardings[3] is not None


def test_disagg_prefill_variant_lowers_staging_on_prefill_pod_only():
    """The disagg-prefill dry-run variant carves the 32-device mesh into
    an 8-device prefill pod and a 24-device decode pod
    (``sharding.carve_pods``) and lowers the staging executable against
    the PREFILL pod only, over the prefill pod's own staging pool
    (``paging.stage_spec_of``: stage_slots * max_pages pages). Every
    sharding the program binds references the carved 8-device submesh —
    the structural form of "the decode pod dispatches zero prefill
    programs": nothing in the staging executable can place work on the
    other 24 devices."""
    from repro.models.model import Model
    from repro.serving.batch import StageState

    mesh = AbstractMesh((("data", 4), ("model", 8)))  # 32 fake devices
    model = Model(registry.get_config("olmo-1b"))
    shape = shapes.SHAPES["decode_32k"]

    _, args, shardings, out_shardings = shapes.build_serve_step(
        model, mesh, shape, shapes.VARIANTS["disagg-prefill"]
    )
    stage_specs, pool_spec = args[4], args[5]
    assert isinstance(stage_specs, StageState)
    meshes = {
        s.mesh for s in jax.tree.leaves((shardings, out_shardings))
        if hasattr(s, "mesh")
    }
    assert len(meshes) == 1, "one pod, one mesh"
    (pod,) = meshes
    assert dict(pod.shape) == {"data": 1, "model": 8}  # 8 of 32 devices
    # the prefill pod allocates out of its OWN pool, fully provisioned
    # per staging lane (stage_slots * max_pages) — not the decode pool
    assert pool_spec.free_stack.shape[0] == (
        stage_specs.page_table.shape[0] * stage_specs.page_table.shape[1]
    )
    # the shared-pool async variant sizes its pool differently (decode
    # slots + staging headroom over the full mesh) — the two programs
    # provably bind different pools
    _, args_a, _, _ = shapes.build_serve_step(
        model, mesh, shape, shapes.VARIANTS["async-prefill"]
    )
    assert args_a[5].free_stack.shape[0] != pool_spec.free_stack.shape[0]


def test_carve_pods_abstract_and_validation():
    from repro.distributed import sharding as shd

    mesh = AbstractMesh((("data", 4), ("model", 8)))
    pre, dec = shd.carve_pods(mesh, 1)
    assert dict(pre.shape) == {"data": 1, "model": 8}
    assert dict(dec.shape) == {"data": 3, "model": 8}
    import pytest
    with pytest.raises(ValueError):
        shd.carve_pods(mesh, 4)  # empty decode pod
    with pytest.raises(ValueError):
        shd.carve_pods(mesh, 0)  # empty prefill pod


def test_analytic_costs_sane():
    for arch in registry.ASSIGNED:
        cfg = registry.get_config(arch)
        for name, shape in shapes.SHAPES.items():
            if name == "long_500k" and arch not in shapes.LONG_OK:
                continue
            a = roofline.analytic_costs(cfg, shape, 256)
            assert a["analytic_compute_s"] > 0
            assert a["analytic_memory_s"] > 0
            assert jnp.isfinite(a["analytic_compute_s"])
    # training must cost more flops than serving for the same arch
    cfg = registry.get_config("olmo-1b")
    tr = roofline.analytic_costs(cfg, shapes.SHAPES["train_4k"], 256)
    de = roofline.analytic_costs(cfg, shapes.SHAPES["decode_32k"], 256)
    assert tr["analytic_compute_s"] > de["analytic_compute_s"]


def test_ragged_moe_reduces_decode_compute():
    cfg = registry.get_config("mixtral-8x22b")
    shape = shapes.SHAPES["decode_32k"]
    base = roofline.analytic_costs(cfg, shape, 256)
    ragged = roofline.analytic_costs(cfg, shape, 256, ragged_moe=True)
    assert ragged["analytic_compute_s"] < 0.5 * base["analytic_compute_s"]


def test_model_flops_moe_active_only():
    cfg = registry.get_config("mixtral-8x22b")
    n_act = roofline.active_param_count(cfg)
    from repro.models.model import Model
    n_tot = Model(cfg).param_count()
    assert n_act < 0.45 * n_tot  # top-2 of 8 experts
