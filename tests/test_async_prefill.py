"""Disaggregated async prefill (the staging lane) tests.

Four layers:

* allocator semantics of the ``staged`` page state — ``ensure(
  mark_staged=True)`` stamps pages invisible-to-decode, adoption
  (:func:`~repro.serving.paging.host_adopt_stage`) transfers them to a
  decode slot's table by flipping marks (refcounts untouched, zero
  copies), release clears the mark whether the page frees or parks
  cached;
* the two-lane :class:`~repro.serving.scheduler.Scheduler` — staging
  admission, the ready queue, adoption as a pure budget key move,
  stage kills, and the TTFT queue/prefill/decode breakdown;
* the engine with ``async_prefill=True`` — bit-identical to the serial
  engine at temperature 0 (concurrent mixed workloads, over-subscribed
  pools with staged kills, prefix-cache composition) and for
  sequential sampled runs; decode provably never maps a staged page
  before its ready flip; lane-interaction telemetry emitted;
* the hypothesis property form: under randomized admit / preempt /
  adopt / retire traffic driven by the real PageBudget policy, device
  allocation never fails, no staged page is ever referenced by a
  decode table, and the pool never leaks.
"""

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from repro.configs import registry
from repro.models import Model
from repro.serving import batch as batch_mod
from repro.serving import paging
from repro.serving.engine import EngineConfig, SpecEngine
from repro.serving.scheduler import Scheduler

SPEC = paging.PageSpec(page_size=4, num_pages=16, max_pages=6)


def _mk(num_slots=2, spec=SPEC):
    table, used = paging.init_tables(spec, num_slots)
    return table, used, paging.init_pool(spec)


# ---------------------------------------------------------------------------
# staged page state (allocator units)
# ---------------------------------------------------------------------------


class TestStagedPageState:
    def test_mark_staged_stamps_granted_pages_only(self):
        table, used, pool = _mk()
        table, used, pool, ok = paging.ensure(
            SPEC, table, used, pool, jnp.asarray([7, 5]),
            jnp.asarray([True, False]), mark_staged=True,
        )
        assert bool(ok[0])
        staged_ids = {int(p) for p in table[0, :2]}
        assert np.asarray(pool.staged).sum() == 2
        assert {p for p in range(16) if pool.staged[p]} == staged_ids
        # plain ensure never stamps
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.asarray([7, 5]),
            jnp.asarray([False, True]),
        )
        assert np.asarray(pool.staged).sum() == 2

    def test_adopt_transfers_pages_without_touching_refcounts(self):
        s_table, s_used, pool = _mk(1)
        s_table, s_used, pool, _ = paging.ensure(
            SPEC, s_table, s_used, pool, jnp.asarray([9]),
            jnp.asarray([True]), mark_staged=True,
        )
        ids = [int(p) for p in s_table[0, :3]]
        d_table, d_used = paging.init_tables(SPEC, 2)
        ref_before = np.asarray(pool.ref).copy()
        d_table, d_used, pool = paging.host_adopt_stage(
            SPEC, d_table, d_used, pool, 1, ids
        )
        assert [int(p) for p in d_table[1, :3]] == ids
        assert int(d_used[1]) == 3
        assert not bool(jnp.any(pool.staged))            # ready flip
        np.testing.assert_array_equal(np.asarray(pool.ref), ref_before)
        # the adopted pages release exactly once, through the decode table
        d_table, d_used, pool = paging.release(
            SPEC, d_table, d_used, pool, jnp.asarray([False, True])
        )
        assert int(pool.free_count) == SPEC.num_pages
        assert int(jnp.max(pool.ref)) == 0

    def test_release_clears_staged_whether_freed_or_cached(self):
        s_table, s_used, pool = _mk(1)
        s_table, s_used, pool, _ = paging.ensure(
            SPEC, s_table, s_used, pool, jnp.asarray([9]),
            jnp.asarray([True]), mark_staged=True,
        )
        cc = np.zeros((1, SPEC.max_pages), bool)
        cc[0, 0] = True  # one fully-written page parks cached
        s_table, s_used, pool = paging.release(
            SPEC, s_table, s_used, pool, jnp.asarray([True]),
            cache_cols=jnp.asarray(cc),
        )
        assert not bool(jnp.any(pool.staged))
        assert int(jnp.sum(pool.cached)) == 1
        assert int(pool.free_count) == SPEC.num_pages - 1

    def test_spec_of_reserves_staging_headroom(self):
        """A fully-provisioned pool (num_pages=None) must cover the
        staging lanes' worst-case reservations on top of the decode
        slots', so async admission never starves and preemption never
        fires — PageBudget.worst_pages never exceeds a slot term."""
        kw = dict(gamma=3, max_slots=2, max_len=64, page_size=8)
        serial = paging.spec_of(EngineConfig(**kw))
        asyncp = paging.spec_of(
            EngineConfig(**kw, async_prefill=True, stage_slots=2)
        )
        assert asyncp.max_pages == serial.max_pages
        assert asyncp.num_pages == serial.num_pages + 2 * serial.max_pages
        budget = paging.PageBudget(asyncp, gamma=3)
        for slot in range(2):
            budget.note_admit(slot, 63)
        for sid in range(2):
            assert budget.can_admit(63)   # staging lane never starved
            budget.note_stage(sid, 63)
        assert not budget.needs_preemption()

    def test_budget_stage_accounting_and_adopt_key_move(self):
        budget = paging.PageBudget(SPEC, gamma=3)
        budget.note_stage(0, 9)
        budget.note_admit(1, 9)
        assert budget.used_worst() == 2 * budget.worst_pages(9)
        before = budget.used_worst()
        budget.note_adopt(0, 2)
        assert budget.used_worst() == before  # pure key move
        assert budget.stage_len == {}
        assert budget.slot_len == {1: 9, 2: 9}
        budget.note_stage(1, 5)
        budget.note_unstage(1)
        assert budget.used_worst() == before


# ---------------------------------------------------------------------------
# two-lane scheduler
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


class TestTwoLaneScheduler:
    def _sched(self, **kw):
        kw.setdefault("num_stage_slots", 2)
        return Scheduler(2, 8, 4, clock=_FakeClock(), **kw)

    def test_stage_admit_then_ready_then_adopt(self):
        s = self._sched()
        rids = [s.submit([1] * 9), s.submit([2] * 5), s.submit([3, 4])]
        staged = s.stage_admit()
        assert [sid for sid, _ in staged] == [0, 1]
        assert s.stage_pending()
        assert s.adopt() == []                 # nothing ready yet
        s.note_stage_prefill_dispatch()        # 4 tokens: sid1 (4 left) done
        assert list(s.ready_q) == [1]
        s.note_stage_prefill_dispatch()        # sid0 (8 left) done
        assert list(s.ready_q) == [1, 0]
        adopted = s.adopt()
        assert [(sid, slot) for sid, slot, _ in adopted] == [(1, 0), (0, 1)]
        assert adopted[0][2].rid == rids[1]
        assert s.ready_slots().keys() == {0, 1}
        # freed staging slots pick up the queue tail
        staged = s.stage_admit()
        assert [sid for sid, _ in staged] == [0]
        assert staged[0][1].rid == rids[2]
        # two-token prompt: one chunk, ready next dispatch
        s.note_stage_prefill_dispatch()
        assert list(s.ready_q) == [0]
        assert s.adopt() == []                 # decode slots full
        s.retire(0, "length")
        assert [(sid, slot) for sid, slot, _ in s.adopt()] == [(0, 0)]
        assert not s.stage_pending()

    def test_single_token_prompt_ready_at_staging(self):
        s = self._sched()
        s.submit([5])
        s.stage_admit()
        assert list(s.ready_q) == [0]
        assert not s.stage_pending()

    def test_kill_stage_requeues_front_and_drops_ready_entry(self):
        s = self._sched()
        r0 = s.submit([1] * 9)
        r1 = s.submit([2] * 3)
        s.stage_admit()
        s.note_stage_prefill_dispatch()        # sid1 ready
        assert list(s.ready_q) == [1]
        victim = s.pick_stage_victim()
        assert victim == 1                     # LIFO by admit_seq
        req = s.kill_stage(victim)
        assert req.rid == r1 and req.preemptions == 1
        assert list(s.ready_q) == []
        assert s.queue[0].rid == r1            # front of the queue
        assert s.stage_req[1] is None
        assert s.has_work()
        assert s.stage_req[0].rid == r0

    def test_stage_budget_gate_preserves_fifo(self):
        spec = paging.PageSpec(page_size=4, num_pages=12, max_pages=10)
        budget = paging.PageBudget(spec, gamma=3)
        s = self._sched(budget=budget)
        s.submit([1] * 30)                     # worst_pages(30) = 10
        s.submit([2] * 3)
        assert len(s.stage_admit()) == 1       # head staged...
        assert len(s.stage_admit()) == 0       # ...short one must NOT overtake
        assert s.queue[0].prompt == [2] * 3

    def test_ttft_breakdown_components(self):
        s = self._sched()
        s.submit([1] * 9)                      # submit_t = 1
        (sid, req), = s.stage_admit()          # stage_t = 2
        s.note_stage_prefill_dispatch()        # 4/8 tokens: not ready
        s.note_stage_prefill_dispatch()        # ready_t = 3
        (_, slot, _), = s.adopt()
        req.first_token_t = s.clock()          # 4 (engine does this)
        req.output = [7]
        s.retire(slot, "length")
        assert req.ttft_queue_s == 1.0         # submit -> staged
        assert req.ttft_prefill_s == 1.0       # staged -> ready
        assert req.ttft_transfer_s == 1.0      # ready -> adopted
        assert req.ttft_decode_s == 1.0        # adopted -> first token
        assert req.ttft_s == req.ttft_queue_s + req.ttft_prefill_s + \
            req.ttft_transfer_s + req.ttft_decode_s
        m = s.request_metrics(gamma=3)[0]
        assert m["ttft_queue_s"] == 1.0
        assert m["ttft_prefill_s"] == 1.0
        assert m["ttft_transfer_s"] == 1.0
        assert m["ttft_decode_s"] == 1.0

    def test_resume_full_claim_refreshes_ready_t(self):
        """A request preempted after its prefill completed (but before
        its first token) whose RESUME is a full-prefix cache claim must
        refresh ready_t — keeping the first attempt's earlier anchor
        made ttft_prefill_s negative."""
        s = Scheduler(1, 8, 4, clock=_FakeClock())
        s.submit([1] * 9)
        s.admit()
        s.note_prefill_dispatch()
        s.note_prefill_dispatch()              # ready_t set (attempt 1)
        first_ready = s.slot_req[0].ready_t
        s.preempt(0)                           # requeued at the front
        (slot, req), = s.admit()               # stage_t overwritten, later
        s.note_prefix_claim(slot, 8)           # resume = full-prefix claim
        assert req.ready_t > first_ready
        req.first_token_t = s.clock()
        req.output = [7]
        assert req.ttft_prefill_s >= 0
        assert req.ttft_decode_s >= 0

    def test_serial_lane_ttft_breakdown(self):
        s = Scheduler(1, 8, 4, clock=_FakeClock())
        s.submit([1] * 9)                      # submit_t = 1
        (slot, req), = s.admit()               # stage_t = 2
        s.note_prefill_dispatch()              # 4/8: clock ticks, not ready
        s.note_prefill_dispatch()              # ready_t set (8 tokens done)
        assert req.ready_t is not None
        prefill_s = req.ready_t - req.stage_t
        req.first_token_t = s.clock()
        assert req.ttft_queue_s == 1.0
        assert req.ttft_prefill_s == prefill_s > 0
        assert req.ttft_decode_s == req.first_token_t - req.ready_t > 0

    def test_staged_kill_wait_routed_out_of_ttft_queue(self):
        """The dead time between a staged kill and the retry's
        re-staging must accumulate in ``pre_first_requeue_wait_s`` —
        subtracted from ``ttft_queue_s`` (whose ``stage_t`` anchor
        restarts at the retry, so prefill time isn't double-counted
        across the two staging attempts) and kept out of the
        post-first-token ``requeue_wait_s`` that ``tokens_per_s``
        corrects by."""
        s = Scheduler(1, 8, 4, clock=_FakeClock(), num_stage_slots=1)
        s.submit([1] * 9)                      # submit_t = 1
        (sid, req), = s.stage_admit()          # stage_t = 2
        s.note_stage_prefill_dispatch()        # 4/8 tokens staged
        s.kill_stage(sid)                      # _preempt_t = 3
        s.clock()                              # 4: queue sits while the
        s.clock()                              # 5: pool stays tight
        (sid2, req2), = s.stage_admit()        # re-staged at 6
        assert req2 is req
        assert req.pre_first_requeue_wait_s == 3.0   # kill(3) -> restage(6)
        assert req.requeue_wait_s == 0.0       # decode correction untouched
        assert req.stage_t == 6.0              # anchor restarted
        s.note_stage_prefill_dispatch()        # 4/8 of attempt 2
        s.note_stage_prefill_dispatch()        # ready_t = 7
        s.adopt()
        req.first_token_t = s.clock()          # 8
        assert req.ttft_queue_s == 2.0         # NOT inflated by the kill
        assert req.ttft_prefill_s == 1.0       # attempt 2 only
        assert req.ttft_s == (
            req.ttft_queue_s + req.ttft_prefill_s + req.ttft_transfer_s
            + req.ttft_decode_s + req.pre_first_requeue_wait_s
        )


# ---------------------------------------------------------------------------
# engine identity + invariants
# ---------------------------------------------------------------------------


def _models(name="smollm-135m", seed=0):
    cfg = registry.smoke_config(name)
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    tgt = Model(cfg)
    drf = Model(cfg.with_(d_model=128, d_ff=256 if cfg.d_ff else 0,
                          name=cfg.name + "-d"))
    kt, kd = jax.random.split(jax.random.key(seed))
    return tgt, drf, tgt.init(kt), drf.init(kd)


def _serve(tgt, drf, tp, dp, cfg, prompts, seed=0):
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    eng.reset(seed=seed)
    rids = [eng.submit(p) for p in prompts]
    res = eng.run()
    return eng, [res[r].output for r in rids]


def _assert_drained(eng):
    pool = eng.batch.pool
    cached = int(jnp.sum(pool.cached))
    assert int(pool.free_count) + cached == pool.free_stack.shape[0]
    assert int(jnp.max(pool.ref)) == 0 or cached > 0
    assert not bool(jnp.any(pool.staged))


MIXED = [
    [5, 3, 8, 1, 2],
    [9, 9, 2, 4, 4, 4, 7, 1, 0, 3, 2, 6, 1, 5, 2, 8, 3, 1],
    [4, 2, 7],
    [1, 2, 3, 4, 5, 6, 7, 8, 9, 1, 2, 3, 4, 5, 6, 7, 8, 9, 1, 2],
    [6, 6, 1],
    [2, 4, 8, 1, 3, 5, 7, 9, 2, 4, 8, 1, 3, 5],
]


class TestAsyncEngineIdentity:
    def test_temp0_concurrent_mixed_workload_bit_identical(self):
        """Cold long prompts interleaved with warm short ones, more
        requests than decode slots: the two-lane engine must commit
        exactly the serial engine's tokens, while actually exercising
        adoption and decode/prefill overlap."""
        tgt, drf, tp, dp = _models()
        outs = {}
        for async_p in (False, True):
            cfg = EngineConfig(
                gamma=3, verifier="block", max_slots=2, max_len=96,
                temperature=0.0, max_new_tokens=10, prefill_chunk=4,
                async_prefill=async_p, stage_slots=2,
            )
            eng, outs[async_p] = _serve(tgt, drf, tp, dp, cfg, MIXED)
            _assert_drained(eng)
            if async_p:
                assert eng.last_stats["adoptions"] == len(MIXED)
                assert eng.last_stats["overlap_steps"] > 0
                assert eng.last_stats["prefill_stall_steps"] == 0
            else:
                assert eng.last_stats["prefill_stall_steps"] > 0
        assert outs[True] == outs[False]

    def test_sequential_sampled_bit_identical(self):
        """One request at a time at a sampled temperature: the staging
        lane consumes no PRNG, so the decode-dispatch key sequence —
        and every sampled token — must match the serial engine."""
        tgt, drf, tp, dp = _models(seed=3)
        outs = {}
        for async_p in (False, True):
            cfg = EngineConfig(
                gamma=3, verifier="block", max_slots=2, max_len=96,
                temperature=0.8, max_new_tokens=10, prefill_chunk=4,
                async_prefill=async_p,
            )
            eng = SpecEngine(tgt, drf, tp, dp, cfg)
            seq = []
            for p in (MIXED[1], MIXED[0], MIXED[3]):
                rid = eng.submit(p)
                seq.append(eng.run()[rid].output)
            outs[async_p] = seq
        assert outs[True] == outs[False]

    def test_oversubscribed_pool_staged_kills_stay_lossless(self):
        """A pool too small for the burst: the async engine sheds load
        by killing background prefills first, and still commits the
        serial engine's exact greedy tokens with zero leaked pages."""
        tgt, drf, tp, dp = _models()
        prompts = [
            [(i * 11 + j) % tgt.cfg.vocab for j in range(20)]
            for i in range(6)
        ]
        outs = {}
        for async_p in (False, True):
            cfg = EngineConfig(
                gamma=3, verifier="block", max_slots=3, max_len=80,
                temperature=0.0, max_new_tokens=40, prefill_chunk=4,
                page_size=4, num_pages=30,
                async_prefill=async_p, stage_slots=2,
            )
            eng, outs[async_p] = _serve(tgt, drf, tp, dp, cfg, prompts)
            assert eng.last_stats["preemptions"] > 0
            _assert_drained(eng)
        assert outs[True] == outs[False]

    def test_prefix_cache_composition_round2_hits(self):
        """async_prefill composes with the prefix cache: a second round
        of repeated-prefix prompts claims at *staging* time and stays
        bit-identical to the serial prefix-cached engine."""
        tgt, drf, tp, dp = _models()
        pre = [7] * 20
        prompts = [pre + [i + 1, i + 2] for i in range(4)]
        outs, hits = {}, {}
        for async_p in (False, True):
            cfg = EngineConfig(
                gamma=3, verifier="block", max_slots=2, max_len=96,
                temperature=0.0, max_new_tokens=8, prefill_chunk=4,
                page_size=4, prefix_cache=True,
                async_prefill=async_p, stage_slots=2,
            )
            eng = SpecEngine(tgt, drf, tp, dp, cfg)
            rounds = []
            for _ in range(2):
                rids = [eng.submit(p) for p in prompts]
                res = eng.run()
                rounds.append([res[r].output for r in rids])
            outs[async_p] = rounds
            hits[async_p] = eng.last_stats["prefix_cache"]["hits"]
            _assert_drained(eng)
        assert outs[True] == outs[False]
        assert hits[True] > 0

    def test_decode_never_maps_a_staged_page(self):
        """The tentpole invariant, checked at every decode dispatch: the
        pages mapped by decode slots' tables are disjoint from the
        pool's staged set (sync per step — smoke-sized workload)."""
        tgt, drf, tp, dp = _models()
        cfg = EngineConfig(
            gamma=3, verifier="block", max_slots=2, max_len=96,
            temperature=0.0, max_new_tokens=8, prefill_chunk=4,
            async_prefill=True, stage_slots=2,
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        inner = eng.runner.decode_step
        checked = {"n": 0}

        def checked_decode(tp_, dp_, tc, dc, batch, key, corrupt=None):
            staged = np.asarray(batch.pool.staged)
            table = np.asarray(batch.page_table)
            used = np.asarray(batch.pages_used)
            active = np.asarray(batch.active)
            for slot in range(batch.num_slots):
                if active[slot]:
                    ids = table[slot, : used[slot]]
                    assert (ids >= 0).all(), (slot, ids)
                    assert not staged[ids].any(), (slot, ids)
            checked["n"] += 1
            return inner(tp_, dp_, tc, dc, batch, key, corrupt=corrupt)

        eng.runner.decode_step = checked_decode
        for p in MIXED:
            eng.submit(p)
        eng.run()
        assert checked["n"] > 0

    def test_async_prefill_requires_fully_paged(self):
        tgt, drf, tp, dp = _models("mixtral-8x22b")  # windowed layers
        cfg = EngineConfig(
            gamma=2, verifier="block", max_slots=1, max_len=64,
            async_prefill=True,
        )
        with pytest.raises(ValueError, match="async_prefill"):
            SpecEngine(tgt, drf, tp, dp, cfg)

    def test_async_prefill_requires_paged(self):
        tgt, drf, tp, dp = _models()
        cfg = EngineConfig(
            gamma=2, verifier="block", max_slots=1, max_len=64,
            paged=False, async_prefill=True,
        )
        with pytest.raises(ValueError, match="paged"):
            SpecEngine(tgt, drf, tp, dp, cfg)


# ---------------------------------------------------------------------------
# randomized traffic: allocation never fails, staged invisible, no leaks
# ---------------------------------------------------------------------------


def _pool_invariant(spec, pool):
    free = int(pool.free_count)
    ref = np.asarray(pool.ref)
    cached = np.asarray(pool.cached)
    live = int((ref > 0).sum())
    parked = int(((ref == 0) & cached).sum())
    assert free + live + parked == spec.num_pages, (free, live, parked)
    assert (ref >= 0).all()
    stack = {int(x) for x in pool.free_stack[:free]}
    assert len(stack) == free
    assert not stack & {p for p in range(spec.num_pages) if ref[p] > 0}
    assert not np.asarray(pool.staged)[np.asarray(pool.cached)].any()


def _async_traffic_lifecycle(seed: int):
    """Randomized two-lane serving traffic driven by the REAL host
    policy (PageBudget staging reservations, adoption as a key move,
    stage-kill-first preemption) against the REAL allocator ops,
    asserting the engine's three load-bearing invariants: budgeted
    ``ensure`` never fails, no decode table ever maps a ``staged``
    page, and the pool drains leak-free. Mirrors the async loop's
    ordering: preempt -> adopt -> stage-admit -> decode-alloc ->
    stage-alloc -> commit/retire."""
    rng = np.random.RandomState(seed)
    gamma = 3
    chunk = 4
    spec = paging.PageSpec(page_size=4, num_pages=40, max_pages=10)
    max_len = 32
    budget = paging.PageBudget(spec, gamma)
    n_slots, n_stage = 3, 2
    d_table, d_used = paging.init_tables(spec, n_slots)
    s_table, s_used = paging.init_tables(spec, n_stage)
    pool = paging.init_pool(spec)
    queue: deque = deque()
    live: dict[int, dict] = {}     # decode slot -> {"tokens": [...]}
    staging: dict[int, dict] = {}  # sid -> {"tokens", "pos", "ready"}
    ready: deque = deque()
    admit_order: dict = {}
    seq = 0

    def staging_invariant():
        staged = np.asarray(pool.staged)
        dt, du = np.asarray(d_table), np.asarray(d_used)
        for slot in live:
            ids = dt[slot, : du[slot]]
            assert (ids >= 0).all()
            assert not staged[ids].any(), (seed, slot)
        expect = set()
        st_, su_ = np.asarray(s_table), np.asarray(s_used)
        for sid in staging:
            expect |= {int(p) for p in st_[sid, : su_[sid]]}
        assert {p for p in range(spec.num_pages) if staged[p]} == expect

    for _ in range(60):
        if rng.rand() < 0.7:
            queue.append(
                rng.randint(0, 7, size=rng.randint(1, 18)).tolist()
            )
        # 1. preemption: staged LIFO first, then decode LIFO
        while budget.needs_preemption():
            if staging:
                sid = max(staging, key=lambda s: admit_order[("s", s)])
                st = staging.pop(sid)
                queue.appendleft(st["tokens"])
                if sid in ready:
                    ready.remove(sid)
                mask = jnp.arange(n_stage) == sid
                s_table, s_used, pool = paging.release(
                    spec, s_table, s_used, pool, mask
                )
                budget.note_unstage(sid)
                admit_order.pop(("s", sid))
            elif len(live) > 1:
                victim = max(live, key=lambda s: admit_order[s])
                queue.appendleft(live.pop(victim)["tokens"])
                mask = jnp.arange(n_slots) == victim
                d_table, d_used, pool = paging.release(
                    spec, d_table, d_used, pool, mask
                )
                budget.note_release(victim)
                admit_order.pop(victim)
            else:
                break
        # 2. adoption (ready-queue FIFO into free decode slots)
        free_slots = [s for s in range(n_slots) if s not in live]
        while ready and free_slots:
            sid = ready.popleft()
            st = staging.pop(sid)
            slot = free_slots.pop(0)
            ids = [int(p) for p in s_table[sid, : int(s_used[sid])]]
            d_table, d_used, pool = paging.host_adopt_stage(
                spec, d_table, d_used, pool, slot, ids
            )
            s_table = s_table.at[sid].set(
                jnp.full((spec.max_pages,), -1, jnp.int32)
            )
            s_used = s_used.at[sid].set(0)
            budget.note_adopt(sid, slot)
            live[slot] = {"tokens": st["tokens"]}
            admit_order[slot] = seq
            seq += 1
            admit_order.pop(("s", sid))
        # 3. staging admission (FIFO, budget-gated)
        for sid in range(n_stage):
            if sid not in staging and queue:
                if not budget.can_admit(len(queue[0])):
                    break
                toks = queue.popleft()
                staging[sid] = {"tokens": toks, "pos": 0}
                if len(toks) <= 1:
                    ready.append(sid)
                budget.note_stage(sid, len(toks))
                admit_order[("s", sid)] = seq
                seq += 1
        # 4. decode allocation must never fail for budgeted slots
        lens = jnp.asarray(
            [len(live[s]["tokens"]) if s in live else 0
             for s in range(n_slots)], jnp.int32,
        )
        run = jnp.asarray([s in live for s in range(n_slots)])
        d_table, d_used, pool, ok = paging.ensure(
            spec, d_table, d_used, pool, lens + gamma + 1, run
        )
        assert bool(jnp.all(jnp.where(run, ok, True))), (
            "decode ensure failed under budget", seed
        )
        # 5. staged allocation (one background chunk) must never fail
        pos = np.zeros(n_stage, np.int32)
        n_tok = np.zeros(n_stage, np.int32)
        for sid, st in staging.items():
            pos[sid] = st["pos"]
            n_tok[sid] = min(chunk, len(st["tokens"]) - 1 - st["pos"])
        pending = jnp.asarray(n_tok > 0)
        s_table, s_used, pool, ok = paging.ensure(
            spec, s_table, s_used, pool,
            jnp.asarray(pos + n_tok), pending, mark_staged=True,
        )
        assert bool(jnp.all(jnp.where(pending, ok, True))), (
            "staged ensure failed under budget", seed
        )
        for sid, st in staging.items():
            st["pos"] += int(n_tok[sid])
            if st["pos"] >= len(st["tokens"]) - 1 and sid not in ready:
                ready.append(sid)
        # 6. commit + retire
        for slot in list(live):
            st = live[slot]
            n_new = int(rng.randint(1, gamma + 2))
            st["tokens"].extend(rng.randint(0, 7, size=n_new).tolist())
            budget.note_commit(slot, n_new)
            if len(st["tokens"]) >= max_len or rng.rand() < 0.2:
                live.pop(slot)
                mask = jnp.arange(n_slots) == slot
                d_table, d_used, pool = paging.release(
                    spec, d_table, d_used, pool, mask
                )
                budget.note_release(slot)
                admit_order.pop(slot)
        _pool_invariant(spec, pool)
        staging_invariant()

    for sid in list(staging):
        mask = jnp.arange(n_stage) == sid
        s_table, s_used, pool = paging.release(
            spec, s_table, s_used, pool, mask
        )
        staging.pop(sid)
    for slot in list(live):
        mask = jnp.arange(n_slots) == slot
        d_table, d_used, pool = paging.release(
            spec, d_table, d_used, pool, mask
        )
        live.pop(slot)
    _pool_invariant(spec, pool)
    assert int(pool.free_count) == spec.num_pages  # no leaks, ever
    assert int(jnp.max(pool.ref)) == 0
    assert not bool(jnp.any(pool.staged))


class TestAsyncTrafficNeverFailsNeverLeaks:
    def test_traffic_deterministic(self):
        for seed in (0, 1, 2):
            _async_traffic_lifecycle(seed)

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_traffic_property(self, seed):
        _async_traffic_lifecycle(seed)


class TestStageStateUnits:
    def test_stage_slot_invariants(self):
        spec = paging.PageSpec(page_size=4, num_pages=16, max_pages=8)
        stage = batch_mod.init_stage(2, 32, spec)
        stage = batch_mod.stage_slot(stage, 1, [4, 2, 7, 1], prefix_len=0)
        assert bool(stage.active[1]) and not bool(stage.ready[1])
        assert int(stage.plen[1]) == 4 and int(stage.pos[1]) == 0
        # full-prefix hit stages ready immediately
        stage = batch_mod.stage_slot(stage, 0, [5, 5, 5], prefix_len=2)
        assert bool(stage.ready[0]) and int(stage.pos[0]) == 2
        stage = batch_mod.clear_stage_slot(stage, 1)
        assert not bool(stage.active[1])
        assert int(stage.pages_used[1]) == 0
        assert int(stage.page_table[1, 0]) == -1
