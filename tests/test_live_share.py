"""Live prefix sharing + cache-aware admission tests.

Four layers:

* the :class:`~repro.serving.paging.PrefixCache` live-span API —
  ``register_live`` (insert-as-you-commit, first-writer-wins,
  idempotent), lazy page resolution, in-place live→cached conversion
  at the owner's release, ``move_owner`` re-keying at adoption, and
  the structural eviction exclusion of live nodes;
* ``host_claim_live`` allocator semantics — pinning an in-use page
  (ref >= 1 → >= 2) keeps it off the free stack until every claimant
  releases, composing with the owner's cache-parking release;
* the scheduler's cache-aware admission — longest-match selection via
  ``match_fn``, deterministic tie-breaks, aging so cold prompts can't
  starve, and the no-overtaking budget stall;
* the engine with ``live_share=True`` — a same-burst workload of N
  identical prompts costs ~1 prefill instead of N (serial AND async),
  outputs bit-identical at temperature 0 and for sequential sampled
  runs, rides survive writer preemption, and the pool drains to zero
  refcounts at quiesce;
* the hypothesis property: under randomized writer/rider traffic,
  pinned live pages never free while a claimant maps them, the host
  mirror of live spans matches the device tables at every step, and
  refcounts drain to zero at quiesce.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from repro.configs import registry
from repro.models import Model
from repro.serving import paging
from repro.serving.engine import EngineConfig, SpecEngine
from repro.serving.scheduler import Scheduler

SPEC = paging.PageSpec(page_size=4, num_pages=16, max_pages=6)


def _mk(num_rows=2, spec=SPEC):
    table, used = paging.init_tables(spec, num_rows)
    return table, used, paging.init_pool(spec)


# ---------------------------------------------------------------------------
# PrefixCache live spans
# ---------------------------------------------------------------------------


class TestLiveSpans:
    def test_register_live_first_writer_wins_and_idempotent(self):
        cache = paging.PrefixCache(SPEC)
        toks = list(range(12))
        cache.register_live(("slot", 0), toks, 2)
        assert cache.live_pages(("slot", 0)) == 2
        # re-registering (monotone growth) only appends the new depth
        cache.register_live(("slot", 0), toks, 3)
        assert cache.live_pages(("slot", 0)) == 3
        cache.register_live(("slot", 0), toks, 3)
        assert cache.live_pages(("slot", 0)) == 3
        # a second writer of the same span creates nothing
        cache.register_live(("slot", 1), toks, 3)
        assert cache.live_pages(("slot", 1)) == 0
        path = cache.lookup(toks + [0])
        assert len(path) == 3
        assert all(n.owner == ("slot", 0) and n.page == -1 for n in path)

    def test_live_lookup_claims_count_live_hits(self):
        cache = paging.PrefixCache(SPEC)
        toks = list(range(8))
        cache.register_live(("slot", 0), toks, 2)
        path = cache.lookup(toks + [9])
        cache.claim(path)
        assert cache.hits == 1 and cache.live_hits == 1
        assert cache.live_pinned_pages() == 2
        # live nodes are structurally non-evictable: not in by_page
        assert cache.reclaimable_pages() == 0
        assert cache.evict_lru(5) == []

    def test_insert_converts_own_live_nodes_in_place(self):
        cache = paging.PrefixCache(SPEC)
        toks = list(range(8))
        cache.register_live(("slot", 0), toks, 2)
        path = cache.lookup(toks + [9])
        cache.claim(path)
        path[0].page, path[1].page = 4, 7  # claimant resolved them
        adopted = cache.insert(toks, [4, 7], owner=("slot", 0))
        assert adopted == [True, True]
        assert path[0].owner is None and path[1].owner is None
        assert cache.by_page[4] is path[0] and cache.by_page[7] is path[1]
        cache.release_live(("slot", 0))  # pure mirror cleanup
        assert cache.live_span_pages == 0
        # the claimant still pins the now-cached nodes
        assert cache.reclaimable_pages() == 0
        cache.release_claims(path)
        assert cache.reclaimable_pages() == 2

    def test_release_live_unlinks_unconverted_nodes(self):
        cache = paging.PrefixCache(SPEC)
        toks = list(range(12))
        cache.register_live(("stage", 1), toks, 1)
        # release without insert (nothing cacheable): the claim-free
        # childless live node unlinks so its soon-freed page can't be
        # looked up. (Engine invariant: release always inserts at least
        # the registered span, so deeper leftovers cannot occur — the
        # defensive assert inside release_live enforces that.)
        cache.release_live(("stage", 1))
        assert cache.lookup(toks + [0]) == []
        assert cache.live_span_pages == 0

    def test_move_owner_rekeys_adoption(self):
        cache = paging.PrefixCache(SPEC)
        toks = list(range(8))
        cache.register_live(("stage", 0), toks, 2)
        cache.move_owner(("stage", 0), ("slot", 3))
        assert cache.live_pages(("stage", 0)) == 0
        assert cache.live_pages(("slot", 3)) == 2
        path = cache.lookup(toks + [0])
        assert all(n.owner == ("slot", 3) for n in path)
        adopted = cache.insert(toks, [2, 5], owner=("slot", 3))
        assert adopted == [True, True]
        cache.release_live(("slot", 3))
        assert cache.cached_pages == 2

    def test_duplicate_writer_release_frees_normally(self):
        """Two writers of identical content: the second's pages must NOT
        adopt into the index (first writer's nodes own the spans), so
        its release frees them."""
        cache = paging.PrefixCache(SPEC)
        toks = list(range(8))
        cache.register_live(("slot", 0), toks, 2)
        cache.register_live(("slot", 1), toks, 2)
        adopted = cache.insert(toks, [8, 9], owner=("slot", 1))
        assert adopted == [False, False]
        cache.release_live(("slot", 1))
        # first writer unaffected
        assert len(cache.lookup(toks + [0])) == 2


class TestHostClaimLive:
    def test_pin_keeps_page_alive_across_owner_release(self):
        table, used, pool = _mk()
        # writer (row 0) prefills 2 pages
        table, used, pool, ok = paging.ensure(
            SPEC, table, used, pool, jnp.array([8, 0]),
            jnp.array([True, False]),
        )
        assert bool(jnp.all(ok))
        ids = [int(p) for p in table[0, :2]]
        # rider (row 1) pins them live: ref 1 -> 2
        table, used, pool = paging.host_claim_live(
            SPEC, table, used, pool, 1, ids
        )
        assert [int(pool.ref[p]) for p in ids] == [2, 2]
        assert used.tolist() == [2, 2]
        # owner releases, parking the pages cached: ref 2 -> 1, pages
        # stay off the free stack (the rider still maps them)
        cc = jnp.zeros((2, SPEC.max_pages), bool).at[0, :2].set(True)
        table, used, pool = paging.release(
            SPEC, table, used, pool, jnp.array([True, False]), cache_cols=cc
        )
        assert [int(pool.ref[p]) for p in ids] == [1, 1]
        assert int(pool.free_count) == 16 - 2
        free = {int(x) for x in pool.free_stack[: int(pool.free_count)]}
        assert not free & set(ids)
        # rider releases (no re-cache): pages park at ref 0, cached
        table, used, pool = paging.release(
            SPEC, table, used, pool, jnp.array([False, True])
        )
        assert [int(pool.ref[p]) for p in ids] == [0, 0]
        assert int(pool.free_count) == 16 - 2
        assert all(bool(pool.cached[p]) for p in ids)
        # eviction is the only path back to free
        pool = paging.host_evict(SPEC, pool, ids)
        assert int(pool.free_count) == 16

    def test_claim_extension_grows_in_place(self):
        table, used, pool = _mk()
        table, used, pool, _ = paging.ensure(
            SPEC, table, used, pool, jnp.array([12, 0]),
            jnp.array([True, False]),
        )
        ids = [int(p) for p in table[0, :3]]
        table, used, pool = paging.host_claim_live(
            SPEC, table, used, pool, 1, ids[:1]
        )
        table, used, pool = paging.host_claim_live(
            SPEC, table, used, pool, 1, ids[1:], start=1
        )
        assert [int(p) for p in table[1, :3]] == ids
        assert int(used[1]) == 3
        assert [int(pool.ref[p]) for p in ids] == [2, 2, 2]


# ---------------------------------------------------------------------------
# cache-aware admission
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


class TestCacheAwareAdmission:
    def test_longest_match_admits_first(self):
        s = Scheduler(1, 8, 4, clock=_FakeClock())
        s.match_fn = lambda prompt: prompt[0]  # match pages := token 0
        r_cold = s.submit([0] * 5)
        r_hot = s.submit([3] * 5)
        (slot, req), = s.admit()
        assert req.rid == r_hot
        assert s.queue[0].rid == r_cold and s.queue[0].age == 1

    def test_fifo_without_match_fn_and_on_ties(self):
        s = Scheduler(2, 8, 4, clock=_FakeClock())
        a, b = s.submit([1] * 5), s.submit([2] * 5)
        admitted = s.admit()
        assert [r.rid for _, r in admitted] == [a, b]
        s2 = Scheduler(2, 8, 4, clock=_FakeClock())
        s2.match_fn = lambda prompt: 1  # all equal: submit order wins
        a2, b2 = s2.submit([1] * 5), s2.submit([2] * 5)
        assert [r.rid for _, r in s2.admit()] == [a2, b2]

    def test_aging_bounds_starvation(self):
        s = Scheduler(1, 8, 4, clock=_FakeClock(), aging_limit=2)
        s.match_fn = lambda prompt: prompt[0]
        cold = s.submit([0] * 5)
        hot1 = s.submit([9] * 5)
        (_, r1), = s.admit()
        assert r1.rid == hot1 and s.queue[0].age == 1
        s.retire(0, "length")
        hot2 = s.submit([9] * 5)
        (_, r2), = s.admit()
        assert r2.rid == hot2 and s.queue[0].age == 2
        s.retire(0, "length")
        s.submit([9] * 5)  # even hotter queue...
        (_, r3), = s.admit()
        assert r3.rid == cold  # ...but the aged request goes first

    def test_budget_stall_no_overtaking(self):
        # pool (5 pages) smaller than one slot's worst case (6), so the
        # selected request stalls; the short request COULD fit (2 pages)
        # but must not overtake past the budget stall
        spec = paging.PageSpec(page_size=4, num_pages=5, max_pages=6)
        budget = paging.PageBudget(spec, gamma=1)
        s = Scheduler(2, 8, 4, clock=_FakeClock(), budget=budget)
        s.match_fn = lambda prompt: len(prompt)
        assert budget.can_admit(4) and not budget.can_admit(61)
        s.submit([1] * 61)  # longest match but cannot fit the pool
        s.submit([2] * 4)
        assert s.admit() == []  # stalled on the SELECTED request
        assert all(r.age == 0 for r in s.queue)

    def test_stage_admit_cache_aware(self):
        s = Scheduler(1, 8, 4, clock=_FakeClock(), num_stage_slots=1)
        s.match_fn = lambda prompt: prompt[0]
        s.submit([0] * 5)
        hot = s.submit([7] * 5)
        (sid, req), = s.stage_admit()
        assert req.rid == hot


class TestRidingMirror:
    def test_riding_rows_excluded_from_prefill_mirror(self):
        s = Scheduler(2, 8, 4, clock=_FakeClock())
        s.submit([1] * 9)
        s.submit([1] * 9)
        s.admit()
        s.set_slot_riding(1, True)
        assert s.prefill_pending()
        consumed = s.note_prefill_dispatch()
        assert consumed == 4  # slot 0 only; the rider held
        assert s.prefill_left(1) == 8
        s.set_slot_riding(1, False)
        assert s.note_prefill_dispatch() == 8  # 4 + 4: both advance
        s2 = Scheduler(1, 8, 4, clock=_FakeClock(), num_stage_slots=2)
        s2.submit([1] * 9)
        s2.submit([1] * 9)
        s2.stage_admit()
        s2.set_stage_riding(1, True)
        assert s2.note_stage_prefill_dispatch() == 4
        assert not s2.stage_riding(0) and s2.stage_riding(1)
        s2.kill_stage(1)
        assert not s2.stage_riding(1)  # cleared with the kill


# ---------------------------------------------------------------------------
# engine: same-burst workload
# ---------------------------------------------------------------------------


def _models(name="smollm-135m", seed=3):
    cfg = registry.smoke_config(name)
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    tgt = Model(cfg)
    drf = Model(cfg.with_(d_model=128, d_ff=256 if cfg.d_ff else 0,
                          name=cfg.name + "-d"))
    kt, kd = jax.random.split(jax.random.key(seed))
    return tgt, drf, tgt.init(kt), drf.init(kd)


def _serve(eng, prompts):
    rids = [eng.submit(p) for p in prompts]
    out = eng.run()
    return [out[r] for r in rids]


# plen - 1 = 16 = 2 full pages at page_size 8: the whole consumable
# prompt is page-aligned, so a rider shares ALL of it (no tail).
BURST_PROMPT = [5, 3, 8, 1, 2, 9, 4, 6, 7, 7, 1, 3, 2, 8, 9, 5, 11]
BASE = dict(
    gamma=3, verifier="block", max_len=96, temperature=0.0,
    max_new_tokens=8, paged=True, page_size=8,
)


class TestEngineLiveShare:
    def _burst(self, n=8):
        return [list(BURST_PROMPT) for _ in range(n)]

    def _pair(self, tgt, drf, tp, dp, prompts, **cfg_kw):
        ref = SpecEngine(
            tgt, drf, tp, dp,
            EngineConfig(prefix_cache=True, live_share=False, **cfg_kw),
        )
        r = _serve(ref, prompts)
        eng = SpecEngine(
            tgt, drf, tp, dp,
            EngineConfig(prefix_cache=True, live_share=True, **cfg_kw),
        )
        g = _serve(eng, prompts)
        return ref, r, eng, g

    def test_same_burst_serial_savings_and_identity(self):
        """8 identical prompts, serial engine, two admission waves
        (max_slots=4): the shared span is prefilled exactly once, with
        temp-0 outputs bit-identical. Vs the cached-but-unshared engine
        tokens strictly reduce (serial prefill batches all slots into
        the same dispatches, so step counts tie); vs the plain FIFO
        baseline both dispatches AND tokens strictly reduce."""
        tgt, drf, tp, dp = _models()
        ref, r, eng, g = self._pair(
            tgt, drf, tp, dp, self._burst(), max_slots=4, **BASE
        )
        assert [x.output for x in g] == [x.output for x in r]
        rs, ls = ref.last_stats, eng.last_stats
        assert ls["prefill_tokens"] < rs["prefill_tokens"]
        assert ls["prefill_steps"] <= rs["prefill_steps"]
        # the shared span is prefilled exactly once
        assert ls["prefill_tokens"] == len(BURST_PROMPT) - 1
        assert ls["prefix_cache"]["live_hits"] >= 3  # wave-1 riders
        assert ls["prefix_cache"]["hits"] == 7
        assert int(jnp.max(eng.batch.pool.ref)) == 0
        # plain FIFO baseline (live_share=False, prefix_cache=False):
        # every request prefills from scratch, every wave dispatches
        base = SpecEngine(
            tgt, drf, tp, dp, EngineConfig(max_slots=4, **BASE)
        )
        b = _serve(base, self._burst())
        assert [x.output for x in g] == [x.output for x in b]
        bs = base.last_stats
        assert ls["prefill_tokens"] < bs["prefill_tokens"]
        assert ls["prefill_steps"] < bs["prefill_steps"]

    def test_same_burst_async_savings_and_identity(self):
        """Same burst through the two-lane engine (stage_slots=2, four
        staging waves): riders share the staging writer's pages and
        later waves claim the parked span — dispatches and tokens
        strictly reduced, outputs bit-identical."""
        tgt, drf, tp, dp = _models()
        ref, r, eng, g = self._pair(
            tgt, drf, tp, dp, self._burst(), max_slots=4,
            async_prefill=True, stage_slots=2, **BASE,
        )
        assert [x.output for x in g] == [x.output for x in r]
        rs, ls = ref.last_stats, eng.last_stats
        assert ls["prefill_tokens"] < rs["prefill_tokens"]
        assert ls["prefill_steps"] < rs["prefill_steps"]
        assert ls["prefill_tokens"] == len(BURST_PROMPT) - 1
        assert ls["prefix_cache"]["live_hits"] >= 1
        assert int(jnp.max(eng.batch.pool.ref)) == 0

    def test_unaligned_tail_still_shares_full_pages(self):
        """A prompt whose consumable span is NOT page-aligned shares its
        full pages and each rider self-prefills only the tail."""
        tgt, drf, tp, dp = _models()
        prompt = BURST_PROMPT + [12, 13, 14]  # plen-1 = 19: 2 pages + 3
        prompts = [list(prompt) for _ in range(4)]
        ref, r, eng, g = self._pair(
            tgt, drf, tp, dp, prompts, max_slots=4, **BASE
        )
        assert [x.output for x in g] == [x.output for x in r]
        rs, ls = ref.last_stats, eng.last_stats
        # 1 full prefill + 3 three-token tails vs 4 full prefills
        assert ls["prefill_tokens"] == 19 + 3 * 3
        assert rs["prefill_tokens"] == 4 * 19
        assert int(jnp.max(eng.batch.pool.ref)) == 0

    def test_sequential_sampled_bitwise_identity(self):
        """Sequential submissions (one run() per request) leave the
        decode key stream untouched by live sharing, so even SAMPLED
        outputs are bit-identical to the non-shared engine."""
        tgt, drf, tp, dp = _models()
        outs = {}
        for ls_on in (False, True):
            cfg = EngineConfig(
                prefix_cache=True, live_share=ls_on, max_slots=2,
                **{**BASE, "temperature": 0.8},
            )
            eng = SpecEngine(tgt, drf, tp, dp, cfg)
            eng.reset(seed=5)
            outs[ls_on] = [
                [x.output for x in _serve(eng, [list(BURST_PROMPT)])]
                for _ in range(3)
            ]
        assert outs[True] == outs[False]

    def test_ride_survives_writer_preemption(self):
        """Over-subscribed pool: riders keep their pinned pages when the
        writer is preempted (its committed span parks cached), outputs
        still match the unshared engine, and the pool drains."""
        tgt, drf, tp, dp = _models()
        base = dict(BASE, max_slots=4, max_new_tokens=24)
        prompts = [list(BURST_PROMPT) for _ in range(4)]
        cfg_kw = dict(base, num_pages=14)
        ref, r, eng, g = self._pair(tgt, drf, tp, dp, prompts, **cfg_kw)
        assert [x.output for x in g] == [x.output for x in r]
        assert int(jnp.max(eng.batch.pool.ref)) == 0

    def test_live_share_requires_prefix_cache(self):
        tgt, drf, tp, dp = _models()
        with pytest.raises(ValueError, match="live_share"):
            SpecEngine(
                tgt, drf, tp, dp,
                EngineConfig(live_share=True, max_slots=2, **BASE),
            )


# ---------------------------------------------------------------------------
# hypothesis property: live claims
# ---------------------------------------------------------------------------


def _resolve(cache, path, table):
    """Test-side twin of the engine's lazy resolution: a live node's
    page comes from its owner's table column at the node's depth."""
    ids = []
    for depth, node in enumerate(path):
        if node.page < 0:
            node.page = int(table[node.owner[1], depth])
            assert node.page >= 0
        ids.append(node.page)
    return ids


def _live_traffic_lifecycle(seed: int):
    """Randomized writer/rider traffic over the REAL allocator ops and
    the REAL live-span index, asserting at every step: (1) a page some
    claimant maps is never on the free stack, (2) the host mirror of
    live spans matches the device tables (every resolved live node's
    page is exactly the owner's table entry at that depth, and no owner
    mirrors more pages than it has committed), and (3) refcounts drain
    to zero at quiesce."""
    rng = np.random.RandomState(seed)
    spec = paging.PageSpec(page_size=4, num_pages=48, max_pages=12)
    cache = paging.PrefixCache(spec)
    num_rows = 4
    table, used = paging.init_tables(spec, num_rows)
    pool = paging.init_pool(spec)
    shared = [rng.randint(0, 7, size=28).tolist() for _ in range(2)]
    # live[row] = {"tokens", "pos" (committed tokens), "claims", "okey"}
    live: dict[int, dict] = {}
    epoch = 0

    def committed_pages(st):
        return max(st["pos"] - 1, 0) // spec.page_size

    def release_row(row):
        nonlocal table, used, pool
        st = live.pop(row)
        cache.release_claims(st["claims"])
        cc = np.zeros((num_rows, spec.max_pages), bool)
        n_cache = committed_pages(st)
        if n_cache > 0:
            ids = [int(p) for p in np.asarray(table[row, :n_cache])]
            assert all(p >= 0 for p in ids)
            cc[row, :n_cache] = cache.insert(
                st["tokens"], ids, owner=st["okey"]
            )
        cache.release_live(st["okey"])
        mask = jnp.arange(num_rows) == row
        table, used, pool = paging.release(
            spec, table, used, pool, mask, cache_cols=jnp.asarray(cc)
        )

    for step in range(50):
        # 1. admit a writer/rider into a free row
        free_rows = [r for r in range(num_rows) if r not in live]
        if free_rows and rng.rand() < 0.7:
            row = free_rows[0]
            base = shared[rng.randint(2)]
            cut = rng.choice([8, 16, 24])
            tail = rng.randint(0, 7, size=rng.randint(1, 5)).tolist()
            toks = base[:cut] + tail
            epoch += 1
            okey = ("row", row, epoch)  # fresh key per admission
            nodes = cache.lookup(toks)
            if nodes:
                cache.claim(nodes)
                ids = _resolve(cache, nodes, np.asarray(table))
                table, used, pool = paging.host_claim_live(
                    spec, table, used, pool, row, ids
                )
            live[row] = {
                "tokens": toks,
                "pos": len(nodes) * spec.page_size,
                "claims": list(nodes),
                "okey": okey,
            }
        # 2. advance each row's prefill by a chunk, registering commits
        for row, st in live.items():
            lim = len(st["tokens"]) - 1
            if st["pos"] >= lim:
                continue
            st["pos"] = min(st["pos"] + rng.randint(1, 9), lim)
            table, used, pool, ok = paging.ensure(
                spec, table, used, pool,
                jnp.where(jnp.arange(num_rows) == row, st["pos"], 0),
                jnp.arange(num_rows) == row,
            )
            assert bool(jnp.all(ok))
            cache.register_live(
                st["okey"], st["tokens"], committed_pages(st)
            )
        # 3. riders extend claims behind the writers
        for row, st in live.items():
            if rng.rand() < 0.5:
                continue
            path = cache.lookup(st["tokens"])
            have = len(st["claims"])
            # never claim past our own committed frontier (the engine's
            # rider jumps pos to the claimed frontier; mirror that)
            avail = len(path)
            if avail > have and st["pos"] <= have * spec.page_size:
                new = path[have:avail]
                ids = _resolve(cache, path[:avail], np.asarray(table))
                cache.claim(new, extend=have > 0)
                table, used, pool = paging.host_claim_live(
                    spec, table, used, pool, row, ids[have:], start=have
                )
                st["claims"].extend(new)
                st["pos"] = avail * spec.page_size
        # 4. random releases (retire / preempt / stage-kill alike)
        for row in list(live):
            if rng.rand() < 0.2:
                release_row(row)
        # -- invariants, every step --------------------------------------
        ref = np.asarray(pool.ref)
        free_set = {
            int(x) for x in pool.free_stack[: int(pool.free_count)]
        }
        assert (ref >= 0).all()
        tab = np.asarray(table)
        for row, st in live.items():
            # (1) pinned pages never free while a claimant maps them
            for node in st["claims"]:
                assert node.page not in free_set, (seed, step, row)
                assert ref[node.page] >= 1
            # (2) host mirror == device tables: every live node this
            # row registered sits at its depth in the row's table
            mine = cache.live.get(st["okey"], [])
            depth_of = {}
            path = cache.lookup(st["tokens"])
            for d, node in enumerate(path):
                depth_of[id(node)] = d
            assert len(mine) <= committed_pages(st)
            for node in mine:
                if node.owner != st["okey"]:
                    continue  # converted/re-owned
                d = depth_of[id(node)]
                if node.page >= 0:
                    assert node.page == int(tab[row, d]), (seed, step)
                else:
                    assert int(tab[row, d]) >= 0  # resolvable
    for row in list(live):
        release_row(row)
    assert int(jnp.max(pool.ref)) == 0
    cached = np.asarray(pool.cached)
    assert set(cache.by_page) <= {
        p for p in range(spec.num_pages) if cached[p]
    }
    assert int(pool.free_count) + int(cached.sum()) == spec.num_pages
    assert cache.live_span_pages == 0


class TestLiveClaimProperty:
    def test_live_traffic_deterministic(self):
        for seed in (0, 1, 2, 3):
            _live_traffic_lifecycle(seed)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_live_traffic_property(self, seed):
        _live_traffic_lifecycle(seed)
