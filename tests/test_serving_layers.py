"""Unit tests for the serving layers: scheduler bookkeeping (no models or
compiles involved), the device-resident BatchState transitions, and the
verification residual-sums backend registry."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import verification
from repro.kernels import ops, ref
from repro.serving import batch as batch_mod
from repro.serving.scheduler import Scheduler


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


class TestScheduler:
    def _sched(self, slots=2, chunk=16):
        return Scheduler(slots, default_max_new=8, prefill_chunk=chunk,
                         clock=_FakeClock())

    def test_fifo_admission_and_slot_reuse(self):
        s = self._sched(slots=2)
        rids = [s.submit([1] * 5) for _ in range(3)]
        admitted = s.admit()
        assert [req.rid for _, req in admitted] == rids[:2]
        assert s.admit() == []  # no free slot
        slot0 = admitted[0][0]
        s.retire(slot0, "length")
        again = s.admit()
        assert len(again) == 1 and again[0][1].rid == rids[2]
        assert again[0][0] == slot0

    def test_prefill_mirror_counts_chunks(self):
        s = self._sched(slots=1, chunk=4)
        s.submit(list(range(10)))  # plen 10 -> 9 tokens to prefill
        s.admit()
        steps = 0
        while s.prefill_pending():
            s.note_prefill_dispatch()
            steps += 1
        assert steps == 3  # ceil(9 / 4)
        assert list(s.ready_slots()) == [0]

    def test_single_token_prompt_ready_immediately(self):
        s = self._sched(slots=1)
        s.submit([7])
        s.admit()
        assert not s.prefill_pending()
        assert list(s.ready_slots()) == [0]

    def test_retire_records_metrics(self):
        s = self._sched(slots=1)
        rid = s.submit([1, 2, 3])
        ((slot, req),) = s.admit()
        req.output.extend([4, 5])
        req.first_token_t = s.clock()
        req.iterations, req.accepted_total = 2, 3
        s.retire(slot, "eos")
        assert not s.has_work()
        (m,) = s.request_metrics(gamma=4)
        assert m["rid"] == rid
        assert m["finish_reason"] == "eos"
        assert m["ttft_s"] > 0
        assert m["tokens_per_s"] > 0
        assert m["acceptance_rate"] == pytest.approx(3 / 8)
        assert m["block_efficiency"] == pytest.approx(5 / 2)

    def test_decode_vs_e2e_tokens_per_s(self):
        """tokens_per_s is decode throughput (first token -> finish);
        queue wait lives only in e2e_tokens_per_s. The old single metric
        divided by finish - submit, so queue/requeue time deflated
        per-request decode throughput."""
        s = self._sched(slots=1)  # fake clock: +1s per reading
        s.submit([1, 2, 3])                 # submit_t  = 1
        ((slot, req),) = s.admit()          # admit_t   = 2
        req.output.extend([7] * 6)
        req.first_token_t = s.clock()       # = 3
        s.retire(slot, "length")            # finish_t  = 4
        (m,) = s.request_metrics(gamma=4)
        assert m["tokens_per_s"] == pytest.approx(6 / 1.0)
        assert m["e2e_tokens_per_s"] == pytest.approx(6 / 3.0)
        assert m["preemptions"] == 0

    def test_requeue_wait_excluded_from_decode_tps(self):
        """A request preempted AFTER its first token must not have the
        requeue wait counted against decode throughput either."""
        s = self._sched(slots=1)            # fake clock: +1s per reading
        s.submit([1, 2, 3])                 # submit_t  = 1
        ((slot, req),) = s.admit()          # admit_t   = 2
        req.output.extend([7] * 3)
        req.first_token_t = s.clock()       # = 3
        s.preempt(slot)                     # _preempt_t = 4
        ((slot, req),) = s.admit()          # readmit   = 5 -> wait 1s
        assert req.requeue_wait_s == pytest.approx(1.0)
        req.output.extend([7] * 3)
        s.retire(slot, "length")            # finish_t  = 6
        (m,) = s.request_metrics(gamma=4)
        # decode window: (6 - 3) - 1 requeued = 2s for 6 tokens
        assert m["tokens_per_s"] == pytest.approx(6 / 2.0)
        assert m["e2e_tokens_per_s"] == pytest.approx(6 / 5.0)
        assert m["preemptions"] == 1

    def test_pick_victim_lifo_by_admission_sequence(self):
        """All requests admitted in one admit() call share one clock
        reading, and slot reuse puts the newest request in the LOWEST
        free slot — so the old (admit_t, slot) tie-break picked the
        wrong victim. The monotonic admit_seq pins true LIFO."""
        s = Scheduler(2, default_max_new=8, prefill_chunk=16,
                      clock=lambda: 0.0)  # constant clock: admit_t ties
        s.submit([1, 2])
        s.submit([3, 4])
        s.admit()                     # -> slots 0, 1 (same admit_t)
        s.retire(0, "length")
        s.submit([5, 6])
        s.admit()                     # newest request lands in slot 0
        assert s.slot_req[0].admit_seq > s.slot_req[1].admit_seq
        assert s.pick_victim() == 0   # LIFO; (admit_t, slot) said 1

    def test_throughput_clamps_to_none_on_degenerate_windows(self):
        """Single-token responses and clock-resolution ties can make the
        decode window first_token -> finish (minus requeue waits) zero
        or negative; both throughput metrics must clamp to None — never
        inf, which json.dumps refuses to serialize (request_metrics
        feeds BENCH_serving.json directly)."""
        import json

        from repro.serving.scheduler import RequestState

        # Exact clock tie: finish_t == first_token_t.
        tie = RequestState(rid=0, prompt=[1], max_new_tokens=1,
                           output=[7], submit_t=0.0,
                           first_token_t=2.0, finish_t=2.0)
        assert tie.tokens_per_s is None
        # Requeue wait swallowing the whole decode window (negative dur).
        neg = RequestState(rid=1, prompt=[1], max_new_tokens=4,
                           output=[7, 7], submit_t=0.0, first_token_t=2.0,
                           finish_t=3.0, requeue_wait_s=5.0)
        assert neg.tokens_per_s is None
        # e2e: finish_t == submit_t tie.
        e2e = RequestState(rid=2, prompt=[1], max_new_tokens=1,
                           output=[7], submit_t=2.0,
                           first_token_t=2.0, finish_t=2.0)
        assert e2e.e2e_tokens_per_s is None
        s = self._sched(slots=1)
        s.submit([1, 2, 3])
        ((slot, req),) = s.admit()
        req.output.append(7)
        req.first_token_t = s.clock()
        req.requeue_wait_s = 100.0
        s.retire(slot, "length")
        (m,) = s.request_metrics(gamma=4)
        assert m["tokens_per_s"] is None
        json.dumps(m)  # must not hit inf/NaN

    def test_requeue_resets_stale_age(self):
        """A preemption victim re-enters the queue fresh: its age from
        the time it spent queued BEFORE admission must not survive the
        requeue, or a once-starved victim would claim the aged fast-path
        over requests that are starving NOW."""
        s = Scheduler(1, default_max_new=8, prefill_chunk=16,
                      clock=_FakeClock(), aging_limit=2)
        s.submit([1, 2, 3])
        ((slot, req),) = s.admit()
        req.age = 5  # stale: pretend it aged past the limit pre-admission
        s.preempt(slot)
        assert s.queue[0] is req and req.age == 0

    def test_pick_victim_prefers_lower_class(self):
        """Preemption sheds best-effort work first: among live slots the
        highest ``priority`` value (lowest class) is the victim, LIFO
        within a class — even when a premium request was admitted more
        recently."""
        s = Scheduler(3, default_max_new=8, prefill_chunk=16,
                      clock=_FakeClock())
        s.submit([1, 2], priority=1)           # slot 0 (best-effort)
        s.submit([3, 4], priority=1)           # slot 1 (best-effort)
        s.admit()
        s.submit([5, 6], priority=0)           # slot 2 (premium, newest)
        s.admit()
        assert s.pick_victim() == 1            # LIFO among class 1
        s.preempt(1)
        assert s.pick_victim() == 0            # still not the premium slot

    def test_prefill_dispatch_reports_consumed_tokens(self):
        s = self._sched(slots=2, chunk=4)
        s.submit(list(range(10)))  # 9 tokens to prefill
        s.submit([1, 2, 3])        # 2 tokens to prefill
        s.admit()
        assert s.note_prefill_dispatch() == 6  # 4 + 2
        assert s.note_prefill_dispatch() == 4  # 4 + 0
        assert s.note_prefill_dispatch() == 1
        assert not s.prefill_pending()

    def test_note_prefix_claim_shrinks_prefill_mirror(self):
        s = self._sched(slots=1, chunk=4)
        s.submit(list(range(10)))  # 9 tokens to prefill
        s.admit()
        s.note_prefix_claim(0, 8)  # 8 of them claimed from the cache
        assert s.prefill_left(0) == 1
        assert s.prefill_pending()
        assert s.note_prefill_dispatch() == 1
        assert list(s.ready_slots()) == [0]


class TestBatchState:
    def test_admit_sets_invariants(self):
        st = batch_mod.init_batch(2, 32)
        st = batch_mod.admit_slot(st, 1, [5, 6, 7], max_new=4)
        assert int(st.lens[1]) == 3
        assert int(st.d_lens[1]) == 2
        assert int(st.t_pref[1]) == 0
        assert bool(st.active[1]) and not bool(st.ready[1])
        assert int(st.out_start[1]) == 3 and int(st.max_new[1]) == 4
        assert st.seq_buf[1, :3].tolist() == [5, 6, 7]
        assert not bool(st.active[0])  # untouched

    def test_single_token_prompt_is_ready(self):
        st = batch_mod.init_batch(1, 16)
        st = batch_mod.admit_slot(st, 0, [9], max_new=2)
        assert bool(st.ready[0])

    def test_release_slot(self):
        st = batch_mod.init_batch(1, 16)
        st = batch_mod.admit_slot(st, 0, [1, 2], max_new=2)
        st = batch_mod.release_slot(st, 0)
        assert not bool(st.active[0]) and not bool(st.ready[0])

    def test_clear_slot_cache_zeroes_one_batch_row(self):
        cache = {"kv": jnp.ones((3, 2, 5, 4))}  # (groups, batch, ...)
        out = batch_mod.clear_slot_cache(cache, 1)
        assert float(jnp.sum(out["kv"][:, 1])) == 0.0
        assert float(jnp.min(out["kv"][:, 0])) == 1.0


class TestResidualBackendRegistry:
    def test_registry_names(self):
        names = verification.residual_backends()
        assert "jnp" in names
        assert "pallas" in names  # registered by repro.kernels.ops import

    def test_auto_resolves_to_kernel_entry_point(self):
        assert (
            verification.resolve_residual_sums("auto")
            is ops.verify_residual_sums
        )
        assert (
            verification.resolve_residual_sums("jnp")
            is verification.default_residual_sums
        )

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError):
            verification.resolve_residual_sums("nope")
        # None means "plain jnp default" at the verifier level, not auto.
        with pytest.raises(ValueError):
            verification.resolve_residual_sums(None)

    def test_pallas_backed_block_verify_matches_jnp(self):
        """Pallas-kernel residual_sums inside block_verify reproduces the
        jnp default bit-for-bit at these shapes (same key -> same result).
        pallas_interpret forces the kernel lowering on CPU."""
        b, g, v = 4, 4, 640
        k1, k2, k3, kk = jax.random.split(jax.random.key(11), 4)
        q = jax.random.dirichlet(k1, jnp.ones(v), (b, g))
        p = jax.random.dirichlet(k2, jnp.ones(v), (b, g + 1))
        toks = jax.random.randint(k3, (b, g), 0, v)
        r_jnp = verification.block_verify(
            kk, toks, q, p,
            residual_sums=verification.resolve_residual_sums("jnp"),
        )
        r_pal = verification.block_verify(
            kk, toks, q, p,
            residual_sums=verification.resolve_residual_sums(
                "pallas_interpret"
            ),
        )
        assert bool(jnp.all(r_jnp.num_accepted == r_pal.num_accepted))
        assert bool(jnp.all(r_jnp.tokens == r_pal.tokens))

    def test_kernel_matches_ref_oracle(self):
        b, k, v = 2, 3, 500
        k1, k2, k3 = jax.random.split(jax.random.key(5), 3)
        ps = jax.random.uniform(k1, (b, k))
        p = jax.random.dirichlet(k2, jnp.ones(v), (b, k))
        q = jax.random.dirichlet(k3, jnp.ones(v), (b, k))
        got = verification.resolve_residual_sums("pallas_interpret")(ps, p, q)
        want = ref.verify_residual_sums(ps, p, q)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5

    def test_kernel_empty_rows_returns_zeros(self):
        """K = 0 (greedy-block at gamma=1 has no middle positions) must
        not crash the kernel wrapper."""
        ps = jnp.zeros((2, 0))
        p = jnp.zeros((2, 0, 64))
        q = jnp.zeros((2, 0, 64))
        for backend in ["pallas", "pallas_interpret", "jnp"]:
            got = verification.resolve_residual_sums(backend)(ps, p, q)
            assert got.shape == (2, 0)

    def test_greedy_block_gamma1_runs_on_pallas_backend(self):
        """Regression: gamma=1 greedy-block routes a K=0 reduction through
        the kernel backend; it must produce a valid result, identical to
        the jnp path."""
        b, v = 3, 64
        k1, k2, k3, kk = jax.random.split(jax.random.key(3), 4)
        q = jax.random.dirichlet(k1, jnp.ones(v), (b, 1))
        p = jax.random.dirichlet(k2, jnp.ones(v), (b, 2))
        toks = jax.random.randint(k3, (b, 1), 0, v)
        r_jnp = verification.greedy_block_verify(kk, toks, q, p)
        for backend in ["pallas", "pallas_interpret"]:
            r_pal = verification.greedy_block_verify(
                kk, toks, q, p,
                residual_sums=verification.resolve_residual_sums(backend),
            )
            assert bool(jnp.all(r_jnp.tokens == r_pal.tokens)), backend


class TestGreedyDenIdentity:
    def test_greedy_block_residual_hook_consistent(self):
        """greedy_block with the fused backend matches the jnp default
        (the derived-denominator identity holds for both)."""
        b, g, v = 3, 4, 320
        k1, k2, k3, kk = jax.random.split(jax.random.key(21), 4)
        q = jax.random.dirichlet(k1, jnp.ones(v), (b, g))
        p = jax.random.dirichlet(k2, jnp.ones(v), (b, g + 1))
        toks = jax.random.randint(k3, (b, g), 0, v)
        r_jnp = verification.greedy_block_verify(kk, toks, q, p)
        r_pal = verification.greedy_block_verify(
            kk, toks, q, p,
            residual_sums=verification.resolve_residual_sums(
                "pallas_interpret"
            ),
        )
        assert bool(jnp.all(r_jnp.num_accepted == r_pal.num_accepted))
        assert bool(jnp.all(r_jnp.tokens == r_pal.tokens))
