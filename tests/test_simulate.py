"""Losslessness (Theorem 1 / Lemma 6) and block-efficiency ordering tests
via the oracle simulator."""

import jax
import numpy as np
import pytest

from repro.core import oracle, simulate

N_SAMPLES = 150_000


def _pair(seed=42, vocab=3, order=1, alpha=0.7, concentration=0.7):
    kt, kd = jax.random.split(jax.random.key(seed))
    target = oracle.random_lm(kt, vocab, order, concentration)
    drafter = oracle.perturbed_drafter(kd, target, alpha)
    return target, drafter


class TestLossless:
    """Theorem 1: SpecDec output ~ M_b^* for every verifier (greedy via the
    Algorithm 5/6 distribution modification)."""

    @pytest.mark.parametrize("name", ["token", "block", "greedy_block"])
    def test_output_distribution_matches_target(self, name):
        target, drafter = _pair()
        length = 3
        exact = oracle.target_joint_distribution(target, length)
        emp = oracle.exact_output_distribution(
            target, drafter, gamma=3, length=length, verifier=name,
            n_samples=N_SAMPLES, key=jax.random.key(7),
        )
        tv = 0.5 * np.abs(emp - exact).sum()
        noise = 1.5 * np.sqrt(len(exact) / N_SAMPLES)
        assert tv < noise, f"{name}: TV={tv:.4f} > {noise:.4f}"

    def test_greedy_lossless_with_adversarial_models(self):
        """Section-2 style anti-correlated models stress the modification."""
        target, drafter = oracle.section2_models()
        length = 3
        exact = oracle.target_joint_distribution(target, length)
        emp = oracle.exact_output_distribution(
            target, drafter, gamma=2, length=length, verifier="greedy_block",
            n_samples=N_SAMPLES, key=jax.random.key(13),
        )
        tv = 0.5 * np.abs(emp - exact).sum()
        assert tv < 1.5 * np.sqrt(len(exact) / N_SAMPLES)


class TestBlockEfficiency:
    def test_ordering_token_le_block(self):
        """Theorem 2 end-to-end: BE(block) >= BE(token) on random models."""
        key = jax.random.key(0)
        for seed in [1, 2, 3]:
            target, drafter = _pair(seed=seed, vocab=16, order=2, alpha=0.4)
            be_tok = float(simulate.block_efficiency(
                key, target, drafter, 8, "token", batch=1024, n_iters=48))
            be_blk = float(simulate.block_efficiency(
                key, target, drafter, 8, "block", batch=1024, n_iters=48))
            assert be_blk >= be_tok - 0.03, (seed, be_tok, be_blk)

    def test_improvement_grows_with_gamma(self):
        """Paper Figure 4: relative improvement increases with gamma."""
        key = jax.random.key(1)
        target, drafter = _pair(seed=5, vocab=16, order=2, alpha=0.5)
        rel = []
        for gamma in [2, 8]:
            be_tok = float(simulate.block_efficiency(
                key, target, drafter, gamma, "token", batch=2048, n_iters=48))
            be_blk = float(simulate.block_efficiency(
                key, target, drafter, gamma, "block", batch=2048, n_iters=48))
            rel.append(be_blk / be_tok - 1.0)
        assert rel[1] > rel[0] - 0.005

    def test_greedy_between_token_and_block(self):
        """Paper Table 3 ordering (on non-adversarial random models)."""
        key = jax.random.key(2)
        target, drafter = _pair(seed=9, vocab=16, order=2, alpha=0.4)
        bes = {
            name: float(simulate.block_efficiency(
                key, target, drafter, 8, name, batch=2048, n_iters=48))
            for name in ["token", "block", "greedy_block"]
        }
        assert bes["block"] >= bes["greedy_block"] - 0.05
        assert bes["greedy_block"] >= bes["token"] - 0.05
