"""Training substrate tests: optimizer, pipeline determinism, loss descent,
checkpoint roundtrip."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import pipeline
from repro.data.tokenizer import ByteTokenizer
from repro.models import Model
from repro.training import checkpoint, optim
from repro.training import train as training
from repro.training.optim import OptConfig


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "Hello, wörld! 123"
    assert tok.decode(tok.encode(s)) == s


def test_pipeline_deterministic():
    a = list(pipeline.batches(seed=3, batch_size=2, seq_len=16, n_steps=3))
    b = list(pipeline.batches(seed=3, batch_size=2, seq_len=16, n_steps=3))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])
    assert a[0]["tokens"].shape == (2, 16)
    # labels are next tokens
    np.testing.assert_array_equal(a[0]["tokens"][:, 1:], a[0]["labels"][:, :-1])


def test_adamw_moves_toward_minimum():
    cfg = OptConfig(lr=0.1, warmup=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = optim.init_opt_state(params)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = optim.apply_updates(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup=10, total_steps=100)
    s0 = float(optim.schedule(cfg, jnp.array(0)))
    s_w = float(optim.schedule(cfg, jnp.array(10)))
    s_end = float(optim.schedule(cfg, jnp.array(100)))
    assert s0 < 0.2 and s_w == pytest.approx(1.0, abs=0.01)
    assert s_end < s_w


def test_training_loss_decreases():
    cfg = registry.get_config("charlm-drafter")
    m = Model(cfg)
    data = pipeline.batches(seed=0, batch_size=8, seq_len=48, n_steps=40)
    _, hist = training.train(
        m, data, n_steps=40,
        opt_cfg=OptConfig(lr=1e-3, warmup=5, total_steps=40), log_every=10,
    )
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_checkpoint_roundtrip():
    cfg = registry.smoke_config("smollm-135m")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, params, {"arch": cfg.name})
        p2 = checkpoint.load(d, params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert checkpoint.load_meta(d)["arch"] == cfg.name


def test_checkpoint_rejects_mismatch():
    m1 = Model(registry.smoke_config("smollm-135m"))
    m2 = Model(registry.smoke_config("olmo-1b"))
    p1 = m1.init(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, p1)
        with pytest.raises(ValueError):
            checkpoint.load(d, m2.init(jax.random.key(0)))
