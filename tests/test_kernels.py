"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests.
All kernels run in interpret mode on CPU (TPU is the compile target)."""

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from repro.core import verification
from repro.kernels import ops, ref

KEY = jax.random.key(7)


def _dirichlet(key, shape, v):
    return jax.random.dirichlet(key, jnp.ones(v), shape)


class TestVerifyResiduals:
    @pytest.mark.parametrize("b,k,v", [
        (1, 1, 128), (4, 9, 1000), (2, 5, 4096), (3, 3, 300), (1, 9, 8192),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, k, v, dtype):
        k1, k2, k3 = jax.random.split(KEY, 3)
        ps = jax.random.uniform(k1, (b, k))
        p = _dirichlet(k2, (b, k), v).astype(dtype)
        q = _dirichlet(k3, (b, k), v).astype(dtype)
        # interpret=True: always exercise the kernel lowering (the bare
        # entry point falls back to the XLA reference off-TPU).
        got = ops.verify_residual_sums(ps, p, q, interpret=True)
        want = ref.verify_residual_sums(ps, p, q)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        assert float(jnp.max(jnp.abs(got - want))) < tol

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 4), k=st.integers(1, 6),
        v=st.sampled_from([130, 512, 1000]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_random_shapes(self, b, k, v, seed):
        k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
        ps = jax.random.uniform(k1, (b, k), minval=0.0, maxval=1.5)
        p = _dirichlet(k2, (b, k), v)
        q = _dirichlet(k3, (b, k), v)
        got = ops.verify_residual_sums(ps, p, q, interpret=True)
        want = ref.verify_residual_sums(ps, p, q)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5
        # residual mass is within [max(ps-1, 0), ps] (distributions sum to 1)
        assert bool(jnp.all(got <= ps + 1e-5))
        assert bool(jnp.all(got >= jnp.maximum(ps - 1.0, 0.0) - 1e-5))

    def test_fused_block_verify_same_distribution(self):
        """The fused kernel path produces the same VerifyResult as the pure
        jnp path for identical rng keys."""
        b, g, v = 8, 5, 1000
        k1, k2, k3, kk = jax.random.split(KEY, 4)
        q = _dirichlet(k1, (b, g), v)
        p = _dirichlet(k2, (b, g + 1), v)
        toks = jax.random.randint(k3, (b, g), 0, v)
        r1 = verification.block_verify(kk, toks, q, p)
        r2 = ops.block_verify_fused(kk, toks, q, p)
        assert bool(jnp.all(r1.num_accepted == r2.num_accepted))
        assert bool(jnp.all(r1.tokens == r2.tokens))


class TestFlashDecode:
    @pytest.mark.parametrize("b,h,kh,hd,c,window,cap", [
        (2, 8, 2, 64, 700, -1, 0.0),
        (1, 4, 4, 32, 1500, 100, 50.0),
        (3, 6, 3, 128, 512, -1, 30.0),
        (1, 16, 2, 64, 513, 64, 0.0),
    ])
    def test_matches_ref(self, b, h, kh, hd, c, window, cap):
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (b, h, hd))
        k = jax.random.normal(ks[1], (b, c, kh, hd))
        v = jax.random.normal(ks[2], (b, c, kh, hd))
        qpos = jax.random.randint(ks[3], (b,), c // 2, c)
        kpos = jnp.broadcast_to(jnp.arange(c)[None], (b, c))
        got = ops.flash_decode(q, k, v, qpos, kpos, window=window, softcap=cap)
        want = ref.flash_decode(q, k, v, qpos, kpos, window=window, softcap=cap)
        assert float(jnp.max(jnp.abs(got - want))) < 2e-5

    def test_ring_invalid_slots_masked(self):
        """Negative key positions (unwritten ring slots) contribute nothing."""
        b, h, kh, hd, c = 1, 4, 2, 64, 600
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, hd))
        k = jax.random.normal(ks[1], (b, c, kh, hd))
        v = jax.random.normal(ks[2], (b, c, kh, hd))
        qpos = jnp.array([c - 1])
        kpos = jnp.broadcast_to(jnp.arange(c)[None], (b, c))
        kpos_holes = jnp.where(kpos % 3 == 0, -1, kpos)
        got = ops.flash_decode(q, k, v, qpos, kpos_holes)
        want = ref.flash_decode(q, k, v, qpos, kpos_holes)
        assert float(jnp.max(jnp.abs(got - want))) < 2e-5

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        b, h, kh, hd, c = 2, 4, 2, 64, 512
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, hd)).astype(dtype)
        k = jax.random.normal(ks[1], (b, c, kh, hd)).astype(dtype)
        v = jax.random.normal(ks[2], (b, c, kh, hd)).astype(dtype)
        qpos = jnp.full((b,), c - 1)
        kpos = jnp.broadcast_to(jnp.arange(c)[None], (b, c))
        got = ops.flash_decode(q, k, v, qpos, kpos)
        want = ref.flash_decode(q, k, v, qpos, kpos)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        assert float(jnp.max(jnp.abs(got.astype(jnp.float32) - want))) < tol


def _paged_setup(key, b, kh, hd, page, maxp, num_pages, lens):
    """Random pool + per-sequence page tables covering ``lens`` tokens,
    with physical pages assigned in a scrambled (non-identity) order."""
    ks = jax.random.split(key, 3)
    k_pool = jax.random.normal(ks[0], (num_pages, page, kh, hd))
    v_pool = jax.random.normal(ks[1], (num_pages, page, kh, hd))
    perm = jax.random.permutation(ks[2], num_pages)
    table = jnp.full((b, maxp), -1, jnp.int32)
    nxt = 0
    for i, ln in enumerate(lens):
        need = -(-ln // page)
        table = table.at[i, :need].set(
            perm[nxt : nxt + need].astype(jnp.int32)
        )
        nxt += need
    return k_pool, v_pool, table


class TestFlashDecodePaged:
    @pytest.mark.parametrize("b,h,kh,hd,page,maxp,window,cap", [
        (2, 8, 2, 64, 64, 8, -1, 0.0),
        (3, 4, 4, 32, 16, 12, 100, 50.0),
        (1, 6, 3, 128, 32, 5, -1, 30.0),
    ])
    def test_matches_gather_ref(self, b, h, kh, hd, page, maxp, window, cap):
        ks = jax.random.split(KEY, 2)
        lens = [(i * 37 + 19) % (maxp * page) + 1 for i in range(b)]
        k_pool, v_pool, table = _paged_setup(
            ks[0], b, kh, hd, page, maxp, b * maxp, lens
        )
        q = jax.random.normal(ks[1], (b, h, hd))
        q_pos = jnp.asarray([ln - 1 for ln in lens])
        total = jnp.asarray(lens)
        got = ops.flash_decode_paged(
            q, k_pool, v_pool, table, q_pos, total,
            window=window, softcap=cap, interpret=True,
        )
        want = ref.flash_decode_paged(
            q, k_pool, v_pool, table, q_pos, total,
            window=window, softcap=cap,
        )
        assert float(jnp.max(jnp.abs(got - want))) < 2e-5

    def test_page_permutation_invariance(self):
        """The same logical cache through two different physical page
        assignments must attend identically (physical ids are opaque)."""
        b, h, kh, hd, page, maxp = 2, 4, 2, 64, 16, 6
        ks = jax.random.split(KEY, 3)
        lens = [70, 33]
        k_pool, v_pool, table = _paged_setup(
            ks[0], b, kh, hd, page, maxp, 32, lens
        )
        q = jax.random.normal(ks[1], (b, h, hd))
        q_pos = jnp.asarray([ln - 1 for ln in lens])
        total = jnp.asarray(lens)
        base = ops.flash_decode_paged(
            q, k_pool, v_pool, table, q_pos, total, interpret=True
        )
        # swap two physical pages and patch the tables accordingly
        perm = jnp.arange(32).at[3].set(11).at[11].set(3)
        got = ops.flash_decode_paged(
            q, k_pool[perm], v_pool[perm],
            jnp.where(table == 3, 11, jnp.where(table == 11, 3, table)),
            q_pos, total, interpret=True,
        )
        assert float(jnp.max(jnp.abs(got - base))) < 1e-6


class TestFlashPrefillPaged:
    @pytest.mark.parametrize("b,s,h,kh,hd,page,maxp,window,cap", [
        (2, 5, 4, 2, 64, 16, 8, -1, 0.0),     # verify chunk (gamma+1)
        (1, 16, 8, 4, 32, 32, 6, 64, 0.0),    # prefill chunk, windowed
        (2, 8, 6, 3, 64, 16, 10, -1, 30.0),
    ])
    def test_matches_gather_ref(
        self, b, s, h, kh, hd, page, maxp, window, cap
    ):
        ks = jax.random.split(KEY, 2)
        lens = [(i * 53 + 29) % (maxp * page - s) + s for i in range(b)]
        k_pool, v_pool, table = _paged_setup(
            ks[0], b, kh, hd, page, maxp, b * maxp, lens
        )
        q = jax.random.normal(ks[1], (b, s, h, hd))
        q_start = jnp.asarray([ln - s for ln in lens])
        total = jnp.asarray(lens)
        got = ops.flash_prefill_paged(
            q, k_pool, v_pool, table, q_start, total,
            window=window, softcap=cap, interpret=True,
        )
        want = ref.flash_prefill_paged(
            q, k_pool, v_pool, table, q_start, total,
            window=window, softcap=cap,
        )
        assert float(jnp.max(jnp.abs(got - want))) < 2e-5

    def test_decode_and_prefill_kernels_agree_at_s1(self):
        """A 1-token chunk through the chunked kernel must equal the
        decode kernel (the ops.attend_paged dispatch boundary)."""
        b, h, kh, hd, page, maxp = 2, 4, 2, 64, 16, 4
        ks = jax.random.split(KEY, 2)
        lens = [30, 17]
        k_pool, v_pool, table = _paged_setup(
            ks[0], b, kh, hd, page, maxp, 16, lens
        )
        q = jax.random.normal(ks[1], (b, 1, h, hd))
        q_pos = jnp.asarray([ln - 1 for ln in lens])
        total = jnp.asarray(lens)
        via_prefill = ops.flash_prefill_paged(
            q, k_pool, v_pool, table, q_pos, total, interpret=True
        )
        via_decode = ops.flash_decode_paged(
            q[:, 0], k_pool, v_pool, table, q_pos, total, interpret=True
        )
        assert float(jnp.max(jnp.abs(via_prefill[:, 0] - via_decode))) < 1e-6


class TestFlashPrefill:
    @pytest.mark.parametrize("b,s,h,kh,hd,window,cap", [
        (2, 300, 4, 2, 64, -1, 0.0),
        (1, 512, 8, 8, 32, 64, 0.0),
        (2, 200, 6, 3, 128, -1, 50.0),
        (1, 257, 4, 1, 64, 128, 30.0),
    ])
    def test_matches_ref(self, b, s, h, kh, hd, window, cap):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kh, hd))
        v = jax.random.normal(ks[2], (b, s, kh, hd))
        got = ops.flash_prefill(q, k, v, window=window, softcap=cap)
        want = ref.flash_prefill(q, k, v, window=window, softcap=cap)
        assert float(jnp.max(jnp.abs(got - want))) < 2e-5
