"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests.
All kernels run in interpret mode on CPU (TPU is the compile target)."""

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline/minimal env: keep deterministic cases running
    from conftest import hypothesis_stub

    given, settings, st = hypothesis_stub()

from repro.core import verification
from repro.kernels import ops, ref

KEY = jax.random.key(7)


def _dirichlet(key, shape, v):
    return jax.random.dirichlet(key, jnp.ones(v), shape)


class TestVerifyResiduals:
    @pytest.mark.parametrize("b,k,v", [
        (1, 1, 128), (4, 9, 1000), (2, 5, 4096), (3, 3, 300), (1, 9, 8192),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, k, v, dtype):
        k1, k2, k3 = jax.random.split(KEY, 3)
        ps = jax.random.uniform(k1, (b, k))
        p = _dirichlet(k2, (b, k), v).astype(dtype)
        q = _dirichlet(k3, (b, k), v).astype(dtype)
        # interpret=True: always exercise the kernel lowering (the bare
        # entry point falls back to the XLA reference off-TPU).
        got = ops.verify_residual_sums(ps, p, q, interpret=True)
        want = ref.verify_residual_sums(ps, p, q)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        assert float(jnp.max(jnp.abs(got - want))) < tol

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 4), k=st.integers(1, 6),
        v=st.sampled_from([130, 512, 1000]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_random_shapes(self, b, k, v, seed):
        k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
        ps = jax.random.uniform(k1, (b, k), minval=0.0, maxval=1.5)
        p = _dirichlet(k2, (b, k), v)
        q = _dirichlet(k3, (b, k), v)
        got = ops.verify_residual_sums(ps, p, q, interpret=True)
        want = ref.verify_residual_sums(ps, p, q)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5
        # residual mass is within [max(ps-1, 0), ps] (distributions sum to 1)
        assert bool(jnp.all(got <= ps + 1e-5))
        assert bool(jnp.all(got >= jnp.maximum(ps - 1.0, 0.0) - 1e-5))

    def test_fused_block_verify_same_distribution(self):
        """The fused kernel path produces the same VerifyResult as the pure
        jnp path for identical rng keys."""
        b, g, v = 8, 5, 1000
        k1, k2, k3, kk = jax.random.split(KEY, 4)
        q = _dirichlet(k1, (b, g), v)
        p = _dirichlet(k2, (b, g + 1), v)
        toks = jax.random.randint(k3, (b, g), 0, v)
        r1 = verification.block_verify(kk, toks, q, p)
        r2 = ops.block_verify_fused(kk, toks, q, p)
        assert bool(jnp.all(r1.num_accepted == r2.num_accepted))
        assert bool(jnp.all(r1.tokens == r2.tokens))


class TestFlashDecode:
    @pytest.mark.parametrize("b,h,kh,hd,c,window,cap", [
        (2, 8, 2, 64, 700, -1, 0.0),
        (1, 4, 4, 32, 1500, 100, 50.0),
        (3, 6, 3, 128, 512, -1, 30.0),
        (1, 16, 2, 64, 513, 64, 0.0),
    ])
    def test_matches_ref(self, b, h, kh, hd, c, window, cap):
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (b, h, hd))
        k = jax.random.normal(ks[1], (b, c, kh, hd))
        v = jax.random.normal(ks[2], (b, c, kh, hd))
        qpos = jax.random.randint(ks[3], (b,), c // 2, c)
        kpos = jnp.broadcast_to(jnp.arange(c)[None], (b, c))
        got = ops.flash_decode(q, k, v, qpos, kpos, window=window, softcap=cap)
        want = ref.flash_decode(q, k, v, qpos, kpos, window=window, softcap=cap)
        assert float(jnp.max(jnp.abs(got - want))) < 2e-5

    def test_ring_invalid_slots_masked(self):
        """Negative key positions (unwritten ring slots) contribute nothing."""
        b, h, kh, hd, c = 1, 4, 2, 64, 600
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, hd))
        k = jax.random.normal(ks[1], (b, c, kh, hd))
        v = jax.random.normal(ks[2], (b, c, kh, hd))
        qpos = jnp.array([c - 1])
        kpos = jnp.broadcast_to(jnp.arange(c)[None], (b, c))
        kpos_holes = jnp.where(kpos % 3 == 0, -1, kpos)
        got = ops.flash_decode(q, k, v, qpos, kpos_holes)
        want = ref.flash_decode(q, k, v, qpos, kpos_holes)
        assert float(jnp.max(jnp.abs(got - want))) < 2e-5

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        b, h, kh, hd, c = 2, 4, 2, 64, 512
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, hd)).astype(dtype)
        k = jax.random.normal(ks[1], (b, c, kh, hd)).astype(dtype)
        v = jax.random.normal(ks[2], (b, c, kh, hd)).astype(dtype)
        qpos = jnp.full((b,), c - 1)
        kpos = jnp.broadcast_to(jnp.arange(c)[None], (b, c))
        got = ops.flash_decode(q, k, v, qpos, kpos)
        want = ref.flash_decode(q, k, v, qpos, kpos)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        assert float(jnp.max(jnp.abs(got.astype(jnp.float32) - want))) < tol


class TestFlashPrefill:
    @pytest.mark.parametrize("b,s,h,kh,hd,window,cap", [
        (2, 300, 4, 2, 64, -1, 0.0),
        (1, 512, 8, 8, 32, 64, 0.0),
        (2, 200, 6, 3, 128, -1, 50.0),
        (1, 257, 4, 1, 64, 128, 30.0),
    ])
    def test_matches_ref(self, b, s, h, kh, hd, window, cap):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kh, hd))
        v = jax.random.normal(ks[2], (b, s, kh, hd))
        got = ops.flash_prefill(q, k, v, window=window, softcap=cap)
        want = ref.flash_prefill(q, k, v, window=window, softcap=cap)
        assert float(jnp.max(jnp.abs(got - want))) < 2e-5
